//! Quickstart: build a small simulated GPU, run the paper's asymmetric
//! sharing pattern (one local sharer, one remote sharer) under sRSP, and
//! print what the hardware did.
//!
//! Run with: `cargo run --release --example quickstart`

use srsp::config::{DeviceConfig, Protocol};
use srsp::gpu::Device;
use srsp::kir::{Asm, Src};
use srsp::sync::{AtomicOp, MemOrder, Scope};

const LOCK: u64 = 0x1000;
const DATA: u64 = 0x2000;

/// wg0 (the local sharer, on CU0) increments DATA under a wg-scope lock
/// many times; wg1 (the remote sharer, on CU1) occasionally grabs the
/// same lock with the RSP remote operations and increments too.
fn kernel(local_iters: u64, remote_iters: u64) -> srsp::kir::Program {
    let mut a = Asm::new();
    let wg = a.reg();
    let lock = a.reg();
    let data = a.reg();
    let old = a.reg();
    let tmp = a.reg();
    let i = a.reg();
    let c = a.reg();

    a.wg_id(wg);
    a.imm(lock, LOCK);
    a.imm(data, DATA);
    a.imm(i, 0);
    a.bnz(wg, "remote");

    // --- local sharer: wg-scope lock, cheap L1 synchronization ---
    a.label("local_loop");
    a.label("local_spin");
    a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Wg);
    a.bnz(old, "local_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Wg);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(local_iters));
    a.bnz(c, "local_loop");
    a.halt();

    // --- remote sharer: rem_acq / rem_rel promotions ---
    a.label("remote");
    a.label("remote_loop");
    a.label("remote_spin");
    a.remote_atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire);
    a.bnz(old, "remote_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.remote_atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(remote_iters));
    a.bnz(c, "remote_loop");
    a.halt();

    a.finish()
}

fn main() {
    let cfg = DeviceConfig::small();
    println!("device: {} CUs (small test configuration)\n", cfg.num_cus);

    let (local_iters, remote_iters) = (200, 10);
    let mut dev = Device::new(cfg, Protocol::SRSP);
    let report = dev.launch_simple(&kernel(local_iters, remote_iters), 2);

    let total = dev.mem.backing.read_u32(DATA);
    assert_eq!(
        total as u64,
        local_iters + remote_iters,
        "mutual exclusion must hold: every increment counted exactly once"
    );
    println!(
        "critical sections: {local_iters} local (wg-scope) + {remote_iters} remote (rem_acq/rem_rel) \
         -> DATA = {total}  ✓ exact"
    );
    println!("kernel finished at cycle {}\n", report.end_cycle);

    let s = dev.take_stats();
    println!("--- what the sRSP hardware did ---");
    println!("wg-scope acquires (fast path)      {:>8}", s.wg_acquires);
    println!("  promoted by PA-TBL hit           {:>8}", s.promoted_acquires);
    println!("  stayed local                     {:>8}", s.local_acquires);
    println!("remote acquires / releases         {:>8} / {}", s.remote_acquires, s.remote_releases);
    println!("selective-flush requests           {:>8}", s.selective_flush_requests);
    println!("  answered by LR-TBL miss (no-op)  {:>8}", s.selective_flush_nops);
    println!("  drained the local sharer's sFIFO {:>8}", s.selective_flush_drains);
    println!("lines flushed / invalidated        {:>8} / {}", s.lines_flushed, s.lines_invalidated);
    println!("L2 accesses                        {:>8}", s.l2_accesses);
}
