//! SSSP on a road network (the paper's `USA-road-BAY` class): run all
//! five scenarios on the Table-1 device, validate every result against a
//! Dijkstra oracle, and print the Fig-4-style comparison for this app.
//!
//! Run with: `cargo run --release --example sssp_roadnet`
//! Pass a DIMACS `.gr` file to use a real road graph:
//!     `cargo run --release --example sssp_roadnet -- bay.gr`

use srsp::config::{DeviceConfig, Scenario};
use srsp::harness::report::format_table;
use srsp::mem::{BackingStore, MemAlloc};
use srsp::workload::driver::run_scenario_seeded;
use srsp::workload::engine::NativeMath;
use srsp::workload::graph::Graph;
use srsp::workload::sssp::Sssp;

fn main() {
    let graph = match std::env::args().nth(1) {
        Some(path) => {
            let text = std::fs::read_to_string(&path).expect("read graph file");
            Graph::from_dimacs_gr(&text).expect("parse DIMACS .gr")
        }
        None => Graph::road_grid(64, 64, 0xC0FFEE),
    };
    graph.validate().unwrap();
    println!(
        "road network: {} vertices, {} edges, max degree {}\n",
        graph.n,
        graph.num_edges(),
        graph.max_degree()
    );

    let cfg = DeviceConfig::default(); // 64 CUs
    let oracle = Sssp::oracle(&graph, 0);
    let reachable = oracle
        .iter()
        .filter(|&&d| d != srsp::workload::engine::DIST_INF)
        .count();
    println!("oracle: {reachable}/{} vertices reachable from source 0\n", graph.n);

    let mut rows = Vec::new();
    let mut base_cycles = 0u64;
    for scenario in Scenario::ALL {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut sssp = Sssp::setup(&graph, &mut alloc, &mut image, 8, 0);
        let (run, mem) = run_scenario_seeded(&cfg, scenario, &mut sssp, NativeMath, 500, image);
        assert!(run.converged, "{scenario}: did not converge");
        assert_eq!(sssp.result(&mem), oracle, "{scenario}: wrong distances");
        if scenario == Scenario::BASELINE {
            base_cycles = run.stats.cycles;
        }
        rows.push(vec![
            scenario.name().to_string(),
            run.rounds.to_string(),
            run.stats.cycles.to_string(),
            format!("{:.3}", base_cycles as f64 / run.stats.cycles as f64),
            run.stats.tasks_stolen.to_string(),
            run.stats.l2_accesses.to_string(),
            "exact ✓".to_string(),
        ]);
    }
    println!(
        "{}",
        format_table(
            &[
                "scenario".into(),
                "rounds".into(),
                "cycles".into(),
                "speedup".into(),
                "steals".into(),
                "L2".into(),
                "vs Dijkstra".into(),
            ],
            &rows
        )
    );
    println!("(paper Fig. 4: SSSP is sRSP's best case; naive RSP loses its gains)");
}
