//! Mutex microbenchmark: the paper's §4 running example, measured.
//!
//! A shared counter protected by one lock; the local sharer performs the
//! overwhelming majority of the critical sections, the remote sharer a
//! configurable few. Compares three designs:
//!
//! * `global`  — every acquire/release at cmp scope (no RSP needed),
//! * `rsp`     — local sharer at wg scope, remote via naive all-L1 RSP,
//! * `srsp`    — local sharer at wg scope, remote via selective sRSP.
//!
//! Run with: `cargo run --release --example mutex_microbench`

use srsp::config::{DeviceConfig, Protocol};
use srsp::gpu::Device;
use srsp::kir::{Asm, Program, Src};
use srsp::sync::{AtomicOp, MemOrder, Scope};

const LOCK: u64 = 0x1000;
const DATA: u64 = 0x2000;
/// Unrelated per-CU working set the heavy flushes/invalidates destroy.
const WSET: u64 = 0x10000;

fn kernel(local_iters: u64, remote_iters: u64, owner_scope: Scope, remote_ops: bool) -> Program {
    let mut a = Asm::new();
    let wg = a.reg();
    let lock = a.reg();
    let data = a.reg();
    let old = a.reg();
    let tmp = a.reg();
    let i = a.reg();
    let c = a.reg();
    let waddr = a.reg();

    a.wg_id(wg);
    a.imm(lock, LOCK);
    a.imm(data, DATA);
    a.imm(i, 0);

    // Everyone warms a private working set (64 lines) they keep touching;
    // all-L1 invalidations force them to refetch it.
    a.shl(waddr, wg, Src::I(14));
    a.add(waddr, waddr, Src::I(WSET));
    a.label("warm");
    a.shl(c, i, Src::I(6));
    a.add(c, c, Src::R(waddr));
    a.ld(tmp, c, 0, 4);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(64));
    a.bnz(c, "warm");
    a.imm(i, 0);

    a.bnz(wg, "other");

    // wg0: the local sharer.
    a.label("local_loop");
    a.label("local_spin");
    a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, owner_scope);
    a.bnz(old, "local_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, owner_scope);
    // Touch the working set between criticals (locality to destroy).
    a.and(c, i, Src::I(63));
    a.shl(c, c, Src::I(6));
    a.add(c, c, Src::R(waddr));
    a.ld(tmp, c, 0, 4);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(local_iters));
    a.bnz(c, "local_loop");
    a.halt();

    // wg1: the remote sharer; wgs 2..: bystanders re-reading their set.
    a.label("other");
    a.eq(c, wg, Src::I(1));
    a.bz(c, "bystander");
    a.label("remote_loop");
    a.label("remote_spin");
    if remote_ops {
        a.remote_atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire);
    } else {
        a.atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire, Scope::Cmp);
    }
    a.bnz(old, "remote_spin");
    a.ld(tmp, data, 0, 4);
    a.add(tmp, tmp, Src::I(1));
    a.st(data, 0, tmp, 4);
    if remote_ops {
        a.remote_atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release);
    } else {
        a.atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release, Scope::Cmp);
    }
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(remote_iters));
    a.bnz(c, "remote_loop");
    a.halt();

    a.label("bystander");
    a.label("by_loop");
    a.and(c, i, Src::I(63));
    a.shl(c, c, Src::I(6));
    a.add(c, c, Src::R(waddr));
    a.ld(tmp, c, 0, 4);
    a.add(i, i, Src::I(1));
    a.lt_u(c, i, Src::I(local_iters));
    a.bnz(c, "by_loop");
    a.halt();

    a.finish()
}

fn run(name: &str, cfg: &DeviceConfig, protocol: Protocol, owner_scope: Scope, remote_ops: bool) {
    let (li, ri) = (400u64, 20u64);
    let mut dev = Device::new(cfg.clone(), protocol);
    dev.launch_simple(&kernel(li, ri, owner_scope, remote_ops), cfg.num_cus);
    let total = dev.mem.backing.read_u32(DATA) as u64;
    assert_eq!(total, li + ri, "{name}: mutual exclusion violated");
    let s = dev.take_stats();
    println!(
        "{name:>7}: cycles {:>9}  sync-overhead {:>10}  lines invalidated {:>7}  L2 {:>7}",
        s.cycles, s.sync_overhead_cycles, s.lines_invalidated, s.l2_accesses
    );
}

fn main() {
    let cfg = DeviceConfig::default(); // 64 CUs, Table-1
    println!(
        "asymmetric mutex on {} CUs: 400 local + 20 remote critical sections\n",
        cfg.num_cus
    );
    run("global", &cfg, Protocol::SCOPED_ONLY, Scope::Cmp, false);
    run("rsp", &cfg, Protocol::RSP_NAIVE, Scope::Wg, true);
    run("srsp", &cfg, Protocol::SRSP, Scope::Wg, true);
    println!("\nexpected shape: global pays on every acquire; naive RSP nukes every");
    println!("bystander's L1 on each remote handoff; sRSP touches only the sharer.");
}
