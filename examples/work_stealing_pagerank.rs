//! **End-to-end driver** — the full three-layer stack on a real workload.
//!
//! Work-stealing PageRank on the Table-1 64-CU device under sRSP, with the
//! per-task vertex math executed by the **AOT-compiled JAX/Pallas
//! artifact** through the PJRT CPU client (`artifacts/pagerank.hlo.txt`,
//! built once by `make artifacts` — Python never runs here):
//!
//!   KIR work-stealing kernel  (Layer 3, Rust simulator)
//!     └─ WorkEngine gathers neighbor contributions through the timed
//!        L1/sFIFO/L2/DRAM hierarchy
//!          └─ PjrtMath executes the Pallas `pagerank_rows` tile kernel
//!             via PJRT (Layer 1+2, compiled from JAX)
//!
//! The run is validated three ways: PJRT values vs the native-Rust tile
//! math, final ranks vs a power-iteration oracle, and rank-mass
//! conservation. Results are recorded in EXPERIMENTS.md.
//!
//! Run with: `make artifacts && cargo run --release --example work_stealing_pagerank`

use srsp::config::{DeviceConfig, Scenario};
use srsp::harness::report::format_table;
use srsp::mem::{BackingStore, MemAlloc};
use srsp::runtime::PjrtMath;
use srsp::workload::driver::run_scenario_seeded;
use srsp::workload::engine::NativeMath;
use srsp::workload::graph::Graph;
use srsp::workload::pagerank::PageRank;
use std::path::Path;
use std::time::Instant;

const ITERS: u32 = 6;
const CHUNK: u32 = 8;

fn run(
    graph: &Graph,
    cfg: &DeviceConfig,
    scenario: Scenario,
    use_pjrt: bool,
) -> (srsp::workload::driver::RunResult, Vec<f32>, f64, u64) {
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    let mut prk = PageRank::setup(graph, &mut alloc, &mut image, CHUNK, ITERS);
    let t0 = Instant::now();
    let (run, mem, calls) = if use_pjrt {
        let math = PjrtMath::from_artifacts(Path::new("artifacts"))
            .expect("load artifacts (run `make artifacts` first)");
        println!("PJRT platform: {}", math.rt.platform());
        let (run, mem) = run_scenario_seeded(cfg, scenario, &mut prk, math, 64, image);
        (run, mem, 0) // calls tracked inside; reported via stats below
    } else {
        let (run, mem) = run_scenario_seeded(cfg, scenario, &mut prk, NativeMath, 64, image);
        (run, mem, 0)
    };
    let wall = t0.elapsed().as_secs_f64();
    let ranks = prk.result(&mem);
    (run, ranks, wall, calls)
}

fn main() {
    let graph = Graph::small_world(2048, 8, 0.1, 0xC0FFEE);
    graph.validate().unwrap();
    let cfg = DeviceConfig::default();
    println!(
        "work-stealing PageRank: {} vertices, {} edges, {} iterations, {} CUs\n",
        graph.n,
        graph.num_edges(),
        ITERS,
        cfg.num_cus
    );

    // 1) Full stack: sRSP + PJRT-executed Pallas kernel.
    let (run_pjrt, ranks_pjrt, wall_pjrt, _) = run(&graph, &cfg, Scenario::SRSP, true);
    assert!(run_pjrt.converged);

    // 2) Same run with the native tile math: values must agree closely.
    let (run_native, ranks_native, wall_native, _) = run(&graph, &cfg, Scenario::SRSP, false);
    let max_dev = ranks_pjrt
        .iter()
        .zip(&ranks_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(
        max_dev < 1e-6,
        "PJRT and native math diverged: {max_dev}"
    );
    assert_eq!(run_pjrt.stats.cycles, run_native.stats.cycles,
        "simulated timing must not depend on the math backend");

    // 3) Oracle: power iteration with the same tiling.
    let oracle = PageRank::oracle(&graph, ITERS);
    let l1: f32 = ranks_pjrt
        .iter()
        .zip(&oracle)
        .map(|(a, b)| (a - b).abs())
        .sum();
    assert!(l1 < 1e-4, "deviates from oracle by {l1}");

    // 4) Rank mass ≈ 1.
    let mass: f32 = ranks_pjrt.iter().sum();
    assert!((mass - 1.0).abs() < 0.02, "rank mass {mass}");

    println!(
        "validation: PJRT≡native (max dev {max_dev:.2e}), oracle L1 {l1:.2e}, mass {mass:.4}\n"
    );

    let s = &run_pjrt.stats;
    let rows = vec![
        vec!["simulated cycles".into(), s.cycles.to_string()],
        vec!["rounds (kernel launches)".into(), run_pjrt.rounds.to_string()],
        vec!["tasks executed".into(), s.tasks_executed.to_string()],
        vec!["tasks stolen".into(), s.tasks_stolen.to_string()],
        vec!["compute ops (XLA batches)".into(), s.compute_ops.to_string()],
        vec!["edges processed".into(), s.compute_items.to_string()],
        vec!["L1 hit rate".into(), format!("{:.1}%", 100.0 * s.l1_hit_rate())],
        vec!["L2 accesses".into(), s.l2_accesses.to_string()],
        vec!["promoted acquires".into(), s.promoted_acquires.to_string()],
        vec!["selective flushes".into(), s.selective_flush_requests.to_string()],
        vec!["wall time (PJRT)".into(), format!("{wall_pjrt:.2}s")],
        vec!["wall time (native)".into(), format!("{wall_native:.2}s")],
        vec![
            "throughput (PJRT)".into(),
            format!("{:.0} edges/s", s.compute_items as f64 / wall_pjrt),
        ],
    ];
    println!(
        "{}",
        format_table(&["metric".into(), "value".into()], &rows)
    );
    println!("top-5 ranked vertices:");
    let mut idx: Vec<u32> = (0..graph.n).collect();
    idx.sort_by(|&a, &b| ranks_pjrt[b as usize].total_cmp(&ranks_pjrt[a as usize]));
    for &v in idx.iter().take(5) {
        println!("  v{v:<6} rank {:.6}  degree {}", ranks_pjrt[v as usize], graph.degree(v));
    }
}
