"""Model shapes + AOT round trip.

Checks that every step function lowers to HLO text that xla_client can
parse back (the same property the Rust runtime depends on), and that the
lowered computation still computes the right values when executed through
the *local* CPU client.
"""

import os

import pytest

# Optional heavyweight dep: skip (don't error) when invoked directly on
# a machine without it (see python/conftest.py for the CI directory run).
pytest.importorskip("jax")

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref


@pytest.mark.parametrize("name", sorted(model.MODELS))
def test_models_lower_to_hlo_text(name):
    lowered = jax.jit(model.MODELS[name]).lower(*model.example_args(name))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, "not HLO text"
    assert "f64" not in text, "accidental f64 promotion would slow the MXU path"


def test_manifest_written(tmp_path):
    out = str(tmp_path / "arts")
    manifest = aot.lower_all(out)
    assert set(manifest["models"]) == {"pagerank", "sssp", "mis"}
    assert manifest["rows"] == ref.ROWS
    assert manifest["k"] == ref.K
    for meta in manifest["models"].values():
        p = os.path.join(out, meta["file"])
        assert os.path.getsize(p) == meta["bytes"]


def test_pagerank_step_values():
    contribs = jnp.ones((ref.ROWS, ref.K), jnp.float32) * 0.25
    d = jnp.asarray([0.5], jnp.float32)
    inv_n = jnp.asarray([0.125], jnp.float32)
    (out,) = model.pagerank_step(contribs, d, inv_n)
    expect = 0.5 * 0.125 + 0.5 * (0.25 * ref.K)
    np.testing.assert_allclose(np.asarray(out), np.full(ref.ROWS, expect), rtol=1e-6)


def test_sssp_step_values():
    tile = jnp.full((ref.ROWS, ref.K), ref.DIST_INF, jnp.int32)
    tile = tile.at[3, 17].set(42)
    (out,) = model.sssp_step(tile)
    out = np.asarray(out)
    assert out[3] == 42
    assert out[0] == ref.DIST_INF


def test_mis_step_values():
    my = jnp.zeros((ref.ROWS,), jnp.uint32).at[1].set(10)
    nbr = jnp.zeros((ref.ROWS, ref.K), jnp.uint32).at[1, 0].set(9)
    (out,) = model.mis_step(my, nbr)
    out = np.asarray(out)
    assert out[1] == 1
    assert out[0] == 0  # priority 0 vs all-zero neighbors: strict > fails
