"""Pallas kernels vs pure-jnp reference — the core numeric signal.

Hypothesis sweeps randomized tile contents (including the padding
conventions the Rust engine relies on); every kernel must match `ref.py`
exactly (integer ops) or to f32 ulp-level (PageRank).
"""

import pytest

# Optional heavyweight deps: skip (don't error) when invoked directly
# on a machine without them. The CI directory run is also shielded by
# python/conftest.py's collect_ignore.
pytest.importorskip("jax")
pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import mis as mis_k
from compile.kernels import pagerank as prk_k
from compile.kernels import ref
from compile.kernels import sssp as sssp_k

ROWS, K = ref.ROWS, ref.K


def rand_f32(rng, shape, lo=0.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, size=shape), jnp.float32)


# ---------------------------------------------------------------------
# PageRank
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), damping=st.floats(0.0, 1.0))
def test_pagerank_matches_ref(seed, damping):
    rng = np.random.default_rng(seed)
    contribs = rand_f32(rng, (ROWS, K))
    # Zero out a random suffix of each row (padding convention).
    keep = rng.integers(0, K + 1, size=ROWS)
    mask = np.arange(K)[None, :] < keep[:, None]
    contribs = jnp.asarray(np.where(mask, contribs, 0.0), jnp.float32)
    d = jnp.asarray([damping], jnp.float32)
    inv_n = jnp.asarray([1.0 / 1000.0], jnp.float32)
    got = prk_k.pagerank_rows(contribs, d, inv_n)
    want = ref.pagerank_rows_ref(contribs, d, inv_n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_pagerank_all_padding_rows():
    contribs = jnp.zeros((ROWS, K), jnp.float32)
    d = jnp.asarray([0.85], jnp.float32)
    inv_n = jnp.asarray([0.01], jnp.float32)
    got = np.asarray(prk_k.pagerank_rows(contribs, d, inv_n))
    np.testing.assert_allclose(got, np.full(ROWS, 0.15 * 0.01), rtol=1e-6)


# ---------------------------------------------------------------------
# SSSP
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sssp_matches_ref_exactly(seed):
    rng = np.random.default_rng(seed)
    tile = rng.integers(0, ref.DIST_INF, size=(ROWS, K), dtype=np.int64)
    # Random padding slots carry DIST_INF.
    pad = rng.random((ROWS, K)) < 0.3
    tile = np.where(pad, ref.DIST_INF, tile).astype(np.int32)
    got = sssp_k.sssp_rows(jnp.asarray(tile))
    want = ref.sssp_rows_ref(jnp.asarray(tile))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sssp_all_inf_row_stays_inf():
    tile = jnp.full((ROWS, K), ref.DIST_INF, jnp.int32)
    got = np.asarray(sssp_k.sssp_rows(tile))
    assert (got == ref.DIST_INF).all()


# ---------------------------------------------------------------------
# MIS
# ---------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mis_matches_ref_exactly(seed):
    rng = np.random.default_rng(seed)
    my_pri = rng.integers(0, 2**32, size=ROWS, dtype=np.uint32)
    nbr = rng.integers(0, 2**32, size=(ROWS, K), dtype=np.uint32)
    pad = rng.random((ROWS, K)) < 0.4
    nbr = np.where(pad, 0, nbr).astype(np.uint32)
    got = mis_k.mis_rows(jnp.asarray(my_pri), jnp.asarray(nbr))
    want = ref.mis_rows_ref(jnp.asarray(my_pri), jnp.asarray(nbr))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mis_uses_unsigned_comparison():
    # A priority above 2^31 must beat a small one — breaks if the kernel
    # silently compares as i32.
    my_pri = np.zeros(ROWS, np.uint32)
    my_pri[0] = 0x8000_0001
    nbr = np.zeros((ROWS, K), np.uint32)
    nbr[0, 0] = 5
    nbr[1, 0] = 0x8000_0001  # row 1's my_pri=0 must lose
    got = np.asarray(mis_k.mis_rows(jnp.asarray(my_pri), jnp.asarray(nbr)))
    assert got[0] == 1
    assert got[1] == 0


def test_mis_strictness():
    # Equal priorities must NOT join (strict >). With the bijective
    # priority mix this only matters for padded slots, but pin it anyway.
    my_pri = np.full(ROWS, 7, np.uint32)
    nbr = np.full((ROWS, K), 7, np.uint32)
    got = np.asarray(mis_k.mis_rows(jnp.asarray(my_pri), jnp.asarray(nbr)))
    assert (got == 0).all()
