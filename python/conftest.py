"""Pytest wiring for the `python/` layer.

Makes the `compile` package importable when the suite is launched from
the repository root (`python -m pytest python/tests -q`, as CI does) and
skips collection gracefully when the optional heavyweight dependencies
(JAX, Hypothesis) are not installed — the Rust side of CI must stay
green on machines without them.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _missing(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is None
    except (ImportError, ValueError):
        return True


collect_ignore = []
if _missing("jax"):
    # Both suites exercise JAX lowering / Pallas kernels.
    collect_ignore += ["tests/test_kernels.py", "tests/test_model_aot.py"]
elif _missing("hypothesis"):
    # Only the randomized kernel sweeps need Hypothesis.
    collect_ignore += ["tests/test_kernels.py"]
