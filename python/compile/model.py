"""Layer-2 JAX model: the batched step functions the Rust coordinator
executes through PJRT.

Each step function wraps the corresponding Layer-1 Pallas kernel
(`kernels/`) in the fixed-shape tile contract (ROWS x K, see
`kernels/ref.py`). These are the *whole* device-side numeric graphs of the
three workloads — the gather/scatter around them is the simulated GPU's
memory traffic, produced in Rust.

Lowered once by `aot.py` into `artifacts/*.hlo.txt`; never imported at
runtime.
"""

import jax
import jax.numpy as jnp

from .kernels import mis as mis_k
from .kernels import pagerank as prk_k
from .kernels import sssp as sssp_k
from .kernels.ref import K, ROWS


def pagerank_step(contribs, damping, inv_n):
    """f32[ROWS,K], f32[1], f32[1] -> (f32[ROWS],)."""
    return (prk_k.pagerank_rows(contribs, damping, inv_n),)


def sssp_step(dist_plus_w):
    """i32[ROWS,K] -> (i32[ROWS],)."""
    return (sssp_k.sssp_rows(dist_plus_w),)


def mis_step(my_pri, nbr_pri):
    """u32[ROWS], u32[ROWS,K] -> (u32[ROWS],)."""
    return (mis_k.mis_rows(my_pri, nbr_pri),)


def example_args(name):
    """ShapeDtypeStructs used to lower each step function."""
    f32 = jnp.float32
    if name == "pagerank":
        return (
            jax.ShapeDtypeStruct((ROWS, K), f32),
            jax.ShapeDtypeStruct((1,), f32),
            jax.ShapeDtypeStruct((1,), f32),
        )
    if name == "sssp":
        return (jax.ShapeDtypeStruct((ROWS, K), jnp.int32),)
    if name == "mis":
        return (
            jax.ShapeDtypeStruct((ROWS,), jnp.uint32),
            jax.ShapeDtypeStruct((ROWS, K), jnp.uint32),
        )
    raise ValueError(f"unknown model {name!r}")


MODELS = {
    "pagerank": pagerank_step,
    "sssp": sssp_step,
    "mis": mis_step,
}
