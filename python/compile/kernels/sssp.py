"""Layer-1 Pallas kernel: SSSP min-plus row reduction.

Each tile row holds ``dist[u] + w(u,v)`` for up to K neighbors of one
vertex (padded with DIST_INF); the kernel reduces each row to its minimum
candidate distance. Integer (i32) math: exact, so the simulated device's
results match the Dijkstra oracle bit-for-bit.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import K, ROWS

BLOCK_ROWS = 128


def _sssp_kernel(tile_ref, out_ref):
    out_ref[...] = jnp.min(tile_ref[...], axis=1)


def sssp_rows(dist_plus_w):
    """dist_plus_w: i32[ROWS, K] -> i32[ROWS]."""
    return pl.pallas_call(
        _sssp_kernel,
        grid=(ROWS // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, K), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ROWS,), jnp.int32),
        interpret=True,
    )(dist_plus_w)
