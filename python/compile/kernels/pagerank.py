"""Layer-1 Pallas kernel: PageRank tile-row reduction.

The GPU paper's per-vertex gather loop becomes, on TPU-style hardware, a
dense ``(ROWS, K)`` tile resident in VMEM whose row-sums feed the VPU; the
BlockSpec carries the HBM->VMEM schedule that the CUDA/HSAIL version
expressed with workgroups (DESIGN.md §Hardware-Adaptation).

``interpret=True`` throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO the Rust runtime can
compile and run.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import K, ROWS

# Rows per grid step: one VMEM block holds BLOCK_ROWS * K f32 = 16 kB,
# comfortably inside a ~16 MB VMEM budget alongside double buffering.
BLOCK_ROWS = 128


def _pagerank_kernel(contribs_ref, damping_ref, inv_n_ref, out_ref):
    """out[i] = (1-d)*inv_n + d * sum_k contribs[i, k]."""
    d = damping_ref[0]
    inv_n = inv_n_ref[0]
    s = jnp.sum(contribs_ref[...], axis=1)
    out_ref[...] = (1.0 - d) * inv_n + d * s


def pagerank_rows(contribs, damping, inv_n):
    """contribs: f32[ROWS, K]; damping, inv_n: f32[1] -> f32[ROWS]."""
    return pl.pallas_call(
        _pagerank_kernel,
        grid=(ROWS // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, K), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ROWS,), jnp.float32),
        interpret=True,
    )(contribs, damping, inv_n)
