"""Layer-1 Pallas kernel: MIS strict local-maximum test.

Row i carries the priorities of vertex i's *undecided* neighbors (0 for
padded/decided slots — priority 0 loses every strict comparison except
against vertex 0, which has priority 0 itself and correctly never beats a
0 slot... but vertex 0's row is compared with `>`, and isolated rows of
all-zero neighbors still admit it, matching the reference semantics).
Unsigned (u32) comparisons — priorities use the full u32 range.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import K, ROWS

BLOCK_ROWS = 128


def _mis_kernel(my_pri_ref, nbr_pri_ref, out_ref):
    m = jnp.max(nbr_pri_ref[...], axis=1)
    out_ref[...] = (my_pri_ref[...] > m).astype(jnp.uint32)


def mis_rows(my_pri, nbr_pri):
    """my_pri: u32[ROWS]; nbr_pri: u32[ROWS, K] -> u32[ROWS] (0/1)."""
    return pl.pallas_call(
        _mis_kernel,
        grid=(ROWS // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_ROWS, K), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ROWS,), jnp.uint32),
        interpret=True,
    )(my_pri, nbr_pri)
