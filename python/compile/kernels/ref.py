"""Pure-jnp reference oracles for the Pallas kernels.

Shapes are the AOT tile contract shared with the Rust runtime
(`rust/src/workload/engine.rs` and `rust/src/runtime/`):

* ROWS = 256 tile rows per executable call,
* K    = 32 neighbor slots per row.

Padding conventions: PageRank pads contributions with 0.0 (exact under
f32 addition), SSSP pads with ``DIST_INF + 0`` (never the minimum for
real slots), MIS pads neighbor priorities with 0 (loses every strict
comparison).
"""

import jax.numpy as jnp

ROWS = 256
K = 32

DIST_INF = 0x3FFF_FFFF


def pagerank_rows_ref(contribs, damping, inv_n):
    """rank_row = (1-d)*inv_n + d * sum(contribs_row).

    contribs: f32[ROWS, K]; damping, inv_n: f32[1].
    Returns f32[ROWS].
    """
    s = jnp.sum(contribs, axis=1)
    return (1.0 - damping[0]) * inv_n[0] + damping[0] * s


def sssp_rows_ref(dist_plus_w):
    """Min-plus row reduction. dist_plus_w: i32[ROWS, K] -> i32[ROWS]."""
    return jnp.min(dist_plus_w, axis=1)


def mis_rows_ref(my_pri, nbr_pri):
    """Strict local-maximum test.

    my_pri: u32[ROWS]; nbr_pri: u32[ROWS, K].
    Returns u32[ROWS] (1 = joins the set).
    """
    m = jnp.max(nbr_pri, axis=1)
    return (my_pri > m).astype(jnp.uint32)
