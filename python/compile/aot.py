"""AOT lowering: JAX/Pallas step functions -> HLO *text* artifacts.

HLO text (not `.serialize()`d protos) is the interchange format: jax >=
0.5 emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import MODELS, example_args
from .kernels.ref import K, ROWS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"rows": ROWS, "k": K, "models": {}}
    for name, fn in MODELS.items():
        lowered = jax.jit(fn).lower(*example_args(name))
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["models"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    lower_all(args.out)


if __name__ == "__main__":
    main()
