"""Build-time compilation layer: JAX/Pallas kernels, the model registry
and the AOT lowering driver that writes `artifacts/*.hlo.txt` for the
Rust runtime. Nothing here runs at simulation time."""
