//! KIR assembler: builder API with named labels, forward references and a
//! simple register allocator. Workload kernels are authored with this —
//! the OpenCL-to-HSAIL compiler analog of the reproduction.

use super::inst::{AluOp, Inst, Program, Reg, Src, NUM_REGS};
use crate::sync::{AtomicOp, MemOrder, Scope};
use std::collections::HashMap;

/// Program builder.
#[derive(Debug, Default)]
pub struct Asm {
    insts: Vec<Inst>,
    labels: HashMap<String, u32>,
    /// (inst index, label) pairs to patch at `finish()`.
    fixups: Vec<(usize, String)>,
    next_reg: u8,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a fresh register.
    pub fn reg(&mut self) -> Reg {
        assert!(
            (self.next_reg as usize) < NUM_REGS,
            "KIR: out of registers ({NUM_REGS})"
        );
        let r = Reg(self.next_reg);
        self.next_reg += 1;
        r
    }

    /// Number of registers allocated so far.
    pub fn regs_used(&self) -> u8 {
        self.next_reg
    }

    /// Define `name` at the current position.
    pub fn label(&mut self, name: &str) {
        let at = self.insts.len() as u32;
        let prev = self.labels.insert(name.to_string(), at);
        assert!(prev.is_none(), "KIR: duplicate label '{name}'");
    }

    fn push(&mut self, inst: Inst) -> &mut Self {
        self.insts.push(inst);
        self
    }

    fn push_branch(&mut self, inst: Inst, label: &str) -> &mut Self {
        self.fixups.push((self.insts.len(), label.to_string()));
        self.insts.push(inst);
        self
    }

    // --- data movement / ALU ---

    pub fn imm(&mut self, dst: Reg, val: u64) -> &mut Self {
        self.push(Inst::Imm { dst, val })
    }

    pub fn mov(&mut self, dst: Reg, src: Reg) -> &mut Self {
        self.push(Inst::Alu {
            op: AluOp::Add,
            dst,
            a: src,
            b: Src::I(0),
        })
    }

    pub fn alu(&mut self, op: AluOp, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.push(Inst::Alu { op, dst, a, b })
    }

    pub fn add(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Add, dst, a, b)
    }

    pub fn sub(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Sub, dst, a, b)
    }

    pub fn mul(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Mul, dst, a, b)
    }

    pub fn and(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::And, dst, a, b)
    }

    pub fn shl(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Shl, dst, a, b)
    }

    pub fn shr(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Shr, dst, a, b)
    }

    pub fn lt_u(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::LtU, dst, a, b)
    }

    pub fn ge_u(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::GeU, dst, a, b)
    }

    pub fn eq(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Eq, dst, a, b)
    }

    pub fn ne(&mut self, dst: Reg, a: Reg, b: Src) -> &mut Self {
        self.alu(AluOp::Ne, dst, a, b)
    }

    // --- memory ---

    pub fn ld(&mut self, dst: Reg, base: Reg, off: i32, size: u8) -> &mut Self {
        debug_assert!(matches!(size, 1 | 2 | 4 | 8));
        self.push(Inst::Ld { dst, base, off, size })
    }

    pub fn st(&mut self, base: Reg, off: i32, src: Reg, size: u8) -> &mut Self {
        debug_assert!(matches!(size, 1 | 2 | 4 | 8));
        self.push(Inst::St { base, off, src, size })
    }

    /// Scoped atomic. `dst` receives the old value.
    #[allow(clippy::too_many_arguments)]
    pub fn atomic(
        &mut self,
        dst: Reg,
        op: AtomicOp,
        addr: Reg,
        operand: Src,
        cmp: Src,
        order: MemOrder,
        scope: Scope,
    ) -> &mut Self {
        self.push(Inst::Atomic {
            dst,
            op,
            addr,
            operand,
            cmp,
            order,
            scope,
            remote: false,
        })
    }

    /// Remote (RSP) atomic: order `Acquire` = `rem_acq`, `Release` =
    /// `rem_rel`, `AcqRel` = `rem_ar`. Scope is always cmp (§3).
    pub fn remote_atomic(
        &mut self,
        dst: Reg,
        op: AtomicOp,
        addr: Reg,
        operand: Src,
        cmp: Src,
        order: MemOrder,
    ) -> &mut Self {
        assert!(order != MemOrder::Relaxed, "remote atomics must synchronize");
        self.push(Inst::Atomic {
            dst,
            op,
            addr,
            operand,
            cmp,
            order,
            scope: Scope::Cmp,
            remote: true,
        })
    }

    // --- control flow ---

    pub fn br(&mut self, label: &str) -> &mut Self {
        self.push_branch(Inst::Br { target: u32::MAX }, label)
    }

    pub fn bnz(&mut self, cond: Reg, label: &str) -> &mut Self {
        self.push_branch(
            Inst::Bnz {
                cond,
                target: u32::MAX,
            },
            label,
        )
    }

    pub fn bz(&mut self, cond: Reg, label: &str) -> &mut Self {
        self.push_branch(
            Inst::Bz {
                cond,
                target: u32::MAX,
            },
            label,
        )
    }

    // --- misc ---

    pub fn compute(&mut self, kind: u32, arg: Reg) -> &mut Self {
        self.push(Inst::Compute { kind, arg })
    }

    pub fn wg_id(&mut self, dst: Reg) -> &mut Self {
        self.push(Inst::WgId { dst })
    }

    pub fn num_wgs(&mut self, dst: Reg) -> &mut Self {
        self.push(Inst::NumWgs { dst })
    }

    pub fn cu_id(&mut self, dst: Reg) -> &mut Self {
        self.push(Inst::CuId { dst })
    }

    pub fn stat(&mut self, counter: super::inst::StatCounter) -> &mut Self {
        self.push(Inst::Stat { counter })
    }

    pub fn halt(&mut self) -> &mut Self {
        self.push(Inst::Halt)
    }

    /// Resolve labels and produce the program.
    pub fn finish(self) -> Program {
        let mut insts = self.insts;
        for (idx, label) in &self.fixups {
            let target = *self
                .labels
                .get(label)
                .unwrap_or_else(|| panic!("KIR: undefined label '{label}'"));
            match &mut insts[*idx] {
                Inst::Br { target: t }
                | Inst::Bnz { target: t, .. }
                | Inst::Bz { target: t, .. } => *t = target,
                other => panic!("fixup on non-branch {other:?}"),
            }
        }
        let mut labels: Vec<(String, u32)> = self.labels.into_iter().collect();
        labels.sort_by_key(|(_, at)| *at);
        Program { insts, labels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut a = Asm::new();
        let r = a.reg();
        a.imm(r, 0);
        a.label("loop");
        a.add(r, r, Src::I(1));
        let c = a.reg();
        a.lt_u(c, r, Src::I(10));
        a.bnz(c, "loop");
        a.bz(c, "end"); // forward reference
        a.br("loop");
        a.label("end");
        a.halt();
        let p = a.finish();
        // bnz -> index of "loop" (1), bz -> index of "end".
        match p.insts[3] {
            Inst::Bnz { target, .. } => assert_eq!(target, 1),
            ref other => panic!("{other:?}"),
        }
        match p.insts[4] {
            Inst::Bz { target, .. } => assert_eq!(target, 6),
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "undefined label")]
    fn undefined_label_panics() {
        let mut a = Asm::new();
        a.br("nowhere");
        let _ = a.finish();
    }

    #[test]
    #[should_panic(expected = "duplicate label")]
    fn duplicate_label_panics() {
        let mut a = Asm::new();
        a.label("x");
        a.label("x");
    }

    #[test]
    fn register_allocation_bounds() {
        let mut a = Asm::new();
        for _ in 0..NUM_REGS {
            a.reg();
        }
        assert_eq!(a.regs_used() as usize, NUM_REGS);
    }
}
