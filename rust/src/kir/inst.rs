//! KIR instruction set.

use crate::sync::{AtomicOp, MemOrder, Scope};

/// Register index (32 registers per work-group context).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Reg(pub u8);

pub const NUM_REGS: usize = 32;

/// Right-hand operand: register or immediate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Src {
    R(Reg),
    I(u64),
}

/// Integer ALU operations (u64 semantics; comparisons produce 0/1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluOp {
    Add,
    Sub,
    Mul,
    /// Unsigned division; division by zero traps (simulation bug).
    DivU,
    RemU,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    /// Unsigned less-than.
    LtU,
    /// Signed less-than (two's complement over u64).
    LtS,
    Eq,
    Ne,
    LeU,
    GeU,
    MinU,
    MaxU,
}

impl AluOp {
    pub fn apply(self, a: u64, b: u64) -> u64 {
        match self {
            AluOp::Add => a.wrapping_add(b),
            AluOp::Sub => a.wrapping_sub(b),
            AluOp::Mul => a.wrapping_mul(b),
            AluOp::DivU => {
                assert!(b != 0, "KIR: division by zero");
                a / b
            }
            AluOp::RemU => {
                assert!(b != 0, "KIR: remainder by zero");
                a % b
            }
            AluOp::And => a & b,
            AluOp::Or => a | b,
            AluOp::Xor => a ^ b,
            AluOp::Shl => a.wrapping_shl(b as u32),
            AluOp::Shr => a.wrapping_shr(b as u32),
            AluOp::LtU => (a < b) as u64,
            AluOp::LtS => ((a as i64) < (b as i64)) as u64,
            AluOp::Eq => (a == b) as u64,
            AluOp::Ne => (a != b) as u64,
            AluOp::LeU => (a <= b) as u64,
            AluOp::GeU => (a >= b) as u64,
            AluOp::MinU => a.min(b),
            AluOp::MaxU => a.max(b),
        }
    }
}

/// One KIR instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Inst {
    /// `dst = val`
    Imm { dst: Reg, val: u64 },
    /// `dst = op(a, b)`
    Alu { op: AluOp, dst: Reg, a: Reg, b: Src },
    /// `dst = mem[base + off]` (plain load, `size` ∈ {1,2,4,8})
    Ld { dst: Reg, base: Reg, off: i32, size: u8 },
    /// `mem[base + off] = src`
    St { base: Reg, off: i32, src: Reg, size: u8 },
    /// Scoped (or remote) atomic on a 4-byte word at `[addr]`.
    ///
    /// `remote = true` selects the RSP operations: order `Acquire` is
    /// `rem_acq`, `Release` is `rem_rel`, `AcqRel` is `rem_ar` (§3).
    Atomic {
        dst: Reg,
        op: AtomicOp,
        addr: Reg,
        operand: Src,
        cmp: Src,
        order: MemOrder,
        scope: Scope,
        remote: bool,
    },
    /// Unconditional branch to instruction index.
    Br { target: u32 },
    /// Branch if `cond != 0`.
    Bnz { cond: Reg, target: u32 },
    /// Branch if `cond == 0`.
    Bz { cond: Reg, target: u32 },
    /// Delegate a batch of data-parallel work to the compute engine.
    /// `arg` is an engine-defined descriptor (usually a task id or a
    /// pointer to a task record).
    Compute { kind: u32, arg: Reg },
    /// `dst = work-group id`
    WgId { dst: Reg },
    /// `dst = number of work-groups`
    NumWgs { dst: Reg },
    /// `dst = CU id this work-group runs on`
    CuId { dst: Reg },
    /// Bump a device performance counter (free: models the CU's hardware
    /// event counters, used for the paper's steal statistics).
    Stat { counter: StatCounter },
    /// Terminate this work-group.
    Halt,
}

/// Device performance counters exposed to KIR programs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatCounter {
    TaskExecuted,
    StealAttempt,
    StealSuccess,
    StealFail,
}

/// A finished KIR program (branch targets resolved).
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub insts: Vec<Inst>,
    /// Optional label map kept for disassembly/debugging.
    pub labels: Vec<(String, u32)>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Human-readable disassembly (debugging aid).
    pub fn disassemble(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for (i, inst) in self.insts.iter().enumerate() {
            for (name, at) in &self.labels {
                if *at == i as u32 {
                    let _ = writeln!(out, "{name}:");
                }
            }
            let _ = writeln!(out, "  {i:4}: {inst:?}");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alu_semantics() {
        assert_eq!(AluOp::Add.apply(u64::MAX, 1), 0);
        assert_eq!(AluOp::Sub.apply(0, 1), u64::MAX);
        assert_eq!(AluOp::LtS.apply(u64::MAX, 0), 1); // -1 < 0 signed
        assert_eq!(AluOp::LtU.apply(u64::MAX, 0), 0);
        assert_eq!(AluOp::MinU.apply(3, 9), 3);
        assert_eq!(AluOp::Eq.apply(4, 4), 1);
        assert_eq!(AluOp::Shl.apply(1, 12), 4096);
    }

    #[test]
    #[should_panic(expected = "division by zero")]
    fn div_zero_traps() {
        AluOp::DivU.apply(1, 0);
    }

    #[test]
    fn disassembly_includes_labels() {
        let p = Program {
            insts: vec![Inst::Imm { dst: Reg(0), val: 1 }, Inst::Halt],
            labels: vec![("start".into(), 0)],
        };
        let d = p.disassemble();
        assert!(d.contains("start:"));
        assert!(d.contains("Halt"));
    }
}
