//! KIR interpreter: executes one work-group's program against the
//! simulated memory system.
//!
//! ALU/branch instructions retire back-to-back (charged `issue_cycles`
//! each) up to a quantum; memory, atomic and compute instructions block
//! the work-group until their computed completion cycle — the event loop
//! in [`crate::gpu::device`] then reschedules it.

use super::inst::{Inst, Program, Reg, Src, NUM_REGS};
use crate::config::Protocol;
use crate::mem::{Addr, MemSystem};
use crate::sim::Cycle;
use crate::sync::{engine, MemOrder, Scope};

/// Max consecutive non-memory instructions executed per event — bounds
/// event-loop starvation from compute-only loops.
pub const QUANTUM_INSTS: usize = 256;

/// Planning memory interface handed to compute engines: functional
/// effects (values, cache state, stats) happen immediately; each access's
/// timing class is recorded and replayed a few per event by the
/// interpreter, so shared-resource contention resolves in global time
/// order (see `MemSystem`'s planned-access section).
pub struct MemAccess<'a> {
    pub mem: &'a mut MemSystem,
    pub cu: u32,
    /// Recorded timing classes, replayed after the engine returns.
    pub steps: Vec<crate::mem::hierarchy::PlannedAccess>,
}

impl<'a> MemAccess<'a> {
    pub fn new(mem: &'a mut MemSystem, cu: u32) -> Self {
        Self {
            mem,
            cu,
            steps: Vec::with_capacity(64),
        }
    }

    pub fn read_u32(&mut self, addr: Addr) -> u32 {
        let (v, p) = self.mem.plan_read(self.cu, addr, 4);
        self.steps.push(p);
        v as u32
    }

    pub fn read_f32(&mut self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_u32(&mut self, addr: Addr, v: u32) {
        let p = self.mem.plan_write(self.cu, addr, 4, v as u64);
        self.steps.push(p);
    }

    pub fn write_f32(&mut self, addr: Addr, v: f32) {
        self.write_u32(addr, v.to_bits());
    }

    pub fn read_u64(&mut self, addr: Addr) -> u64 {
        let (v, p) = self.mem.plan_read(self.cu, addr, 8);
        self.steps.push(p);
        v
    }
}

/// Engine for `Compute` instructions. Returns the number of work-items
/// processed (charged `compute_cycles_per_item` each on top of the memory
/// time accumulated in `MemAccess.now`).
pub trait ComputeEngine {
    fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64;
}

/// Engine that does nothing (for pure-synchronization microbenchmarks).
#[derive(Debug, Default)]
pub struct NoopEngine;

impl ComputeEngine for NoopEngine {
    fn compute(&mut self, _mem: &mut MemAccess<'_>, _kind: u32, _arg: u64) -> u64 {
        0
    }
}

/// Accesses replayed per scheduling event: bounds the time skew of the
/// eager functional execution while keeping event-queue overhead low.
pub const REPLAY_BATCH: usize = 8;

/// Per-work-group execution context.
#[derive(Debug, Clone)]
pub struct WgContext {
    pub wg_id: u32,
    pub cu: u32,
    pub pc: u32,
    pub regs: [u64; NUM_REGS],
    pub halted: bool,
    /// Planned compute-op accesses awaiting timed replay.
    pending: std::collections::VecDeque<crate::mem::hierarchy::PlannedAccess>,
    /// Compute cycles charged after the last pending access.
    pending_tail: Cycle,
}

impl WgContext {
    pub fn new(wg_id: u32, cu: u32) -> Self {
        Self {
            wg_id,
            cu,
            pc: 0,
            regs: [0; NUM_REGS],
            halted: false,
            pending: std::collections::VecDeque::new(),
            pending_tail: 0,
        }
    }

    #[inline]
    fn get(&self, r: Reg) -> u64 {
        self.regs[r.0 as usize]
    }

    #[inline]
    fn set(&mut self, r: Reg, v: u64) {
        self.regs[r.0 as usize] = v;
    }

    #[inline]
    fn src(&self, s: Src) -> u64 {
        match s {
            Src::R(r) => self.get(r),
            Src::I(v) => v,
        }
    }
}

/// Result of one scheduling step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Work-group blocked until this cycle; reschedule it there.
    Continue(Cycle),
    /// Work-group executed `Halt`.
    Halted,
}

/// Execute up to one blocking instruction (plus up to [`QUANTUM_INSTS`]
/// non-blocking ones before it) starting at `now`.
pub fn step(
    ctx: &mut WgContext,
    prog: &Program,
    mem: &mut MemSystem,
    protocol: Protocol,
    num_wgs: u32,
    engine_impl: &mut dyn ComputeEngine,
    now: Cycle,
) -> StepResult {
    let mut t = now;
    // Replay pending compute-op accesses first (a few per event).
    if !ctx.pending.is_empty() {
        for _ in 0..REPLAY_BATCH {
            let Some(acc) = ctx.pending.pop_front() else { break };
            t = mem.replay_access(ctx.cu, acc, t);
        }
        if ctx.pending.is_empty() {
            t += std::mem::take(&mut ctx.pending_tail);
        }
        return StepResult::Continue(t);
    }
    for _ in 0..QUANTUM_INSTS {
        assert!(
            (ctx.pc as usize) < prog.insts.len(),
            "KIR: pc {} out of bounds (wg {})",
            ctx.pc,
            ctx.wg_id
        );
        let inst = prog.insts[ctx.pc as usize];
        mem.stats.instructions += 1;
        match inst {
            Inst::Imm { dst, val } => {
                ctx.set(dst, val);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::Alu { op, dst, a, b } => {
                let v = op.apply(ctx.get(a), ctx.src(b));
                ctx.set(dst, v);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::WgId { dst } => {
                ctx.set(dst, ctx.wg_id as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::NumWgs { dst } => {
                ctx.set(dst, num_wgs as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::CuId { dst } => {
                ctx.set(dst, ctx.cu as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::Stat { counter } => {
                use super::inst::StatCounter::*;
                match counter {
                    TaskExecuted => mem.stats.tasks_executed += 1,
                    StealAttempt => mem.stats.steal_attempts += 1,
                    StealSuccess => mem.stats.tasks_stolen += 1,
                    StealFail => mem.stats.steal_failures += 1,
                }
                ctx.pc += 1;
            }
            Inst::Br { target } => {
                ctx.pc = target;
                t += mem.cfg.issue_cycles;
            }
            Inst::Bnz { cond, target } => {
                ctx.pc = if ctx.get(cond) != 0 { target } else { ctx.pc + 1 };
                t += mem.cfg.issue_cycles;
            }
            Inst::Bz { cond, target } => {
                ctx.pc = if ctx.get(cond) == 0 { target } else { ctx.pc + 1 };
                t += mem.cfg.issue_cycles;
            }
            Inst::Halt => {
                ctx.halted = true;
                return StepResult::Halted;
            }
            Inst::Ld { dst, base, off, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off as i64);
                let (v, done) = mem.l1_read(ctx.cu, addr, size as usize, t);
                ctx.set(dst, v);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            Inst::St { base, off, src, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off as i64);
                let done = mem.l1_write(ctx.cu, addr, size as usize, ctx.get(src), t);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            Inst::Atomic {
                dst,
                op,
                addr,
                operand,
                cmp,
                order,
                scope,
                remote,
            } => {
                let a = ctx.get(addr);
                let operand = ctx.src(operand) as u32;
                let cmp = ctx.src(cmp) as u32;
                let out = if remote {
                    engine::remote_op(mem, protocol, ctx.cu, a, op, order, operand, cmp, t)
                } else {
                    engine::sync_op(mem, protocol, ctx.cu, a, op, order, scope, operand, cmp, t)
                };
                ctx.set(dst, out.value as u64);
                ctx.pc += 1;
                return StepResult::Continue(out.done);
            }
            Inst::Compute { kind, arg } => {
                mem.stats.compute_ops += 1;
                let arg = ctx.get(arg);
                let mut access = MemAccess::new(mem, ctx.cu);
                let items = engine_impl.compute(&mut access, kind, arg);
                let steps = std::mem::take(&mut access.steps);
                mem.stats.compute_items += items;
                ctx.pending = steps.into();
                ctx.pending_tail = items * mem.cfg.compute_cycles_per_item;
                ctx.pc += 1;
                if ctx.pending.is_empty() {
                    return StepResult::Continue(t + std::mem::take(&mut ctx.pending_tail));
                }
                // Replay begins on the next event.
                return StepResult::Continue(t);
            }
        }
    }
    // Quantum expired without a blocking op: yield, stay runnable.
    StepResult::Continue(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::kir::asm::Asm;
    use crate::sync::AtomicOp;

    fn run_to_halt(prog: &Program, mem: &mut MemSystem) -> (WgContext, Cycle) {
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let mut t = 0;
        loop {
            match step(&mut ctx, prog, mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(next) => t = next.max(t + 1),
                StepResult::Halted => return (ctx, t),
            }
        }
    }

    #[test]
    fn loop_sums_to_ten() {
        let mut a = Asm::new();
        let acc = a.reg();
        let i = a.reg();
        let c = a.reg();
        let out = a.reg();
        a.imm(acc, 0);
        a.imm(i, 0);
        a.label("loop");
        a.add(acc, acc, Src::R(i));
        a.add(i, i, Src::I(1));
        a.lt_u(c, i, Src::I(5));
        a.bnz(c, "loop");
        a.imm(out, 0x100);
        a.st(out, 0, acc, 4);
        a.halt();
        let p = a.finish();

        let mut mem = MemSystem::new(DeviceConfig::small());
        let (_ctx, t) = run_to_halt(&p, &mut mem);
        let (v, _) = mem.l1_read(0, 0x100, 4, t);
        assert_eq!(v, 0 + 1 + 2 + 3 + 4);
    }

    #[test]
    fn load_store_round_trip_and_intrinsics() {
        let mut a = Asm::new();
        let base = a.reg();
        let v = a.reg();
        let wg = a.reg();
        a.imm(base, 0x200);
        a.wg_id(wg);
        a.num_wgs(v);
        a.st(base, 0, v, 4);
        a.st(base, 8, wg, 4);
        a.ld(v, base, 0, 4);
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(3, 1);
        let mut eng = NoopEngine;
        let mut t = 0;
        loop {
            match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 8, &mut eng, t) {
                StepResult::Continue(n) => t = n.max(t + 1),
                StepResult::Halted => break,
            }
        }
        let (nw, _) = mem.l1_read(1, 0x200, 4, t);
        let (wgid, _) = mem.l1_read(1, 0x208, 4, t);
        assert_eq!(nw, 8);
        assert_eq!(wgid, 3);
    }

    #[test]
    fn atomic_cas_spinlock_smoke() {
        // acquire(CAS 0->1 wg scope), increment counter, release(store 0).
        let mut a = Asm::new();
        let lock = a.reg();
        let ctr = a.reg();
        let old = a.reg();
        let tmp = a.reg();
        a.imm(lock, 0x300);
        a.imm(ctr, 0x340);
        a.label("spin");
        a.atomic(
            old,
            AtomicOp::Cas,
            lock,
            Src::I(1),
            Src::I(0),
            MemOrder::Acquire,
            Scope::Wg,
        );
        a.bnz(old, "spin");
        a.ld(tmp, ctr, 0, 4);
        a.add(tmp, tmp, Src::I(1));
        a.st(ctr, 0, tmp, 4);
        a.atomic(
            old,
            AtomicOp::Store,
            lock,
            Src::I(0),
            Src::I(0),
            MemOrder::Release,
            Scope::Wg,
        );
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let (_ctx, t) = run_to_halt(&p, &mut mem);
        let (v, _) = mem.l1_read(0, 0x340, 4, t);
        assert_eq!(v, 1);
        // sRSP: the wg-scope release recorded an LR-TBL entry.
        assert_eq!(mem.cu(0).lr_tbl.len(), 1);
    }

    #[test]
    fn quantum_bounds_alu_only_loops() {
        // Infinite ALU loop: step() must return after QUANTUM_INSTS.
        let mut a = Asm::new();
        let r = a.reg();
        a.label("forever");
        a.add(r, r, Src::I(1));
        a.br("forever");
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, 0) {
            StepResult::Continue(t) => assert!(t >= QUANTUM_INSTS as u64 / 2),
            StepResult::Halted => panic!("must not halt"),
        }
    }

    #[test]
    fn compute_engine_invoked_with_timing() {
        struct CountingEngine {
            calls: u32,
        }
        impl ComputeEngine for CountingEngine {
            fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64 {
                assert_eq!(kind, 7);
                assert_eq!(arg, 42);
                self.calls += 1;
                mem.write_u32(0x400, 11);
                5 // items
            }
        }
        let mut a = Asm::new();
        let r = a.reg();
        a.imm(r, 42);
        a.compute(7, r);
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = CountingEngine { calls: 0 };
        let mut t = 0;
        loop {
            match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(n) => t = n.max(t + 1),
                StepResult::Halted => break,
            }
        }
        assert_eq!(eng.calls, 1);
        assert_eq!(mem.stats.compute_items, 5);
        let (v, _) = mem.l1_read(0, 0x400, 4, t);
        assert_eq!(v, 11);
    }

    #[test]
    #[should_panic(expected = "pc")]
    fn running_off_the_end_traps() {
        let p = Program {
            insts: vec![Inst::Imm {
                dst: Reg(0),
                val: 1,
            }],
            labels: vec![],
        };
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let _ = step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, 0);
    }
}
