//! KIR interpreter: executes one work-group's program against the
//! simulated memory system.
//!
//! ALU/branch instructions retire back-to-back (charged `issue_cycles`
//! each) up to a quantum; memory, atomic and compute instructions block
//! the work-group until their computed completion cycle — the event loop
//! in [`crate::gpu::device`] then reschedules it.
//!
//! Two interpreters share the same semantics:
//!
//! * [`step`] — the original instruction-by-instruction reference path,
//!   kept frozen so every optimization has an in-tree semantic oracle
//!   (selected by [`crate::sim::perfstats::set_reference_paths`]).
//! * [`step_decoded`] — the hot path over a [`DecodedProgram`]: operands
//!   pre-resolved at decode time (register/immediate ALU split, load
//!   offsets pre-widened), the per-instruction `issue_cycles` config
//!   lookup hoisted out of the dispatch loop, and the instruction
//!   counter batched per quantum instead of bumped per instruction.
//!
//! Both run the planned-access replay through one shared buffer that is
//! recycled across `Compute` events instead of allocated per event.

use super::inst::{AluOp, Inst, Program, Reg, Src, StatCounter, NUM_REGS};
use crate::config::Protocol;
use crate::mem::hierarchy::PlannedAccess;
use crate::mem::{Addr, MemSystem};
use crate::sim::Cycle;
use crate::sync::{engine, AtomicOp, MemOrder, Scope};

/// Max consecutive non-memory instructions executed per event — bounds
/// event-loop starvation from compute-only loops.
pub const QUANTUM_INSTS: usize = 256;

/// Planning memory interface handed to compute engines: functional
/// effects (values, cache state, stats) happen immediately; each access's
/// timing class is recorded and replayed a few per event by the
/// interpreter, so shared-resource contention resolves in global time
/// order (see `MemSystem`'s planned-access section).
pub struct MemAccess<'a> {
    pub mem: &'a mut MemSystem,
    pub cu: u32,
    /// Recorded timing classes, replayed after the engine returns.
    pub steps: Vec<PlannedAccess>,
}

impl<'a> MemAccess<'a> {
    pub fn new(mem: &'a mut MemSystem, cu: u32) -> Self {
        Self::with_buffer(mem, cu, Vec::with_capacity(64))
    }

    /// Record into a caller-provided buffer (cleared here), so the
    /// interpreter can recycle one allocation across compute events.
    pub fn with_buffer(mem: &'a mut MemSystem, cu: u32, mut steps: Vec<PlannedAccess>) -> Self {
        steps.clear();
        Self { mem, cu, steps }
    }

    pub fn read_u32(&mut self, addr: Addr) -> u32 {
        let (v, p) = self.mem.plan_read(self.cu, addr, 4);
        self.steps.push(p);
        v as u32
    }

    pub fn read_f32(&mut self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_u32(&mut self, addr: Addr, v: u32) {
        let p = self.mem.plan_write(self.cu, addr, 4, v as u64);
        self.steps.push(p);
    }

    pub fn write_f32(&mut self, addr: Addr, v: f32) {
        self.write_u32(addr, v.to_bits());
    }

    pub fn read_u64(&mut self, addr: Addr) -> u64 {
        let (v, p) = self.mem.plan_read(self.cu, addr, 8);
        self.steps.push(p);
        v
    }
}

/// Engine for `Compute` instructions. Returns the number of work-items
/// processed (charged `compute_cycles_per_item` each on top of the memory
/// time accumulated in `MemAccess.now`).
pub trait ComputeEngine {
    fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64;
}

/// Engine that does nothing (for pure-synchronization microbenchmarks).
#[derive(Debug, Default)]
pub struct NoopEngine;

impl ComputeEngine for NoopEngine {
    fn compute(&mut self, _mem: &mut MemAccess<'_>, _kind: u32, _arg: u64) -> u64 {
        0
    }
}

/// Accesses replayed per scheduling event: bounds the time skew of the
/// eager functional execution while keeping event-queue overhead low.
pub const REPLAY_BATCH: usize = 8;

/// Per-work-group execution context.
#[derive(Debug, Clone)]
pub struct WgContext {
    pub wg_id: u32,
    pub cu: u32,
    pub pc: u32,
    pub regs: [u64; NUM_REGS],
    pub halted: bool,
    /// Planned compute-op accesses awaiting timed replay. The buffer is
    /// recycled across compute events (`pending_head` walks it instead of
    /// popping), so steady-state execution allocates nothing per event.
    pending: Vec<PlannedAccess>,
    /// Replay cursor into `pending`.
    pending_head: usize,
    /// Compute cycles charged after the last pending access.
    pending_tail: Cycle,
}

impl WgContext {
    pub fn new(wg_id: u32, cu: u32) -> Self {
        Self {
            wg_id,
            cu,
            pc: 0,
            regs: [0; NUM_REGS],
            halted: false,
            pending: Vec::new(),
            pending_head: 0,
            pending_tail: 0,
        }
    }

    #[inline]
    fn get(&self, r: Reg) -> u64 {
        self.regs[r.0 as usize]
    }

    #[inline]
    fn set(&mut self, r: Reg, v: u64) {
        self.regs[r.0 as usize] = v;
    }

    #[inline]
    fn src(&self, s: Src) -> u64 {
        match s {
            Src::R(r) => self.get(r),
            Src::I(v) => v,
        }
    }
}

/// Result of one scheduling step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepResult {
    /// Work-group blocked until this cycle; reschedule it there.
    Continue(Cycle),
    /// Work-group executed `Halt`.
    Halted,
}

/// Replay up to [`REPLAY_BATCH`] pending compute-op accesses. On drain,
/// the compute-cycle tail is charged and the buffer is reset for reuse
/// (capacity retained). Shared by both interpreter paths.
#[inline]
fn replay_pending(ctx: &mut WgContext, mem: &mut MemSystem, mut t: Cycle) -> Cycle {
    let end = (ctx.pending_head + REPLAY_BATCH).min(ctx.pending.len());
    while ctx.pending_head < end {
        let acc = ctx.pending[ctx.pending_head];
        ctx.pending_head += 1;
        t = mem.replay_access(ctx.cu, acc, t);
    }
    if ctx.pending_head == ctx.pending.len() {
        ctx.pending.clear();
        ctx.pending_head = 0;
        t += std::mem::take(&mut ctx.pending_tail);
    }
    t
}

/// Hand the recycled pending buffer to the engine, run it, and take the
/// recorded plan back. Shared by both interpreter paths.
#[inline]
fn run_compute(
    ctx: &mut WgContext,
    mem: &mut MemSystem,
    engine_impl: &mut dyn ComputeEngine,
    kind: u32,
    arg: u64,
) -> u64 {
    debug_assert!(ctx.pending.is_empty(), "compute with a plan still pending");
    ctx.pending_head = 0;
    let buf = std::mem::take(&mut ctx.pending);
    let mut access = MemAccess::with_buffer(mem, ctx.cu, buf);
    let items = engine_impl.compute(&mut access, kind, arg);
    ctx.pending = access.steps;
    ctx.pending_tail = items * mem.cfg.compute_cycles_per_item;
    items
}

/// Execute up to one blocking instruction (plus up to [`QUANTUM_INSTS`]
/// non-blocking ones before it) starting at `now`.
///
/// This is the frozen reference path; [`step_decoded`] is the hot path.
pub fn step(
    ctx: &mut WgContext,
    prog: &Program,
    mem: &mut MemSystem,
    protocol: Protocol,
    num_wgs: u32,
    engine_impl: &mut dyn ComputeEngine,
    now: Cycle,
) -> StepResult {
    let mut t = now;
    // Replay pending compute-op accesses first (a few per event).
    if !ctx.pending.is_empty() {
        return StepResult::Continue(replay_pending(ctx, mem, t));
    }
    for _ in 0..QUANTUM_INSTS {
        assert!(
            (ctx.pc as usize) < prog.insts.len(),
            "KIR: pc {} out of bounds (wg {})",
            ctx.pc,
            ctx.wg_id
        );
        let inst = prog.insts[ctx.pc as usize];
        mem.stats.instructions += 1;
        match inst {
            Inst::Imm { dst, val } => {
                ctx.set(dst, val);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::Alu { op, dst, a, b } => {
                let v = op.apply(ctx.get(a), ctx.src(b));
                ctx.set(dst, v);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::WgId { dst } => {
                ctx.set(dst, ctx.wg_id as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::NumWgs { dst } => {
                ctx.set(dst, num_wgs as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::CuId { dst } => {
                ctx.set(dst, ctx.cu as u64);
                ctx.pc += 1;
                t += mem.cfg.issue_cycles;
            }
            Inst::Stat { counter } => {
                use super::inst::StatCounter::*;
                match counter {
                    TaskExecuted => mem.stats.tasks_executed += 1,
                    StealAttempt => mem.stats.steal_attempts += 1,
                    StealSuccess => mem.stats.tasks_stolen += 1,
                    StealFail => mem.stats.steal_failures += 1,
                }
                ctx.pc += 1;
            }
            Inst::Br { target } => {
                ctx.pc = target;
                t += mem.cfg.issue_cycles;
            }
            Inst::Bnz { cond, target } => {
                ctx.pc = if ctx.get(cond) != 0 { target } else { ctx.pc + 1 };
                t += mem.cfg.issue_cycles;
            }
            Inst::Bz { cond, target } => {
                ctx.pc = if ctx.get(cond) == 0 { target } else { ctx.pc + 1 };
                t += mem.cfg.issue_cycles;
            }
            Inst::Halt => {
                ctx.halted = true;
                return StepResult::Halted;
            }
            Inst::Ld { dst, base, off, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off as i64);
                let (v, done) = mem.l1_read(ctx.cu, addr, size as usize, t);
                ctx.set(dst, v);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            Inst::St { base, off, src, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off as i64);
                let done = mem.l1_write(ctx.cu, addr, size as usize, ctx.get(src), t);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            Inst::Atomic {
                dst,
                op,
                addr,
                operand,
                cmp,
                order,
                scope,
                remote,
            } => {
                let a = ctx.get(addr);
                let operand = ctx.src(operand) as u32;
                let cmp = ctx.src(cmp) as u32;
                let out = if remote {
                    engine::remote_op(mem, protocol, ctx.cu, a, op, order, operand, cmp, t)
                } else {
                    engine::sync_op(mem, protocol, ctx.cu, a, op, order, scope, operand, cmp, t)
                };
                ctx.set(dst, out.value as u64);
                ctx.pc += 1;
                return StepResult::Continue(out.done);
            }
            Inst::Compute { kind, arg } => {
                let arg = ctx.get(arg);
                let items = run_compute(ctx, mem, engine_impl, kind, arg);
                mem.stats.record_compute(items);
                ctx.pc += 1;
                if ctx.pending.is_empty() {
                    return StepResult::Continue(t + std::mem::take(&mut ctx.pending_tail));
                }
                // Replay begins on the next event.
                return StepResult::Continue(t);
            }
        }
    }
    // Quantum expired without a blocking op: yield, stay runnable.
    StepResult::Continue(t)
}

/// One pre-decoded instruction: operand shapes resolved once at decode
/// time so the dispatch loop does no `Src` matching and no offset
/// widening per execution.
#[derive(Debug, Clone, Copy)]
enum DInst {
    Imm { dst: Reg, val: u64 },
    /// ALU with a register right-hand operand.
    AluRR { op: AluOp, dst: Reg, a: Reg, b: Reg },
    /// ALU with an immediate right-hand operand (pre-extracted).
    AluRI { op: AluOp, dst: Reg, a: Reg, b: u64 },
    /// Load with the offset pre-widened to the add width.
    Ld { dst: Reg, base: Reg, off: i64, size: u8 },
    St { base: Reg, off: i64, src: Reg, size: u8 },
    Atomic {
        dst: Reg,
        op: AtomicOp,
        addr: Reg,
        operand: Src,
        cmp: Src,
        order: MemOrder,
        scope: Scope,
        remote: bool,
    },
    Br { target: u32 },
    Bnz { cond: Reg, target: u32 },
    Bz { cond: Reg, target: u32 },
    Compute { kind: u32, arg: Reg },
    WgId { dst: Reg },
    NumWgs { dst: Reg },
    CuId { dst: Reg },
    Stat { counter: StatCounter },
    Halt,
}

/// A [`Program`] decoded once per launch for the hot interpreter path.
/// Decoding is a pure representation change — [`step_decoded`] over the
/// decoded form and [`step`] over the source form are observationally
/// identical, including trap behaviour (out-of-range branch targets trap
/// at execution time with the same `pc` assertion, not at decode time).
#[derive(Debug, Clone)]
pub struct DecodedProgram {
    insts: Vec<DInst>,
}

impl DecodedProgram {
    pub fn decode(p: &Program) -> Self {
        // Exhaustive match (no wildcard): a new Inst variant cannot ship
        // without deciding its decoded form — the drift guard that keeps
        // the two interpreters in lockstep.
        let insts = p
            .insts
            .iter()
            .map(|inst| match *inst {
                Inst::Imm { dst, val } => DInst::Imm { dst, val },
                Inst::Alu { op, dst, a, b } => match b {
                    Src::R(r) => DInst::AluRR { op, dst, a, b: r },
                    Src::I(v) => DInst::AluRI { op, dst, a, b: v },
                },
                Inst::Ld { dst, base, off, size } => DInst::Ld {
                    dst,
                    base,
                    off: off as i64,
                    size,
                },
                Inst::St { base, off, src, size } => DInst::St {
                    base,
                    off: off as i64,
                    src,
                    size,
                },
                Inst::Atomic {
                    dst,
                    op,
                    addr,
                    operand,
                    cmp,
                    order,
                    scope,
                    remote,
                } => DInst::Atomic {
                    dst,
                    op,
                    addr,
                    operand,
                    cmp,
                    order,
                    scope,
                    remote,
                },
                Inst::Br { target } => DInst::Br { target },
                Inst::Bnz { cond, target } => DInst::Bnz { cond, target },
                Inst::Bz { cond, target } => DInst::Bz { cond, target },
                Inst::Compute { kind, arg } => DInst::Compute { kind, arg },
                Inst::WgId { dst } => DInst::WgId { dst },
                Inst::NumWgs { dst } => DInst::NumWgs { dst },
                Inst::CuId { dst } => DInst::CuId { dst },
                Inst::Stat { counter } => DInst::Stat { counter },
                Inst::Halt => DInst::Halt,
            })
            .collect();
        Self { insts }
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }
}

/// The hot-path twin of [`step`], over a [`DecodedProgram`]. Same
/// semantics instruction for instruction; the speed comes from decode-once
/// operands, the hoisted `issue_cycles` lookup, and batching the retired-
/// instruction counter per quantum (flushed on every exit path, so the
/// final `instructions` total is identical to the reference).
pub fn step_decoded(
    ctx: &mut WgContext,
    prog: &DecodedProgram,
    mem: &mut MemSystem,
    protocol: Protocol,
    num_wgs: u32,
    engine_impl: &mut dyn ComputeEngine,
    now: Cycle,
) -> StepResult {
    let mut t = now;
    if !ctx.pending.is_empty() {
        return StepResult::Continue(replay_pending(ctx, mem, t));
    }
    let issue = mem.cfg.issue_cycles;
    let mut executed: u64 = 0;
    for _ in 0..QUANTUM_INSTS {
        assert!(
            (ctx.pc as usize) < prog.insts.len(),
            "KIR: pc {} out of bounds (wg {})",
            ctx.pc,
            ctx.wg_id
        );
        let inst = prog.insts[ctx.pc as usize];
        executed += 1;
        match inst {
            DInst::Imm { dst, val } => {
                ctx.set(dst, val);
                ctx.pc += 1;
                t += issue;
            }
            DInst::AluRR { op, dst, a, b } => {
                let v = op.apply(ctx.get(a), ctx.get(b));
                ctx.set(dst, v);
                ctx.pc += 1;
                t += issue;
            }
            DInst::AluRI { op, dst, a, b } => {
                let v = op.apply(ctx.get(a), b);
                ctx.set(dst, v);
                ctx.pc += 1;
                t += issue;
            }
            DInst::WgId { dst } => {
                ctx.set(dst, ctx.wg_id as u64);
                ctx.pc += 1;
                t += issue;
            }
            DInst::NumWgs { dst } => {
                ctx.set(dst, num_wgs as u64);
                ctx.pc += 1;
                t += issue;
            }
            DInst::CuId { dst } => {
                ctx.set(dst, ctx.cu as u64);
                ctx.pc += 1;
                t += issue;
            }
            DInst::Stat { counter } => {
                // Hardware event counters are free: no issue cycles.
                match counter {
                    StatCounter::TaskExecuted => mem.stats.tasks_executed += 1,
                    StatCounter::StealAttempt => mem.stats.steal_attempts += 1,
                    StatCounter::StealSuccess => mem.stats.tasks_stolen += 1,
                    StatCounter::StealFail => mem.stats.steal_failures += 1,
                }
                ctx.pc += 1;
            }
            DInst::Br { target } => {
                ctx.pc = target;
                t += issue;
            }
            DInst::Bnz { cond, target } => {
                ctx.pc = if ctx.get(cond) != 0 { target } else { ctx.pc + 1 };
                t += issue;
            }
            DInst::Bz { cond, target } => {
                ctx.pc = if ctx.get(cond) == 0 { target } else { ctx.pc + 1 };
                t += issue;
            }
            DInst::Halt => {
                ctx.halted = true;
                mem.stats.instructions += executed;
                return StepResult::Halted;
            }
            DInst::Ld { dst, base, off, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off);
                mem.stats.instructions += executed;
                let (v, done) = mem.l1_read(ctx.cu, addr, size as usize, t);
                ctx.set(dst, v);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            DInst::St { base, off, src, size } => {
                let addr = ctx.get(base).wrapping_add_signed(off);
                mem.stats.instructions += executed;
                let done = mem.l1_write(ctx.cu, addr, size as usize, ctx.get(src), t);
                ctx.pc += 1;
                return StepResult::Continue(done);
            }
            DInst::Atomic {
                dst,
                op,
                addr,
                operand,
                cmp,
                order,
                scope,
                remote,
            } => {
                let a = ctx.get(addr);
                let operand = ctx.src(operand) as u32;
                let cmp = ctx.src(cmp) as u32;
                mem.stats.instructions += executed;
                let out = if remote {
                    engine::remote_op(mem, protocol, ctx.cu, a, op, order, operand, cmp, t)
                } else {
                    engine::sync_op(mem, protocol, ctx.cu, a, op, order, scope, operand, cmp, t)
                };
                ctx.set(dst, out.value as u64);
                ctx.pc += 1;
                return StepResult::Continue(out.done);
            }
            DInst::Compute { kind, arg } => {
                mem.stats.instructions += executed;
                let arg = ctx.get(arg);
                let items = run_compute(ctx, mem, engine_impl, kind, arg);
                mem.stats.record_compute(items);
                ctx.pc += 1;
                if ctx.pending.is_empty() {
                    return StepResult::Continue(t + std::mem::take(&mut ctx.pending_tail));
                }
                return StepResult::Continue(t);
            }
        }
    }
    mem.stats.instructions += executed;
    StepResult::Continue(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::kir::asm::Asm;
    use crate::sync::AtomicOp;

    fn run_to_halt(prog: &Program, mem: &mut MemSystem) -> (WgContext, Cycle) {
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let mut t = 0;
        loop {
            match step(&mut ctx, prog, mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(next) => t = next.max(t + 1),
                StepResult::Halted => return (ctx, t),
            }
        }
    }

    fn run_to_halt_decoded(prog: &Program, mem: &mut MemSystem) -> (WgContext, Cycle) {
        let d = DecodedProgram::decode(prog);
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let mut t = 0;
        loop {
            match step_decoded(&mut ctx, &d, mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(next) => t = next.max(t + 1),
                StepResult::Halted => return (ctx, t),
            }
        }
    }

    #[test]
    fn loop_sums_to_ten() {
        let mut a = Asm::new();
        let acc = a.reg();
        let i = a.reg();
        let c = a.reg();
        let out = a.reg();
        a.imm(acc, 0);
        a.imm(i, 0);
        a.label("loop");
        a.add(acc, acc, Src::R(i));
        a.add(i, i, Src::I(1));
        a.lt_u(c, i, Src::I(5));
        a.bnz(c, "loop");
        a.imm(out, 0x100);
        a.st(out, 0, acc, 4);
        a.halt();
        let p = a.finish();

        let mut mem = MemSystem::new(DeviceConfig::small());
        let (_ctx, t) = run_to_halt(&p, &mut mem);
        let (v, _) = mem.l1_read(0, 0x100, 4, t);
        assert_eq!(v, 0 + 1 + 2 + 3 + 4);
    }

    #[test]
    fn load_store_round_trip_and_intrinsics() {
        let mut a = Asm::new();
        let base = a.reg();
        let v = a.reg();
        let wg = a.reg();
        a.imm(base, 0x200);
        a.wg_id(wg);
        a.num_wgs(v);
        a.st(base, 0, v, 4);
        a.st(base, 8, wg, 4);
        a.ld(v, base, 0, 4);
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(3, 1);
        let mut eng = NoopEngine;
        let mut t = 0;
        loop {
            match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 8, &mut eng, t) {
                StepResult::Continue(n) => t = n.max(t + 1),
                StepResult::Halted => break,
            }
        }
        let (nw, _) = mem.l1_read(1, 0x200, 4, t);
        let (wgid, _) = mem.l1_read(1, 0x208, 4, t);
        assert_eq!(nw, 8);
        assert_eq!(wgid, 3);
    }

    #[test]
    fn atomic_cas_spinlock_smoke() {
        // acquire(CAS 0->1 wg scope), increment counter, release(store 0).
        let mut a = Asm::new();
        let lock = a.reg();
        let ctr = a.reg();
        let old = a.reg();
        let tmp = a.reg();
        a.imm(lock, 0x300);
        a.imm(ctr, 0x340);
        a.label("spin");
        a.atomic(
            old,
            AtomicOp::Cas,
            lock,
            Src::I(1),
            Src::I(0),
            MemOrder::Acquire,
            Scope::Wg,
        );
        a.bnz(old, "spin");
        a.ld(tmp, ctr, 0, 4);
        a.add(tmp, tmp, Src::I(1));
        a.st(ctr, 0, tmp, 4);
        a.atomic(
            old,
            AtomicOp::Store,
            lock,
            Src::I(0),
            Src::I(0),
            MemOrder::Release,
            Scope::Wg,
        );
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let (_ctx, t) = run_to_halt(&p, &mut mem);
        let (v, _) = mem.l1_read(0, 0x340, 4, t);
        assert_eq!(v, 1);
        // sRSP: the wg-scope release recorded an LR-TBL entry.
        assert_eq!(mem.cu(0).lr_tbl.len(), 1);
    }

    #[test]
    fn quantum_bounds_alu_only_loops() {
        // Infinite ALU loop: step() must return after QUANTUM_INSTS.
        let mut a = Asm::new();
        let r = a.reg();
        a.label("forever");
        a.add(r, r, Src::I(1));
        a.br("forever");
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, 0) {
            StepResult::Continue(t) => assert!(t >= QUANTUM_INSTS as u64 / 2),
            StepResult::Halted => panic!("must not halt"),
        }
    }

    #[test]
    fn compute_engine_invoked_with_timing() {
        struct CountingEngine {
            calls: u32,
        }
        impl ComputeEngine for CountingEngine {
            fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64 {
                assert_eq!(kind, 7);
                assert_eq!(arg, 42);
                self.calls += 1;
                mem.write_u32(0x400, 11);
                5 // items
            }
        }
        let mut a = Asm::new();
        let r = a.reg();
        a.imm(r, 42);
        a.compute(7, r);
        a.halt();
        let p = a.finish();
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = CountingEngine { calls: 0 };
        let mut t = 0;
        loop {
            match step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(n) => t = n.max(t + 1),
                StepResult::Halted => break,
            }
        }
        assert_eq!(eng.calls, 1);
        assert_eq!(mem.stats.compute_items, 5);
        let (v, _) = mem.l1_read(0, 0x400, 4, t);
        assert_eq!(v, 11);
    }

    #[test]
    #[should_panic(expected = "pc")]
    fn running_off_the_end_traps() {
        let p = Program {
            insts: vec![Inst::Imm {
                dst: Reg(0),
                val: 1,
            }],
            labels: vec![],
        };
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let _ = step(&mut ctx, &p, &mut mem, Protocol::SRSP, 1, &mut eng, 0);
    }

    #[test]
    #[should_panic(expected = "pc")]
    fn decoded_running_off_the_end_traps() {
        let p = Program {
            insts: vec![Inst::Imm {
                dst: Reg(0),
                val: 1,
            }],
            labels: vec![],
        };
        let d = DecodedProgram::decode(&p);
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = NoopEngine;
        let _ = step_decoded(&mut ctx, &d, &mut mem, Protocol::SRSP, 1, &mut eng, 0);
    }

    /// The equivalence oracle in miniature: every test program must leave
    /// identical timing, stats and memory under both interpreter paths.
    #[test]
    fn decoded_matches_reference() {
        let programs: Vec<Program> = vec![
            {
                // ALU/branch loop + store (covers AluRR/AluRI split).
                let mut a = Asm::new();
                let acc = a.reg();
                let i = a.reg();
                let c = a.reg();
                let out = a.reg();
                a.imm(acc, 0);
                a.imm(i, 0);
                a.label("loop");
                a.add(acc, acc, Src::R(i));
                a.add(i, i, Src::I(3));
                a.lt_u(c, i, Src::I(30));
                a.bnz(c, "loop");
                a.imm(out, 0x100);
                a.st(out, 0, acc, 4);
                a.ld(acc, out, 0, 4);
                a.halt();
                a.finish()
            },
            {
                // Atomic CAS lock + intrinsics (covers Atomic/WgId paths).
                let mut a = Asm::new();
                let lock = a.reg();
                let ctr = a.reg();
                let old = a.reg();
                let tmp = a.reg();
                a.imm(lock, 0x300);
                a.imm(ctr, 0x340);
                a.label("spin");
                a.atomic(
                    old,
                    AtomicOp::Cas,
                    lock,
                    Src::I(1),
                    Src::I(0),
                    MemOrder::Acquire,
                    Scope::Wg,
                );
                a.bnz(old, "spin");
                a.ld(tmp, ctr, 0, 4);
                a.add(tmp, tmp, Src::I(1));
                a.st(ctr, 0, tmp, 4);
                a.atomic(
                    old,
                    AtomicOp::Store,
                    lock,
                    Src::I(0),
                    Src::I(0),
                    MemOrder::Release,
                    Scope::Wg,
                );
                a.halt();
                a.finish()
            },
        ];
        for p in &programs {
            let mut ref_mem = MemSystem::new(DeviceConfig::small());
            let (ref_ctx, ref_t) = run_to_halt(p, &mut ref_mem);
            let mut fast_mem = MemSystem::new(DeviceConfig::small());
            let (fast_ctx, fast_t) = run_to_halt_decoded(p, &mut fast_mem);
            assert_eq!(ref_t, fast_t, "completion cycle must match");
            assert_eq!(ref_ctx.pc, fast_ctx.pc);
            assert_eq!(ref_ctx.regs, fast_ctx.regs);
            assert_eq!(ref_mem.stats.instructions, fast_mem.stats.instructions);
            assert_eq!(ref_mem.stats.l1_hits, fast_mem.stats.l1_hits);
            assert_eq!(ref_mem.stats.l1_misses, fast_mem.stats.l1_misses);
            assert_eq!(
                ref_mem.stats.sync_overhead_cycles,
                fast_mem.stats.sync_overhead_cycles
            );
        }
    }

    /// The planned-access buffer must be recycled across compute events:
    /// after the first plan drains, the second compute records into the
    /// same allocation (no per-event Vec).
    #[test]
    fn compute_buffer_recycled_across_events() {
        struct BurstEngine;
        impl ComputeEngine for BurstEngine {
            fn compute(&mut self, mem: &mut MemAccess<'_>, _kind: u32, arg: u64) -> u64 {
                for k in 0..12u64 {
                    mem.write_u32(0x800 + arg * 0x100 + k * 4, k as u32);
                }
                12
            }
        }
        let mut a = Asm::new();
        let r = a.reg();
        a.imm(r, 0);
        a.compute(1, r);
        a.imm(r, 1);
        a.compute(1, r);
        a.halt();
        let p = a.finish();
        let d = DecodedProgram::decode(&p);
        let mut mem = MemSystem::new(DeviceConfig::small());
        let mut ctx = WgContext::new(0, 0);
        let mut eng = BurstEngine;
        let mut t = 0;
        let mut buf_ptr: Option<*const PlannedAccess> = None;
        loop {
            match step_decoded(&mut ctx, &d, &mut mem, Protocol::SRSP, 1, &mut eng, t) {
                StepResult::Continue(n) => t = n.max(t + 1),
                StepResult::Halted => break,
            }
            if !ctx.pending.is_empty() {
                match buf_ptr {
                    None => buf_ptr = Some(ctx.pending.as_ptr()),
                    Some(ptr) => assert_eq!(
                        ptr,
                        ctx.pending.as_ptr(),
                        "second compute must reuse the first plan's allocation"
                    ),
                }
            }
        }
        assert!(ctx.pending.is_empty());
        assert_eq!(ctx.pending_head, 0);
        assert!(ctx.pending.capacity() >= 12, "capacity retained for reuse");
        assert_eq!(mem.stats.compute_ops, 2);
        assert_eq!(mem.stats.compute_items, 24);
    }
}
