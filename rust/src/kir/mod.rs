//! KIR — the Kernel IR.
//!
//! The paper compiles OpenCL kernels to HSAIL and runs them on gem5's
//! timing model. KIR is this reproduction's analog: a small register
//! machine with ALU ops, branches, plain loads/stores and *scoped/remote
//! atomics*, interpreted against the simulated memory system. The
//! work-stealing deques and the graph kernels are written in KIR (via the
//! [`asm`] builder), so all their synchronization behaviour — including
//! stale reads from non-coherent L1s — is produced by real program
//! execution, not a canned trace.
//!
//! Floating-point vertex math is delegated to a [`ComputeEngine`]
//! (`Compute` instruction): the engine issues the gather/scatter memory
//! traffic through the timed [`MemAccess`] interface and performs the
//! batch numerics either natively or through the AOT-compiled XLA
//! artifact (see [`crate::runtime`]). One work-group is modeled as one
//! logical execution stream (the unit of the paper's deques).

pub mod asm;
pub mod inst;
pub mod interp;

pub use asm::Asm;
pub use inst::{AluOp, Inst, Program, Reg, Src};
pub use interp::{
    ComputeEngine, DecodedProgram, MemAccess, NoopEngine, StepResult, WgContext, QUANTUM_INSTS,
};
