//! The evaluation harness: workload presets matched to §5.1 and the
//! regeneration of Table 1 and Figures 4-6.

pub mod figures;
pub mod presets;
pub mod report;

pub use figures::{fig4_speedup, fig5_l2, fig6_overhead, scaling_sweep, FigureCell, FigureTable};
pub use presets::{WorkloadPreset, WorkloadSize};
pub use report::{format_table, geomean};
