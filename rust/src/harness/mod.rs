//! The evaluation harness: workload presets matched to §5.1, the
//! regeneration of Table 1 and Figures 4-6, the scenario-matrix
//! [`runner`] that executes pipeline shards (in-process threads or
//! `srsp worker` subprocesses), and the machine-readable JSON/CSV
//! [`report`] emission plus the distributed merge stage.

pub mod bench;
pub mod figures;
pub mod presets;
pub mod report;
pub mod runner;
pub mod tracefile;

pub use bench::{BenchOpts, BenchReport, CellBench, BENCH_SCHEMA};
pub use figures::{fig4_speedup, fig5_l2, fig6_overhead, scaling_sweep, FigureCell, FigureTable};
pub use presets::{WorkloadPreset, WorkloadSize, DEFAULT_SEED};
pub use report::{
    check_row_round_trip, format_table, geomean, PartialReport, Report, ReportFormat, ReportRow,
};
pub use runner::{
    execute_plan, execute_plan_cached, execute_shard, execute_shard_cached, into_run_results,
    run_validated, CellOutcome, CellResult, Runner,
};
pub use tracefile::{TraceCell, TracePartial, TraceReport};
// Grid construction and seeding policy live with the coordinator;
// re-exported so harness users keep one import root.
pub use crate::coordinator::{classic_grid, full_grid, Cell, Seeding};
