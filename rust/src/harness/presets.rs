//! Workload presets: app + input-class + chunking, at paper scale or at
//! test scale.
//!
//! The paper's inputs are DIMACS graphs; the presets use the matching
//! synthetic generator classes (DESIGN.md substitution table). Real
//! DIMACS/MatrixMarket files can be substituted through the CLI
//! (`--graph path.gr`).

use crate::mem::{BackingStore, MemAlloc};
use crate::workload::driver::{App, Workload};
use crate::workload::graph::Graph;
use crate::workload::mis::Mis;
use crate::workload::pagerank::PageRank;
use crate::workload::sssp::Sssp;

/// Scale of a preset run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadSize {
    /// Unit-test scale (seconds on 4 CUs).
    Tiny,
    /// Bench scale for the 64-CU figure runs.
    Paper,
}

/// The classic workload-generation seed used by every paper-figure
/// preset. Runs that do not ask for explicit seeding reproduce the
/// figures byte-for-byte with this value.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

/// A fully-specified workload instance.
pub struct WorkloadPreset {
    pub app: App,
    pub graph: Graph,
    pub chunk: u32,
    pub max_rounds: u32,
    /// PageRank iterations (ignored by SSSP/MIS, which run to
    /// convergence).
    pub iters: u32,
    /// Seed the input graph was generated from (recorded in reports).
    pub seed: u64,
}

impl WorkloadPreset {
    /// Build the preset for `app` at `size` (§5.1 input classes:
    /// PRK ← small-world, SSSP ← road grid, MIS ← power-law) with the
    /// classic figure seed.
    pub fn new(app: App, size: WorkloadSize) -> Self {
        Self::new_seeded(app, size, DEFAULT_SEED)
    }

    /// Build the preset for `app` at `size` with an explicit generator
    /// seed (the scenario-matrix runner derives one per grid cell).
    pub fn new_seeded(app: App, size: WorkloadSize, seed: u64) -> Self {
        match (app, size) {
            (App::PageRank, WorkloadSize::Paper) => WorkloadPreset {
                app,
                graph: Graph::small_world(4096, 8, 0.1, seed),
                chunk: 8,
                max_rounds: 16,
                iters: 6,
                seed,
            },
            (App::PageRank, WorkloadSize::Tiny) => WorkloadPreset {
                app,
                graph: Graph::small_world(256, 4, 0.1, seed),
                chunk: 8,
                max_rounds: 8,
                iters: 3,
                seed,
            },
            (App::Sssp, WorkloadSize::Paper) => WorkloadPreset {
                app,
                graph: Graph::road_grid(64, 64, seed),
                chunk: 8,
                max_rounds: 400,
                iters: 0,
                seed,
            },
            (App::Sssp, WorkloadSize::Tiny) => WorkloadPreset {
                app,
                graph: Graph::road_grid(16, 16, seed),
                chunk: 8,
                max_rounds: 200,
                iters: 0,
                seed,
            },
            (App::Mis, WorkloadSize::Paper) => WorkloadPreset {
                app,
                graph: Graph::power_law(4096, 3, seed),
                chunk: 8,
                max_rounds: 64,
                iters: 0,
                seed,
            },
            (App::Mis, WorkloadSize::Tiny) => WorkloadPreset {
                app,
                graph: Graph::power_law(256, 2, seed),
                chunk: 8,
                max_rounds: 32,
                iters: 0,
                seed,
            },
        }
    }

    /// Override the graph (e.g. a real DIMACS file).
    pub fn with_graph(mut self, g: Graph) -> Self {
        self.graph = g;
        self
    }

    /// Instantiate the workload: allocates and seeds device memory,
    /// returning the workload object and the initial memory image.
    pub fn instantiate(&self) -> (Box<dyn Workload>, BackingStore) {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl: Box<dyn Workload> = match self.app {
            App::PageRank => Box::new(PageRank::setup(
                &self.graph,
                &mut alloc,
                &mut image,
                self.chunk,
                self.iters,
            )),
            App::Sssp => Box::new(Sssp::setup(&self.graph, &mut alloc, &mut image, self.chunk, 0)),
            App::Mis => Box::new(Mis::setup(&self.graph, &mut alloc, &mut image, self.chunk)),
        };
        (wl, image)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_instantiate() {
        for app in App::ALL {
            for size in [WorkloadSize::Tiny, WorkloadSize::Paper] {
                let p = WorkloadPreset::new(app, size);
                p.graph.validate().unwrap();
                let (wl, _image) = p.instantiate();
                assert_eq!(wl.name(), app.name());
                assert!(!wl.kinds().is_empty());
            }
        }
    }

    #[test]
    fn seeded_presets_deterministic_and_seed_sensitive() {
        for app in App::ALL {
            let a = WorkloadPreset::new_seeded(app, WorkloadSize::Tiny, 1);
            let b = WorkloadPreset::new_seeded(app, WorkloadSize::Tiny, 1);
            let c = WorkloadPreset::new_seeded(app, WorkloadSize::Tiny, 2);
            a.graph.validate().unwrap();
            c.graph.validate().unwrap();
            assert_eq!(a.graph.col, b.graph.col, "same seed, same graph");
            assert_ne!(a.graph.col, c.graph.col, "different seed, different graph");
            let classic = WorkloadPreset::new(app, WorkloadSize::Tiny);
            assert_eq!(classic.seed, DEFAULT_SEED);
        }
    }

    #[test]
    fn paper_presets_bigger_than_tiny() {
        for app in App::ALL {
            let tiny = WorkloadPreset::new(app, WorkloadSize::Tiny);
            let paper = WorkloadPreset::new(app, WorkloadSize::Paper);
            assert!(paper.graph.n > tiny.graph.n);
        }
    }
}
