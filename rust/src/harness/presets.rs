//! Workload presets, resolved through the workload registry.
//!
//! Historically this module owned a hard-coded `App` enum and a match
//! over the three §5.1 apps; presets are now built by each registered
//! [`Kernel`](crate::workload::registry::Kernel) itself (input class,
//! default chunking, tunable parameters), so this module re-exports the
//! registry types under the harness paths the rest of the crate and the
//! downstream tools import.
//!
//! The paper's inputs are DIMACS graphs; the kernels use the matching
//! synthetic generator classes (DESIGN.md substitution table). Real
//! DIMACS/MatrixMarket files can be substituted through the CLI
//! (`--graph path.gr`).

pub use crate::workload::registry::{
    Instance, Params, WorkloadId, WorkloadPreset, WorkloadSize, DEFAULT_SEED,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::registry;

    #[test]
    fn presets_instantiate_for_every_registered_workload() {
        for id in registry::all() {
            for size in [WorkloadSize::Tiny, WorkloadSize::Paper] {
                let p = WorkloadPreset::new(id, size);
                if let Some(g) = &p.graph {
                    g.validate().unwrap();
                }
                let (wl, _image) = p.instantiate();
                assert_eq!(wl.name(), id.display());
                assert!(!wl.kinds().is_empty());
                assert!(p.max_rounds > 0);
            }
        }
    }

    #[test]
    fn seeded_presets_deterministic_and_seed_sensitive() {
        for id in [registry::PRK, registry::SSSP, registry::MIS, registry::BFS] {
            let a = WorkloadPreset::new_seeded(id, WorkloadSize::Tiny, 1);
            let b = WorkloadPreset::new_seeded(id, WorkloadSize::Tiny, 1);
            let c = WorkloadPreset::new_seeded(id, WorkloadSize::Tiny, 2);
            let (ga, gb, gc) = (a.graph.unwrap(), b.graph.unwrap(), c.graph.unwrap());
            ga.validate().unwrap();
            gc.validate().unwrap();
            assert_eq!(ga.col, gb.col, "same seed, same graph");
            assert_ne!(ga.col, gc.col, "different seed, different graph");
            let classic = WorkloadPreset::new(id, WorkloadSize::Tiny);
            assert_eq!(classic.seed, DEFAULT_SEED);
        }
    }

    #[test]
    fn paper_presets_bigger_than_tiny() {
        for id in [registry::PRK, registry::SSSP, registry::MIS, registry::BFS] {
            let tiny = WorkloadPreset::new(id, WorkloadSize::Tiny);
            let paper = WorkloadPreset::new(id, WorkloadSize::Paper);
            assert!(paper.graph.unwrap().n > tiny.graph.unwrap().n);
        }
        // Non-graph kernels scale their synthetic sizes instead.
        let tiny = WorkloadPreset::new(registry::STRESS, WorkloadSize::Tiny);
        let paper = WorkloadPreset::new(registry::STRESS, WorkloadSize::Paper);
        assert!(paper.params.get("tasks") > tiny.params.get("tasks"));
    }
}
