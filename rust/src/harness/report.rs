//! Reporting helpers: geomean, table formatting and the machine-readable
//! JSON/CSV matrix reports emitted by the scenario-matrix runner
//! (`--report json|csv` on the CLI). Serialization is hand-rolled — no
//! serde offline — over a fixed flat schema, [`Report::CSV_COLUMNS`].

use std::fmt::Write as _;

/// Geometric mean of positive values (the paper's summary statistic).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Render a table with a header row and aligned columns.
pub fn format_table(header: &[String], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:>w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(header, &widths));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        assert_eq!(row.len(), ncols);
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Machine-readable output formats for matrix reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReportFormat {
    Json,
    Csv,
}

impl ReportFormat {
    pub fn from_name(s: &str) -> Option<ReportFormat> {
        match s {
            "json" => Some(ReportFormat::Json),
            "csv" => Some(ReportFormat::Csv),
            _ => None,
        }
    }
}

/// One row of a scenario-matrix report: one executed grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportRow {
    pub app: String,
    pub scenario: String,
    pub cus: u32,
    /// Workload-generation seed the cell's input graph came from.
    pub seed: u64,
    /// `k=v;...` rendering of the explicit parameter overrides (empty
    /// when the cell ran pure defaults; `;`-separated, never commas, so
    /// the CSV needs no quoting).
    pub params: String,
    /// `k=v;...` rendering of the protocol-parameter overrides the
    /// cell's protocol consumed (`--proto-param`; same quoting-free
    /// format as `params`).
    pub proto_params: String,
    /// Long-format sweep coordinates (`axis=v;...`, e.g.
    /// `remote-ratio=0.4;cu-count=8`) for cells produced by a
    /// [`SweepPlan`](crate::coordinator::SweepPlan); empty for plain
    /// grid cells. One column for any axis composition keeps the report
    /// schema fixed while surfaces stay plottable in long format.
    pub axis_values: String,
    /// The remote-ratio sweep coordinate (`None` for workloads without
    /// the axis) — first-class so protocol × r crossover curves plot
    /// straight from the CSV.
    pub remote_ratio: Option<f64>,
    pub rounds: u32,
    pub converged: bool,
    /// `Some(ok)` when the run was checked against the native oracle;
    /// `None` when validation was not requested.
    pub validated: Option<bool>,
    pub cycles: u64,
    pub instructions: u64,
    pub l1_hit_rate: f64,
    pub l2_accesses: u64,
    pub sync_overhead_cycles: u64,
    pub tasks_executed: u64,
    pub tasks_stolen: u64,
    /// sRSP table-pressure counters (§4): zero under non-sRSP protocols.
    pub lr_tbl_overflows: u64,
    pub pa_tbl_overflows: u64,
    /// Selective-flush outcome split: nop acks (LR-TBL miss) vs drains —
    /// the selectivity the remote-ratio sweep measures.
    pub selective_flush_nops: u64,
    pub selective_flush_drains: u64,
}

/// A full matrix report; rows are in grid order (stable across `--jobs`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Report {
    pub rows: Vec<ReportRow>,
}

impl Report {
    /// The flat report schema, in serialization order (shared by the CSV
    /// header and the JSON object keys).
    pub const CSV_COLUMNS: [&'static str; 22] = [
        "app",
        "scenario",
        "cus",
        "seed",
        "params",
        "proto_params",
        "axis_values",
        "remote_ratio",
        "rounds",
        "converged",
        "validated",
        "cycles",
        "instructions",
        "l1_hit_rate",
        "l2_accesses",
        "sync_overhead_cycles",
        "tasks_executed",
        "tasks_stolen",
        "lr_tbl_overflows",
        "pa_tbl_overflows",
        "selective_flush_nops",
        "selective_flush_drains",
    ];

    /// Render as CSV: a header line plus one line per row. Cell values
    /// are numbers, booleans, bare scenario/app names and `;`-separated
    /// parameter strings — no quoting or escaping is ever needed.
    pub fn to_csv(&self) -> String {
        let mut out = Self::CSV_COLUMNS.join(",");
        out.push('\n');
        for r in &self.rows {
            let validated = match r.validated {
                Some(true) => "true",
                Some(false) => "false",
                None => "",
            };
            let remote_ratio = match r.remote_ratio {
                Some(v) => v.to_string(),
                None => String::new(),
            };
            writeln!(
                out,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{:.6},{},{},{},{},{},{},{},{}",
                r.app,
                r.scenario,
                r.cus,
                r.seed,
                r.params,
                r.proto_params,
                r.axis_values,
                remote_ratio,
                r.rounds,
                r.converged,
                validated,
                r.cycles,
                r.instructions,
                r.l1_hit_rate,
                r.l2_accesses,
                r.sync_overhead_cycles,
                r.tasks_executed,
                r.tasks_stolen,
                r.lr_tbl_overflows,
                r.pa_tbl_overflows,
                r.selective_flush_nops,
                r.selective_flush_drains,
            )
            .expect("writing to a String cannot fail");
        }
        out
    }

    /// Render as a JSON array of flat objects (keys = [`Self::CSV_COLUMNS`]).
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, r) in self.rows.iter().enumerate() {
            let validated = match r.validated {
                Some(true) => "true",
                Some(false) => "false",
                None => "null",
            };
            let remote_ratio = match r.remote_ratio {
                Some(v) => v.to_string(),
                None => "null".to_string(),
            };
            write!(
                out,
                "  {{\"app\":\"{}\",\"scenario\":\"{}\",\"cus\":{},\"seed\":{},\
                 \"params\":\"{}\",\"proto_params\":\"{}\",\"axis_values\":\"{}\",\
                 \"remote_ratio\":{},\
                 \"rounds\":{},\"converged\":{},\"validated\":{},\"cycles\":{},\
                 \"instructions\":{},\"l1_hit_rate\":{:.6},\"l2_accesses\":{},\
                 \"sync_overhead_cycles\":{},\"tasks_executed\":{},\"tasks_stolen\":{},\
                 \"lr_tbl_overflows\":{},\"pa_tbl_overflows\":{},\
                 \"selective_flush_nops\":{},\"selective_flush_drains\":{}}}",
                r.app,
                r.scenario,
                r.cus,
                r.seed,
                r.params,
                r.proto_params,
                r.axis_values,
                remote_ratio,
                r.rounds,
                r.converged,
                validated,
                r.cycles,
                r.instructions,
                r.l1_hit_rate,
                r.l2_accesses,
                r.sync_overhead_cycles,
                r.tasks_executed,
                r.tasks_stolen,
                r.lr_tbl_overflows,
                r.pa_tbl_overflows,
                r.selective_flush_nops,
                r.selective_flush_drains,
            )
            .expect("writing to a String cannot fail");
            out.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> Report {
        let row = |app: &str, scenario: &str, validated| ReportRow {
            app: app.to_string(),
            scenario: scenario.to_string(),
            cus: 8,
            seed: 0xC0FFEE,
            params: String::new(),
            proto_params: String::new(),
            axis_values: String::new(),
            remote_ratio: None,
            rounds: 5,
            converged: true,
            validated,
            cycles: 123_456,
            instructions: 9_999,
            l1_hit_rate: 0.875,
            l2_accesses: 4_321,
            sync_overhead_cycles: 777,
            tasks_executed: 64,
            tasks_stolen: 7,
            lr_tbl_overflows: 1,
            pa_tbl_overflows: 2,
            selective_flush_nops: 30,
            selective_flush_drains: 40,
        };
        let mut sweep_row = row("STRESS", "srsp", Some(true));
        sweep_row.params = "remote_ratio=0.4".to_string();
        sweep_row.proto_params = "lr_tbl_entries=4".to_string();
        sweep_row.axis_values = "remote-ratio=0.4;cu-count=8".to_string();
        sweep_row.remote_ratio = Some(0.4);
        Report {
            rows: vec![
                row("PRK", "baseline", None),
                row("SSSP", "srsp", Some(true)),
                row("MIS", "rsp", Some(false)),
                sweep_row,
            ],
        }
    }

    #[test]
    fn csv_schema_is_rectangular() {
        let csv = sample_report().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 5, "header + 4 rows");
        assert_eq!(lines[0], Report::CSV_COLUMNS.join(","));
        for line in &lines {
            assert_eq!(
                line.split(',').count(),
                Report::CSV_COLUMNS.len(),
                "ragged CSV line: {line}"
            );
        }
        assert!(lines[1].ends_with(",64,7,1,2,30,40"));
        assert!(lines[1].contains(",,"), "unvalidated row has empty cell");
        assert!(lines[2].contains(",true,"));
        assert!(lines[3].contains(",false,"));
        // The sweep row carries the axis coordinates in long format next
        // to the derived remote_ratio column, plus the
        // protocol-parameter overrides.
        assert!(lines[4]
            .contains(",remote_ratio=0.4,lr_tbl_entries=4,remote-ratio=0.4;cu-count=8,0.4,"));
    }

    #[test]
    fn json_rows_carry_every_column() {
        let json = sample_report().to_json();
        assert!(json.starts_with("[\n"));
        assert!(json.ends_with("]\n"));
        assert_eq!(json.matches("{\"app\":").count(), 4);
        for key in Report::CSV_COLUMNS {
            assert_eq!(
                json.matches(&format!("\"{key}\":")).count(),
                4,
                "key {key} missing from some row"
            );
        }
        // Balanced braces and nulls for the absent optional cells.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(json.contains("\"validated\":null"));
        assert!(json.contains("\"remote_ratio\":null"));
        assert!(json.contains("\"remote_ratio\":0.4"));
        assert!(json.contains("\"axis_values\":\"\""));
        assert!(json.contains("\"axis_values\":\"remote-ratio=0.4;cu-count=8\""));
        assert!(json.contains("\"params\":\"remote_ratio=0.4\""));
        assert!(json.contains("\"proto_params\":\"lr_tbl_entries=4\""));
        assert!(json.contains("\"l1_hit_rate\":0.875000"));
        assert!(json.contains("\"selective_flush_drains\":40"));
    }

    #[test]
    fn empty_report_serializes() {
        let r = Report::default();
        assert_eq!(r.to_csv().lines().count(), 1, "header only");
        assert_eq!(r.to_json(), "[\n]\n");
    }

    #[test]
    fn geomean_of_report_ratios() {
        // The figure pipeline feeds report-derived ratios through
        // `geomean`; spot-check the composition on a tiny example.
        let rep = sample_report();
        let cycles: Vec<f64> = rep.rows.iter().map(|r| r.cycles as f64).collect();
        let base = cycles[0];
        let ratios: Vec<f64> = cycles.iter().map(|c| base / c).collect();
        assert!((geomean(&ratios) - 1.0).abs() < 1e-12, "identical cycles");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn table_alignment() {
        let t = format_table(
            &["app".into(), "speedup".into()],
            &[
                vec!["PRK".into(), "1.29".into()],
                vec!["SSSP".into(), "1.40".into()],
            ],
        );
        assert!(t.contains("PRK"));
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{t}");
    }
}
