//! Reporting helpers: geomean, table formatting, the machine-readable
//! JSON/CSV matrix reports emitted by the scenario-matrix runner
//! (`--report json|csv` on the CLI), and the **merge stage** of the
//! distributed pipeline ([`PartialReport`] → [`Report::merge`]).
//! Serialization is hand-rolled — no serde offline — over one versioned
//! flat schema, [`REPORT_SCHEMA`], that the writers, the merger and the
//! tests all reference.

use std::fmt::Write as _;

use crate::coordinator::cache::CacheCounters;
use crate::jsonio::{self, Json};

/// Geometric mean of positive values (the paper's summary statistic).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Render a table with a header row and aligned columns.
pub fn format_table(header: &[String], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:>w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(header, &widths));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        assert_eq!(row.len(), ncols);
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

/// Machine-readable output formats for matrix reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReportFormat {
    Json,
    Csv,
}

impl ReportFormat {
    pub fn from_name(s: &str) -> Option<ReportFormat> {
        match s {
            "json" => Some(ReportFormat::Json),
            "csv" => Some(ReportFormat::Csv),
            _ => None,
        }
    }
}

/// One row of a scenario-matrix report: one executed grid cell.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportRow {
    pub app: String,
    pub scenario: String,
    pub cus: u32,
    /// Workload-generation seed the cell's input graph came from.
    pub seed: u64,
    /// `k=v;...` rendering of the explicit parameter overrides (empty
    /// when the cell ran pure defaults; `;`-separated, never commas, so
    /// the CSV needs no quoting).
    pub params: String,
    /// `k=v;...` rendering of the protocol-parameter overrides the
    /// cell's protocol consumed (`--proto-param`; same quoting-free
    /// format as `params`).
    pub proto_params: String,
    /// Long-format sweep coordinates (`axis=v;...`, e.g.
    /// `remote-ratio=0.4;cu-count=8`) for cells produced by a
    /// [`SweepPlan`](crate::coordinator::SweepPlan); empty for plain
    /// grid cells. One column for any axis composition keeps the report
    /// schema fixed while surfaces stay plottable in long format.
    pub axis_values: String,
    /// The remote-ratio sweep coordinate (`None` for workloads without
    /// the axis) — first-class so protocol × r crossover curves plot
    /// straight from the CSV.
    pub remote_ratio: Option<f64>,
    pub rounds: u32,
    pub converged: bool,
    /// `Some(ok)` when the run was checked against the native oracle;
    /// `None` when validation was not requested.
    pub validated: Option<bool>,
    pub cycles: u64,
    pub instructions: u64,
    pub l1_hit_rate: f64,
    pub l2_accesses: u64,
    pub sync_overhead_cycles: u64,
    pub tasks_executed: u64,
    pub tasks_stolen: u64,
    /// sRSP table-pressure counters (§4): zero under non-sRSP protocols.
    pub lr_tbl_overflows: u64,
    pub pa_tbl_overflows: u64,
    /// Selective-flush outcome split: nop acks (LR-TBL miss) vs drains —
    /// the selectivity the remote-ratio sweep measures.
    pub selective_flush_nops: u64,
    pub selective_flush_drains: u64,
}

/// A full matrix report; rows are in grid order (stable across `--jobs`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Report {
    pub rows: Vec<ReportRow>,
}

/// The one versioned report schema: the flat column list in
/// serialization order (shared by the CSV header and the JSON object
/// keys) plus a format version the distributed pipeline embeds in every
/// [`PartialReport`] so a merge never silently mixes generations.
pub struct ReportSchema {
    /// Bumped on every column change: v1 = 20 columns, v2 added
    /// `proto_params`, v3 added `axis_values`, v4 added the cache-counter
    /// envelope to partial reports (columns unchanged).
    pub version: u32,
    pub columns: &'static [&'static str],
}

/// The current report schema. Writers, the merger and the tests all
/// reference this constant — the column count appears nowhere else.
pub const REPORT_SCHEMA: ReportSchema = ReportSchema {
    version: 4,
    columns: &[
        "app",
        "scenario",
        "cus",
        "seed",
        "params",
        "proto_params",
        "axis_values",
        "remote_ratio",
        "rounds",
        "converged",
        "validated",
        "cycles",
        "instructions",
        "l1_hit_rate",
        "l2_accesses",
        "sync_overhead_cycles",
        "tasks_executed",
        "tasks_stolen",
        "lr_tbl_overflows",
        "pa_tbl_overflows",
        "selective_flush_nops",
        "selective_flush_drains",
    ],
};

impl Report {
    /// Render as CSV: a header line plus one line per row. Cell values
    /// are numbers, booleans, bare scenario/app names and `;`-separated
    /// parameter strings — no quoting or escaping is ever needed.
    pub fn to_csv(&self) -> String {
        let mut out = REPORT_SCHEMA.columns.join(",");
        out.push('\n');
        for r in &self.rows {
            let validated = match r.validated {
                Some(true) => "true",
                Some(false) => "false",
                None => "",
            };
            let remote_ratio = match r.remote_ratio {
                Some(v) => v.to_string(),
                None => String::new(),
            };
            writeln!(
                out,
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{:.6},{},{},{},{},{},{},{},{}",
                r.app,
                r.scenario,
                r.cus,
                r.seed,
                r.params,
                r.proto_params,
                r.axis_values,
                remote_ratio,
                r.rounds,
                r.converged,
                validated,
                r.cycles,
                r.instructions,
                r.l1_hit_rate,
                r.l2_accesses,
                r.sync_overhead_cycles,
                r.tasks_executed,
                r.tasks_stolen,
                r.lr_tbl_overflows,
                r.pa_tbl_overflows,
                r.selective_flush_nops,
                r.selective_flush_drains,
            )
            .expect("writing to a String cannot fail");
        }
        out
    }

    /// Render as a JSON array of flat objects (keys =
    /// [`REPORT_SCHEMA`]`.columns`).
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, r) in self.rows.iter().enumerate() {
            let validated = match r.validated {
                Some(true) => "true",
                Some(false) => "false",
                None => "null",
            };
            let remote_ratio = match r.remote_ratio {
                Some(v) => v.to_string(),
                None => "null".to_string(),
            };
            write!(
                out,
                "  {{\"app\":\"{}\",\"scenario\":\"{}\",\"cus\":{},\"seed\":{},\
                 \"params\":\"{}\",\"proto_params\":\"{}\",\"axis_values\":\"{}\",\
                 \"remote_ratio\":{},\
                 \"rounds\":{},\"converged\":{},\"validated\":{},\"cycles\":{},\
                 \"instructions\":{},\"l1_hit_rate\":{:.6},\"l2_accesses\":{},\
                 \"sync_overhead_cycles\":{},\"tasks_executed\":{},\"tasks_stolen\":{},\
                 \"lr_tbl_overflows\":{},\"pa_tbl_overflows\":{},\
                 \"selective_flush_nops\":{},\"selective_flush_drains\":{}}}",
                r.app,
                r.scenario,
                r.cus,
                r.seed,
                r.params,
                r.proto_params,
                r.axis_values,
                remote_ratio,
                r.rounds,
                r.converged,
                validated,
                r.cycles,
                r.instructions,
                r.l1_hit_rate,
                r.l2_accesses,
                r.sync_overhead_cycles,
                r.tasks_executed,
                r.tasks_stolen,
                r.lr_tbl_overflows,
                r.pa_tbl_overflows,
                r.selective_flush_nops,
                r.selective_flush_drains,
            )
            .expect("writing to a String cannot fail");
            out.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        out.push_str("]\n");
        out
    }

    /// Stage 4 of the distributed pipeline: reassemble worker partial
    /// reports into the grid-ordered report. The result is
    /// byte-identical to the single-process run of the same plan for any
    /// worker count — the partial-row encoding is lossless, and rows
    /// land by global grid index. Any gap is a loud error, never a
    /// short report: missing/duplicate shards, disagreeing run shapes,
    /// duplicate or missing cell indices all fail the merge.
    pub fn merge(partials: &[PartialReport]) -> Result<Report, String> {
        let Some(first) = partials.first() else {
            return Err("merge needs at least one partial report".into());
        };
        let (num_shards, total) = (first.num_shards, first.total_cells);
        if partials.len() != num_shards {
            return Err(format!(
                "merge needs all {num_shards} partial report(s) of the run, got {} — \
                 a worker is missing",
                partials.len()
            ));
        }
        let mut seen_shards = vec![false; num_shards];
        let mut slots: Vec<Option<ReportRow>> = (0..total).map(|_| None).collect();
        for p in partials {
            if p.num_shards != num_shards || p.total_cells != total {
                return Err(format!(
                    "partial report of shard {} disagrees on the run shape \
                     ({}/{} vs {num_shards}/{total}): reports from different runs?",
                    p.shard, p.num_shards, p.total_cells
                ));
            }
            if p.shard >= num_shards {
                return Err(format!(
                    "shard index {} is outside the declared {num_shards} shard(s)",
                    p.shard
                ));
            }
            if seen_shards[p.shard] {
                return Err(format!("two partial reports claim shard {}", p.shard));
            }
            seen_shards[p.shard] = true;
            for (index, row) in &p.rows {
                if *index >= total {
                    return Err(format!(
                        "shard {}: grid index {index} is outside the declared {total} cell(s)",
                        p.shard
                    ));
                }
                if slots[*index].is_some() {
                    return Err(format!("grid cell {index} was reported twice"));
                }
                // A row that does not round-trip losslessly would break
                // the byte-identity invariant downstream (and could
                // poison a result cache) — reject the partial instead.
                check_row_round_trip(row)
                    .map_err(|e| format!("shard {}: grid cell {index}: {e}", p.shard))?;
                slots[*index] = Some(row.clone());
            }
        }
        let missing = slots.iter().filter(|s| s.is_none()).count();
        if missing > 0 {
            let first_gap = slots.iter().position(|s| s.is_none()).unwrap_or(0);
            return Err(format!(
                "merge is missing {missing} of {total} cell(s) (first gap at grid index \
                 {first_gap}): a worker died or emitted a truncated partial report"
            ));
        }
        Ok(Report {
            rows: slots.into_iter().flatten().collect(),
        })
    }
}

/// One worker's slice of a distributed run: the stage-3 output and
/// stage-4 input of the pipeline. Rows are tagged with their global grid
/// index so the merge can reassemble any shard interleaving; the
/// metadata triple (`shard`, `num_shards`, `total_cells`) lets the merge
/// prove completeness instead of assuming it.
///
/// Unlike [`Report::to_json`], whose `l1_hit_rate` is rounded for
/// presentation, the partial encoding is **lossless** (shortest
/// round-trip float rendering, raw `u64`s) — the merged report must be
/// byte-identical to the single-process run, so nothing may be lost in
/// transit.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialReport {
    pub shard: usize,
    pub num_shards: usize,
    pub total_cells: usize,
    /// This shard's result-cache accounting (all zero when the worker
    /// ran uncached); the coordinator sums the shards' counters.
    pub cache: CacheCounters,
    /// `(global grid index, row)` pairs, ascending by index.
    pub rows: Vec<(usize, ReportRow)>,
}

impl PartialReport {
    /// Wrap a complete result grid (every cell, in grid order, from any
    /// mix of sources) as the single all-covering partial of a
    /// one-shard split — `Report::merge(&[partial])` then reproduces
    /// the local single-process report byte-for-byte. The serve
    /// coordinator assembles each finished job this way.
    pub fn from_grid(rows: Vec<(usize, ReportRow)>, cache: CacheCounters) -> PartialReport {
        PartialReport {
            shard: 0,
            num_shards: 1,
            total_cells: rows.len(),
            cache,
            rows,
        }
    }

    /// Serialize to the worker-output JSON format, stamped with
    /// [`REPORT_SCHEMA`]`.version`.
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("report_version".into(), Json::u32(REPORT_SCHEMA.version)),
            ("shard".into(), Json::usize(self.shard)),
            ("num_shards".into(), Json::usize(self.num_shards)),
            ("total_cells".into(), Json::usize(self.total_cells)),
            ("cache".into(), self.cache.to_json()),
            (
                "rows".into(),
                Json::Arr(self.rows.iter().map(|(i, r)| row_to_json(*i, r)).collect()),
            ),
        ])
        .render()
    }

    /// Parse a worker-output file; loud on malformation or a schema
    /// version this binary does not speak.
    pub fn from_json(text: &str) -> Result<PartialReport, String> {
        let v = jsonio::parse(text)?;
        let version = v.get("report_version")?.as_u32()?;
        if version != REPORT_SCHEMA.version {
            return Err(format!(
                "partial report has schema version {version}, this binary speaks {}",
                REPORT_SCHEMA.version
            ));
        }
        let mut rows = Vec::new();
        for (i, r) in v.get("rows")?.arr()?.iter().enumerate() {
            rows.push(row_from_json(r).map_err(|e| format!("row {i}: {e}"))?);
        }
        Ok(PartialReport {
            shard: v.get("shard")?.as_usize()?,
            num_shards: v.get("num_shards")?.as_usize()?,
            total_cells: v.get("total_cells")?.as_usize()?,
            cache: CacheCounters::from_json(v.get("cache")?)?,
            rows,
        })
    }
}

/// Lossless JSON encoding of one indexed report row: the field encoding
/// of [`row_value_to_json`] with the grid index prepended.
fn row_to_json(index: usize, r: &ReportRow) -> Json {
    let Json::Obj(mut fields) = row_value_to_json(r) else {
        unreachable!("row_value_to_json always builds an object")
    };
    fields.insert(0, ("index".into(), Json::usize(index)));
    Json::Obj(fields)
}

/// Lossless JSON encoding of one report row's fields. The exhaustive
/// destructuring is the drift guard: a new [`ReportRow`] column that is
/// not carried across the worker boundary (or the result cache, which
/// reuses this codec) no longer compiles.
pub(crate) fn row_value_to_json(r: &ReportRow) -> Json {
    let ReportRow {
        app,
        scenario,
        cus,
        seed,
        params,
        proto_params,
        axis_values,
        remote_ratio,
        rounds,
        converged,
        validated,
        cycles,
        instructions,
        l1_hit_rate,
        l2_accesses,
        sync_overhead_cycles,
        tasks_executed,
        tasks_stolen,
        lr_tbl_overflows,
        pa_tbl_overflows,
        selective_flush_nops,
        selective_flush_drains,
    } = r;
    Json::Obj(vec![
        ("app".into(), Json::str(app.clone())),
        ("scenario".into(), Json::str(scenario.clone())),
        ("cus".into(), Json::u32(*cus)),
        ("seed".into(), Json::u64(*seed)),
        ("params".into(), Json::str(params.clone())),
        ("proto_params".into(), Json::str(proto_params.clone())),
        ("axis_values".into(), Json::str(axis_values.clone())),
        (
            "remote_ratio".into(),
            match remote_ratio {
                Some(v) => Json::f64(*v),
                None => Json::Null,
            },
        ),
        ("rounds".into(), Json::u32(*rounds)),
        ("converged".into(), Json::Bool(*converged)),
        (
            "validated".into(),
            match validated {
                Some(b) => Json::Bool(*b),
                None => Json::Null,
            },
        ),
        ("cycles".into(), Json::u64(*cycles)),
        ("instructions".into(), Json::u64(*instructions)),
        ("l1_hit_rate".into(), Json::f64(*l1_hit_rate)),
        ("l2_accesses".into(), Json::u64(*l2_accesses)),
        ("sync_overhead_cycles".into(), Json::u64(*sync_overhead_cycles)),
        ("tasks_executed".into(), Json::u64(*tasks_executed)),
        ("tasks_stolen".into(), Json::u64(*tasks_stolen)),
        ("lr_tbl_overflows".into(), Json::u64(*lr_tbl_overflows)),
        ("pa_tbl_overflows".into(), Json::u64(*pa_tbl_overflows)),
        ("selective_flush_nops".into(), Json::u64(*selective_flush_nops)),
        (
            "selective_flush_drains".into(),
            Json::u64(*selective_flush_drains),
        ),
    ])
}

fn row_from_json(v: &Json) -> Result<(usize, ReportRow), String> {
    Ok((v.get("index")?.as_usize()?, row_value_from_json(v)?))
}

pub(crate) fn row_value_from_json(v: &Json) -> Result<ReportRow, String> {
    let opt_f64 = |key: &str| -> Result<Option<f64>, String> {
        match v.get(key)? {
            Json::Null => Ok(None),
            other => other.as_f64().map(Some).map_err(|e| format!("{key}: {e}")),
        }
    };
    let opt_bool = |key: &str| -> Result<Option<bool>, String> {
        match v.get(key)? {
            Json::Null => Ok(None),
            other => other.as_bool().map(Some).map_err(|e| format!("{key}: {e}")),
        }
    };
    let row = ReportRow {
        app: v.get("app")?.as_str()?.to_string(),
        scenario: v.get("scenario")?.as_str()?.to_string(),
        cus: v.get("cus")?.as_u32()?,
        seed: v.get("seed")?.as_u64()?,
        params: v.get("params")?.as_str()?.to_string(),
        proto_params: v.get("proto_params")?.as_str()?.to_string(),
        axis_values: v.get("axis_values")?.as_str()?.to_string(),
        remote_ratio: opt_f64("remote_ratio")?,
        rounds: v.get("rounds")?.as_u32()?,
        converged: v.get("converged")?.as_bool()?,
        validated: opt_bool("validated")?,
        cycles: v.get("cycles")?.as_u64()?,
        instructions: v.get("instructions")?.as_u64()?,
        l1_hit_rate: v.get("l1_hit_rate")?.as_f64()?,
        l2_accesses: v.get("l2_accesses")?.as_u64()?,
        sync_overhead_cycles: v.get("sync_overhead_cycles")?.as_u64()?,
        tasks_executed: v.get("tasks_executed")?.as_u64()?,
        tasks_stolen: v.get("tasks_stolen")?.as_u64()?,
        lr_tbl_overflows: v.get("lr_tbl_overflows")?.as_u64()?,
        pa_tbl_overflows: v.get("pa_tbl_overflows")?.as_u64()?,
        selective_flush_nops: v.get("selective_flush_nops")?.as_u64()?,
        selective_flush_drains: v.get("selective_flush_drains")?.as_u64()?,
    };
    Ok(row)
}

/// Check that `r` survives the lossless row codec exactly: encode,
/// parse, decode, re-encode — the row and its token stream must both be
/// identical. The finite checks come first because the JSON writer
/// (correctly) refuses non-finite numbers, and a crafted partial can
/// smuggle one in (`1e999` parses to infinity): the guard turns what
/// would be a panic into a loud rejection. [`Report::merge`] runs this
/// on every incoming row and the result cache on every insert, so a
/// lossy row can neither break byte-identity nor poison the store.
pub fn check_row_round_trip(r: &ReportRow) -> Result<(), String> {
    if !r.l1_hit_rate.is_finite() {
        return Err(format!("l1_hit_rate {} is not finite", r.l1_hit_rate));
    }
    if let Some(v) = r.remote_ratio {
        if !v.is_finite() {
            return Err(format!("remote_ratio {v} is not finite"));
        }
    }
    let rendered = row_value_to_json(r).render();
    let parsed = jsonio::parse(&rendered)?;
    let back = row_value_from_json(&parsed)?;
    if back != *r {
        return Err("report row does not round-trip through the jsonio codec".into());
    }
    if row_value_to_json(&back).render() != rendered {
        return Err("report row round-trips to a different token stream".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> Report {
        let row = |app: &str, scenario: &str, validated| ReportRow {
            app: app.to_string(),
            scenario: scenario.to_string(),
            cus: 8,
            seed: 0xC0FFEE,
            params: String::new(),
            proto_params: String::new(),
            axis_values: String::new(),
            remote_ratio: None,
            rounds: 5,
            converged: true,
            validated,
            cycles: 123_456,
            instructions: 9_999,
            l1_hit_rate: 0.875,
            l2_accesses: 4_321,
            sync_overhead_cycles: 777,
            tasks_executed: 64,
            tasks_stolen: 7,
            lr_tbl_overflows: 1,
            pa_tbl_overflows: 2,
            selective_flush_nops: 30,
            selective_flush_drains: 40,
        };
        let mut sweep_row = row("STRESS", "srsp", Some(true));
        sweep_row.params = "remote_ratio=0.4".to_string();
        sweep_row.proto_params = "lr_tbl_entries=4".to_string();
        sweep_row.axis_values = "remote-ratio=0.4;cu-count=8".to_string();
        sweep_row.remote_ratio = Some(0.4);
        Report {
            rows: vec![
                row("PRK", "baseline", None),
                row("SSSP", "srsp", Some(true)),
                row("MIS", "rsp", Some(false)),
                sweep_row,
            ],
        }
    }

    #[test]
    fn csv_schema_is_rectangular() {
        let csv = sample_report().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 5, "header + 4 rows");
        assert_eq!(lines[0], REPORT_SCHEMA.columns.join(","));
        for line in &lines {
            assert_eq!(
                line.split(',').count(),
                REPORT_SCHEMA.columns.len(),
                "ragged CSV line: {line}"
            );
        }
        assert!(lines[1].ends_with(",64,7,1,2,30,40"));
        assert!(lines[1].contains(",,"), "unvalidated row has empty cell");
        assert!(lines[2].contains(",true,"));
        assert!(lines[3].contains(",false,"));
        // The sweep row carries the axis coordinates in long format next
        // to the derived remote_ratio column, plus the
        // protocol-parameter overrides.
        assert!(lines[4]
            .contains(",remote_ratio=0.4,lr_tbl_entries=4,remote-ratio=0.4;cu-count=8,0.4,"));
    }

    #[test]
    fn json_rows_carry_every_column() {
        let json = sample_report().to_json();
        assert!(json.starts_with("[\n"));
        assert!(json.ends_with("]\n"));
        assert_eq!(json.matches("{\"app\":").count(), 4);
        for key in REPORT_SCHEMA.columns {
            assert_eq!(
                json.matches(&format!("\"{key}\":")).count(),
                4,
                "key {key} missing from some row"
            );
        }
        // Balanced braces and nulls for the absent optional cells.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert!(json.contains("\"validated\":null"));
        assert!(json.contains("\"remote_ratio\":null"));
        assert!(json.contains("\"remote_ratio\":0.4"));
        assert!(json.contains("\"axis_values\":\"\""));
        assert!(json.contains("\"axis_values\":\"remote-ratio=0.4;cu-count=8\""));
        assert!(json.contains("\"params\":\"remote_ratio=0.4\""));
        assert!(json.contains("\"proto_params\":\"lr_tbl_entries=4\""));
        assert!(json.contains("\"l1_hit_rate\":0.875000"));
        assert!(json.contains("\"selective_flush_drains\":40"));
    }

    #[test]
    fn empty_report_serializes() {
        let r = Report::default();
        assert_eq!(r.to_csv().lines().count(), 1, "header only");
        assert_eq!(r.to_json(), "[\n]\n");
    }

    #[test]
    fn geomean_of_report_ratios() {
        // The figure pipeline feeds report-derived ratios through
        // `geomean`; spot-check the composition on a tiny example.
        let rep = sample_report();
        let cycles: Vec<f64> = rep.rows.iter().map(|r| r.cycles as f64).collect();
        let base = cycles[0];
        let ratios: Vec<f64> = cycles.iter().map(|c| base / c).collect();
        assert!((geomean(&ratios) - 1.0).abs() < 1e-12, "identical cycles");
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn schema_constant_is_consistent() {
        // One source of truth: the CSV writer, the JSON writer and the
        // partial-row codec must all agree with REPORT_SCHEMA.columns.
        let rep = sample_report();
        let header = rep.to_csv().lines().next().unwrap().to_string();
        assert_eq!(header, REPORT_SCHEMA.columns.join(","));
        let partial = PartialReport {
            shard: 0,
            num_shards: 1,
            total_cells: rep.rows.len(),
            cache: Default::default(),
            rows: rep.rows.iter().cloned().enumerate().collect(),
        };
        let json = partial.to_json();
        for key in REPORT_SCHEMA.columns {
            assert!(json.contains(&format!("\"{key}\":")), "partial rows miss {key}");
        }
        assert!(json.contains(&format!("\"report_version\":{}", REPORT_SCHEMA.version)));
    }

    #[test]
    fn partial_report_json_round_trips_losslessly() {
        let mut rep = sample_report();
        // Values that stress the codec: a full-width u64 seed and floats
        // with no exact short decimal.
        rep.rows[0].seed = (1u64 << 63) + 12345;
        rep.rows[0].l1_hit_rate = 1.0 / 3.0;
        rep.rows[3].remote_ratio = Some(0.1 + 0.2); // 0.30000000000000004
        let partial = PartialReport {
            shard: 1,
            num_shards: 2,
            total_cells: 8,
            cache: CacheCounters {
                hits: 3,
                misses: 1,
                preset_reuses: 2,
            },
            rows: rep.rows.iter().cloned().enumerate().map(|(i, r)| (2 * i, r)).collect(),
        };
        let back = PartialReport::from_json(&partial.to_json()).unwrap();
        assert_eq!(back, partial);
        assert_eq!(back.rows[0].1.seed, (1u64 << 63) + 12345);
        assert_eq!(back.rows[0].1.l1_hit_rate.to_bits(), (1.0f64 / 3.0).to_bits());
    }

    #[test]
    fn merge_reassembles_in_grid_order() {
        let rep = sample_report();
        let total = rep.rows.len();
        // Striped split: shard 0 gets even indices, shard 1 odd.
        let split = |parity: usize| PartialReport {
            shard: parity,
            num_shards: 2,
            total_cells: total,
            cache: Default::default(),
            rows: rep
                .rows
                .iter()
                .cloned()
                .enumerate()
                .filter(|(i, _)| i % 2 == parity)
                .collect(),
        };
        // Merge order must not matter.
        let merged = Report::merge(&[split(1), split(0)]).unwrap();
        assert_eq!(merged, rep);
        assert_eq!(merged.to_csv(), rep.to_csv());
        assert_eq!(merged.to_json(), rep.to_json());
    }

    #[test]
    fn merge_failures_are_loud() {
        let rep = sample_report();
        let total = rep.rows.len();
        let shard = |parity: usize| PartialReport {
            shard: parity,
            num_shards: 2,
            total_cells: total,
            cache: Default::default(),
            rows: rep
                .rows
                .iter()
                .cloned()
                .enumerate()
                .filter(|(i, _)| i % 2 == parity)
                .collect(),
        };
        assert!(Report::merge(&[]).unwrap_err().contains("at least one"));
        // A missing worker.
        let err = Report::merge(&[shard(0)]).unwrap_err();
        assert!(err.contains("a worker is missing"), "{err}");
        // The same shard twice.
        let err = Report::merge(&[shard(0), shard(0)]).unwrap_err();
        assert!(err.contains("shard 0"), "{err}");
        // A truncated partial: right shard set, rows missing.
        let mut short = shard(1);
        short.rows.pop();
        let err = Report::merge(&[shard(0), short]).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
        // Disagreeing run shapes.
        let mut alien = shard(1);
        alien.total_cells = total + 1;
        let err = Report::merge(&[shard(0), alien]).unwrap_err();
        assert!(err.contains("disagrees"), "{err}");
        // A duplicated cell index across shards.
        let mut dup = shard(1);
        dup.rows[0].0 = 0; // collides with shard 0's first cell
        let err = Report::merge(&[shard(0), dup]).unwrap_err();
        assert!(err.contains("twice"), "{err}");
        // A schema-version mismatch is caught at parse time.
        let current = format!("\"report_version\":{}", REPORT_SCHEMA.version);
        let stale = shard(0).to_json().replacen(&current, "\"report_version\":1", 1);
        let err = PartialReport::from_json(&stale).unwrap_err();
        assert!(err.contains("schema version 1"), "{err}");
    }

    #[test]
    fn merge_rejects_lossy_rows() {
        // `1e999` is a valid JSON number token that parses to infinity —
        // the writer would panic on it, so the merge must reject it
        // before any re-encode. (This is the poison row a result cache
        // would otherwise store.)
        let rep = sample_report();
        let partial = PartialReport {
            shard: 0,
            num_shards: 1,
            total_cells: rep.rows.len(),
            cache: Default::default(),
            rows: rep.rows.iter().cloned().enumerate().collect(),
        };
        let poisoned = partial
            .to_json()
            .replacen("\"l1_hit_rate\":0.875", "\"l1_hit_rate\":1e999", 1);
        let parsed = PartialReport::from_json(&poisoned).expect("1e999 is a parseable token");
        let err = Report::merge(&[parsed]).unwrap_err();
        assert!(err.contains("not finite"), "{err}");
        // The direct check agrees.
        let mut bad = rep.rows[0].clone();
        bad.l1_hit_rate = f64::INFINITY;
        assert!(check_row_round_trip(&bad).unwrap_err().contains("not finite"));
        bad.l1_hit_rate = 0.5;
        bad.remote_ratio = Some(f64::NAN);
        assert!(check_row_round_trip(&bad).unwrap_err().contains("not finite"));
        assert!(check_row_round_trip(&rep.rows[0]).is_ok());
    }

    #[test]
    fn table_alignment() {
        let t = format_table(
            &["app".into(), "speedup".into()],
            &[
                vec!["PRK".into(), "1.29".into()],
                vec!["SSSP".into(), "1.40".into()],
            ],
        );
        assert!(t.contains("PRK"));
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{t}");
    }
}
