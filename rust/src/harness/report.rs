//! Reporting helpers: geomean, table formatting.

/// Geometric mean of positive values (the paper's summary statistic).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Render a table with a header row and aligned columns.
pub fn format_table(header: &[String], rows: &[Vec<String>]) -> String {
    let ncols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:>w$} |", c, w = widths[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(header, &widths));
    out.push('|');
    for w in &widths {
        out.push_str(&format!("{}|", "-".repeat(w + 2)));
    }
    out.push('\n');
    for row in rows {
        assert_eq!(row.len(), ncols);
        out.push_str(&fmt_row(row, &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn table_alignment() {
        let t = format_table(
            &["app".into(), "speedup".into()],
            &[
                vec!["PRK".into(), "1.29".into()],
                vec!["SSSP".into(), "1.40".into()],
            ],
        );
        assert!(t.contains("PRK"));
        let widths: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{t}");
    }
}
