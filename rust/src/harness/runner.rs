//! The parallel scenario-matrix runner: the **execution half** of the
//! evaluation grids.
//!
//! Which cells exist, in what order, and how their seeds derive is the
//! *distribution policy* and lives in [`crate::coordinator`]; this module
//! takes a cell list (or a [`SweepPlan`]) and executes it. Every grid
//! [`Cell`](crate::coordinator::Cell) is an independent, single-threaded
//! simulation — its own [`Device`](crate::gpu::Device), memory image and
//! workload instance are all constructed inside the worker thread that
//! executes it — so cells parallelize with no shared mutable state.
//! Workers pull cell indices from an atomic counter (dynamic load
//! balancing: the 64-CU sRSP cells cost far more than the 4-CU baseline
//! cells) and send results back over a channel; results are reassembled
//! in grid order, so the output is byte-for-byte identical for any
//! `--jobs` value.
//!
//! Workloads are resolved through the [`crate::workload::registry`] and
//! sweep dimensions through the [`crate::coordinator::axis`] registry:
//! instantiation, parameter handling, oracle validation and cell
//! specialization are all self-described by the registered
//! implementations — nothing here matches on a workload, protocol or
//! axis identity.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

use super::presets::{WorkloadPreset, WorkloadSize};
use super::report::{Report, ReportRow};
use crate::config::{DeviceConfig, Scenario};
use crate::coordinator::{Cell, Seeding, SweepPlan};
use crate::sync::protocol;
use crate::workload::driver::{run_scenario_seeded, RunResult};
use crate::workload::engine::NativeMath;
use crate::workload::registry::WorkloadId;

/// Outcome of one executed cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    /// The workload seed the cell actually ran with.
    pub seed: u64,
    /// `k=v;...` rendering of the explicit parameter overrides the cell's
    /// preset carried (empty when the run used pure defaults).
    pub params: String,
    /// `k=v;...` rendering of the protocol-parameter overrides the
    /// cell's protocol consumed (`--proto-param` plus any sweep-axis
    /// contribution; empty when none apply — cells of a mixed grid only
    /// surface their own protocol's keys).
    pub proto_params: String,
    /// Long-format sweep coordinates (`axis=v;...`) when the cell came
    /// from a [`SweepPlan`]; empty for plain grid cells.
    pub axis_values: String,
    /// The remote-ratio sweep coordinate, when the workload declares one
    /// (the stress family); `None` for workloads without the axis.
    pub remote_ratio: Option<f64>,
    pub result: RunResult,
    /// `Some(ok)` when oracle validation was requested.
    pub validated: Option<bool>,
}

/// Strip cell metadata for the figure pipelines, which require every run
/// to have converged (`max_rounds` bounds are sized so the classic seeds
/// always do).
pub fn into_run_results(results: Vec<CellResult>) -> Vec<RunResult> {
    results
        .into_iter()
        .map(|c| {
            assert!(
                c.result.converged,
                "{}/{} on {} CUs did not converge (seed {:#x})",
                c.result.app, c.result.scenario, c.cell.num_cus, c.seed
            );
            c.result
        })
        .collect()
}

/// Run one (preset, scenario) pair and check the final memory against
/// the workload's self-described oracle (each registered kernel builds
/// its own check: exactness for SSSP/MIS/BFS/stress/prodcons, L1-norm
/// tolerance for PageRank, whose floating-point accumulation order
/// differs between the tiled device math and the oracle).
pub fn run_validated(
    cfg: &DeviceConfig,
    preset: &WorkloadPreset,
    scenario: Scenario,
) -> (RunResult, bool) {
    let inst = preset.instance();
    let mut wl = inst.workload;
    let (run, mem) = run_scenario_seeded(
        cfg,
        scenario,
        wl.as_mut(),
        NativeMath,
        preset.max_rounds,
        inst.image,
    );
    let ok = run.converged && (inst.check)(&mem).is_ok();
    (run, ok)
}

/// One fully-specialized, ready-to-execute cell: the grid coordinates
/// plus everything a sweep axis may have contributed beyond the cell
/// itself. Plain grid cells carry empty extras — the execution core
/// never knows whether a sweep produced its input.
struct Planned<'a> {
    cell: Cell,
    preset: &'a WorkloadPreset,
    /// Axis-contributed protocol-parameter overrides, appended after the
    /// runner's own (`--proto-param`) list so an axis that owns a key
    /// wins.
    proto_params: Vec<(String, f64)>,
    /// Long-format sweep coordinates for the report (empty off-sweep).
    axis_values: String,
}

/// The scenario-matrix runner configuration.
#[derive(Debug, Clone)]
pub struct Runner {
    /// Worker thread count (0 is treated as 1; clamped to the cell
    /// count).
    pub jobs: usize,
    pub seeding: Seeding,
    pub size: WorkloadSize,
    /// Check every cell against its native oracle.
    pub validate: bool,
    /// `--param` overrides applied to every preset this runner builds.
    /// Panics on a kernel that does not declare a key — the CLI restricts
    /// `--param` to single-workload commands, so a mixed grid never sees
    /// overrides.
    pub params: Vec<(String, f64)>,
    /// Device template; `num_cus` is overridden per cell.
    pub cfg: DeviceConfig,
}

impl Runner {
    /// A runner with classic shared seeding, default parameters and no
    /// validation — the configuration the figure pipelines use.
    pub fn new(cfg: DeviceConfig, size: WorkloadSize, jobs: usize) -> Self {
        Runner {
            jobs,
            seeding: Seeding::default(),
            size,
            validate: false,
            params: Vec::new(),
            cfg,
        }
    }

    /// Worker count the host reports as available.
    pub fn default_jobs() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Build the preset for `app` from this runner's size, params and an
    /// explicit seed, with `extra` overrides appended (the sweep axes own
    /// their key, so they win over user `--param`s).
    fn build_preset(&self, app: WorkloadId, seed: u64, extra: &[(String, f64)]) -> WorkloadPreset {
        let mut overrides = self.params.clone();
        overrides.extend_from_slice(extra);
        WorkloadPreset::with_params(app, self.size, seed, &overrides)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Run one standalone cell: generates the input, builds the device,
    /// simulates and (when enabled) validates, entirely within the
    /// calling thread.
    pub fn run_cell(&self, cell: &Cell) -> CellResult {
        let seed = self.seeding.seed_for(cell);
        let preset = self.build_preset(cell.app, seed, &[]);
        self.run_one(&Planned {
            cell: *cell,
            preset: &preset,
            proto_params: Vec::new(),
            axis_values: String::new(),
        })
    }

    /// Run one planned cell against an already-generated preset (which
    /// must match the cell's app and the runner's seeding — the grid
    /// entry points share one preset across all scenarios of a grid
    /// point instead of regenerating the identical input per scenario).
    fn run_one(&self, p: &Planned<'_>) -> CellResult {
        let mut cfg = DeviceConfig {
            num_cus: p.cell.num_cus,
            ..self.cfg.clone()
        };
        cfg.proto_params.extend_from_slice(&p.proto_params);
        let (result, validated) = if self.validate {
            let (run, ok) = run_validated(&cfg, p.preset, p.cell.scenario);
            (run, Some(ok))
        } else {
            let (mut wl, image) = p.preset.instantiate();
            let (run, _mem) = run_scenario_seeded(
                &cfg,
                p.cell.scenario,
                wl.as_mut(),
                NativeMath,
                p.preset.max_rounds,
                image,
            );
            (run, None)
        };
        CellResult {
            cell: p.cell,
            seed: p.preset.seed,
            params: p.preset.params.overrides_display(),
            proto_params: protocol::overrides_display(
                p.cell.scenario.protocol(),
                &cfg.proto_params,
            ),
            axis_values: p.axis_values.clone(),
            remote_ratio: p.preset.remote_ratio(),
            result,
            validated,
        }
    }

    /// Run `cells` across `self.jobs` OS threads. Returns results in
    /// `cells` order regardless of scheduling, so any jobs count yields
    /// byte-identical output.
    pub fn run_cells(&self, cells: &[Cell]) -> Vec<CellResult> {
        // Seeds ignore the scenario, so every distinct (app, seed) pair
        // needs exactly one input: generate each once, up front, and
        // share it read-only across the workers.
        let mut presets: HashMap<(WorkloadId, u64), WorkloadPreset> = HashMap::new();
        for cell in cells {
            let seed = self.seeding.seed_for(cell);
            presets
                .entry((cell.app, seed))
                .or_insert_with(|| self.build_preset(cell.app, seed, &[]));
        }
        let planned: Vec<Planned<'_>> = cells
            .iter()
            .map(|c| Planned {
                cell: *c,
                preset: &presets[&(c.app, self.seeding.seed_for(c))],
                proto_params: Vec::new(),
                axis_values: String::new(),
            })
            .collect();
        self.run_planned(&planned)
    }

    /// Execute a [`SweepPlan`]: the cross-product grid of the plan's
    /// axes, every combo run under every plan scenario on one shared
    /// preset — and therefore one task population — so the resulting
    /// curve or surface compares protocols on identical inputs. Cells
    /// run in the plan's combo-major order (all scenarios of one grid
    /// point adjacent, mirroring the report's row grouping); a one-axis
    /// plan reproduces the historical single-axis sweep orders exactly.
    pub fn run_sweep(&self, plan: &SweepPlan) -> Vec<CellResult> {
        let combos = plan.combos();
        let presets: Vec<WorkloadPreset> = combos
            .iter()
            .map(|combo| {
                let num_cus = combo.spec.num_cus.unwrap_or(self.cfg.num_cus);
                // Seeds ignore the scenario (and any parameter-only
                // coordinate: those sweeps vary placement over one
                // shared task population); per-cell seeding derives a
                // distinct input per device size.
                let seed = self.seeding.seed_for(&Cell {
                    app: plan.app,
                    scenario: Scenario::SRSP,
                    num_cus,
                });
                self.build_preset(plan.app, seed, &combo.spec.params)
            })
            .collect();
        let planned: Vec<Planned<'_>> = combos
            .iter()
            .zip(&presets)
            .flat_map(|(combo, preset)| {
                let num_cus = combo.spec.num_cus.unwrap_or(self.cfg.num_cus);
                plan.scenarios.iter().map(move |&scenario| Planned {
                    cell: Cell {
                        app: plan.app,
                        scenario,
                        num_cus,
                    },
                    preset,
                    proto_params: combo.spec.proto_params.clone(),
                    axis_values: combo.axis_values(),
                })
            })
            .collect();
        self.run_planned(&planned)
    }

    /// The shared sharding core: dynamic work queue over an atomic
    /// counter, results reassembled in input order.
    fn run_planned(&self, planned: &[Planned<'_>]) -> Vec<CellResult> {
        let jobs = self.jobs.clamp(1, planned.len().max(1));
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, CellResult)>();
        thread::scope(|scope| {
            for _ in 0..jobs {
                let tx = tx.clone();
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(p) = planned.get(i) else { break };
                    if tx.send((i, self.run_one(p))).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);
        let mut slots: Vec<Option<CellResult>> = planned.iter().map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("worker exited without reporting its cell"))
            .collect()
    }
}

impl Report {
    /// Assemble the machine-readable report for a set of executed cells.
    pub fn from_cells(results: &[CellResult]) -> Report {
        let rows = results
            .iter()
            .map(|c| ReportRow {
                app: c.result.app.to_string(),
                scenario: c.result.scenario.name().to_string(),
                cus: c.cell.num_cus,
                seed: c.seed,
                params: c.params.clone(),
                proto_params: c.proto_params.clone(),
                axis_values: c.axis_values.clone(),
                remote_ratio: c.remote_ratio,
                rounds: c.result.rounds,
                converged: c.result.converged,
                validated: c.validated,
                cycles: c.result.stats.cycles,
                instructions: c.result.stats.instructions,
                l1_hit_rate: c.result.stats.l1_hit_rate(),
                l2_accesses: c.result.stats.l2_accesses,
                sync_overhead_cycles: c.result.stats.sync_overhead_cycles,
                tasks_executed: c.result.stats.tasks_executed,
                tasks_stolen: c.result.stats.tasks_stolen,
                lr_tbl_overflows: c.result.stats.lr_tbl_overflows,
                pa_tbl_overflows: c.result.stats.pa_tbl_overflows,
                selective_flush_nops: c.result.stats.selective_flush_nops,
                selective_flush_drains: c.result.stats.selective_flush_drains,
            })
            .collect();
        Report { rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{axis, classic_grid, full_grid, RATIO_SCENARIOS};
    use crate::harness::presets::DEFAULT_SEED;
    use crate::workload::registry;

    fn tiny_runner(jobs: usize, seeding: Seeding, validate: bool) -> Runner {
        Runner {
            jobs,
            seeding,
            size: WorkloadSize::Tiny,
            validate,
            params: Vec::new(),
            cfg: DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
        }
    }

    #[test]
    fn jobs_1_and_jobs_4_byte_identical() {
        let cells = classic_grid(4);
        let serial = tiny_runner(1, Seeding::PerCell(42), false).run_cells(&cells);
        let parallel = tiny_runner(4, Seeding::PerCell(42), false).run_cells(&cells);
        // Full structural equality, stats included (Debug covers every
        // counter, including the BTreeMap of named counters).
        assert_eq!(
            format!("{serial:?}"),
            format!("{parallel:?}"),
            "--jobs must never change results"
        );
        // And the emitted artifacts are byte-identical too.
        let a = Report::from_cells(&serial);
        let b = Report::from_cells(&parallel);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn validation_passes_on_tiny_cells() {
        let cells = [
            Cell {
                app: registry::PRK,
                scenario: Scenario::BASELINE,
                num_cus: 4,
            },
            Cell {
                app: registry::SSSP,
                scenario: Scenario::SRSP,
                num_cus: 4,
            },
            Cell {
                app: registry::MIS,
                scenario: Scenario::RSP,
                num_cus: 4,
            },
            Cell {
                app: registry::BFS,
                scenario: Scenario::SRSP,
                num_cus: 4,
            },
        ];
        let results = tiny_runner(2, Seeding::default(), true).run_cells(&cells);
        for c in &results {
            assert_eq!(
                c.validated,
                Some(true),
                "{}/{} failed its oracle",
                c.result.app,
                c.result.scenario
            );
            assert_eq!(c.seed, DEFAULT_SEED);
            assert_eq!(c.params, "", "matrix cells run pure defaults");
            assert_eq!(c.axis_values, "", "plain grid cells carry no axis coordinates");
        }
        let report = Report::from_cells(&results);
        assert_eq!(report.rows.len(), cells.len());
        assert!(report.to_csv().contains(",true,"));
    }

    #[test]
    fn full_grid_covers_every_registered_workload_and_validates() {
        // The registry round-trip at runner level: every registered
        // workload × srsp validates on the tiny device.
        let cells: Vec<Cell> = full_grid(4)
            .into_iter()
            .filter(|c| c.scenario == Scenario::SRSP)
            .collect();
        assert_eq!(cells.len(), registry::all().count());
        let results = tiny_runner(4, Seeding::default(), true).run_cells(&cells);
        for c in &results {
            assert_eq!(
                c.validated,
                Some(true),
                "{}/{} failed its oracle",
                c.result.app,
                c.result.scenario
            );
        }
    }

    #[test]
    fn remote_ratio_sweep_shape_params_and_oracles() {
        let runner = tiny_runner(4, Seeding::default(), true);
        let points = [0.0, 0.5];
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, points.to_vec())
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), points.len() * RATIO_SCENARIOS.len());
        for (i, c) in results.iter().enumerate() {
            let (want_r, want_scenario) = (points[i / 3], RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.scenario, want_scenario);
            assert_eq!(c.remote_ratio, Some(want_r), "cell {i}");
            assert_eq!(c.validated, Some(true), "{want_scenario:?} r={want_r}");
            assert_eq!(c.params, format!("remote_ratio={want_r}"));
            assert_eq!(c.axis_values, format!("remote-ratio={want_r}"));
        }
        // The report carries the axis as a first-class column.
        let report = Report::from_cells(&results);
        assert!(report.to_csv().contains("axis_values"));
        assert!(report.to_csv().contains("remote-ratio=0.5"));
    }

    #[test]
    fn cu_count_sweep_shape_and_oracles() {
        let runner = tiny_runner(4, Seeding::PerCell(11), true);
        let points = [2.0, 4.0];
        let plan = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT])
            .unwrap()
            .with_points(axis::CU_COUNT, points.to_vec())
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), points.len() * RATIO_SCENARIOS.len());
        for (i, c) in results.iter().enumerate() {
            let (want_cus, want_scenario) = (points[i / 3] as u32, RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.scenario, want_scenario);
            assert_eq!(c.cell.num_cus, want_cus, "cell {i}");
            assert_eq!(c.validated, Some(true), "{want_scenario:?} cus={want_cus}");
        }
        // All protocols at one CU count share a seed (identical inputs);
        // different CU counts derive different ones under PerCell.
        assert_eq!(results[0].seed, results[2].seed);
        assert_ne!(results[0].seed, results[3].seed);
        // The report carries the axis through the existing cus column
        // and the long-format coordinate column.
        let report = Report::from_cells(&results);
        assert!(report.to_csv().contains(",2,"));
        assert!(report.to_csv().contains("cu-count=4"));
    }

    #[test]
    fn composed_sweep_crosses_both_axes() {
        let runner = tiny_runner(4, Seeding::PerCell(3), true);
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 1.0])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![2.0, 4.0])
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), 2 * 2 * RATIO_SCENARIOS.len());
        let combos = plan.combos();
        for (i, c) in results.iter().enumerate() {
            let combo = &combos[i / RATIO_SCENARIOS.len()];
            assert_eq!(c.cell.scenario, RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.num_cus, combo.spec.num_cus.unwrap());
            assert_eq!(c.remote_ratio, combo.coord(axis::REMOTE_RATIO));
            assert_eq!(c.axis_values, combo.axis_values());
            assert_eq!(c.validated, Some(true), "cell {i}: {}", c.axis_values);
        }
        // Scenarios of one combo share the input; the device size drives
        // the seed, the ratio does not (placement over one population).
        assert_eq!(results[0].seed, results[2].seed);
        assert_eq!(results[0].seed, results[6].seed, "ratio must not reseed");
        assert_ne!(results[0].seed, results[3].seed, "CU count must reseed");
    }

    #[test]
    fn registry_only_axes_run_end_to_end() {
        // hot-set and migration exist only as axis-registry entries; the
        // runner and coordinator carry no code specific to them.
        let runner = tiny_runner(4, Seeding::default(), true);
        for (id, key) in [(axis::HOT_SET, "hot_set"), (axis::MIGRATION, "migration")] {
            let plan = SweepPlan::new(registry::STRESS, &[id])
                .unwrap()
                .with_points(id, vec![1.0, 2.0])
                .unwrap();
            let results = runner.run_sweep(&plan);
            assert_eq!(results.len(), 2 * RATIO_SCENARIOS.len());
            for c in &results {
                assert_eq!(c.validated, Some(true), "{}: {}", id.name(), c.axis_values);
            }
            assert_eq!(results[0].params, format!("{key}=1"));
            assert_eq!(results[3].axis_values, format!("{}=2", id.name()));
        }
    }

    #[test]
    fn sweep_jobs_1_and_4_byte_identical() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![2.0, 4.0])
            .unwrap();
        let serial = tiny_runner(1, Seeding::PerCell(9), true).run_sweep(&plan);
        let parallel = tiny_runner(4, Seeding::PerCell(9), true).run_sweep(&plan);
        assert_eq!(format!("{serial:?}"), format!("{parallel:?}"));
        assert_eq!(
            Report::from_cells(&serial).to_csv(),
            Report::from_cells(&parallel).to_csv()
        );
    }

    #[test]
    fn proto_params_reach_the_device_and_the_report() {
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.cfg.proto_params = vec![("lr_tbl_entries".to_string(), 1.0)];
        let srsp = runner.run_cell(&Cell {
            app: registry::STRESS,
            scenario: Scenario::SRSP,
            num_cus: 4,
        });
        // The one-entry LR-TBL must actually be in effect (overflows
        // fire) and the cell still validates.
        assert_eq!(srsp.validated, Some(true));
        assert!(srsp.result.stats.lr_tbl_overflows > 0);
        assert_eq!(srsp.proto_params, "lr_tbl_entries=1");
        // A scoped-protocol cell ignores the key and reports nothing.
        let steal = runner.run_cell(&Cell {
            app: registry::STRESS,
            scenario: Scenario::STEAL_ONLY,
            num_cus: 4,
        });
        assert_eq!(steal.validated, Some(true));
        assert_eq!(steal.proto_params, "");
        let report = Report::from_cells(&[srsp, steal]);
        assert!(report.to_csv().contains("lr_tbl_entries=1"));
    }

    #[test]
    fn runner_params_reach_the_preset() {
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.params = vec![("tasks".to_string(), 32.0)];
        let cell = Cell {
            app: registry::STRESS,
            scenario: Scenario::SRSP,
            num_cus: 4,
        };
        let r = runner.run_cell(&cell);
        assert_eq!(r.params, "tasks=32");
        assert_eq!(r.validated, Some(true));
    }

    #[test]
    fn sweep_axis_overrides_win_over_runner_params() {
        // The axis owns its key: a user --param remote_ratio is
        // overridden by the swept coordinate, not silently kept.
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.params = vec![("remote_ratio".to_string(), 0.9)];
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.25])
            .unwrap();
        let results = runner.run_sweep(&plan);
        for c in &results {
            assert_eq!(c.remote_ratio, Some(0.25));
            assert_eq!(c.validated, Some(true));
        }
    }

    #[test]
    #[should_panic(expected = "unknown parameter")]
    fn runner_rejects_unknown_params() {
        let mut runner = tiny_runner(1, Seeding::default(), false);
        runner.params = vec![("bogus".to_string(), 1.0)];
        let cell = Cell {
            app: registry::PRK,
            scenario: Scenario::BASELINE,
            num_cus: 4,
        };
        let _ = runner.run_cell(&cell);
    }
}
