//! The scenario-matrix runner: the **execute stage** of the evaluation
//! pipeline.
//!
//! Which cells exist, in what order, and how their seeds derive is the
//! *distribution policy* and lives in [`crate::coordinator`]; this
//! module executes lowered [`ExecutionPlan`]s. Every grid
//! [`Cell`](crate::coordinator::Cell) is an independent, single-threaded
//! simulation — its own [`Device`](crate::gpu::Device), memory image and
//! workload instance are all constructed inside the executor that runs
//! it — so cells parallelize with no shared mutable state.
//!
//! All execution flows through the one pipeline: the coordinator lowers
//! a [`SweepPlan`] or cell list into an [`ExecutionPlan`], the plan is
//! [partitioned](crate::coordinator::shard::partition) into
//! deterministic [`ShardSpec`]s, [`execute_shard`] runs one shard
//! serially in the calling context, and results reassemble by global
//! grid index. `--jobs N` runs the plan's cells on N in-process threads
//! pulling from one shared work-stealing queue (cell cost varies by an
//! order of magnitude across `cu-count`/size axes, so a static deal
//! leaves threads idle behind the slowest shard); `srsp worker --shard
//! <file>` runs exactly one shard in a subprocess and emits a
//! [`PartialReport`]. Both executors run the same per-cell code and
//! reassemble by the global grid index each result carries, which is
//! what makes any `--jobs` / `--workers` split byte-identical.
//!
//! Workloads are resolved through the [`crate::workload::registry`] and
//! sweep dimensions through the [`crate::coordinator::axis`] registry:
//! instantiation, parameter handling, oracle validation and cell
//! specialization are all self-described by the registered
//! implementations — nothing here matches on a workload, protocol or
//! axis identity.

use std::collections::BTreeMap;
use std::thread;

use super::presets::{WorkloadPreset, WorkloadSize};
use super::report::{PartialReport, Report, ReportRow};
use crate::config::{DeviceConfig, Scenario};
use crate::coordinator::cache::{self, CacheCounters, CacheStore};
use crate::coordinator::shard::{self, ShardSpec};
use crate::coordinator::{Cell, ExecutionPlan, PlannedCell, Seeding, SweepPlan};
use crate::sim::perfstats;
use crate::sync::protocol;
use crate::workload::driver::{run_scenario_seeded, RunResult};
use crate::workload::engine::NativeMath;

/// Outcome of one executed cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    /// The workload seed the cell actually ran with.
    pub seed: u64,
    /// `k=v;...` rendering of the explicit parameter overrides the cell's
    /// preset carried (empty when the run used pure defaults).
    pub params: String,
    /// `k=v;...` rendering of the protocol-parameter overrides the
    /// cell's protocol consumed (`--proto-param` plus any sweep-axis
    /// contribution; empty when none apply — cells of a mixed grid only
    /// surface their own protocol's keys).
    pub proto_params: String,
    /// Long-format sweep coordinates (`axis=v;...`) when the cell came
    /// from a [`SweepPlan`]; empty for plain grid cells.
    pub axis_values: String,
    /// The remote-ratio sweep coordinate, when the workload declares one
    /// (the stress family); `None` for workloads without the axis.
    pub remote_ratio: Option<f64>,
    pub result: RunResult,
    /// `Some(ok)` when oracle validation was requested.
    pub validated: Option<bool>,
}

/// Strip cell metadata for the figure pipelines, which require every run
/// to have converged (`max_rounds` bounds are sized so the classic seeds
/// always do).
pub fn into_run_results(results: Vec<CellResult>) -> Vec<RunResult> {
    results
        .into_iter()
        .map(|c| {
            assert!(
                c.result.converged,
                "{}/{} on {} CUs did not converge (seed {:#x})",
                c.result.app, c.result.scenario, c.cell.num_cus, c.seed
            );
            c.result
        })
        .collect()
}

/// Run one (preset, scenario) pair and check the final memory against
/// the workload's self-described oracle (each registered kernel builds
/// its own check: exactness for SSSP/MIS/BFS/stress/prodcons, L1-norm
/// tolerance for PageRank, whose floating-point accumulation order
/// differs between the tiled device math and the oracle).
pub fn run_validated(
    cfg: &DeviceConfig,
    preset: &WorkloadPreset,
    scenario: Scenario,
) -> (RunResult, bool) {
    let inst = preset.instance();
    let mut wl = inst.workload;
    let (run, mem) = run_scenario_seeded(
        cfg,
        scenario,
        wl.as_mut(),
        NativeMath,
        preset.max_rounds,
        inst.image,
    );
    let ok = run.converged && (inst.check)(&mem).is_ok();
    (run, ok)
}

/// The scenario-matrix runner configuration.
#[derive(Debug, Clone)]
pub struct Runner {
    /// Worker thread count (0 is treated as 1; clamped to the cell
    /// count).
    pub jobs: usize,
    pub seeding: Seeding,
    pub size: WorkloadSize,
    /// Check every cell against its native oracle.
    pub validate: bool,
    /// `--param` overrides applied to every preset this runner builds.
    /// Panics on a kernel that does not declare a key — the CLI restricts
    /// `--param` to single-workload commands, so a mixed grid never sees
    /// overrides.
    pub params: Vec<(String, f64)>,
    /// Device template; `num_cus` is overridden per cell.
    pub cfg: DeviceConfig,
}

impl Runner {
    /// A runner with classic shared seeding, default parameters and no
    /// validation — the configuration the figure pipelines use.
    pub fn new(cfg: DeviceConfig, size: WorkloadSize, jobs: usize) -> Self {
        Runner {
            jobs,
            seeding: Seeding::default(),
            size,
            validate: false,
            params: Vec::new(),
            cfg,
        }
    }

    /// Worker count the host reports as available.
    pub fn default_jobs() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Run one standalone cell. Routed through the same plan-lowering as
    /// every grid — single-cell and sweep paths cannot drift.
    pub fn run_cell(&self, cell: &Cell) -> CellResult {
        let plan = ExecutionPlan::lower_cells(self, std::slice::from_ref(cell));
        execute_plan(&plan, 1)
            .pop()
            .expect("one planned cell yields one result")
    }

    /// Run `cells` across `self.jobs` work-stealing executor threads.
    /// Returns results in `cells` order regardless of scheduling, so any
    /// jobs count yields byte-identical output.
    pub fn run_cells(&self, cells: &[Cell]) -> Vec<CellResult> {
        execute_plan(&ExecutionPlan::lower_cells(self, cells), self.jobs)
    }

    /// Execute a [`SweepPlan`]: the cross-product grid of the plan's
    /// axes, every combo run under every plan scenario on one shared
    /// input population, so the resulting curve or surface compares
    /// protocols on identical inputs. Cells run in the plan's
    /// combo-major order (all scenarios of one grid point adjacent,
    /// mirroring the report's row grouping); a one-axis plan reproduces
    /// the historical single-axis sweep orders exactly.
    pub fn run_sweep(&self, plan: &SweepPlan) -> Vec<CellResult> {
        execute_plan(&ExecutionPlan::lower_sweep(self, plan), self.jobs)
    }
}

/// Stable preset-cache key for one planned cell: presets are shared
/// between cells exactly when workload, seed and override list agree
/// (`f64` renders via shortest round-trip `Display`, so the rendering is
/// injective up to value equality).
fn preset_key(cell: &PlannedCell) -> (u64, u64, String) {
    let params: Vec<String> = cell.params.iter().map(|(k, v)| format!("{k}={v}")).collect();
    (cell.cell.app.ord(), cell.seed, params.join(";"))
}

/// Generated inputs keyed by [`preset_key`] — one entry per distinct
/// `(workload, seed, params)` triple, shared read-only by every cell
/// that agrees on the triple (scenarios of one grid point must compare
/// on identical inputs — and generation is deterministic, so a worker
/// process rebuilding the same preset sees the same bytes).
type PresetCache = BTreeMap<(u64, u64, String), WorkloadPreset>;

/// Generate every distinct input `cells` needs, exactly once each.
/// With a [`CacheStore`], the preset layer is consulted first and feeds
/// back: inputs already generated by *any* previous invocation against
/// the same store are deserialized instead of regenerated, and fresh
/// generations are persisted for the next run.
fn build_presets<'a>(
    size: WorkloadSize,
    cells: impl Iterator<Item = &'a PlannedCell>,
    store: Option<&CacheStore>,
) -> PresetCache {
    let mut presets = PresetCache::new();
    for pc in cells {
        presets.entry(preset_key(pc)).or_insert_with(|| {
            if let Some(store) = store {
                let key = cache::preset_key(pc.cell.app, size, pc.seed, &pc.params);
                if let Some(p) = store.load_preset(&key, pc.cell.app, size, pc.seed) {
                    return p;
                }
                let p = WorkloadPreset::with_params(pc.cell.app, size, pc.seed, &pc.params)
                    .unwrap_or_else(|e| panic!("{e}"));
                store.insert_preset(&key, &p);
                return p;
            }
            WorkloadPreset::with_params(pc.cell.app, size, pc.seed, &pc.params)
                .unwrap_or_else(|e| panic!("{e}"))
        });
    }
    presets
}

/// Stage 3 of the pipeline: execute one [`ShardSpec`] serially in the
/// calling context, in ascending grid-index order, generating the
/// shard's own inputs (the subprocess executor: a worker shares no
/// memory with its siblings). Returns `(global grid index, result)`
/// pairs for reassembly.
pub fn execute_shard(spec: &ShardSpec) -> Vec<(usize, CellResult)> {
    let presets = build_presets(spec.size, spec.cells.iter().map(|(_, pc)| pc), None);
    execute_shard_with(spec, &presets)
}

/// [`execute_shard`] against an already-generated preset cache — the
/// in-process executor generates each distinct input once per *run* and
/// shares it read-only across all shard threads, like the pre-pipeline
/// runner did.
fn execute_shard_with(spec: &ShardSpec, presets: &PresetCache) -> Vec<(usize, CellResult)> {
    spec.cells
        .iter()
        .map(|(index, pc)| (*index, run_planned_cell(spec, pc, &presets[&preset_key(pc)])))
        .collect()
}

/// Run one planned cell of a shard against its (already-generated)
/// preset: build the device, simulate, and (when the shard asks)
/// validate against the workload's native oracle.
fn run_planned_cell(spec: &ShardSpec, pc: &PlannedCell, preset: &WorkloadPreset) -> CellResult {
    let mut cfg = DeviceConfig {
        num_cus: pc.cell.num_cus,
        ..spec.cfg.clone()
    };
    cfg.proto_params.extend_from_slice(&pc.proto_params);
    let (result, validated) = if spec.validate {
        let (run, ok) = run_validated(&cfg, preset, pc.cell.scenario);
        (run, Some(ok))
    } else {
        let (mut wl, image) = preset.instantiate();
        let (run, _mem) = run_scenario_seeded(
            &cfg,
            pc.cell.scenario,
            wl.as_mut(),
            NativeMath,
            preset.max_rounds,
            image,
        );
        (run, None)
    };
    CellResult {
        cell: pc.cell,
        seed: preset.seed,
        params: preset.params.overrides_display(),
        proto_params: protocol::overrides_display(pc.cell.scenario.protocol(), &cfg.proto_params),
        axis_values: pc.axis_values.clone(),
        remote_ratio: preset.remote_ratio(),
        result,
        validated,
    }
}

/// The in-process executor: run the plan's cells across `jobs` worker
/// threads pulling from one shared work-stealing queue (an atomic
/// next-index over the plan), reassembling by global grid index. With
/// one job the cells run serially on the calling thread (undisturbed
/// panic messages). Scheduling never touches results — every cell
/// carries its grid index and lands in its slot regardless of which
/// thread ran it — so any jobs count is byte-identical to `--jobs 1`.
pub fn execute_plan(plan: &ExecutionPlan, jobs: usize) -> Vec<CellResult> {
    execute_plan_with_store(plan, jobs, None)
}

/// [`execute_plan`] with an optional result-cache store backing the
/// preset layer. All store access happens on the calling thread (preset
/// generation up front, before the worker threads spawn).
fn execute_plan_with_store(
    plan: &ExecutionPlan,
    jobs: usize,
    store: Option<&CacheStore>,
) -> Vec<CellResult> {
    // One all-cells spec carries the run shape (device config, size,
    // validation) the cell executor needs; the queue deals its cells
    // out dynamically instead of pre-splitting them.
    let spec = shard::partition(plan, 1)
        .pop()
        .expect("partition yields at least one shard");
    // Generate each distinct input once for the whole run, up front;
    // the worker threads share the cache read-only. (Subprocess workers
    // regenerate their shard's inputs instead — no shared memory.)
    let presets = build_presets(plan.size, plan.cells.iter(), store);
    let jobs = jobs.clamp(1, plan.cells.len().max(1));
    let indexed: Vec<(usize, CellResult)> = if jobs == 1 {
        execute_shard_with(&spec, &presets)
    } else {
        execute_stealing(&spec, jobs, &presets)
    };
    let mut slots: Vec<Option<CellResult>> = plan.cells.iter().map(|_| None).collect();
    for (i, r) in indexed {
        assert!(slots[i].is_none(), "grid cell {i} executed twice");
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|s| s.expect("an executor exited without covering its cells"))
        .collect()
}

/// The work-stealing parallel section: `jobs` threads pull cells off a
/// shared atomic cursor in plan order until it runs dry. A pull whose
/// queue position falls outside the thread's static share of the plan
/// (the balanced contiguous deal `position * jobs / cells`) counts as a
/// steal — the load imbalance the shared queue actually corrected
/// relative to a static split. Per-thread busy/idle wall time and the
/// steal count feed the perfstats collector (stderr one-liners and the
/// bench artifact); none of it is report data.
fn execute_stealing(
    spec: &ShardSpec,
    jobs: usize,
    presets: &PresetCache,
) -> Vec<(usize, CellResult)> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Instant;

    let n = spec.cells.len();
    let cursor = AtomicUsize::new(0);
    let mut all = Vec::with_capacity(n);
    let (mut steals, mut busy, mut idle) = (0u64, 0u64, 0u64);
    thread::scope(|scope| {
        let cursor = &cursor;
        let handles: Vec<_> = (0..jobs)
            .map(|t| {
                scope.spawn(move || {
                    let section = Instant::now();
                    let mut part = Vec::new();
                    let (mut steals, mut busy) = (0u64, 0u64);
                    loop {
                        let k = cursor.fetch_add(1, Ordering::Relaxed);
                        if k >= n {
                            break;
                        }
                        if k * jobs / n != t {
                            steals += 1;
                        }
                        let (index, pc) = &spec.cells[k];
                        let t0 = Instant::now();
                        part.push((*index, run_planned_cell(spec, pc, &presets[&preset_key(pc)])));
                        busy += t0.elapsed().as_nanos() as u64;
                    }
                    let wall = section.elapsed().as_nanos() as u64;
                    // Each worker returns its results plus its
                    // thread-local perf counters; the caller folds them
                    // into its own collector so `--jobs N` loses no
                    // wall-clock attribution.
                    (part, perfstats::take_thread(), steals, busy, wall.saturating_sub(busy))
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok((mut part, perf, s, b, i)) => {
                    perfstats::add_thread(&perf);
                    steals += s;
                    busy += b;
                    idle += i;
                    all.append(&mut part);
                }
                // Re-raise the worker's own panic payload (e.g. a bad
                // --param key) instead of a generic join error.
                Err(e) => std::panic::resume_unwind(e),
            }
        }
    });
    perfstats::add_sched(steals, busy, idle, jobs as u64);
    all
}

/// One cell of a cache-aware execution: either freshly simulated this
/// run, or a lossless row served from the result cache. The cached row
/// *is* the row [`ReportRow::from_cell`] produced when the cell was
/// first simulated and validated, so reports assembled from outcomes
/// are byte-identical to a cold run.
pub enum CellOutcome {
    Fresh(CellResult),
    Cached(ReportRow),
}

impl CellOutcome {
    /// The report row of this cell, whichever path produced it.
    pub fn row(&self) -> ReportRow {
        match self {
            CellOutcome::Fresh(c) => ReportRow::from_cell(c),
            CellOutcome::Cached(r) => r.clone(),
        }
    }

    /// The full [`CellResult`], available only for freshly-simulated
    /// cells (a cached row cannot reconstruct the full `Stats`).
    pub fn fresh(&self) -> Option<&CellResult> {
        match self {
            CellOutcome::Fresh(c) => Some(c),
            CellOutcome::Cached(_) => None,
        }
    }
}

/// Whether a plan's cells participate in the cell-result layer: only
/// oracle-validated rows are trustworthy enough to store, and traced
/// runs are for observation, not caching (a served cell would silently
/// emit no events).
pub(crate) fn cell_layer_active(validate: bool, cfg: &DeviceConfig) -> bool {
    validate && cfg.trace_capacity == 0
}

/// The cache-aware in-process executor: probe the store for every cell,
/// simulate only the misses (through the same shard pipeline as the
/// uncached path), and insert each freshly-validated row. Returns the
/// outcomes in grid order plus the run's cache counters (already folded
/// into the perfstats one-liner). With no store this is exactly
/// [`execute_plan`].
pub fn execute_plan_cached(
    plan: &ExecutionPlan,
    jobs: usize,
    store: Option<&CacheStore>,
) -> (Vec<CellOutcome>, CacheCounters) {
    let Some(store) = store else {
        let results = execute_plan(plan, jobs);
        return (
            results.into_iter().map(CellOutcome::Fresh).collect(),
            CacheCounters::default(),
        );
    };
    let cache_cells = cell_layer_active(plan.validate, &plan.cfg);
    let mut slots: Vec<Option<CellOutcome>> = plan.cells.iter().map(|_| None).collect();
    let (mut miss_idx, mut miss_cells) = (Vec::new(), Vec::new());
    for (i, pc) in plan.cells.iter().enumerate() {
        let hit = if cache_cells {
            store.lookup_cell(&cache::cell_key(&plan.cfg, plan.size, plan.validate, pc))
        } else {
            None
        };
        match hit {
            Some(row) => slots[i] = Some(CellOutcome::Cached(row)),
            None => {
                miss_idx.push(i);
                miss_cells.push(pc.clone());
            }
        }
    }
    if !miss_cells.is_empty() {
        let sub = ExecutionPlan {
            cells: miss_cells.clone(),
            ..plan.clone()
        };
        let results = execute_plan_with_store(&sub, jobs, Some(store));
        for ((&i, pc), r) in miss_idx.iter().zip(miss_cells.iter()).zip(results) {
            if cache_cells && r.validated == Some(true) {
                store.insert_cell(
                    &cache::cell_key(&plan.cfg, plan.size, plan.validate, pc),
                    &ReportRow::from_cell(&r),
                );
            }
            slots[i] = Some(CellOutcome::Fresh(r));
        }
    }
    let counters = store.take_counters();
    perfstats::add_cache(counters.hits, counters.misses, counters.preset_reuses);
    (
        slots
            .into_iter()
            .map(|s| s.expect("every planned cell resolves to an outcome"))
            .collect(),
        counters,
    )
}

/// The cache-aware worker executor: [`execute_shard`] with
/// lookup-before-execute and insert-after-validate against `store`.
/// Serial like the uncached shard path; outcomes come back ascending by
/// global grid index.
pub fn execute_shard_cached(
    spec: &ShardSpec,
    store: &CacheStore,
) -> (Vec<(usize, CellOutcome)>, CacheCounters) {
    let cache_cells = cell_layer_active(spec.validate, &spec.cfg);
    let mut outcomes: Vec<(usize, CellOutcome)> = Vec::with_capacity(spec.cells.len());
    let mut miss_cells = Vec::new();
    for (i, pc) in &spec.cells {
        let hit = if cache_cells {
            store.lookup_cell(&cache::cell_key(&spec.cfg, spec.size, spec.validate, pc))
        } else {
            None
        };
        match hit {
            Some(row) => outcomes.push((*i, CellOutcome::Cached(row))),
            None => miss_cells.push((*i, pc.clone())),
        }
    }
    if !miss_cells.is_empty() {
        let sub = ShardSpec {
            cells: miss_cells,
            ..spec.clone()
        };
        let presets = build_presets(sub.size, sub.cells.iter().map(|(_, pc)| pc), Some(store));
        let results = execute_shard_with(&sub, &presets);
        for ((i, pc), (ri, r)) in sub.cells.iter().zip(results) {
            debug_assert_eq!(*i, ri);
            if cache_cells && r.validated == Some(true) {
                store.insert_cell(
                    &cache::cell_key(&spec.cfg, spec.size, spec.validate, pc),
                    &ReportRow::from_cell(&r),
                );
            }
            outcomes.push((*i, CellOutcome::Fresh(r)));
        }
    }
    outcomes.sort_by_key(|(i, _)| *i);
    let counters = store.take_counters();
    perfstats::add_cache(counters.hits, counters.misses, counters.preset_reuses);
    (outcomes, counters)
}

impl ReportRow {
    /// The report projection of one executed cell — the single place a
    /// [`CellResult`] becomes a row, shared by the whole-run report and
    /// the per-shard partial reports so the two can never drift.
    pub fn from_cell(c: &CellResult) -> ReportRow {
        ReportRow {
            app: c.result.app.to_string(),
            scenario: c.result.scenario.name().to_string(),
            cus: c.cell.num_cus,
            seed: c.seed,
            params: c.params.clone(),
            proto_params: c.proto_params.clone(),
            axis_values: c.axis_values.clone(),
            remote_ratio: c.remote_ratio,
            rounds: c.result.rounds,
            converged: c.result.converged,
            validated: c.validated,
            cycles: c.result.stats.cycles,
            instructions: c.result.stats.instructions,
            l1_hit_rate: c.result.stats.l1_hit_rate(),
            l2_accesses: c.result.stats.l2_accesses,
            sync_overhead_cycles: c.result.stats.sync_overhead_cycles,
            tasks_executed: c.result.stats.tasks_executed,
            tasks_stolen: c.result.stats.tasks_stolen,
            lr_tbl_overflows: c.result.stats.lr_tbl_overflows,
            pa_tbl_overflows: c.result.stats.pa_tbl_overflows,
            selective_flush_nops: c.result.stats.selective_flush_nops,
            selective_flush_drains: c.result.stats.selective_flush_drains,
        }
    }
}

impl Report {
    /// Assemble the machine-readable report for a set of executed cells.
    pub fn from_cells(results: &[CellResult]) -> Report {
        Report {
            rows: results.iter().map(ReportRow::from_cell).collect(),
        }
    }

    /// Assemble the report for a cache-aware execution. Cached rows are
    /// the stored lossless rows, fresh rows project through
    /// [`ReportRow::from_cell`] — the same path as [`Report::from_cells`],
    /// so a warm report is byte-identical to its cold counterpart.
    pub fn from_outcomes(outcomes: &[CellOutcome]) -> Report {
        Report {
            rows: outcomes.iter().map(CellOutcome::row).collect(),
        }
    }
}

impl PartialReport {
    /// Package one executed shard as the worker-boundary artifact
    /// (stage-3 output): rows tagged with their global grid index, plus
    /// the run shape the merge stage checks completeness against.
    pub fn from_shard(spec: &ShardSpec, results: &[(usize, CellResult)]) -> PartialReport {
        PartialReport {
            shard: spec.shard,
            num_shards: spec.num_shards,
            total_cells: spec.total_cells,
            cache: CacheCounters::default(),
            rows: results
                .iter()
                .map(|(i, c)| (*i, ReportRow::from_cell(c)))
                .collect(),
        }
    }

    /// [`PartialReport::from_shard`] for a cache-aware worker: cached
    /// and fresh outcomes both contribute their lossless rows, and the
    /// shard's cache counters ride the envelope for the coordinator to
    /// sum.
    pub fn from_outcomes(
        spec: &ShardSpec,
        outcomes: &[(usize, CellOutcome)],
        cache: CacheCounters,
    ) -> PartialReport {
        PartialReport {
            shard: spec.shard,
            num_shards: spec.num_shards,
            total_cells: spec.total_cells,
            cache,
            rows: outcomes.iter().map(|(i, o)| (*i, o.row())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{axis, classic_grid, full_grid, RATIO_SCENARIOS};
    use crate::harness::presets::DEFAULT_SEED;
    use crate::workload::registry;

    fn tiny_runner(jobs: usize, seeding: Seeding, validate: bool) -> Runner {
        Runner {
            jobs,
            seeding,
            size: WorkloadSize::Tiny,
            validate,
            params: Vec::new(),
            cfg: DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
        }
    }

    #[test]
    fn jobs_1_and_jobs_4_byte_identical() {
        let cells = classic_grid(4);
        let serial = tiny_runner(1, Seeding::PerCell(42), false).run_cells(&cells);
        let parallel = tiny_runner(4, Seeding::PerCell(42), false).run_cells(&cells);
        // Full structural equality, stats included (Debug covers every
        // counter, including the BTreeMap of named counters).
        assert_eq!(
            format!("{serial:?}"),
            format!("{parallel:?}"),
            "--jobs must never change results"
        );
        // And the emitted artifacts are byte-identical too.
        let a = Report::from_cells(&serial);
        let b = Report::from_cells(&parallel);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn validation_passes_on_tiny_cells() {
        let cells = [
            Cell {
                app: registry::PRK,
                scenario: Scenario::BASELINE,
                num_cus: 4,
            },
            Cell {
                app: registry::SSSP,
                scenario: Scenario::SRSP,
                num_cus: 4,
            },
            Cell {
                app: registry::MIS,
                scenario: Scenario::RSP,
                num_cus: 4,
            },
            Cell {
                app: registry::BFS,
                scenario: Scenario::SRSP,
                num_cus: 4,
            },
        ];
        let results = tiny_runner(2, Seeding::default(), true).run_cells(&cells);
        for c in &results {
            assert_eq!(
                c.validated,
                Some(true),
                "{}/{} failed its oracle",
                c.result.app,
                c.result.scenario
            );
            assert_eq!(c.seed, DEFAULT_SEED);
            assert_eq!(c.params, "", "matrix cells run pure defaults");
            assert_eq!(c.axis_values, "", "plain grid cells carry no axis coordinates");
        }
        let report = Report::from_cells(&results);
        assert_eq!(report.rows.len(), cells.len());
        assert!(report.to_csv().contains(",true,"));
    }

    #[test]
    fn full_grid_covers_every_registered_workload_and_validates() {
        // The registry round-trip at runner level: every registered
        // workload × srsp validates on the tiny device.
        let cells: Vec<Cell> = full_grid(4)
            .into_iter()
            .filter(|c| c.scenario == Scenario::SRSP)
            .collect();
        assert_eq!(cells.len(), registry::all().count());
        let results = tiny_runner(4, Seeding::default(), true).run_cells(&cells);
        for c in &results {
            assert_eq!(
                c.validated,
                Some(true),
                "{}/{} failed its oracle",
                c.result.app,
                c.result.scenario
            );
        }
    }

    #[test]
    fn remote_ratio_sweep_shape_params_and_oracles() {
        let runner = tiny_runner(4, Seeding::default(), true);
        let points = [0.0, 0.5];
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, points.to_vec())
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), points.len() * RATIO_SCENARIOS.len());
        for (i, c) in results.iter().enumerate() {
            let (want_r, want_scenario) = (points[i / 3], RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.scenario, want_scenario);
            assert_eq!(c.remote_ratio, Some(want_r), "cell {i}");
            assert_eq!(c.validated, Some(true), "{want_scenario:?} r={want_r}");
            assert_eq!(c.params, format!("remote_ratio={want_r}"));
            assert_eq!(c.axis_values, format!("remote-ratio={want_r}"));
        }
        // The report carries the axis as a first-class column.
        let report = Report::from_cells(&results);
        assert!(report.to_csv().contains("axis_values"));
        assert!(report.to_csv().contains("remote-ratio=0.5"));
    }

    #[test]
    fn cu_count_sweep_shape_and_oracles() {
        let runner = tiny_runner(4, Seeding::PerCell(11), true);
        let points = [2.0, 4.0];
        let plan = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT])
            .unwrap()
            .with_points(axis::CU_COUNT, points.to_vec())
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), points.len() * RATIO_SCENARIOS.len());
        for (i, c) in results.iter().enumerate() {
            let (want_cus, want_scenario) = (points[i / 3] as u32, RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.scenario, want_scenario);
            assert_eq!(c.cell.num_cus, want_cus, "cell {i}");
            assert_eq!(c.validated, Some(true), "{want_scenario:?} cus={want_cus}");
        }
        // All protocols at one CU count share a seed (identical inputs);
        // different CU counts derive different ones under PerCell.
        assert_eq!(results[0].seed, results[2].seed);
        assert_ne!(results[0].seed, results[3].seed);
        // The report carries the axis through the existing cus column
        // and the long-format coordinate column.
        let report = Report::from_cells(&results);
        assert!(report.to_csv().contains(",2,"));
        assert!(report.to_csv().contains("cu-count=4"));
    }

    #[test]
    fn composed_sweep_crosses_both_axes() {
        let runner = tiny_runner(4, Seeding::PerCell(3), true);
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 1.0])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![2.0, 4.0])
            .unwrap();
        let results = runner.run_sweep(&plan);
        assert_eq!(results.len(), 2 * 2 * RATIO_SCENARIOS.len());
        let combos = plan.combos();
        for (i, c) in results.iter().enumerate() {
            let combo = &combos[i / RATIO_SCENARIOS.len()];
            assert_eq!(c.cell.scenario, RATIO_SCENARIOS[i % 3]);
            assert_eq!(c.cell.num_cus, combo.spec.num_cus.unwrap());
            assert_eq!(c.remote_ratio, combo.coord(axis::REMOTE_RATIO));
            assert_eq!(c.axis_values, combo.axis_values());
            assert_eq!(c.validated, Some(true), "cell {i}: {}", c.axis_values);
        }
        // Scenarios of one combo share the input; the device size drives
        // the seed, the ratio does not (placement over one population).
        assert_eq!(results[0].seed, results[2].seed);
        assert_eq!(results[0].seed, results[6].seed, "ratio must not reseed");
        assert_ne!(results[0].seed, results[3].seed, "CU count must reseed");
    }

    #[test]
    fn registry_only_axes_run_end_to_end() {
        // hot-set and migration exist only as axis-registry entries; the
        // runner and coordinator carry no code specific to them.
        let runner = tiny_runner(4, Seeding::default(), true);
        for (id, key) in [(axis::HOT_SET, "hot_set"), (axis::MIGRATION, "migration")] {
            let plan = SweepPlan::new(registry::STRESS, &[id])
                .unwrap()
                .with_points(id, vec![1.0, 2.0])
                .unwrap();
            let results = runner.run_sweep(&plan);
            assert_eq!(results.len(), 2 * RATIO_SCENARIOS.len());
            for c in &results {
                assert_eq!(c.validated, Some(true), "{}: {}", id.name(), c.axis_values);
            }
            assert_eq!(results[0].params, format!("{key}=1"));
            assert_eq!(results[3].axis_values, format!("{}=2", id.name()));
        }
    }

    #[test]
    fn sweep_jobs_1_and_4_byte_identical() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![2.0, 4.0])
            .unwrap();
        let serial = tiny_runner(1, Seeding::PerCell(9), true).run_sweep(&plan);
        let parallel = tiny_runner(4, Seeding::PerCell(9), true).run_sweep(&plan);
        assert_eq!(format!("{serial:?}"), format!("{parallel:?}"));
        assert_eq!(
            Report::from_cells(&serial).to_csv(),
            Report::from_cells(&parallel).to_csv()
        );
    }

    #[test]
    fn sharded_partials_merge_byte_identical_to_in_process() {
        // The pipeline's acceptance property at the library level: for
        // any shard count, executing the shards separately and merging
        // their (JSON-round-tripped) partial reports reproduces the
        // in-process report byte for byte.
        let runner = tiny_runner(4, Seeding::PerCell(9), true);
        let cells = classic_grid(4);
        let direct = Report::from_cells(&runner.run_cells(&cells));
        let plan = ExecutionPlan::lower_cells(&runner, &cells);
        for workers in [1, 2, 4] {
            let partials: Vec<PartialReport> = shard::partition(&plan, workers)
                .iter()
                .map(|s| PartialReport::from_shard(s, &execute_shard(s)))
                .map(|p| PartialReport::from_json(&p.to_json()).expect("partial round-trip"))
                .collect();
            let merged = Report::merge(&partials).unwrap();
            assert_eq!(merged.to_csv(), direct.to_csv(), "{workers} workers");
            assert_eq!(merged.to_json(), direct.to_json(), "{workers} workers");
        }
    }

    #[test]
    fn proto_params_reach_the_device_and_the_report() {
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.cfg.proto_params = vec![("lr_tbl_entries".to_string(), 1.0)];
        let srsp = runner.run_cell(&Cell {
            app: registry::STRESS,
            scenario: Scenario::SRSP,
            num_cus: 4,
        });
        // The one-entry LR-TBL must actually be in effect (overflows
        // fire) and the cell still validates.
        assert_eq!(srsp.validated, Some(true));
        assert!(srsp.result.stats.lr_tbl_overflows > 0);
        assert_eq!(srsp.proto_params, "lr_tbl_entries=1");
        // A scoped-protocol cell ignores the key and reports nothing.
        let steal = runner.run_cell(&Cell {
            app: registry::STRESS,
            scenario: Scenario::STEAL_ONLY,
            num_cus: 4,
        });
        assert_eq!(steal.validated, Some(true));
        assert_eq!(steal.proto_params, "");
        let report = Report::from_cells(&[srsp, steal]);
        assert!(report.to_csv().contains("lr_tbl_entries=1"));
    }

    #[test]
    fn runner_params_reach_the_preset() {
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.params = vec![("tasks".to_string(), 32.0)];
        let cell = Cell {
            app: registry::STRESS,
            scenario: Scenario::SRSP,
            num_cus: 4,
        };
        let r = runner.run_cell(&cell);
        assert_eq!(r.params, "tasks=32");
        assert_eq!(r.validated, Some(true));
    }

    #[test]
    fn sweep_axis_overrides_win_over_runner_params() {
        // The axis owns its key: a user --param remote_ratio is
        // overridden by the swept coordinate, not silently kept.
        let mut runner = tiny_runner(1, Seeding::default(), true);
        runner.params = vec![("remote_ratio".to_string(), 0.9)];
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.25])
            .unwrap();
        let results = runner.run_sweep(&plan);
        for c in &results {
            assert_eq!(c.remote_ratio, Some(0.25));
            assert_eq!(c.validated, Some(true));
        }
    }

    #[test]
    #[should_panic(expected = "unknown parameter")]
    fn runner_rejects_unknown_params() {
        let mut runner = tiny_runner(1, Seeding::default(), false);
        runner.params = vec![("bogus".to_string(), 1.0)];
        let cell = Cell {
            app: registry::PRK,
            scenario: Scenario::BASELINE,
            num_cus: 4,
        };
        let _ = runner.run_cell(&cell);
    }
}
