//! The parallel scenario-matrix runner: shards the protocol × app ×
//! CU-count grid across OS threads.
//!
//! Every grid [`Cell`] is an independent, single-threaded simulation —
//! its own [`Device`](crate::gpu::Device), memory image and workload
//! instance are all constructed inside the worker thread that executes
//! it — so cells parallelize with no shared mutable state. Workers pull
//! cell indices from an atomic counter (dynamic load balancing: the
//! 64-CU sRSP cells cost far more than the 4-CU baseline cells) and send
//! results back over a channel; results are reassembled in grid order,
//! so the output is byte-for-byte identical for any `--jobs` value.
//!
//! Seeding is deterministic either way: [`Seeding::Shared`] reproduces
//! the classic figure presets, [`Seeding::PerCell`] derives an
//! independent [`SplitMix64`] stream per (app, CU-count) pair. The seed
//! deliberately ignores the scenario: all scenarios of one app at one CU
//! count must share an input graph or vs-Baseline ratios would compare
//! different problems.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::thread;

use super::presets::{WorkloadPreset, WorkloadSize, DEFAULT_SEED};
use super::report::{Report, ReportRow};
use crate::config::{DeviceConfig, Scenario};
use crate::mem::{BackingStore, MemAlloc};
use crate::sim::SplitMix64;
use crate::workload::driver::{run_scenario_seeded, App, RunResult};
use crate::workload::engine::NativeMath;
use crate::workload::mis::Mis;
use crate::workload::pagerank::PageRank;
use crate::workload::sssp::Sssp;

/// One cell of the protocol × app × CU-count grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    pub app: App,
    pub scenario: Scenario,
    pub num_cus: u32,
}

/// How workload-generation seeds are assigned to grid cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Seeding {
    /// Every cell uses the same seed — the classic figure presets
    /// (`DEFAULT_SEED` reproduces the paper figures byte-for-byte).
    Shared(u64),
    /// Each (app, CU-count) pair derives its own seed from a base value
    /// via [`SplitMix64`]; scenarios still share the graph (see module
    /// docs).
    PerCell(u64),
}

impl Default for Seeding {
    fn default() -> Self {
        Seeding::Shared(DEFAULT_SEED)
    }
}

impl Seeding {
    /// The workload seed for `cell`.
    pub fn seed_for(self, cell: &Cell) -> u64 {
        match self {
            Seeding::Shared(seed) => seed,
            Seeding::PerCell(base) => {
                let tag = ((app_ord(cell.app) + 1) << 32) | u64::from(cell.num_cus);
                SplitMix64::new(base ^ tag).next_u64()
            }
        }
    }
}

/// Stable per-app ordinal used for seed derivation (do not reorder:
/// recorded seeds in saved reports depend on it).
fn app_ord(app: App) -> u64 {
    match app {
        App::PageRank => 0,
        App::Sssp => 1,
        App::Mis => 2,
    }
}

/// Outcome of one executed cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub cell: Cell,
    /// The workload seed the cell actually ran with.
    pub seed: u64,
    pub result: RunResult,
    /// `Some(ok)` when oracle validation was requested.
    pub validated: Option<bool>,
}

/// The full §5.1 evaluation grid (every app × every scenario) at one CU
/// count, in stable (app-major) order.
pub fn full_grid(num_cus: u32) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(App::ALL.len() * Scenario::ALL.len());
    for app in App::ALL {
        for scenario in Scenario::ALL {
            cells.push(Cell {
                app,
                scenario,
                num_cus,
            });
        }
    }
    cells
}

/// Strip cell metadata for the figure pipelines, which require every run
/// to have converged (`max_rounds` bounds are sized so the classic seeds
/// always do).
pub fn into_run_results(results: Vec<CellResult>) -> Vec<RunResult> {
    results
        .into_iter()
        .map(|c| {
            assert!(
                c.result.converged,
                "{}/{} on {} CUs did not converge (seed {:#x})",
                c.result.app, c.result.scenario, c.cell.num_cus, c.seed
            );
            c.result
        })
        .collect()
}

/// Run one (preset, scenario) pair and check the final memory against
/// the app's native oracle: exactness for SSSP/MIS, L1-norm tolerance
/// for PageRank (floating-point accumulation order differs between the
/// tiled device math and the oracle).
pub fn run_validated(
    cfg: &DeviceConfig,
    preset: &WorkloadPreset,
    scenario: Scenario,
) -> (RunResult, bool) {
    let mut alloc = MemAlloc::new();
    let mut image = BackingStore::new();
    match preset.app {
        App::PageRank => {
            let mut wl = PageRank::setup(
                &preset.graph,
                &mut alloc,
                &mut image,
                preset.chunk,
                preset.iters,
            );
            let oracle = PageRank::oracle(&preset.graph, preset.iters);
            let (run, mem) = run_scenario_seeded(
                cfg,
                scenario,
                &mut wl,
                NativeMath,
                preset.max_rounds,
                image,
            );
            let got = wl.result(&mem);
            let diff: f32 = got.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).sum();
            let ok = run.converged && diff < 1e-3;
            (run, ok)
        }
        App::Sssp => {
            let mut wl = Sssp::setup(&preset.graph, &mut alloc, &mut image, preset.chunk, 0);
            let oracle = Sssp::oracle(&preset.graph, 0);
            let (run, mem) = run_scenario_seeded(
                cfg,
                scenario,
                &mut wl,
                NativeMath,
                preset.max_rounds,
                image,
            );
            let ok = run.converged && wl.result(&mem) == oracle;
            (run, ok)
        }
        App::Mis => {
            let mut wl = Mis::setup(&preset.graph, &mut alloc, &mut image, preset.chunk);
            let oracle = Mis::oracle(&preset.graph);
            let (run, mem) = run_scenario_seeded(
                cfg,
                scenario,
                &mut wl,
                NativeMath,
                preset.max_rounds,
                image,
            );
            let got = wl.result(&mem);
            let ok = run.converged
                && Mis::validate_mis(&preset.graph, &got).is_ok()
                && got == oracle;
            (run, ok)
        }
    }
}

/// The scenario-matrix runner configuration.
#[derive(Debug, Clone)]
pub struct Runner {
    /// Worker thread count (0 is treated as 1; clamped to the cell
    /// count).
    pub jobs: usize,
    pub seeding: Seeding,
    pub size: WorkloadSize,
    /// Check every cell against its native oracle.
    pub validate: bool,
    /// Device template; `num_cus` is overridden per cell.
    pub cfg: DeviceConfig,
}

impl Runner {
    /// A runner with classic shared seeding and no validation — the
    /// configuration the figure pipelines use.
    pub fn new(cfg: DeviceConfig, size: WorkloadSize, jobs: usize) -> Self {
        Runner {
            jobs,
            seeding: Seeding::default(),
            size,
            validate: false,
            cfg,
        }
    }

    /// Worker count the host reports as available.
    pub fn default_jobs() -> usize {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Run one standalone cell: generates the input graph, builds the
    /// device, simulates and (when enabled) validates, entirely within
    /// the calling thread.
    pub fn run_cell(&self, cell: &Cell) -> CellResult {
        let seed = self.seeding.seed_for(cell);
        let preset = WorkloadPreset::new_seeded(cell.app, self.size, seed);
        self.run_cell_with(cell, &preset)
    }

    /// Run `cell` against an already-generated preset (which must match
    /// the cell's app and the runner's seeding — `run_cells` shares one
    /// preset across all scenarios of an (app, CU-count) pair instead of
    /// regenerating the identical graph per scenario).
    fn run_cell_with(&self, cell: &Cell, preset: &WorkloadPreset) -> CellResult {
        let cfg = DeviceConfig {
            num_cus: cell.num_cus,
            ..self.cfg.clone()
        };
        let (result, validated) = if self.validate {
            let (run, ok) = run_validated(&cfg, preset, cell.scenario);
            (run, Some(ok))
        } else {
            let (mut wl, image) = preset.instantiate();
            let (run, _mem) = run_scenario_seeded(
                &cfg,
                cell.scenario,
                wl.as_mut(),
                NativeMath,
                preset.max_rounds,
                image,
            );
            (run, None)
        };
        CellResult {
            cell: *cell,
            seed: preset.seed,
            result,
            validated,
        }
    }

    /// Run `cells` across `self.jobs` OS threads. Returns results in
    /// `cells` order regardless of scheduling, so any jobs count yields
    /// byte-identical output.
    pub fn run_cells(&self, cells: &[Cell]) -> Vec<CellResult> {
        // Seeds ignore the scenario, so every distinct (app, seed) pair
        // needs exactly one input graph: generate each once, up front,
        // and share it read-only across the workers.
        let mut presets: HashMap<(App, u64), WorkloadPreset> = HashMap::new();
        for cell in cells {
            let seed = self.seeding.seed_for(cell);
            presets
                .entry((cell.app, seed))
                .or_insert_with(|| WorkloadPreset::new_seeded(cell.app, self.size, seed));
        }
        let presets = &presets;
        let jobs = self.jobs.clamp(1, cells.len().max(1));
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, CellResult)>();
        thread::scope(|scope| {
            for _ in 0..jobs {
                let tx = tx.clone();
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    let Some(cell) = cells.get(i) else { break };
                    let seed = self.seeding.seed_for(cell);
                    let preset = &presets[&(cell.app, seed)];
                    if tx.send((i, self.run_cell_with(cell, preset))).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);
        let mut slots: Vec<Option<CellResult>> = cells.iter().map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("worker exited without reporting its cell"))
            .collect()
    }
}

impl Report {
    /// Assemble the machine-readable report for a set of executed cells.
    pub fn from_cells(results: &[CellResult]) -> Report {
        let rows = results
            .iter()
            .map(|c| ReportRow {
                app: c.result.app.to_string(),
                scenario: c.result.scenario.name().to_string(),
                cus: c.cell.num_cus,
                seed: c.seed,
                rounds: c.result.rounds,
                converged: c.result.converged,
                validated: c.validated,
                cycles: c.result.stats.cycles,
                instructions: c.result.stats.instructions,
                l1_hit_rate: c.result.stats.l1_hit_rate(),
                l2_accesses: c.result.stats.l2_accesses,
                sync_overhead_cycles: c.result.stats.sync_overhead_cycles,
                tasks_executed: c.result.stats.tasks_executed,
                tasks_stolen: c.result.stats.tasks_stolen,
            })
            .collect();
        Report { rows }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_runner(jobs: usize, seeding: Seeding, validate: bool) -> Runner {
        Runner {
            jobs,
            seeding,
            size: WorkloadSize::Tiny,
            validate,
            cfg: DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
        }
    }

    #[test]
    fn grid_covers_every_pair() {
        let g = full_grid(8);
        assert_eq!(g.len(), App::ALL.len() * Scenario::ALL.len());
        for app in App::ALL {
            for scenario in Scenario::ALL {
                assert!(g.iter().any(|c| c.app == app && c.scenario == scenario));
            }
        }
        assert!(g.iter().all(|c| c.num_cus == 8));
    }

    #[test]
    fn per_cell_seeds_share_graphs_across_scenarios() {
        let cell = |app, scenario, num_cus| Cell {
            app,
            scenario,
            num_cus,
        };
        let s = Seeding::PerCell(42);
        let base = s.seed_for(&cell(App::PageRank, Scenario::Baseline, 4));
        // Deterministic.
        assert_eq!(base, s.seed_for(&cell(App::PageRank, Scenario::Baseline, 4)));
        // Scenario must NOT change the seed (ratios need shared inputs).
        assert_eq!(base, s.seed_for(&cell(App::PageRank, Scenario::Srsp, 4)));
        // App and CU count must.
        assert_ne!(base, s.seed_for(&cell(App::Sssp, Scenario::Baseline, 4)));
        assert_ne!(base, s.seed_for(&cell(App::PageRank, Scenario::Baseline, 8)));
        // A different base diverges; shared seeding ignores the cell.
        let other_base = Seeding::PerCell(43);
        assert_ne!(base, other_base.seed_for(&cell(App::PageRank, Scenario::Baseline, 4)));
        let shared = Seeding::Shared(7);
        assert_eq!(7, shared.seed_for(&cell(App::Mis, Scenario::Rsp, 64)));
    }

    #[test]
    fn jobs_1_and_jobs_4_byte_identical() {
        let cells = full_grid(4);
        let serial = tiny_runner(1, Seeding::PerCell(42), false).run_cells(&cells);
        let parallel = tiny_runner(4, Seeding::PerCell(42), false).run_cells(&cells);
        // Full structural equality, stats included (Debug covers every
        // counter, including the BTreeMap of named counters).
        assert_eq!(
            format!("{serial:?}"),
            format!("{parallel:?}"),
            "--jobs must never change results"
        );
        // And the emitted artifacts are byte-identical too.
        let a = Report::from_cells(&serial);
        let b = Report::from_cells(&parallel);
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_json(), b.to_json());
    }

    #[test]
    fn validation_passes_on_tiny_cells() {
        let cells = [
            Cell {
                app: App::PageRank,
                scenario: Scenario::Baseline,
                num_cus: 4,
            },
            Cell {
                app: App::Sssp,
                scenario: Scenario::Srsp,
                num_cus: 4,
            },
            Cell {
                app: App::Mis,
                scenario: Scenario::Rsp,
                num_cus: 4,
            },
        ];
        let results = tiny_runner(2, Seeding::default(), true).run_cells(&cells);
        for c in &results {
            assert_eq!(
                c.validated,
                Some(true),
                "{}/{} failed its oracle",
                c.result.app,
                c.result.scenario
            );
            assert_eq!(c.seed, DEFAULT_SEED);
        }
        let report = Report::from_cells(&results);
        assert_eq!(report.rows.len(), cells.len());
        assert!(report.to_csv().contains(",true,"));
    }
}
