//! Regeneration of the paper's Figures 4–6 and the scaling sweep.
//!
//! * **Fig. 4** — speedup of the five scenarios relative to Baseline per
//!   app, plus geomean.
//! * **Fig. 5** — L2 accesses relative to Baseline (the paper's
//!   bandwidth-utilization proxy).
//! * **Fig. 6** — synchronization overhead of RSP and sRSP relative to
//!   RSP (RSP = 1.0).
//! * **Scaling sweep** — sRSP vs RSP speedup as CU count grows (the §1/§7
//!   scalability claim).
//! * **Sweep surfaces** — the generic reduction of any executed
//!   [`SweepPlan`] (one row per grid combo: coordinates, scoped-steal
//!   baseline, per-protocol speedup) shared by the CLI table and the
//!   sweep benches.

use super::presets::{WorkloadPreset, WorkloadSize};
use super::report::{format_table, geomean, Report, ReportRow};
use super::runner::{into_run_results, CellResult, Runner};
use crate::config::{DeviceConfig, Scenario};
use crate::coordinator::axis::AxisId;
use crate::coordinator::{classic_apps, classic_grid, SweepPlan};
use crate::sim::Stats;
use crate::workload::driver::{run_scenario_seeded, RunResult};
use crate::workload::engine::NativeMath;

// The CU-count sweep's flattened cell list is distribution policy and
// lives with the rest of it; re-exported here for the sweep pipelines.
pub use crate::coordinator::scaling_cells;

/// The §5.1 figure apps' display names, in figure order.
fn classic_names() -> [&'static str; 3] {
    classic_apps().map(|id| id.display())
}

/// One measured cell of a figure.
#[derive(Debug, Clone)]
pub struct FigureCell {
    pub app: &'static str,
    pub scenario: Scenario,
    pub value: f64,
    pub raw: f64,
}

/// A rendered figure: rows = apps (+ geomean), columns = scenarios.
#[derive(Debug, Clone)]
pub struct FigureTable {
    pub title: String,
    pub cells: Vec<FigureCell>,
    pub scenarios: Vec<Scenario>,
}

impl FigureTable {
    pub fn value(&self, app: &str, s: Scenario) -> Option<f64> {
        self.cells
            .iter()
            .find(|c| c.app == app && c.scenario == s)
            .map(|c| c.value)
    }

    /// Geomean across apps for a scenario.
    pub fn geomean(&self, s: Scenario) -> f64 {
        let vals: Vec<f64> = self
            .cells
            .iter()
            .filter(|c| c.scenario == s)
            .map(|c| c.value)
            .collect();
        geomean(&vals)
    }

    pub fn render(&self) -> String {
        let mut header = vec!["app".to_string()];
        header.extend(self.scenarios.iter().map(|s| s.name().to_string()));
        let apps: Vec<&str> = {
            let mut seen = Vec::new();
            for c in &self.cells {
                if !seen.contains(&c.app) {
                    seen.push(c.app);
                }
            }
            seen
        };
        let mut rows = Vec::new();
        for app in &apps {
            let mut row = vec![app.to_string()];
            for &s in &self.scenarios {
                row.push(format!("{:.3}", self.value(app, s).unwrap_or(f64::NAN)));
            }
            rows.push(row);
        }
        let mut gm = vec!["geomean".to_string()];
        for &s in &self.scenarios {
            gm.push(format!("{:.3}", self.geomean(s)));
        }
        rows.push(gm);
        format!("{}\n{}", self.title, format_table(&header, &rows))
    }
}

/// Run every classic (app, scenario) pair once; returns raw stats. Cells
/// are sharded over all available cores through the scenario-matrix
/// [`Runner`]; use [`run_matrix_jobs`] for explicit worker control.
pub fn run_matrix(cfg: &DeviceConfig, size: WorkloadSize) -> Vec<RunResult> {
    run_matrix_jobs(cfg, size, Runner::default_jobs())
}

/// [`run_matrix`] with an explicit worker-thread count. Results are
/// identical for every `jobs` value (grid order, classic seeding).
pub fn run_matrix_jobs(cfg: &DeviceConfig, size: WorkloadSize, jobs: usize) -> Vec<RunResult> {
    let runner = Runner::new(cfg.clone(), size, jobs);
    into_run_results(runner.run_cells(&classic_grid(cfg.num_cus)))
}

/// Run one (preset, scenario) pair.
pub fn run_one(cfg: &DeviceConfig, preset: &WorkloadPreset, scenario: Scenario) -> RunResult {
    let (mut wl, image) = preset.instantiate();
    let (run, _mem) = run_scenario_seeded(
        cfg,
        scenario,
        wl.as_mut(),
        NativeMath,
        preset.max_rounds,
        image,
    );
    assert!(
        run.converged,
        "{:?}/{:?} did not converge within {} rounds",
        preset.id, scenario, preset.max_rounds
    );
    run
}

fn stat_of<'a>(results: &'a [RunResult], app: &str, s: Scenario) -> &'a Stats {
    &results
        .iter()
        .find(|r| r.app == app && r.scenario == s)
        .unwrap_or_else(|| panic!("missing run {app}/{s:?}"))
        .stats
}

/// Fig. 4: speedup vs Baseline (higher is better).
pub fn fig4_speedup(results: &[RunResult]) -> FigureTable {
    let mut cells = Vec::new();
    for app in classic_names() {
        let base = stat_of(results, app, Scenario::BASELINE).cycles as f64;
        for s in Scenario::ALL {
            let c = stat_of(results, app, s).cycles as f64;
            cells.push(FigureCell {
                app,
                scenario: s,
                value: base / c,
                raw: c,
            });
        }
    }
    FigureTable {
        title: "Fig. 4 — speedup relative to Baseline".into(),
        cells,
        scenarios: Scenario::ALL.to_vec(),
    }
}

/// Fig. 5: L2 accesses relative to Baseline (lower is better).
pub fn fig5_l2(results: &[RunResult]) -> FigureTable {
    let mut cells = Vec::new();
    for app in classic_names() {
        let base = stat_of(results, app, Scenario::BASELINE).l2_accesses as f64;
        for s in Scenario::ALL {
            let v = stat_of(results, app, s).l2_accesses as f64;
            cells.push(FigureCell {
                app,
                scenario: s,
                value: v / base,
                raw: v,
            });
        }
    }
    FigureTable {
        title: "Fig. 5 — L2 accesses relative to Baseline".into(),
        cells,
        scenarios: Scenario::ALL.to_vec(),
    }
}

/// Fig. 6: synchronization overhead relative to RSP (RSP = 1.0; lower is
/// better). Compares only the two promotion-capable scenarios, like the
/// paper.
pub fn fig6_overhead(results: &[RunResult]) -> FigureTable {
    let scenarios = vec![Scenario::RSP, Scenario::SRSP];
    let mut cells = Vec::new();
    for app in classic_names() {
        let rsp = stat_of(results, app, Scenario::RSP).sync_overhead_cycles as f64;
        for &s in &scenarios {
            let v = stat_of(results, app, s).sync_overhead_cycles as f64;
            cells.push(FigureCell {
                app,
                scenario: s,
                value: if rsp > 0.0 { v / rsp } else { 1.0 },
                raw: v,
            });
        }
    }
    FigureTable {
        title: "Fig. 6 — sync overhead relative to RSP".into(),
        cells,
        scenarios,
    }
}

/// Scalability sweep: geomean speedup of RSP and sRSP (vs Baseline at the
/// same CU count) as the device grows. Returns rows of
/// `(num_cus, rsp_speedup, srsp_speedup)`.
pub fn scaling_sweep(cus: &[u32], size: WorkloadSize) -> Vec<(u32, f64, f64)> {
    scaling_sweep_jobs(cus, size, Runner::default_jobs())
}

/// [`scaling_sweep`] with an explicit worker count. The whole CU-count ×
/// app × scenario grid is flattened into one cell list, so every
/// simulation — across *all* device sizes — can run concurrently.
pub fn scaling_sweep_jobs(cus: &[u32], size: WorkloadSize, jobs: usize) -> Vec<(u32, f64, f64)> {
    let cells = scaling_cells(cus);
    let runner = Runner::new(DeviceConfig::default(), size, jobs);
    scaling_rows(cus, &runner.run_cells(&cells))
}

/// One reduced row of an executed [`SweepPlan`]: the grid coordinates
/// plus the paper's protocol comparison at that point (speedup of the
/// promotion protocols over global-scope stealing).
#[derive(Debug, Clone)]
pub struct SweepRow {
    /// `(axis, value)` per composed axis, in plan order.
    pub coords: Vec<(AxisId, f64)>,
    /// Cycles of the global-scope stealing baseline at this point.
    pub steal_cycles: u64,
    /// Speedup of naive RSP over the stealing baseline.
    pub rsp_speedup: f64,
    /// Speedup of sRSP over the stealing baseline.
    pub srsp_speedup: f64,
}

/// Reduce executed sweep cells to one [`SweepRow`] per grid combo. The
/// plan must compare the three [`RATIO_SCENARIOS`] protocols (the
/// default every sweep runs) and `results` must be [`Runner::run_sweep`]
/// output for that plan, in its combo-major order.
///
/// [`RATIO_SCENARIOS`]: crate::coordinator::RATIO_SCENARIOS
pub fn sweep_speedup_rows(plan: &SweepPlan, results: &[CellResult]) -> Vec<SweepRow> {
    sweep_speedup_rows_report(plan, &Report::from_cells(results))
}

/// [`sweep_speedup_rows`] over an already-assembled [`Report`] — the
/// form the distributed path reduces, where per-cell results live only
/// inside the workers and the coordinator sees merged report rows. The
/// in-process path delegates here through [`Report::from_cells`], so the
/// two modes share one reduction.
pub fn sweep_speedup_rows_report(plan: &SweepPlan, report: &Report) -> Vec<SweepRow> {
    let per_combo = plan.scenarios.len();
    let combos = plan.combos();
    assert_eq!(
        report.rows.len(),
        combos.len() * per_combo,
        "report must cover the plan's full grid"
    );
    let cycles_of = |chunk: &[ReportRow], scenario: Scenario| {
        chunk
            .iter()
            .find(|r| r.scenario == scenario.name())
            .unwrap_or_else(|| panic!("sweep table needs the {} scenario", scenario.name()))
            .cycles as f64
    };
    combos
        .iter()
        .zip(report.rows.chunks(per_combo))
        .map(|(combo, chunk)| {
            let steal = cycles_of(chunk, Scenario::STEAL_ONLY);
            SweepRow {
                coords: combo.coords.clone(),
                steal_cycles: steal as u64,
                rsp_speedup: steal / cycles_of(chunk, Scenario::RSP),
                srsp_speedup: steal / cycles_of(chunk, Scenario::SRSP),
            }
        })
        .collect()
}

/// Reduce executed sweep cells back to `(num_cus, rsp, srsp)` geomean
/// rows, one per requested CU count.
pub fn scaling_rows(cus: &[u32], results: &[CellResult]) -> Vec<(u32, f64, f64)> {
    cus.iter()
        .map(|&n| {
            let group: Vec<CellResult> = results
                .iter()
                .filter(|c| c.cell.num_cus == n)
                .cloned()
                .collect();
            let group = into_run_results(group);
            let f4 = fig4_speedup(&group);
            (n, f4.geomean(Scenario::RSP), f4.geomean(Scenario::SRSP))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_rows_reduce_in_combo_order() {
        use crate::coordinator::axis;
        use crate::workload::registry;
        let mut runner = Runner::new(
            DeviceConfig {
                num_cus: 4,
                ..DeviceConfig::small()
            },
            WorkloadSize::Tiny,
            4,
        );
        runner.validate = true;
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 1.0])
            .unwrap();
        let results = runner.run_sweep(&plan);
        let rows = sweep_speedup_rows(&plan, &results);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].coords, vec![(axis::REMOTE_RATIO, 0.0)]);
        assert_eq!(rows[1].coords, vec![(axis::REMOTE_RATIO, 1.0)]);
        for r in &rows {
            assert!(r.steal_cycles > 0);
            assert!(r.rsp_speedup > 0.0 && r.srsp_speedup > 0.0);
        }
    }

    #[test]
    fn figure_pipeline_tiny() {
        // End-to-end harness smoke test at tiny scale / 4 CUs.
        let cfg = DeviceConfig {
            num_cus: 4,
            ..DeviceConfig::small()
        };
        let results = run_matrix(&cfg, WorkloadSize::Tiny);
        assert_eq!(results.len(), 15);

        let f4 = fig4_speedup(&results);
        // Baseline speedup is 1.0 by construction.
        for app in classic_names() {
            let v = f4.value(app, Scenario::BASELINE).unwrap();
            assert!((v - 1.0).abs() < 1e-9);
        }
        let f5 = fig5_l2(&results);
        for app in classic_names() {
            assert!((f5.value(app, Scenario::BASELINE).unwrap() - 1.0).abs() < 1e-9);
        }
        let f6 = fig6_overhead(&results);
        for app in classic_names() {
            assert!((f6.value(app, Scenario::RSP).unwrap() - 1.0).abs() < 1e-9);
            // At tiny scale (4 CUs, 2 kB L1s) naive RSP's all-L1 work is
            // nearly free, so only structural facts are asserted here;
            // the paper-scale shape (sRSP ≪ RSP) is validated by the
            // 64-CU integration test and the fig6 bench.
            assert!(f6.value(app, Scenario::SRSP).unwrap() > 0.0);
        }
        // Render paths don't panic.
        let _ = f4.render();
        let _ = f5.render();
        let _ = f6.render();
    }
}
