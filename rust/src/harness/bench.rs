//! The `srsp bench` measurement core and JSON emitter.
//!
//! Replaces the ad-hoc `println!` bench binaries with one shared,
//! versioned pipeline: a bench run measures a set of (workload, scenario)
//! cells through [`figures::run_one`] — warmup runs, then `repeats` timed
//! runs — and emits a `BENCH_*.json` artifact carrying per-repeat wall
//! times, median/min, and derived throughput rates (cells/sec, Minstr/s,
//! Mcycles/s) plus the [`PerfStats`] sim-vs-workload cost attribution.
//!
//! Workloads and scenarios are resolved through the registries
//! ([`registry::resolve`], [`Scenario::from_name`]) rather than
//! hard-coded consts, so `srsp bench hotpath --app sssp --scenario hlrc`
//! measures any registered pair.
//!
//! `--compare-reference` measures every cell under **both** interpreter
//! paths — the kept-in-tree reference path and the decode-once fast path
//! — in one artifact, asserting the simulated results are identical and
//! recording the wall-time speedup. That artifact is the performance
//! evidence for the fast path: the claim ships with its own control.

use std::time::Instant;

use super::figures;
use super::presets::{WorkloadPreset, WorkloadSize};
use crate::config::{DeviceConfig, Scenario};
use crate::jsonio::Json;
use crate::sim::perfstats::{self, PerfStats};
use crate::workload::registry::{self, WorkloadId};

/// Version of the emitted `BENCH_*.json` schema. Bump on any field
/// rename/removal; additions are backward-compatible.
pub const BENCH_SCHEMA: u32 = 1;

/// Interpreter path a cell was measured under.
pub const PATH_DECODED: &str = "decoded";
pub const PATH_REFERENCE: &str = "reference";

/// One bench request: which cells, how many repeats.
pub struct BenchOpts {
    pub size: WorkloadSize,
    pub repeats: u32,
    pub warmup: u32,
    /// Also measure the pre-decode reference interpreter and record the
    /// speedup (asserting identical simulated results).
    pub compare_reference: bool,
    pub apps: Vec<WorkloadId>,
    pub scenarios: Vec<Scenario>,
}

impl BenchOpts {
    /// The `srsp bench hotpath` default cell set: the classic PageRank
    /// kernel under the no-steal scoped scenario and the two promotion
    /// protocols — the simulator's hot loop with and without steal
    /// traffic. Names resolve through the registries.
    pub fn hotpath(size: WorkloadSize) -> Self {
        let apps = vec![registry::resolve("prk").expect("prk is registered")];
        let scenarios = ["scope", "srsp", "rsp"]
            .iter()
            .map(|n| Scenario::from_name(n).expect("classic scenario name"))
            .collect();
        BenchOpts {
            size,
            repeats: 5,
            warmup: 1,
            compare_reference: false,
            apps,
            scenarios,
        }
    }
}

/// One measured (workload, scenario, path) cell.
#[derive(Debug, Clone)]
pub struct CellBench {
    pub app: &'static str,
    pub scenario: &'static str,
    /// [`PATH_DECODED`] or [`PATH_REFERENCE`].
    pub path: &'static str,
    /// Wall seconds of each timed repeat, in run order.
    pub wall_secs: Vec<f64>,
    pub median_secs: f64,
    pub min_secs: f64,
    /// Simulated results (identical across repeats — asserted).
    pub sim_cycles: u64,
    pub instructions: u64,
    pub rounds: u32,
    /// Host-side cost attribution summed over the timed repeats.
    pub perf: PerfStats,
}

impl CellBench {
    /// Timed cell executions per wall second (1 / median).
    pub fn cells_per_sec(&self) -> f64 {
        1.0 / self.median_secs.max(1e-12)
    }

    /// Millions of simulated instructions per wall second.
    pub fn minstr_per_sec(&self) -> f64 {
        self.instructions as f64 / self.median_secs.max(1e-12) / 1e6
    }

    /// Millions of simulated cycles per wall second.
    pub fn mcycles_per_sec(&self) -> f64 {
        self.sim_cycles as f64 / self.median_secs.max(1e-12) / 1e6
    }
}

/// A finished bench run, ready to render as `BENCH_*.json`.
pub struct BenchReport {
    pub schema: u32,
    /// Bench kind (`hotpath`).
    pub kind: String,
    pub size: WorkloadSize,
    pub num_cus: u32,
    pub repeats: u32,
    pub warmup: u32,
    pub cells: Vec<CellBench>,
}

impl BenchReport {
    fn cells_on(&self, path: &str) -> impl Iterator<Item = &CellBench> {
        self.cells.iter().filter(move |c| c.path == path)
    }

    /// Sum of per-cell cells/sec over one path (aggregate throughput).
    pub fn total_cells_per_sec(&self, path: &str) -> f64 {
        let total: f64 = self.cells_on(path).map(|c| c.median_secs.max(1e-12)).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.cells_on(path).count() as f64 / total
    }

    /// Aggregate Minstr/s over one path (total instructions / total median
    /// wall).
    pub fn total_minstr_per_sec(&self, path: &str) -> f64 {
        let secs: f64 = self.cells_on(path).map(|c| c.median_secs.max(1e-12)).sum();
        let instr: u64 = self.cells_on(path).map(|c| c.instructions).sum();
        if secs <= 0.0 {
            return 0.0;
        }
        instr as f64 / secs / 1e6
    }

    /// Host-side cost attribution summed over every measured cell
    /// (including the scheduler counters a scheduled run contributes).
    pub fn total_perf(&self) -> PerfStats {
        let mut perf = PerfStats::default();
        for c in &self.cells {
            perf.merge(&c.perf);
        }
        perf
    }

    /// Median-wall speedup of the decoded path over the reference path
    /// (`None` unless both paths were measured).
    pub fn speedup_vs_reference(&self) -> Option<f64> {
        let dec: f64 = self
            .cells_on(PATH_DECODED)
            .map(|c| c.median_secs.max(1e-12))
            .sum();
        let reference: f64 = self
            .cells_on(PATH_REFERENCE)
            .map(|c| c.median_secs.max(1e-12))
            .sum();
        if dec <= 0.0 || reference <= 0.0 {
            return None;
        }
        Some(reference / dec)
    }

    /// Render the versioned JSON artifact.
    pub fn to_json(&self) -> String {
        let cells = self
            .cells
            .iter()
            .map(|c| {
                Json::Obj(vec![
                    ("app".into(), Json::str(c.app)),
                    ("scenario".into(), Json::str(c.scenario)),
                    ("path".into(), Json::str(c.path)),
                    (
                        "wall_secs".into(),
                        Json::Arr(c.wall_secs.iter().map(|&w| Json::f64(w)).collect()),
                    ),
                    ("median_secs".into(), Json::f64(c.median_secs)),
                    ("min_secs".into(), Json::f64(c.min_secs)),
                    ("cells_per_sec".into(), Json::f64(c.cells_per_sec())),
                    ("minstr_per_sec".into(), Json::f64(c.minstr_per_sec())),
                    ("mcycles_per_sec".into(), Json::f64(c.mcycles_per_sec())),
                    ("sim_cycles".into(), Json::u64(c.sim_cycles)),
                    ("instructions".into(), Json::u64(c.instructions)),
                    ("rounds".into(), Json::u32(c.rounds)),
                    ("launches".into(), Json::u64(c.perf.launches)),
                    ("events".into(), Json::u64(c.perf.events)),
                    ("launch_nanos".into(), Json::u64(c.perf.launch_nanos)),
                    ("engine_nanos".into(), Json::u64(c.perf.engine_nanos)),
                    ("sim_nanos".into(), Json::u64(c.perf.sim_nanos())),
                ])
            })
            .collect();

        let mut totals = vec![
            (
                "cells_per_sec".into(),
                Json::f64(self.total_cells_per_sec(PATH_DECODED)),
            ),
            (
                "minstr_per_sec".into(),
                Json::f64(self.total_minstr_per_sec(PATH_DECODED)),
            ),
        ];
        if let Some(s) = self.speedup_vs_reference() {
            totals.push(("speedup_vs_reference".into(), Json::f64(s)));
        }
        // Scheduler counters summed over the measured cells. Bench cells
        // run serially, so these stay zero unless a scheduled run's
        // PerfStats flowed into the report; utilization is emitted only
        // when some scheduled section was actually measured (schema-
        // compatible addition — absent means "nothing scheduled").
        let perf = self.total_perf();
        totals.push(("sched_steals".into(), Json::u64(perf.sched_steals)));
        totals.push(("sched_busy_nanos".into(), Json::u64(perf.sched_busy_nanos)));
        totals.push(("sched_idle_nanos".into(), Json::u64(perf.sched_idle_nanos)));
        if let Some(u) = perf.utilization() {
            totals.push(("utilization".into(), Json::f64(u)));
        }

        let root = Json::Obj(vec![
            ("schema".into(), Json::u32(self.schema)),
            ("kind".into(), Json::str(self.kind.clone())),
            ("size".into(), Json::str(size_name(self.size))),
            ("num_cus".into(), Json::u32(self.num_cus)),
            ("repeats".into(), Json::u32(self.repeats)),
            ("warmup".into(), Json::u32(self.warmup)),
            ("cells".into(), Json::Arr(cells)),
            ("totals".into(), Json::Obj(totals)),
        ]);
        let mut s = root.render();
        s.push('\n');
        s
    }

    /// One-line-per-cell human rendering (stderr companion of the JSON).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for c in &self.cells {
            out.push_str(&format!(
                "{:>5}/{:<14} {:<9} wall {:>8.3}s  Mcycles/s {:>8.2}  Minstr/s {:>8.2}\n",
                c.app,
                c.scenario,
                c.path,
                c.median_secs,
                c.mcycles_per_sec(),
                c.minstr_per_sec(),
            ));
        }
        if let Some(s) = self.speedup_vs_reference() {
            out.push_str(&format!("decoded path speedup vs reference: {s:.2}x\n"));
        }
        let perf = self.total_perf();
        if let Some(u) = perf.utilization() {
            out.push_str(&format!(
                "scheduler: steals {}  idle {}ns  utilization {u:.3}\n",
                perf.sched_steals, perf.sched_idle_nanos
            ));
        }
        out
    }
}

pub(crate) fn size_name(size: WorkloadSize) -> &'static str {
    match size {
        WorkloadSize::Tiny => "tiny",
        WorkloadSize::Paper => "paper",
    }
}

/// Median of the sample (mean of the middle pair for even counts).
fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let n = s.len();
    if n % 2 == 1 {
        s[n / 2]
    } else {
        (s[n / 2 - 1] + s[n / 2]) / 2.0
    }
}

/// Measure one cell under the currently selected interpreter path.
///
/// The per-thread [`perfstats`] collector is drained before each repeat
/// and summed, so the attribution covers exactly the timed runs.
fn measure_cell(
    cfg: &DeviceConfig,
    id: WorkloadId,
    scenario: Scenario,
    opts: &BenchOpts,
    path: &'static str,
) -> CellBench {
    let preset = WorkloadPreset::new(id, opts.size);
    for _ in 0..opts.warmup {
        let _ = figures::run_one(cfg, &preset, scenario);
    }
    let mut wall_secs = Vec::with_capacity(opts.repeats as usize);
    let mut perf = PerfStats::default();
    let mut last: Option<(u64, u64, u32)> = None;
    for _ in 0..opts.repeats.max(1) {
        let _ = perfstats::take_thread();
        let t0 = Instant::now();
        let r = figures::run_one(cfg, &preset, scenario);
        wall_secs.push(t0.elapsed().as_secs_f64());
        perf.merge(&perfstats::take_thread());
        let key = (r.stats.cycles, r.stats.instructions, r.rounds);
        if let Some(prev) = last {
            assert_eq!(prev, key, "{id}/{scenario:?}: repeats must be deterministic");
        }
        last = Some(key);
    }
    let (sim_cycles, instructions, rounds) = last.expect("at least one repeat");
    CellBench {
        app: id.name(),
        scenario: scenario.name(),
        path,
        median_secs: median(&wall_secs),
        min_secs: wall_secs.iter().copied().fold(f64::INFINITY, f64::min),
        wall_secs,
        sim_cycles,
        instructions,
        rounds,
        perf,
    }
}

/// Run a bench request: every (workload, scenario) cell, on the decoded
/// path — plus, under `compare_reference`, the same cells on the
/// reference path first, with simulated-result identity asserted.
///
/// The interpreter-path switch is process-global; concurrent launches on
/// other threads stay *correct* either way (the paths are observationally
/// identical — that is what the identity assertions pin), they just may
/// be attributed to the other path's wall time. The CLI runs one bench
/// at a time, so this does not arise outside the test suite.
pub fn run_bench(cfg: &DeviceConfig, opts: &BenchOpts) -> BenchReport {
    let mut cells = Vec::new();
    if opts.compare_reference {
        perfstats::set_reference_paths(true);
        for &id in &opts.apps {
            for &sc in &opts.scenarios {
                cells.push(measure_cell(cfg, id, sc, opts, PATH_REFERENCE));
            }
        }
    }
    perfstats::set_reference_paths(false);
    for &id in &opts.apps {
        for &sc in &opts.scenarios {
            let cell = measure_cell(cfg, id, sc, opts, PATH_DECODED);
            if let Some(reference) = cells.iter().find(|c| {
                c.path == PATH_REFERENCE && c.app == cell.app && c.scenario == cell.scenario
            }) {
                assert_eq!(
                    (reference.sim_cycles, reference.instructions, reference.rounds),
                    (cell.sim_cycles, cell.instructions, cell.rounds),
                    "{}/{}: decoded path must reproduce the reference results",
                    cell.app,
                    cell.scenario,
                );
            }
            cells.push(cell);
        }
    }
    BenchReport {
        schema: BENCH_SCHEMA,
        kind: "hotpath".into(),
        size: opts.size,
        num_cus: cfg.num_cus,
        repeats: opts.repeats.max(1),
        warmup: opts.warmup,
        cells,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    #[test]
    fn median_handles_odd_and_even_samples() {
        assert_eq!(median(&[3.0]), 3.0);
        assert_eq!(median(&[4.0, 1.0, 3.0]), 3.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn hotpath_bench_emits_versioned_json() {
        let mut cfg = DeviceConfig::small();
        cfg.num_cus = 4;
        let opts = BenchOpts {
            size: WorkloadSize::Tiny,
            repeats: 1,
            warmup: 0,
            compare_reference: false,
            apps: vec![registry::resolve("stress").unwrap()],
            scenarios: vec![Scenario::from_name("scope").unwrap()],
        };
        let report = run_bench(&cfg, &opts);
        assert_eq!(report.cells.len(), 1);
        let c = &report.cells[0];
        assert_eq!(c.app, "stress");
        assert_eq!(c.scenario, "scope");
        assert_eq!(c.path, PATH_DECODED);
        assert!(c.sim_cycles > 0 && c.instructions > 0);
        assert!(c.perf.launches > 0 && c.perf.events > 0);

        let parsed = jsonio::parse(&report.to_json()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_u32().unwrap(), BENCH_SCHEMA);
        assert_eq!(parsed.get("kind").unwrap().as_str().unwrap(), "hotpath");
        assert_eq!(parsed.get("size").unwrap().as_str().unwrap(), "tiny");
        let cells = parsed.get("cells").unwrap().arr().unwrap();
        assert_eq!(cells.len(), 1);
        assert!(cells[0].get("minstr_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(cells[0].get("cells_per_sec").unwrap().as_f64().unwrap() > 0.0);
        let totals = parsed.get("totals").unwrap();
        assert!(totals.get("cells_per_sec").unwrap().as_f64().unwrap() > 0.0);
        // Scheduler counters ride along (zero for a serial bench), and
        // utilization stays absent until a scheduled section is measured.
        assert_eq!(totals.get("sched_steals").unwrap().as_u64().unwrap(), 0);
        assert_eq!(totals.get("sched_idle_nanos").unwrap().as_u64().unwrap(), 0);
        assert!(totals.get("utilization").is_err());
    }

    #[test]
    fn compare_reference_pins_identical_results_and_reports_speedup() {
        let mut cfg = DeviceConfig::small();
        cfg.num_cus = 4;
        let opts = BenchOpts {
            size: WorkloadSize::Tiny,
            repeats: 1,
            warmup: 0,
            compare_reference: true,
            apps: vec![registry::resolve("stress").unwrap()],
            scenarios: vec![Scenario::from_name("srsp").unwrap()],
        };
        // run_bench itself asserts reference/decoded result identity.
        let report = run_bench(&cfg, &opts);
        assert_eq!(report.cells.len(), 2);
        let speedup = report.speedup_vs_reference().expect("both paths measured");
        assert!(speedup > 0.0);
        let json = report.to_json();
        let parsed = jsonio::parse(&json).unwrap();
        let totals = parsed.get("totals").unwrap();
        assert!(totals.get("speedup_vs_reference").unwrap().as_f64().unwrap() > 0.0);
    }
}
