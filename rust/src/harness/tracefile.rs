//! Trace-file emission and merging: the harness layer over
//! [`sim::trace`](crate::sim::trace).
//!
//! A [`TraceReport`] is the grid-ordered set of per-cell traces a traced
//! `run`/`sweep` writes; its primary serialization is JSONL (one
//! [`jsonio`] object per line: a schema header, then per cell a cell
//! header, its events, its sparse per-CU counter rows and its
//! cycle-bucket reduction). [`TracePartial`] is the worker-boundary
//! artifact of a distributed traced sweep, merged exactly like
//! [`PartialReport`](super::report::PartialReport): rows land by global
//! grid index, and any gap, duplicate or shape disagreement is a loud
//! error — so a merged trace file is byte-identical to the
//! single-process run's.
//!
//! The secondary exporter renders Chrome/Perfetto `trace_event` JSON
//! (load in `ui.perfetto.dev` or `chrome://tracing`): one process per
//! grid cell, one thread per CU, one instant event per trace event with
//! `ts` in simulated cycles (read the viewer's µs as cycles).

use super::report::format_table;
use super::runner::CellResult;
use crate::coordinator::shard::ShardSpec;
use crate::jsonio::{self, Json};
use crate::sim::trace::{CellTrace, TraceEvent, TraceKind, DEVICE_CU, TIMELINE_BUCKET_CYCLES};
use crate::sim::TRACE_SCHEMA;

/// One grid cell's trace plus the identity needed to read it stand-alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceCell {
    pub app: String,
    pub scenario: String,
    pub seed: u64,
    pub trace: CellTrace,
}

impl TraceCell {
    /// Package one executed cell's harvested trace. Loud when the cell
    /// carried none — a traced command must never write a silently
    /// shorter trace file.
    pub fn from_cell(index: usize, c: &CellResult) -> Result<TraceCell, String> {
        let Some(trace) = &c.result.trace else {
            return Err(format!(
                "cell {index} ({}/{}) produced no trace — the device ran with trace_capacity 0",
                c.result.app,
                c.result.scenario.name()
            ));
        };
        Ok(TraceCell {
            app: c.result.app.to_string(),
            scenario: c.result.scenario.name().to_string(),
            seed: c.seed,
            trace: (**trace).clone(),
        })
    }

    /// Lossless JSON encoding (the trace-partial payload).
    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("app".into(), Json::str(self.app.clone())),
            ("scenario".into(), Json::str(self.scenario.clone())),
            ("seed".into(), Json::u64(self.seed)),
            ("trace".into(), self.trace.to_json()),
        ])
    }

    fn from_json(v: &Json) -> Result<TraceCell, String> {
        Ok(TraceCell {
            app: v.get("app")?.as_str()?.to_string(),
            scenario: v.get("scenario")?.as_str()?.to_string(),
            seed: v.get("seed")?.as_u64()?,
            trace: CellTrace::from_json(v.get("trace")?)?,
        })
    }
}

/// The grid-ordered trace of one whole run — what `--trace <file>`
/// writes and `srsp trace` reads.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceReport {
    pub cells: Vec<TraceCell>,
}

impl TraceReport {
    /// Assemble from executed cells in grid order. Errors when any cell
    /// carries no trace.
    pub fn from_cells(results: &[CellResult]) -> Result<TraceReport, String> {
        let cells = results
            .iter()
            .enumerate()
            .map(|(i, c)| TraceCell::from_cell(i, c))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TraceReport { cells })
    }

    /// The JSONL trace file: a schema header line, then per cell its
    /// header, events, sparse per-CU counter rows and cycle buckets.
    pub fn render_jsonl(&self) -> String {
        let mut out = String::new();
        let mut push = |v: Json| {
            out.push_str(&v.render());
            out.push('\n');
        };
        push(Json::Obj(vec![
            ("schema".into(), Json::u32(TRACE_SCHEMA)),
            ("total_cells".into(), Json::usize(self.cells.len())),
            ("bucket_cycles".into(), Json::u64(TIMELINE_BUCKET_CYCLES)),
        ]));
        for (i, c) in self.cells.iter().enumerate() {
            let t = &c.trace;
            push(Json::Obj(vec![
                ("cell".into(), Json::usize(i)),
                ("app".into(), Json::str(c.app.clone())),
                ("scenario".into(), Json::str(c.scenario.clone())),
                ("seed".into(), Json::u64(c.seed)),
                ("cus".into(), Json::usize(t.per_cu.len())),
                ("capacity".into(), Json::u64(t.capacity)),
                ("events".into(), Json::usize(t.events.len())),
                ("dropped".into(), Json::u64(t.dropped)),
                ("truncated".into(), Json::Bool(t.truncated())),
            ]));
            for e in &t.events {
                push(Json::Obj(vec![
                    ("cell".into(), Json::usize(i)),
                    ("cycle".into(), Json::u64(e.cycle)),
                    ("cu".into(), Json::u32(e.cu)),
                    ("wg".into(), Json::u32(e.wg)),
                    ("kind".into(), Json::str(e.kind.name())),
                    ("addr".into(), Json::u64(e.addr)),
                    ("detail".into(), Json::u64(e.detail)),
                ]));
            }
            for (cu, row) in t.per_cu.iter().enumerate() {
                let counts: Vec<(String, Json)> = TraceKind::ALL
                    .iter()
                    .filter(|k| row[k.index()] > 0)
                    .map(|k| (k.name().to_string(), Json::u64(row[k.index()])))
                    .collect();
                if counts.is_empty() {
                    continue;
                }
                push(Json::Obj(vec![
                    ("cell".into(), Json::usize(i)),
                    ("cu".into(), Json::usize(cu)),
                    ("counts".into(), Json::Obj(counts)),
                ]));
            }
            for (start, n) in t.timeline() {
                push(Json::Obj(vec![
                    ("cell".into(), Json::usize(i)),
                    ("bucket_start".into(), Json::u64(start)),
                    ("events".into(), Json::u64(n)),
                ]));
            }
        }
        out
    }

    /// Parse [`Self::render_jsonl`] output; loud on a foreign schema
    /// version, out-of-order cells, or a truncated file. Bucket lines
    /// are a derived reduction and are skipped (recomputed on demand).
    pub fn parse_jsonl(text: &str) -> Result<TraceReport, String> {
        let mut cells: Vec<TraceCell> = Vec::new();
        let mut expected_events: Vec<usize> = Vec::new();
        let mut declared_cells: Option<usize> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let n = lineno + 1;
            let line = raw.trim();
            if line.is_empty() {
                continue;
            }
            let ctx = |e: String| format!("trace line {n}: {e}");
            let v = jsonio::parse(line).map_err(ctx)?;
            if let Ok(schema) = v.get("schema") {
                let schema = schema.as_u32().map_err(ctx)?;
                if schema != TRACE_SCHEMA {
                    return Err(format!(
                        "trace file has schema version {schema}, this binary speaks {TRACE_SCHEMA}"
                    ));
                }
                let total = v.get("total_cells").and_then(|c| c.as_usize()).map_err(ctx)?;
                declared_cells = Some(total);
                continue;
            }
            if declared_cells.is_none() {
                return Err(format!("trace line {n}: data before the schema header"));
            }
            if v.get("app").is_ok() {
                let index = v.get("cell").and_then(|c| c.as_usize()).map_err(ctx)?;
                if index != cells.len() {
                    return Err(format!(
                        "trace line {n}: cell {index} out of order (expected {})",
                        cells.len()
                    ));
                }
                let cus = v.get("cus").and_then(|c| c.as_usize()).map_err(ctx)?;
                expected_events.push(v.get("events").and_then(|c| c.as_usize()).map_err(ctx)?);
                cells.push(TraceCell {
                    app: v.get("app").and_then(|a| a.as_str()).map_err(ctx)?.to_string(),
                    scenario: v
                        .get("scenario")
                        .and_then(|s| s.as_str())
                        .map_err(ctx)?
                        .to_string(),
                    seed: v.get("seed").and_then(|s| s.as_u64()).map_err(ctx)?,
                    trace: CellTrace {
                        capacity: v.get("capacity").and_then(|c| c.as_u64()).map_err(ctx)?,
                        dropped: v.get("dropped").and_then(|d| d.as_u64()).map_err(ctx)?,
                        events: Vec::new(),
                        per_cu: vec![[0; TraceKind::COUNT]; cus],
                    },
                });
                continue;
            }
            let index = v.get("cell").and_then(|c| c.as_usize()).map_err(ctx)?;
            if index + 1 != cells.len() {
                return Err(format!(
                    "trace line {n}: cell {index} data outside its cell block"
                ));
            }
            let cur = &mut cells[index].trace;
            if v.get("kind").is_ok() {
                let kind_name = v.get("kind").and_then(|k| k.as_str()).map_err(ctx)?;
                let kind = TraceKind::from_name(kind_name).ok_or_else(|| {
                    format!("trace line {n}: unknown trace kind '{kind_name}'")
                })?;
                cur.events.push(TraceEvent {
                    cycle: v.get("cycle").and_then(|c| c.as_u64()).map_err(ctx)?,
                    cu: v.get("cu").and_then(|c| c.as_u32()).map_err(ctx)?,
                    wg: v.get("wg").and_then(|w| w.as_u32()).map_err(ctx)?,
                    kind,
                    addr: v.get("addr").and_then(|a| a.as_u64()).map_err(ctx)?,
                    detail: v.get("detail").and_then(|d| d.as_u64()).map_err(ctx)?,
                });
            } else if let Ok(counts) = v.get("counts") {
                let cu = v.get("cu").and_then(|c| c.as_usize()).map_err(ctx)?;
                let cus = cur.per_cu.len();
                let slot = cur.per_cu.get_mut(cu).ok_or_else(|| {
                    format!("trace line {n}: per_cu row for CU {cu} outside the declared {cus}")
                })?;
                let Json::Obj(counts) = counts else {
                    return Err(format!("trace line {n}: counts is not an object"));
                };
                for (name, val) in counts {
                    let kind = TraceKind::from_name(name).ok_or_else(|| {
                        format!("trace line {n}: unknown trace kind '{name}'")
                    })?;
                    slot[kind.index()] = val.as_u64().map_err(ctx)?;
                }
            } else if v.get("bucket_start").is_ok() {
                // Derived cycle-bucket reduction: recomputable from the
                // events, so it carries no state worth re-ingesting.
            } else {
                return Err(format!("trace line {n}: unrecognized line form"));
            }
        }
        let Some(want) = declared_cells else {
            return Err("trace file has no schema header".into());
        };
        if cells.len() != want {
            return Err(format!(
                "trace file declares {want} cell(s) but carries {}",
                cells.len()
            ));
        }
        for (i, (c, want)) in cells.iter().zip(&expected_events).enumerate() {
            if c.trace.events.len() != *want {
                return Err(format!(
                    "cell {i} declares {want} event(s) but carries {} — truncated trace file?",
                    c.trace.events.len()
                ));
            }
        }
        Ok(TraceReport { cells })
    }

    /// Chrome/Perfetto `trace_event` JSON: pid = grid cell, tid = CU,
    /// instant events at `ts` = simulated cycle.
    pub fn render_perfetto(&self) -> String {
        let meta = |pid: usize, tid: Option<Json>, what: &str, name: String| {
            let mut o = vec![("ph".into(), Json::str("M")), ("pid".into(), Json::usize(pid))];
            if let Some(tid) = tid {
                o.push(("tid".into(), tid));
            }
            o.push(("name".into(), Json::str(what)));
            o.push(("args".into(), Json::Obj(vec![("name".into(), Json::str(name))])));
            Json::Obj(o)
        };
        let mut evs: Vec<Json> = Vec::new();
        for (i, c) in self.cells.iter().enumerate() {
            evs.push(meta(
                i,
                None,
                "process_name",
                format!("cell {i}: {}/{} seed {:#x}", c.app, c.scenario, c.seed),
            ));
            for cu in 0..c.trace.per_cu.len() {
                evs.push(meta(i, Some(Json::usize(cu)), "thread_name", format!("CU {cu}")));
            }
            evs.push(meta(
                i,
                Some(Json::u32(DEVICE_CU)),
                "thread_name",
                "device".to_string(),
            ));
            for e in &c.trace.events {
                evs.push(Json::Obj(vec![
                    ("ph".into(), Json::str("i")),
                    ("s".into(), Json::str("t")),
                    ("name".into(), Json::str(e.kind.name())),
                    ("ts".into(), Json::u64(e.cycle)),
                    ("pid".into(), Json::usize(i)),
                    ("tid".into(), Json::u32(e.cu)),
                    (
                        "args".into(),
                        Json::Obj(vec![
                            ("wg".into(), Json::u32(e.wg)),
                            ("addr".into(), Json::str(format!("{:#x}", e.addr))),
                            ("detail".into(), Json::u64(e.detail)),
                        ]),
                    ),
                ]));
            }
        }
        Json::Obj(vec![("traceEvents".into(), Json::Arr(evs))]).render()
    }

    /// Human summary: per cell, the per-CU attribution table (the
    /// asymmetry the summed `Stats` cannot show).
    pub fn summary_table(&self) -> String {
        let header: Vec<String> = [
            "cu", "wg_acq", "wg_rel", "promo", "local", "sel_nop", "sel_drain", "lr_ovf",
            "pa_ovf", "l1_inv", "total",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let mut out = String::new();
        for (i, c) in self.cells.iter().enumerate() {
            let t = &c.trace;
            out.push_str(&format!(
                "cell {i}: {}/{} seed {:#x} — {} event(s) in ring\n",
                c.app,
                c.scenario,
                c.seed,
                t.events.len()
            ));
            if t.truncated() {
                out.push_str(&format!(
                    "  TRUNCATED: ring (capacity {}) dropped the {} oldest event(s); \
                     the per-CU counts below remain exact\n",
                    t.capacity, t.dropped
                ));
            }
            let rows: Vec<Vec<String>> = t
                .per_cu
                .iter()
                .enumerate()
                .filter(|(_, row)| row.iter().any(|&n| n > 0))
                .map(|(cu, row)| {
                    let pick = |k: TraceKind| row[k.index()].to_string();
                    vec![
                        cu.to_string(),
                        pick(TraceKind::WgAcquire),
                        pick(TraceKind::WgRelease),
                        pick(TraceKind::Promotion),
                        pick(TraceKind::LocalAcquire),
                        pick(TraceKind::SelFlushNop),
                        pick(TraceKind::SelFlushDrain),
                        pick(TraceKind::LrOverflow),
                        pick(TraceKind::PaOverflow),
                        pick(TraceKind::L1Invalidate),
                        row.iter().sum::<u64>().to_string(),
                    ]
                })
                .collect();
            if rows.is_empty() {
                out.push_str("  (no per-CU events)\n");
            } else {
                out.push_str(&format_table(&header, &rows));
            }
            out.push('\n');
        }
        out
    }

    /// Human time series: per cell, events per cycle bucket.
    pub fn timeline_table(&self) -> String {
        let header: Vec<String> = ["bucket_start", "events"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let mut out = String::new();
        for (i, c) in self.cells.iter().enumerate() {
            out.push_str(&format!(
                "cell {i}: {}/{} seed {:#x} (bucket = {TIMELINE_BUCKET_CYCLES} cycles)\n",
                c.app, c.scenario, c.seed
            ));
            let rows: Vec<Vec<String>> = c
                .trace
                .timeline()
                .into_iter()
                .map(|(s, n)| vec![s.to_string(), n.to_string()])
                .collect();
            if rows.is_empty() {
                out.push_str("  (no events)\n");
            } else {
                out.push_str(&format_table(&header, &rows));
            }
            out.push('\n');
        }
        out
    }
}

/// The registered event kinds, one wire name per line (`srsp trace kinds`).
pub fn kinds_listing() -> String {
    let mut out = format!(
        "trace schema v{TRACE_SCHEMA}: {} event kind(s)\n",
        TraceKind::COUNT
    );
    for k in TraceKind::ALL {
        out.push_str("  ");
        out.push_str(k.name());
        out.push('\n');
    }
    out
}

/// One worker's slice of a distributed traced run, merged exactly like
/// [`PartialReport`](super::report::PartialReport): indexed cells plus
/// the run shape the merge proves completeness against.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TracePartial {
    pub shard: usize,
    pub num_shards: usize,
    pub total_cells: usize,
    /// `(global grid index, cell trace)` pairs, ascending by index.
    pub cells: Vec<(usize, TraceCell)>,
}

impl TracePartial {
    /// Package one executed shard's traces as the worker-boundary
    /// artifact. Errors when any cell carries no trace.
    pub fn from_shard(
        spec: &ShardSpec,
        results: &[(usize, CellResult)],
    ) -> Result<TracePartial, String> {
        let mut cells = Vec::with_capacity(results.len());
        for (index, c) in results {
            cells.push((*index, TraceCell::from_cell(*index, c)?));
        }
        Ok(TracePartial {
            shard: spec.shard,
            num_shards: spec.num_shards,
            total_cells: spec.total_cells,
            cells,
        })
    }

    /// Serialize to the worker trace-output JSON, stamped with
    /// [`TRACE_SCHEMA`].
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("trace_version".into(), Json::u32(TRACE_SCHEMA)),
            ("shard".into(), Json::usize(self.shard)),
            ("num_shards".into(), Json::usize(self.num_shards)),
            ("total_cells".into(), Json::usize(self.total_cells)),
            (
                "cells".into(),
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|(i, c)| {
                            let mut o = vec![("index".into(), Json::usize(*i))];
                            if let Json::Obj(fields) = c.to_json() {
                                o.extend(fields);
                            }
                            Json::Obj(o)
                        })
                        .collect(),
                ),
            ),
        ])
        .render()
    }

    /// Parse a worker trace-output file; loud on malformation or a
    /// schema version this binary does not speak.
    pub fn from_json(text: &str) -> Result<TracePartial, String> {
        let v = jsonio::parse(text)?;
        let version = v.get("trace_version")?.as_u32()?;
        if version != TRACE_SCHEMA {
            return Err(format!(
                "trace partial has schema version {version}, this binary speaks {TRACE_SCHEMA}"
            ));
        }
        let mut cells = Vec::new();
        for (i, c) in v.get("cells")?.arr()?.iter().enumerate() {
            let index = c.get("index")?.as_usize().map_err(|e| format!("cell {i}: {e}"))?;
            cells.push((index, TraceCell::from_json(c).map_err(|e| format!("cell {i}: {e}"))?));
        }
        Ok(TracePartial {
            shard: v.get("shard")?.as_usize()?,
            num_shards: v.get("num_shards")?.as_usize()?,
            total_cells: v.get("total_cells")?.as_usize()?,
            cells,
        })
    }

    /// Reassemble worker trace partials into the grid-ordered
    /// [`TraceReport`] — same completeness proof as
    /// [`Report::merge`](super::report::Report::merge): any missing or
    /// duplicate shard, shape disagreement, or cell gap is a loud error,
    /// never a silently shorter trace.
    pub fn merge(partials: &[TracePartial]) -> Result<TraceReport, String> {
        let Some(first) = partials.first() else {
            return Err("trace merge needs at least one trace partial".into());
        };
        let (num_shards, total) = (first.num_shards, first.total_cells);
        if partials.len() != num_shards {
            return Err(format!(
                "trace merge needs all {num_shards} trace partial(s) of the run, got {} — \
                 a worker is missing",
                partials.len()
            ));
        }
        let mut seen_shards = vec![false; num_shards];
        let mut slots: Vec<Option<TraceCell>> = (0..total).map(|_| None).collect();
        for p in partials {
            if p.num_shards != num_shards || p.total_cells != total {
                return Err(format!(
                    "trace partial of shard {} disagrees on the run shape \
                     ({}/{} vs {num_shards}/{total}): partials from different runs?",
                    p.shard, p.num_shards, p.total_cells
                ));
            }
            if p.shard >= num_shards {
                return Err(format!(
                    "shard index {} is outside the declared {num_shards} shard(s)",
                    p.shard
                ));
            }
            if seen_shards[p.shard] {
                return Err(format!("two trace partials claim shard {}", p.shard));
            }
            seen_shards[p.shard] = true;
            for (index, cell) in &p.cells {
                if *index >= total {
                    return Err(format!(
                        "shard {}: grid index {index} is outside the declared {total} cell(s)",
                        p.shard
                    ));
                }
                if slots[*index].is_some() {
                    return Err(format!("grid cell {index} was traced twice"));
                }
                slots[*index] = Some(cell.clone());
            }
        }
        let missing = slots.iter().filter(|s| s.is_none()).count();
        if missing > 0 {
            let first_gap = slots.iter().position(|s| s.is_none()).unwrap_or(0);
            return Err(format!(
                "trace merge is missing {missing} of {total} cell(s) (first gap at grid index \
                 {first_gap}): a worker died or emitted a truncated trace partial"
            ));
        }
        Ok(TraceReport {
            cells: slots.into_iter().flatten().collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::TraceSink;

    fn cell(seed: u64, events: &[(u64, u32, TraceKind)]) -> TraceCell {
        let mut sink = TraceSink::new(8, 4);
        sink.set_wg(1);
        for &(cycle, cu, kind) in events {
            sink.emit(cycle, cu, kind, 0x1000, 2);
        }
        TraceCell {
            app: "stress".into(),
            scenario: "srsp".into(),
            seed,
            trace: *sink.take_cell().unwrap(),
        }
    }

    fn report() -> TraceReport {
        TraceReport {
            cells: vec![
                cell(
                    0xAB,
                    &[
                        (5, 0, TraceKind::WgRelease),
                        (9, 1, TraceKind::RemoteAcquire),
                        (11, 0, TraceKind::SelFlushDrain),
                        (2000, 0, TraceKind::Promotion),
                    ],
                ),
                cell(0xCD, &[(3, 2, TraceKind::LocalAcquire)]),
            ],
        }
    }

    #[test]
    fn jsonl_round_trips_byte_identically() {
        let r = report();
        let text = r.render_jsonl();
        assert!(text.starts_with(&format!("{{\"schema\":{TRACE_SCHEMA}")));
        assert!(text.contains("\"kind\":\"promotion\""));
        let back = TraceReport::parse_jsonl(&text).unwrap();
        assert_eq!(back, r);
        // Render → parse → render is a fixpoint (byte identity).
        assert_eq!(back.render_jsonl(), text);
    }

    #[test]
    fn jsonl_rejects_foreign_schema_and_truncation() {
        let text = report().render_jsonl();
        let foreign = text.replacen(
            &format!("\"schema\":{TRACE_SCHEMA}"),
            "\"schema\":999",
            1,
        );
        assert!(TraceReport::parse_jsonl(&foreign)
            .unwrap_err()
            .contains("schema version 999"));
        // Drop the last line (an event or bucket of the last cell).
        let cut = &text[..text.trim_end().rfind('\n').unwrap() + 1];
        let err = TraceReport::parse_jsonl(cut);
        // Either an event-count mismatch or a lost bucket line — bucket
        // lines are derived, so cutting one of those still parses; cut
        // until the parse fails to prove the event guard fires.
        let mut t = cut.to_string();
        let mut saw_guard = err.is_err();
        while !saw_guard {
            t = t[..t.trim_end().rfind('\n').unwrap() + 1].to_string();
            saw_guard = TraceReport::parse_jsonl(&t).is_err();
        }
        assert!(saw_guard);
    }

    #[test]
    fn perfetto_export_shape() {
        let text = report().render_perfetto();
        assert!(text.starts_with("{\"traceEvents\":["));
        assert!(text.contains("\"ph\":\"M\""));
        assert!(text.contains("\"name\":\"promotion\""));
        assert!(text.contains("\"thread_name\""));
    }

    #[test]
    fn summary_and_timeline_render() {
        let r = report();
        let s = r.summary_table();
        assert!(s.contains("cell 0: stress/srsp"));
        assert!(s.contains("sel_drain"));
        let t = r.timeline_table();
        assert!(t.contains("bucket_start"));
        assert!(kinds_listing().contains("sel_flush_nop"));
    }

    fn partial(
        shard: usize,
        num_shards: usize,
        total: usize,
        cells: Vec<(usize, TraceCell)>,
    ) -> TracePartial {
        TracePartial {
            shard,
            num_shards,
            total_cells: total,
            cells,
        }
    }

    #[test]
    fn partial_json_round_trips_and_merge_reassembles() {
        let r = report();
        let p0 = partial(0, 2, 2, vec![(1, r.cells[1].clone())]);
        let p1 = partial(1, 2, 2, vec![(0, r.cells[0].clone())]);
        let p0 = TracePartial::from_json(&p0.to_json()).unwrap();
        let p1 = TracePartial::from_json(&p1.to_json()).unwrap();
        let merged = TracePartial::merge(&[p0, p1]).unwrap();
        assert_eq!(merged, r);
        assert_eq!(merged.render_jsonl(), r.render_jsonl());
    }

    #[test]
    fn merge_failures_are_loud() {
        let r = report();
        let whole = partial(0, 1, 2, vec![(0, r.cells[0].clone()), (1, r.cells[1].clone())]);
        assert!(TracePartial::merge(&[]).unwrap_err().contains("at least one"));
        assert!(TracePartial::merge(&[partial(0, 2, 2, vec![])])
            .unwrap_err()
            .contains("a worker is missing"));
        assert!(
            TracePartial::merge(&[whole.clone(), partial(0, 1, 2, vec![])]).unwrap_err()
                .contains("needs all 1"),
        );
        // A gap is a loud error, not a shorter report.
        assert!(TracePartial::merge(&[partial(0, 1, 2, vec![(0, r.cells[0].clone())])])
            .unwrap_err()
            .contains("missing 1 of 2"));
        // Duplicate cells too.
        assert!(TracePartial::merge(&[partial(
            0,
            1,
            2,
            vec![(0, r.cells[0].clone()), (0, r.cells[1].clone())]
        )])
        .unwrap_err()
        .contains("traced twice"));
        // Version guard.
        let stale = whole.to_json().replacen(
            &format!("\"trace_version\":{TRACE_SCHEMA}"),
            "\"trace_version\":999",
            1,
        );
        assert!(TracePartial::from_json(&stale)
            .unwrap_err()
            .contains("schema version 999"));
    }
}
