//! Device, protocol and scenario configuration.
//!
//! [`DeviceConfig`] carries the paper's Table-1 parameters as defaults and
//! can be loaded from / saved to a simple `key = value` config file
//! ([`file`] — no serde offline, so the parser is hand-rolled).
//!
//! Protocols are *not* an enum: [`Protocol`] is a stable handle into the
//! [`crate::sync::protocol::PROTOCOLS`] registry, and a [`Scenario`] is a
//! sharing pattern (steal? wg-scope owner?) *paired with* a registered
//! protocol. The paper's five evaluation scenarios are provided as
//! constants; any registered protocol gets a scenario through
//! [`Scenario::for_protocol`], so new protocols are selectable by
//! registry name with no changes here.

pub mod file;

pub use file::{parse_config_str, ConfigError};

// The protocol identity lives with the registry; re-exported here so the
// historical `config::Protocol` import path keeps working.
pub use crate::sync::protocol::Protocol;

use crate::jsonio::{self, Json};

use std::fmt;

/// One evaluation scenario: which synchronization protocol the memory
/// system runs, whether work-stealing is enabled, and whether the queue
/// owner uses light wg-scope synchronization.
///
/// The five §5.1 scenarios are [`Scenario::ALL`]; every additional
/// registered protocol (hLRC, srsp-adaptive, ...) gets its canonical
/// scenario from [`Scenario::for_protocol`]. Fields are private so only
/// meaningful combinations exist: a wg-scope owner with stealing enabled
/// requires a protocol that can promote (remote ops) or transfer
/// ownership lazily — anything else would be a racy program, and cannot
/// be constructed.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Scenario {
    protocol: Protocol,
    steals: bool,
    local_owner: bool,
}

impl Scenario {
    /// Stealing disabled; queue ops use cmp (global) scope.
    pub const BASELINE: Scenario = Scenario {
        protocol: Protocol::SCOPED_ONLY,
        steals: false,
        local_owner: false,
    };
    /// Stealing disabled; queue ops use wg (local) scope.
    pub const SCOPE_ONLY: Scenario = Scenario {
        protocol: Protocol::SCOPED_ONLY,
        steals: false,
        local_owner: true,
    };
    /// Stealing enabled; all sync at cmp scope.
    pub const STEAL_ONLY: Scenario = Scenario {
        protocol: Protocol::SCOPED_ONLY,
        steals: true,
        local_owner: false,
    };
    /// Stealing enabled; owner at wg scope, steals via remote ops, naive
    /// all-L1 promotion.
    pub const RSP: Scenario = Scenario {
        protocol: Protocol::RSP_NAIVE,
        steals: true,
        local_owner: true,
    };
    /// Stealing enabled; owner at wg scope, steals via remote ops,
    /// selective promotion (the paper's contribution).
    pub const SRSP: Scenario = Scenario {
        protocol: Protocol::SRSP,
        steals: true,
        local_owner: true,
    };
    /// Extension (§6 related work): stealing enabled; *all* queue sync at
    /// wg scope, lazily transferred between owners by the hLRC protocol.
    /// Not part of the paper's five evaluated scenarios.
    pub const HLRC: Scenario = Scenario {
        protocol: Protocol::HLRC,
        steals: true,
        local_owner: true,
    };
    /// Extension: sRSP with the eager-invalidation fallback.
    pub const SRSP_ADAPTIVE: Scenario = Scenario {
        protocol: Protocol::SRSP_ADAPTIVE,
        steals: true,
        local_owner: true,
    };

    /// The paper's five evaluated scenarios (§5.1). Extension protocols
    /// are intentionally excluded (the figures compare these five).
    pub const ALL: [Scenario; 5] = [
        Scenario::BASELINE,
        Scenario::SCOPE_ONLY,
        Scenario::STEAL_ONLY,
        Scenario::RSP,
        Scenario::SRSP,
    ];

    /// The canonical scenario for a registered protocol: steal-enabled
    /// with a wg-scope owner when the protocol makes that correct
    /// (remote ops or lazy transfer), the wg-scope no-steal scenario
    /// otherwise.
    pub fn for_protocol(p: Protocol) -> Scenario {
        let proto = p.proto();
        Scenario {
            protocol: p,
            steals: proto.supports_remote() || proto.lazy_wg_transfer(),
            local_owner: true,
        }
    }

    pub fn name(self) -> &'static str {
        match (self.steals, self.local_owner) {
            (false, false) => "baseline",
            (true, false) => "steal",
            (true, true) => self.protocol.name(),
            (false, true) => {
                // The classic wg-scope-only scenario keeps its paper
                // name; a promotion-capable protocol in this slot (never
                // constructed today) would surface its own.
                if self.protocol.proto().supports_remote() {
                    self.protocol.name()
                } else {
                    "scope"
                }
            }
        }
    }

    /// Resolve a scenario name: one of the fixed sharing patterns
    /// (`baseline`/`scope`/`steal`) or any registered protocol name
    /// (`rsp`, `srsp`, `hlrc`, `srsp-adaptive`, ...).
    pub fn from_name(s: &str) -> Option<Scenario> {
        // Case-insensitive like protocol::resolve, so one flag has one
        // matching rule across its whole vocabulary.
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "baseline" => Some(Scenario::BASELINE),
            "scope" | "scope-only" => Some(Scenario::SCOPE_ONLY),
            "steal" | "steal-only" => Some(Scenario::STEAL_ONLY),
            other => crate::sync::protocol::resolve(other).map(Scenario::for_protocol),
        }
    }

    /// Does this scenario steal work from other queues?
    pub fn steals(self) -> bool {
        self.steals
    }

    /// Does the queue owner use light wg-scope synchronization?
    pub fn local_owner_sync(self) -> bool {
        self.local_owner
    }

    /// Do steals use the remote-scope-promotion operations?
    pub fn remote_ops(self) -> bool {
        self.steals && self.local_owner && self.protocol.proto().supports_remote()
    }

    /// Do steals use plain wg-scope ops, relying on the protocol to
    /// transfer ownership lazily (hLRC)?
    pub fn lazy_transfer(self) -> bool {
        self.steals && self.local_owner && self.protocol.proto().lazy_wg_transfer()
    }

    /// The memory-system protocol this scenario runs on.
    pub fn protocol(self) -> Protocol {
        self.protocol
    }
}

impl fmt::Debug for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Full device configuration. Defaults reproduce Table 1 of the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Number of Compute Units (paper: 64).
    pub num_cus: u32,
    /// Work-groups dispatched per CU (paper's work-stealing setup: one
    /// deque per work-group, one work-group per CU).
    pub wgs_per_cu: u32,

    // --- L1 data cache (per CU): 16kB, 64B lines, 16-way, 4-cycle ---
    pub l1_size: u32,
    pub l1_ways: u32,
    pub l1_latency: u64,
    /// sFIFO depth (paper: 16 entries).
    pub l1_sfifo: u32,

    // --- L2 (shared): 512kB, 64B lines, 16-way, 24-cycle ---
    pub l2_size: u32,
    pub l2_ways: u32,
    pub l2_latency: u64,
    pub l2_sfifo: u32,
    /// Number of L2 banks (line-interleaved) for port contention.
    pub l2_banks: u32,
    /// Cycles a bank is occupied per access.
    pub l2_bank_occupancy: u64,

    // --- Interconnect L1 <-> L2 ---
    pub xbar_latency: u64,
    /// Per-L1 link occupancy per message.
    pub xbar_occupancy: u64,

    // --- DRAM: DDR3, 8 channels, 500 MHz ---
    pub dram_channels: u32,
    pub dram_latency: u64,
    /// GPU cycles a channel is occupied per 64B line transfer
    /// (64B / (8B × 2 × 500MHz) at a 1 GHz core clock = 8 cycles).
    pub dram_occupancy: u64,

    // --- sRSP structures ---
    /// LR-TBL capacity (entries). 0 disables the table (degenerates to
    /// conservative full flush on every selective-flush request).
    pub lr_tbl_entries: u32,
    /// PA-TBL capacity (entries).
    pub pa_tbl_entries: u32,

    /// Cycles per work-item of a `Compute` KIR op (models ALU/SIMD
    /// throughput of a CU).
    pub compute_cycles_per_item: u64,
    /// Fixed issue cost of any instruction.
    pub issue_cycles: u64,

    /// Line size (bytes). 64 everywhere in the paper.
    pub line_size: u32,

    /// Sync-event trace ring-buffer capacity
    /// ([`TraceSink`](crate::sim::trace::TraceSink)); 0 (the default)
    /// disables tracing entirely. Tracing is observe-only: the value
    /// never changes simulated results, only whether they are recorded.
    pub trace_capacity: u32,

    /// Protocol-parameter overrides (`--proto-param k=v`), resolved
    /// against the *selected* protocol's registry spec when the device is
    /// built; keys a protocol does not declare are ignored for that
    /// protocol (a mixed grid's scoped cells have no tables to size).
    /// Empty for config-file and default-constructed configs.
    pub proto_params: Vec<(String, f64)>,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            num_cus: 64,
            wgs_per_cu: 1,
            l1_size: 16 * 1024,
            l1_ways: 16,
            l1_latency: 4,
            l1_sfifo: 16,
            l2_size: 512 * 1024,
            l2_ways: 16,
            l2_latency: 24,
            l2_sfifo: 24,
            l2_banks: 16,
            l2_bank_occupancy: 2,
            xbar_latency: 8,
            xbar_occupancy: 1,
            dram_channels: 8,
            dram_latency: 100,
            dram_occupancy: 8,
            lr_tbl_entries: 16,
            pa_tbl_entries: 16,
            compute_cycles_per_item: 2,
            issue_cycles: 1,
            line_size: 64,
            trace_capacity: 0,
            proto_params: Vec::new(),
        }
    }
}

impl DeviceConfig {
    /// A small device for fast unit tests: 4 CUs, small caches.
    pub fn small() -> Self {
        Self {
            num_cus: 4,
            l1_size: 2 * 1024,
            l2_size: 32 * 1024,
            ..Self::default()
        }
    }

    pub fn total_wgs(&self) -> u32 {
        self.num_cus * self.wgs_per_cu
    }

    pub fn l1_sets(&self) -> u32 {
        self.l1_size / self.line_size / self.l1_ways
    }

    pub fn l2_sets(&self) -> u32 {
        self.l2_size / self.line_size / self.l2_ways
    }

    /// Validate internal consistency (powers of two where indexing needs
    /// them, nonzero sizes).
    pub fn validate(&self) -> Result<(), String> {
        if self.num_cus == 0 {
            return Err("num_cus must be > 0".into());
        }
        if self.line_size != 64 {
            return Err("line_size must be 64 (paper, Table 1)".into());
        }
        for (name, v) in [
            ("l1_sets", self.l1_sets()),
            ("l2_sets", self.l2_sets()),
            ("l2_banks", self.l2_banks),
            ("dram_channels", self.dram_channels),
        ] {
            if v == 0 || !v.is_power_of_two() {
                return Err(format!("{name} must be a nonzero power of two, got {v}"));
            }
        }
        if self.l1_sfifo == 0 || self.l2_sfifo == 0 {
            return Err("sFIFO depths must be > 0".into());
        }
        Ok(())
    }

    /// JSON encoding for the distributed-pipeline stage files
    /// ([`ExecutionPlan`](crate::coordinator::ExecutionPlan) /
    /// [`ShardSpec`](crate::coordinator::shard::ShardSpec)). The
    /// exhaustive destructuring is the drift guard: adding a
    /// `DeviceConfig` field without teaching the pipeline about it no
    /// longer compiles.
    pub fn to_json(&self) -> Json {
        let DeviceConfig {
            num_cus,
            wgs_per_cu,
            l1_size,
            l1_ways,
            l1_latency,
            l1_sfifo,
            l2_size,
            l2_ways,
            l2_latency,
            l2_sfifo,
            l2_banks,
            l2_bank_occupancy,
            xbar_latency,
            xbar_occupancy,
            dram_channels,
            dram_latency,
            dram_occupancy,
            lr_tbl_entries,
            pa_tbl_entries,
            compute_cycles_per_item,
            issue_cycles,
            line_size,
            trace_capacity,
            proto_params,
        } = self;
        Json::Obj(vec![
            ("num_cus".into(), Json::u32(*num_cus)),
            ("wgs_per_cu".into(), Json::u32(*wgs_per_cu)),
            ("l1_size".into(), Json::u32(*l1_size)),
            ("l1_ways".into(), Json::u32(*l1_ways)),
            ("l1_latency".into(), Json::u64(*l1_latency)),
            ("l1_sfifo".into(), Json::u32(*l1_sfifo)),
            ("l2_size".into(), Json::u32(*l2_size)),
            ("l2_ways".into(), Json::u32(*l2_ways)),
            ("l2_latency".into(), Json::u64(*l2_latency)),
            ("l2_sfifo".into(), Json::u32(*l2_sfifo)),
            ("l2_banks".into(), Json::u32(*l2_banks)),
            ("l2_bank_occupancy".into(), Json::u64(*l2_bank_occupancy)),
            ("xbar_latency".into(), Json::u64(*xbar_latency)),
            ("xbar_occupancy".into(), Json::u64(*xbar_occupancy)),
            ("dram_channels".into(), Json::u32(*dram_channels)),
            ("dram_latency".into(), Json::u64(*dram_latency)),
            ("dram_occupancy".into(), Json::u64(*dram_occupancy)),
            ("lr_tbl_entries".into(), Json::u32(*lr_tbl_entries)),
            ("pa_tbl_entries".into(), Json::u32(*pa_tbl_entries)),
            (
                "compute_cycles_per_item".into(),
                Json::u64(*compute_cycles_per_item),
            ),
            ("issue_cycles".into(), Json::u64(*issue_cycles)),
            ("line_size".into(), Json::u32(*line_size)),
            ("trace_capacity".into(), Json::u32(*trace_capacity)),
            ("proto_params".into(), jsonio::pairs_to_json(proto_params)),
        ])
    }

    /// Inverse of [`Self::to_json`]; every field is required (a worker
    /// must never fill gaps with defaults that could diverge from the
    /// coordinator's) and the result is re-validated.
    pub fn from_json(v: &Json) -> Result<DeviceConfig, String> {
        let w = |k: &str| -> Result<u32, String> {
            v.get(k)?.as_u32().map_err(|e| format!("{k}: {e}"))
        };
        let u = |k: &str| -> Result<u64, String> {
            v.get(k)?.as_u64().map_err(|e| format!("{k}: {e}"))
        };
        let cfg = DeviceConfig {
            num_cus: w("num_cus")?,
            wgs_per_cu: w("wgs_per_cu")?,
            l1_size: w("l1_size")?,
            l1_ways: w("l1_ways")?,
            l1_latency: u("l1_latency")?,
            l1_sfifo: w("l1_sfifo")?,
            l2_size: w("l2_size")?,
            l2_ways: w("l2_ways")?,
            l2_latency: u("l2_latency")?,
            l2_sfifo: w("l2_sfifo")?,
            l2_banks: w("l2_banks")?,
            l2_bank_occupancy: u("l2_bank_occupancy")?,
            xbar_latency: u("xbar_latency")?,
            xbar_occupancy: u("xbar_occupancy")?,
            dram_channels: w("dram_channels")?,
            dram_latency: u("dram_latency")?,
            dram_occupancy: u("dram_occupancy")?,
            lr_tbl_entries: w("lr_tbl_entries")?,
            pa_tbl_entries: w("pa_tbl_entries")?,
            compute_cycles_per_item: u("compute_cycles_per_item")?,
            issue_cycles: u("issue_cycles")?,
            line_size: w("line_size")?,
            trace_capacity: w("trace_capacity")?,
            proto_params: jsonio::pairs_from_json(v.get("proto_params")?)
                .map_err(|e| format!("proto_params: {e}"))?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Render the Table-1 style parameter listing.
    pub fn table1(&self) -> String {
        format!(
            "| Parameter            | Value                                             |\n\
             |----------------------|---------------------------------------------------|\n\
             | Compute Units        | {} CUs, {} work-group(s)/CU                        |\n\
             | L1 data cache        | {}kB, {}B lines, {}-way, {}-cycle, {}-entry sFIFO  |\n\
             | L2 cache             | {}kB, {}B lines, {}-way, {}-cycle, {}-entry sFIFO  |\n\
             | L2 banking           | {} banks, {} cycle(s)/access                       |\n\
             | Interconnect         | {}-cycle latency, {} cycle(s)/message              |\n\
             | DRAM                 | DDR3, {} channels, {}-cycle latency                |\n\
             | Cache protocol       | no-allocate-on-write, write-combining              |\n\
             | LR-TBL / PA-TBL      | {} / {} entries                                    |",
            self.num_cus,
            self.wgs_per_cu,
            self.l1_size / 1024,
            self.line_size,
            self.l1_ways,
            self.l1_latency,
            self.l1_sfifo,
            self.l2_size / 1024,
            self.line_size,
            self.l2_ways,
            self.l2_latency,
            self.l2_sfifo,
            self.l2_banks,
            self.l2_bank_occupancy,
            self.xbar_latency,
            self.xbar_occupancy,
            self.dram_channels,
            self.dram_latency,
            self.lr_tbl_entries,
            self.pa_tbl_entries,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = DeviceConfig::default();
        assert_eq!(c.num_cus, 64);
        assert_eq!(c.l1_size, 16 * 1024);
        assert_eq!(c.l1_ways, 16);
        assert_eq!(c.l1_latency, 4);
        assert_eq!(c.l1_sfifo, 16);
        assert_eq!(c.l2_size, 512 * 1024);
        assert_eq!(c.l2_latency, 24);
        assert_eq!(c.l2_sfifo, 24);
        assert_eq!(c.dram_channels, 8);
        assert_eq!(c.l1_sets(), 16); // 16kB / 64B / 16-way
        assert_eq!(c.l2_sets(), 512); // 512kB / 64B / 16-way
        c.validate().unwrap();
    }

    #[test]
    fn small_config_valid() {
        DeviceConfig::small().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_line_size() {
        let c = DeviceConfig {
            line_size: 32,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_pow2_sets() {
        let c = DeviceConfig {
            l1_size: 24 * 1024, // 24kB/64/16 = 24 sets: not a power of two
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_properties() {
        let (b, sc, st) = (Scenario::BASELINE, Scenario::SCOPE_ONLY, Scenario::STEAL_ONLY);
        assert!(!b.steals() && !b.local_owner_sync());
        assert!(!sc.steals() && sc.local_owner_sync());
        assert!(st.steals() && !st.remote_ops());
        let rsp = Scenario::RSP;
        assert!(rsp.steals() && rsp.remote_ops());
        assert_eq!(rsp.protocol().name(), "rsp");
        assert!(Scenario::SRSP.remote_ops());
        assert_eq!(Scenario::SRSP.protocol().name(), "srsp");
        for s in Scenario::ALL {
            assert_eq!(Scenario::from_name(s.name()), Some(s));
        }
        assert_eq!(Scenario::from_name("bogus"), None);
    }

    #[test]
    fn scenarios_resolve_by_protocol_registry_name() {
        // Every registered protocol yields a scenario by name, with no
        // enum to extend: this is the acceptance property of the
        // registry refactor.
        for p in crate::sync::protocol::all() {
            let s = Scenario::for_protocol(p);
            assert_eq!(s.protocol(), p);
            assert_eq!(Scenario::from_name(p.name()), Some(s), "{}", p.name());
        }
        // The extension protocols surface their registry names directly.
        assert_eq!(Scenario::HLRC.name(), "hlrc");
        assert!(Scenario::HLRC.lazy_transfer() && !Scenario::HLRC.remote_ops());
        assert_eq!(Scenario::SRSP_ADAPTIVE.name(), "srsp-adaptive");
        assert!(Scenario::SRSP_ADAPTIVE.remote_ops());
        // The scoped protocol's canonical scenario is the classic
        // wg-scope no-steal one.
        assert_eq!(Scenario::from_name("scoped"), Some(Scenario::SCOPE_ONLY));
    }

    #[test]
    fn device_config_json_round_trips() {
        let mut cfg = DeviceConfig::small();
        cfg.proto_params = vec![
            ("lr_tbl_entries".to_string(), 4.0),
            ("overflow_threshold".to_string(), 0.25),
        ];
        let text = cfg.to_json().render();
        let v = jsonio::parse(&text).unwrap();
        assert_eq!(DeviceConfig::from_json(&v).unwrap(), cfg);
        // Defaults too (empty proto_params).
        let cfg = DeviceConfig::default();
        let v = jsonio::parse(&cfg.to_json().render()).unwrap();
        assert_eq!(DeviceConfig::from_json(&v).unwrap(), cfg);
        // A missing field is a loud error, never a default.
        let err = DeviceConfig::from_json(&Json::Obj(vec![])).unwrap_err();
        assert!(err.contains("num_cus"), "{err}");
        // An invalid configuration is rejected on load, not at run time.
        let bad = DeviceConfig {
            num_cus: 0,
            ..DeviceConfig::default()
        };
        let v = jsonio::parse(&bad.to_json().render()).unwrap();
        assert!(DeviceConfig::from_json(&v).is_err());
    }

    #[test]
    fn table1_renders() {
        let t = DeviceConfig::default().table1();
        assert!(t.contains("64 CUs"));
        assert!(t.contains("16kB"));
        assert!(t.contains("512kB"));
    }
}
