//! Device, protocol and scenario configuration.
//!
//! [`DeviceConfig`] carries the paper's Table-1 parameters as defaults and
//! can be loaded from / saved to a simple `key = value` config file
//! ([`file`] — no serde offline, so the parser is hand-rolled).

pub mod file;

pub use file::{parse_config_str, ConfigError};

use std::fmt;

/// Synchronization protocol implemented by the memory system.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Protocol {
    /// Scoped acquire/release only; remote ops are *not* supported
    /// (work-stealing scenarios that need them must use cmp scope).
    ScopedOnly,
    /// Naive Remote-Scope-Promotion (Orr et al.): remote ops flush and/or
    /// invalidate **every** L1 in the device.
    RspNaive,
    /// Scalable RSP (this paper): selective-flush via LR-TBL, selective
    /// (deferred) invalidation via PA-TBL.
    Srsp,
    /// heterogeneous Lazy Release Consistency (Alsop et al., MICRO'16) —
    /// the paper's §6 closest related work, implemented as an extension
    /// comparator: sync variables are *owned* by one L1 at a time
    /// (registry at the L2); any other CU's wg-scope sync op lazily
    /// transfers ownership (previous owner flushes, requester
    /// invalidates). Scalable, but lock transfers ping-pong and each
    /// registered variable burns registry/cache capacity — the costs the
    /// paper calls out.
    Hlrc,
}

impl Protocol {
    pub fn name(self) -> &'static str {
        match self {
            Protocol::ScopedOnly => "scoped",
            Protocol::RspNaive => "rsp",
            Protocol::Srsp => "srsp",
            Protocol::Hlrc => "hlrc",
        }
    }
}

impl fmt::Display for Protocol {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The five evaluation scenarios of §5.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scenario {
    /// Stealing disabled; queue ops use cmp (global) scope.
    Baseline,
    /// Stealing disabled; queue ops use wg (local) scope.
    ScopeOnly,
    /// Stealing enabled; all sync at cmp scope.
    StealOnly,
    /// Stealing enabled; owner at wg scope, steals via remote ops, naive
    /// all-L1 promotion.
    Rsp,
    /// Stealing enabled; owner at wg scope, steals via remote ops,
    /// selective promotion (the paper's contribution).
    Srsp,
    /// Extension (§6 related work): stealing enabled; *all* queue sync at
    /// wg scope, lazily transferred between owners by the hLRC protocol.
    /// Not part of the paper's five evaluated scenarios.
    Hlrc,
}

impl Scenario {
    /// The paper's five evaluated scenarios (§5.1). `Hlrc` is an
    /// extension and intentionally excluded.
    pub const ALL: [Scenario; 5] = [
        Scenario::Baseline,
        Scenario::ScopeOnly,
        Scenario::StealOnly,
        Scenario::Rsp,
        Scenario::Srsp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Baseline => "baseline",
            Scenario::ScopeOnly => "scope",
            Scenario::StealOnly => "steal",
            Scenario::Rsp => "rsp",
            Scenario::Srsp => "srsp",
            Scenario::Hlrc => "hlrc",
        }
    }

    pub fn from_name(s: &str) -> Option<Scenario> {
        Some(match s {
            "baseline" => Scenario::Baseline,
            "scope" | "scope-only" => Scenario::ScopeOnly,
            "steal" | "steal-only" => Scenario::StealOnly,
            "rsp" => Scenario::Rsp,
            "srsp" => Scenario::Srsp,
            "hlrc" => Scenario::Hlrc,
            _ => return None,
        })
    }

    /// Does this scenario steal work from other queues?
    pub fn steals(self) -> bool {
        matches!(
            self,
            Scenario::StealOnly | Scenario::Rsp | Scenario::Srsp | Scenario::Hlrc
        )
    }

    /// Does the queue owner use light wg-scope synchronization?
    pub fn local_owner_sync(self) -> bool {
        matches!(
            self,
            Scenario::ScopeOnly | Scenario::Rsp | Scenario::Srsp | Scenario::Hlrc
        )
    }

    /// Do steals use the remote-scope-promotion operations?
    pub fn remote_ops(self) -> bool {
        matches!(self, Scenario::Rsp | Scenario::Srsp)
    }

    /// Do steals use plain wg-scope ops, relying on the protocol to
    /// transfer ownership lazily (hLRC)?
    pub fn lazy_transfer(self) -> bool {
        matches!(self, Scenario::Hlrc)
    }

    /// The memory-system protocol this scenario runs on.
    pub fn protocol(self) -> Protocol {
        match self {
            Scenario::Baseline | Scenario::ScopeOnly | Scenario::StealOnly => {
                Protocol::ScopedOnly
            }
            Scenario::Rsp => Protocol::RspNaive,
            Scenario::Srsp => Protocol::Srsp,
            Scenario::Hlrc => Protocol::Hlrc,
        }
    }
}

impl fmt::Display for Scenario {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Full device configuration. Defaults reproduce Table 1 of the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceConfig {
    /// Number of Compute Units (paper: 64).
    pub num_cus: u32,
    /// Work-groups dispatched per CU (paper's work-stealing setup: one
    /// deque per work-group, one work-group per CU).
    pub wgs_per_cu: u32,

    // --- L1 data cache (per CU): 16kB, 64B lines, 16-way, 4-cycle ---
    pub l1_size: u32,
    pub l1_ways: u32,
    pub l1_latency: u64,
    /// sFIFO depth (paper: 16 entries).
    pub l1_sfifo: u32,

    // --- L2 (shared): 512kB, 64B lines, 16-way, 24-cycle ---
    pub l2_size: u32,
    pub l2_ways: u32,
    pub l2_latency: u64,
    pub l2_sfifo: u32,
    /// Number of L2 banks (line-interleaved) for port contention.
    pub l2_banks: u32,
    /// Cycles a bank is occupied per access.
    pub l2_bank_occupancy: u64,

    // --- Interconnect L1 <-> L2 ---
    pub xbar_latency: u64,
    /// Per-L1 link occupancy per message.
    pub xbar_occupancy: u64,

    // --- DRAM: DDR3, 8 channels, 500 MHz ---
    pub dram_channels: u32,
    pub dram_latency: u64,
    /// GPU cycles a channel is occupied per 64B line transfer
    /// (64B / (8B × 2 × 500MHz) at a 1 GHz core clock = 8 cycles).
    pub dram_occupancy: u64,

    // --- sRSP structures ---
    /// LR-TBL capacity (entries). 0 disables the table (degenerates to
    /// conservative full flush on every selective-flush request).
    pub lr_tbl_entries: u32,
    /// PA-TBL capacity (entries).
    pub pa_tbl_entries: u32,

    /// Cycles per work-item of a `Compute` KIR op (models ALU/SIMD
    /// throughput of a CU).
    pub compute_cycles_per_item: u64,
    /// Fixed issue cost of any instruction.
    pub issue_cycles: u64,

    /// Line size (bytes). 64 everywhere in the paper.
    pub line_size: u32,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            num_cus: 64,
            wgs_per_cu: 1,
            l1_size: 16 * 1024,
            l1_ways: 16,
            l1_latency: 4,
            l1_sfifo: 16,
            l2_size: 512 * 1024,
            l2_ways: 16,
            l2_latency: 24,
            l2_sfifo: 24,
            l2_banks: 16,
            l2_bank_occupancy: 2,
            xbar_latency: 8,
            xbar_occupancy: 1,
            dram_channels: 8,
            dram_latency: 100,
            dram_occupancy: 8,
            lr_tbl_entries: 16,
            pa_tbl_entries: 16,
            compute_cycles_per_item: 2,
            issue_cycles: 1,
            line_size: 64,
        }
    }
}

impl DeviceConfig {
    /// A small device for fast unit tests: 4 CUs, small caches.
    pub fn small() -> Self {
        Self {
            num_cus: 4,
            l1_size: 2 * 1024,
            l2_size: 32 * 1024,
            ..Self::default()
        }
    }

    pub fn total_wgs(&self) -> u32 {
        self.num_cus * self.wgs_per_cu
    }

    pub fn l1_sets(&self) -> u32 {
        self.l1_size / self.line_size / self.l1_ways
    }

    pub fn l2_sets(&self) -> u32 {
        self.l2_size / self.line_size / self.l2_ways
    }

    /// Validate internal consistency (powers of two where indexing needs
    /// them, nonzero sizes).
    pub fn validate(&self) -> Result<(), String> {
        if self.num_cus == 0 {
            return Err("num_cus must be > 0".into());
        }
        if self.line_size != 64 {
            return Err("line_size must be 64 (paper, Table 1)".into());
        }
        for (name, v) in [
            ("l1_sets", self.l1_sets()),
            ("l2_sets", self.l2_sets()),
            ("l2_banks", self.l2_banks),
            ("dram_channels", self.dram_channels),
        ] {
            if v == 0 || !v.is_power_of_two() {
                return Err(format!("{name} must be a nonzero power of two, got {v}"));
            }
        }
        if self.l1_sfifo == 0 || self.l2_sfifo == 0 {
            return Err("sFIFO depths must be > 0".into());
        }
        Ok(())
    }

    /// Render the Table-1 style parameter listing.
    pub fn table1(&self) -> String {
        format!(
            "| Parameter            | Value                                             |\n\
             |----------------------|---------------------------------------------------|\n\
             | Compute Units        | {} CUs, {} work-group(s)/CU                        |\n\
             | L1 data cache        | {}kB, {}B lines, {}-way, {}-cycle, {}-entry sFIFO  |\n\
             | L2 cache             | {}kB, {}B lines, {}-way, {}-cycle, {}-entry sFIFO  |\n\
             | L2 banking           | {} banks, {} cycle(s)/access                       |\n\
             | Interconnect         | {}-cycle latency, {} cycle(s)/message              |\n\
             | DRAM                 | DDR3, {} channels, {}-cycle latency                |\n\
             | Cache protocol       | no-allocate-on-write, write-combining              |\n\
             | LR-TBL / PA-TBL      | {} / {} entries                                    |",
            self.num_cus,
            self.wgs_per_cu,
            self.l1_size / 1024,
            self.line_size,
            self.l1_ways,
            self.l1_latency,
            self.l1_sfifo,
            self.l2_size / 1024,
            self.line_size,
            self.l2_ways,
            self.l2_latency,
            self.l2_sfifo,
            self.l2_banks,
            self.l2_bank_occupancy,
            self.xbar_latency,
            self.xbar_occupancy,
            self.dram_channels,
            self.dram_latency,
            self.lr_tbl_entries,
            self.pa_tbl_entries,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = DeviceConfig::default();
        assert_eq!(c.num_cus, 64);
        assert_eq!(c.l1_size, 16 * 1024);
        assert_eq!(c.l1_ways, 16);
        assert_eq!(c.l1_latency, 4);
        assert_eq!(c.l1_sfifo, 16);
        assert_eq!(c.l2_size, 512 * 1024);
        assert_eq!(c.l2_latency, 24);
        assert_eq!(c.l2_sfifo, 24);
        assert_eq!(c.dram_channels, 8);
        assert_eq!(c.l1_sets(), 16); // 16kB / 64B / 16-way
        assert_eq!(c.l2_sets(), 512); // 512kB / 64B / 16-way
        c.validate().unwrap();
    }

    #[test]
    fn small_config_valid() {
        DeviceConfig::small().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_line_size() {
        let c = DeviceConfig {
            line_size: 32,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_non_pow2_sets() {
        let c = DeviceConfig {
            l1_size: 24 * 1024, // 24kB/64/16 = 24 sets: not a power of two
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_properties() {
        use Scenario::*;
        assert!(!Baseline.steals() && !Baseline.local_owner_sync());
        assert!(!ScopeOnly.steals() && ScopeOnly.local_owner_sync());
        assert!(StealOnly.steals() && !StealOnly.remote_ops());
        assert!(Rsp.steals() && Rsp.remote_ops() && Rsp.protocol() == Protocol::RspNaive);
        assert!(Srsp.remote_ops() && Srsp.protocol() == Protocol::Srsp);
        for s in Scenario::ALL {
            assert_eq!(Scenario::from_name(s.name()), Some(s));
        }
        assert_eq!(Scenario::from_name("bogus"), None);
    }

    #[test]
    fn table1_renders() {
        let t = DeviceConfig::default().table1();
        assert!(t.contains("64 CUs"));
        assert!(t.contains("16kB"));
        assert!(t.contains("512kB"));
    }
}
