//! Hand-rolled `key = value` config-file parser (TOML-lite).
//!
//! Supported syntax: `#`-comments, blank lines, `key = value` with integer
//! values (decimal, `0x` hex, or `k`/`M` size suffixes) and bare-word
//! values for enumerations. Unknown keys are errors — catching typos in
//! experiment configs matters more than forward compatibility here.

use super::DeviceConfig;

/// Error from config parsing, with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

fn err(line: usize, msg: impl Into<String>) -> ConfigError {
    ConfigError {
        line,
        msg: msg.into(),
    }
}

/// Parse an integer with optional `0x` prefix or `k`/`M` suffix.
fn parse_int(s: &str, line: usize) -> Result<u64, ConfigError> {
    let s = s.trim();
    let (body, mult) = if let Some(b) = s.strip_suffix(['k', 'K']) {
        (b, 1024)
    } else if let Some(b) = s.strip_suffix(['m', 'M']) {
        (b, 1024 * 1024)
    } else {
        (s, 1)
    };
    let v = if let Some(hex) = body.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
    } else {
        body.trim().parse()
    }
    .map_err(|_| err(line, format!("invalid integer '{s}'")))?;
    Ok(v * mult)
}

/// Parse config text into a [`DeviceConfig`], starting from defaults.
pub fn parse_config_str(text: &str) -> Result<DeviceConfig, ConfigError> {
    let mut c = DeviceConfig::default();
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err(line_no, format!("expected 'key = value', got '{line}'")))?;
        let key = key.trim();
        let value = value.trim();
        let int = || parse_int(value, line_no);
        match key {
            "num_cus" => c.num_cus = int()? as u32,
            "wgs_per_cu" => c.wgs_per_cu = int()? as u32,
            "l1_size" => c.l1_size = int()? as u32,
            "l1_ways" => c.l1_ways = int()? as u32,
            "l1_latency" => c.l1_latency = int()?,
            "l1_sfifo" => c.l1_sfifo = int()? as u32,
            "l2_size" => c.l2_size = int()? as u32,
            "l2_ways" => c.l2_ways = int()? as u32,
            "l2_latency" => c.l2_latency = int()?,
            "l2_sfifo" => c.l2_sfifo = int()? as u32,
            "l2_banks" => c.l2_banks = int()? as u32,
            "l2_bank_occupancy" => c.l2_bank_occupancy = int()?,
            "xbar_latency" => c.xbar_latency = int()?,
            "xbar_occupancy" => c.xbar_occupancy = int()?,
            "dram_channels" => c.dram_channels = int()? as u32,
            "dram_latency" => c.dram_latency = int()?,
            "dram_occupancy" => c.dram_occupancy = int()?,
            "lr_tbl_entries" => c.lr_tbl_entries = int()? as u32,
            "pa_tbl_entries" => c.pa_tbl_entries = int()? as u32,
            "compute_cycles_per_item" => c.compute_cycles_per_item = int()?,
            "issue_cycles" => c.issue_cycles = int()?,
            "line_size" => c.line_size = int()? as u32,
            "trace_capacity" => c.trace_capacity = int()? as u32,
            _ => return Err(err(line_no, format!("unknown key '{key}'"))),
        }
    }
    c.validate().map_err(|m| err(0, m))?;
    Ok(c)
}

/// Load a config file from disk.
pub fn load_config(path: &std::path::Path) -> Result<DeviceConfig, Box<dyn std::error::Error>> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse_config_str(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let cfg = parse_config_str(
            "# paper Table 1\n\
             num_cus = 64\n\
             l1_size = 16k\n\
             l2_size = 512k   # shared\n\
             l1_latency = 4\n\
             dram_channels = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.num_cus, 64);
        assert_eq!(cfg.l1_size, 16 * 1024);
        assert_eq!(cfg.l2_size, 512 * 1024);
    }

    #[test]
    fn hex_and_suffixes() {
        assert_eq!(parse_int("0x10", 1).unwrap(), 16);
        assert_eq!(parse_int("2k", 1).unwrap(), 2048);
        assert_eq!(parse_int("1M", 1).unwrap(), 1 << 20);
        assert!(parse_int("zz", 1).is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let e = parse_config_str("l1_sizz = 16k\n").unwrap_err();
        assert!(e.msg.contains("unknown key"));
        assert_eq!(e.line, 1);
    }

    #[test]
    fn bad_syntax_rejected() {
        assert!(parse_config_str("num_cus 64\n").is_err());
    }

    #[test]
    fn validation_applied_after_parse() {
        // 24 kB L1 with 16 ways -> 24 sets: not a power of two.
        assert!(parse_config_str("l1_size = 24k\n").is_err());
    }

    #[test]
    fn empty_config_is_defaults() {
        let cfg = parse_config_str("").unwrap();
        assert_eq!(cfg, DeviceConfig::default());
    }
}
