//! CSR graphs: parsers for the paper's input formats and synthetic
//! generators matched to each DIMACS input's class.
//!
//! The paper uses `cond-mat-2003` (collaboration network → small-world),
//! `USA-road-BAY` (road network → grid-like, low degree, high diameter)
//! and `caidaRouterLevel` (router topology → power-law). Real files can be
//! loaded with [`Graph::from_dimacs_gr`] / [`Graph::from_matrix_market`];
//! the benches use the generators so the repository is self-contained.

use crate::jsonio::Json;
use crate::sim::SplitMix64;

/// Undirected graph in CSR form with u32 edge weights (1 for unweighted).
#[derive(Debug, Clone)]
pub struct Graph {
    pub n: u32,
    /// `row_ptr[v]..row_ptr[v+1]` indexes `col`/`weight` for vertex v.
    pub row_ptr: Vec<u32>,
    pub col: Vec<u32>,
    pub weight: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list (deduplicated, self-loops
    /// dropped, symmetrized).
    pub fn from_edges(n: u32, edges: &[(u32, u32, u32)]) -> Self {
        use std::collections::BTreeSet;
        let mut adj: Vec<BTreeSet<(u32, u32)>> = vec![BTreeSet::new(); n as usize];
        for &(u, v, w) in edges {
            if u == v || u >= n || v >= n {
                continue;
            }
            // Keep the first weight seen for a duplicate edge.
            if !adj[u as usize].iter().any(|&(x, _)| x == v) {
                adj[u as usize].insert((v, w));
            }
            if !adj[v as usize].iter().any(|&(x, _)| x == u) {
                adj[v as usize].insert((u, w));
            }
        }
        let mut row_ptr = Vec::with_capacity(n as usize + 1);
        let mut col = Vec::new();
        let mut weight = Vec::new();
        row_ptr.push(0u32);
        for v in 0..n as usize {
            for &(u, w) in &adj[v] {
                col.push(u);
                weight.push(w);
            }
            row_ptr.push(col.len() as u32);
        }
        Graph {
            n,
            row_ptr,
            col,
            weight,
        }
    }

    pub fn num_edges(&self) -> usize {
        self.col.len()
    }

    pub fn degree(&self, v: u32) -> u32 {
        self.row_ptr[v as usize + 1] - self.row_ptr[v as usize]
    }

    pub fn neighbors(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.row_ptr[v as usize] as usize;
        let hi = self.row_ptr[v as usize + 1] as usize;
        self.col[lo..hi]
            .iter()
            .copied()
            .zip(self.weight[lo..hi].iter().copied())
    }

    pub fn max_degree(&self) -> u32 {
        (0..self.n).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Structural sanity: symmetric, sorted rows, weights positive.
    pub fn validate(&self) -> Result<(), String> {
        if self.row_ptr.len() != self.n as usize + 1 {
            return Err("row_ptr length".into());
        }
        if *self.row_ptr.last().unwrap() as usize != self.col.len() {
            return Err("row_ptr end".into());
        }
        if self.col.len() != self.weight.len() {
            return Err("weight length".into());
        }
        for v in 0..self.n {
            for (u, w) in self.neighbors(v) {
                if u >= self.n {
                    return Err(format!("edge target {u} out of range"));
                }
                if w == 0 {
                    return Err("zero weight".into());
                }
                if !self.neighbors(u).any(|(x, _)| x == v) {
                    return Err(format!("asymmetric edge {v}->{u}"));
                }
            }
        }
        Ok(())
    }

    /// Serialize the CSR arrays (the result cache's preset layer stores
    /// generated graphs so repeated sweeps skip generation).
    pub fn to_json(&self) -> Json {
        let u32s = |xs: &[u32]| Json::Arr(xs.iter().map(|&v| Json::u32(v)).collect());
        Json::Obj(vec![
            ("n".into(), Json::u32(self.n)),
            ("row_ptr".into(), u32s(&self.row_ptr)),
            ("col".into(), u32s(&self.col)),
            ("weight".into(), u32s(&self.weight)),
        ])
    }

    /// Inverse of [`Graph::to_json`]; runs [`Graph::validate`] so a
    /// corrupted record can never produce a structurally broken graph.
    pub fn from_json(v: &Json) -> Result<Graph, String> {
        let arr_u32 = |key: &str| -> Result<Vec<u32>, String> {
            v.get(key)?
                .arr()?
                .iter()
                .map(|x| x.as_u32())
                .collect::<Result<Vec<u32>, String>>()
                .map_err(|e| format!("{key}: {e}"))
        };
        let g = Graph {
            n: v.get("n")?.as_u32()?,
            row_ptr: arr_u32("row_ptr")?,
            col: arr_u32("col")?,
            weight: arr_u32("weight")?,
        };
        g.validate()?;
        Ok(g)
    }

    // ------------------------------------------------------------------
    // Generators (matched to the paper's input classes)
    // ------------------------------------------------------------------

    /// Road-network analog of `USA-road-BAY`: a w×h grid (4-neighbor) with
    /// integer weights in `[1, 100]` and a sparse set of "highway"
    /// shortcuts (long-range edges), giving low degree and high diameter.
    pub fn road_grid(w: u32, h: u32, seed: u64) -> Graph {
        let n = w * h;
        let mut rng = SplitMix64::new(seed);
        let mut edges = Vec::new();
        let id = |x: u32, y: u32| y * w + x;
        for y in 0..h {
            for x in 0..w {
                if x + 1 < w {
                    edges.push((id(x, y), id(x + 1, y), 1 + rng.below(100) as u32));
                }
                if y + 1 < h {
                    edges.push((id(x, y), id(x, y + 1), 1 + rng.below(100) as u32));
                }
            }
        }
        // ~n/64 highway shortcuts.
        for _ in 0..(n / 64).max(1) {
            let a = rng.below(n as u64) as u32;
            let b = rng.below(n as u64) as u32;
            edges.push((a, b, 50 + rng.below(200) as u32));
        }
        Graph::from_edges(n, &edges)
    }

    /// Small-world analog of `cond-mat-2003` (Watts–Strogatz): ring of
    /// degree `k` with rewiring probability `beta`.
    pub fn small_world(n: u32, k: u32, beta: f64, seed: u64) -> Graph {
        assert!(k >= 2 && k % 2 == 0);
        let mut rng = SplitMix64::new(seed);
        let mut edges = Vec::new();
        for v in 0..n {
            for j in 1..=k / 2 {
                let mut u = (v + j) % n;
                if rng.chance(beta) {
                    u = rng.below(n as u64) as u32;
                }
                edges.push((v, u, 1));
            }
        }
        Graph::from_edges(n, &edges)
    }

    /// Power-law analog of `caidaRouterLevel` (Barabási–Albert
    /// preferential attachment, `m` edges per new vertex).
    pub fn power_law(n: u32, m: u32, seed: u64) -> Graph {
        assert!(n > m && m >= 1);
        let mut rng = SplitMix64::new(seed);
        let mut edges: Vec<(u32, u32, u32)> = Vec::new();
        // Repeated-endpoint list implements preferential attachment.
        let mut endpoints: Vec<u32> = Vec::new();
        // Seed clique over the first m+1 vertices.
        for a in 0..=m {
            for b in (a + 1)..=m {
                edges.push((a, b, 1));
                endpoints.push(a);
                endpoints.push(b);
            }
        }
        for v in (m + 1)..n {
            let mut chosen = Vec::with_capacity(m as usize);
            while chosen.len() < m as usize {
                let u = endpoints[rng.index(endpoints.len())];
                if u != v && !chosen.contains(&u) {
                    chosen.push(u);
                }
            }
            for &u in &chosen {
                edges.push((v, u, 1));
                endpoints.push(v);
                endpoints.push(u);
            }
        }
        Graph::from_edges(n, &edges)
    }

    // ------------------------------------------------------------------
    // Parsers
    // ------------------------------------------------------------------

    /// DIMACS shortest-path format (`.gr`): `p sp <n> <m>` header and
    /// `a <u> <v> <w>` arcs (1-based vertices).
    pub fn from_dimacs_gr(text: &str) -> Result<Graph, String> {
        let mut n = 0u32;
        let mut edges = Vec::new();
        for (lno, line) in text.lines().enumerate() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("c") | None => continue,
                Some("p") => {
                    // p sp n m
                    let _sp = it.next();
                    n = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| format!("line {}: bad p line", lno + 1))?;
                }
                Some("a") => {
                    let u: u32 = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| format!("line {}: bad arc", lno + 1))?;
                    let v: u32 = it
                        .next()
                        .and_then(|s| s.parse().ok())
                        .ok_or_else(|| format!("line {}: bad arc", lno + 1))?;
                    let w: u32 = it.next().and_then(|s| s.parse().ok()).unwrap_or(1);
                    if u == 0 || v == 0 {
                        return Err(format!("line {}: DIMACS vertices are 1-based", lno + 1));
                    }
                    edges.push((u - 1, v - 1, w.max(1)));
                }
                Some(_) => continue,
            }
        }
        if n == 0 {
            return Err("missing 'p' header".into());
        }
        Ok(Graph::from_edges(n, &edges))
    }

    /// MatrixMarket pattern format (as distributed for `cond-mat-2003` /
    /// `caidaRouterLevel`): `%%`-comments, then `n n m`, then `u v` pairs
    /// (1-based).
    pub fn from_matrix_market(text: &str) -> Result<Graph, String> {
        let mut lines = text.lines().filter(|l| !l.starts_with('%'));
        let header = lines.next().ok_or("empty file")?;
        let mut it = header.split_whitespace();
        let n: u32 = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or("bad header")?;
        let mut edges = Vec::new();
        for (lno, line) in lines.enumerate() {
            let mut it = line.split_whitespace();
            let (Some(u), Some(v)) = (it.next(), it.next()) else {
                continue;
            };
            let u: u32 = u.parse().map_err(|_| format!("line {}: bad u", lno + 2))?;
            let v: u32 = v.parse().map_err(|_| format!("line {}: bad v", lno + 2))?;
            if u == 0 || v == 0 {
                return Err("MatrixMarket vertices are 1-based".into());
            }
            edges.push((u - 1, v - 1, 1));
        }
        Ok(Graph::from_edges(n, &edges))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_symmetrizes_and_dedups() {
        let g = Graph::from_edges(4, &[(0, 1, 5), (1, 0, 7), (2, 3, 1), (3, 3, 9)]);
        g.validate().unwrap();
        assert_eq!(g.num_edges(), 4); // (0,1),(1,0),(2,3),(3,2); self-loop dropped
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.neighbors(0).next(), Some((1, 5)));
        assert_eq!(g.neighbors(1).next(), Some((0, 5)), "first weight kept");
        assert_eq!(g.degree(3), 1, "self loop dropped");
    }

    #[test]
    fn road_grid_structure() {
        let g = Graph::road_grid(8, 8, 1);
        g.validate().unwrap();
        assert_eq!(g.n, 64);
        // Interior vertices have degree >= 4 (plus any highways).
        assert!(g.degree(9) >= 4);
        // Low max degree (road-like).
        assert!(g.max_degree() <= 10, "max degree {}", g.max_degree());
    }

    #[test]
    fn small_world_structure() {
        let g = Graph::small_world(128, 4, 0.1, 2);
        g.validate().unwrap();
        let avg = g.num_edges() as f64 / g.n as f64;
        assert!((3.0..5.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn power_law_has_hubs() {
        let g = Graph::power_law(512, 2, 3);
        g.validate().unwrap();
        let max = g.max_degree();
        let avg = g.num_edges() as u32 / g.n;
        assert!(
            max > 6 * avg,
            "power-law should have hubs: max={max} avg={avg}"
        );
    }

    #[test]
    fn generators_deterministic() {
        let a = Graph::power_law(100, 2, 42);
        let b = Graph::power_law(100, 2, 42);
        assert_eq!(a.col, b.col);
        let c = Graph::power_law(100, 2, 43);
        assert_ne!(a.col, c.col);
    }

    #[test]
    fn dimacs_gr_round_trip() {
        let text = "c comment\np sp 4 3\na 1 2 10\na 2 3 20\na 3 4 1\n";
        let g = Graph::from_dimacs_gr(text).unwrap();
        g.validate().unwrap();
        assert_eq!(g.n, 4);
        assert_eq!(g.neighbors(0).next(), Some((1, 10)));
        assert_eq!(g.neighbors(3).next(), Some((2, 1)));
    }

    #[test]
    fn dimacs_rejects_garbage() {
        assert!(Graph::from_dimacs_gr("a 1 2 3\n").is_err()); // no header
        assert!(Graph::from_dimacs_gr("p sp 4 1\na 0 2 3\n").is_err()); // 0-based
    }

    #[test]
    fn matrix_market_parse() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n3 3 2\n1 2\n2 3\n";
        let g = Graph::from_matrix_market(text).unwrap();
        g.validate().unwrap();
        assert_eq!(g.n, 3);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn json_codec_round_trips_and_validates() {
        let g = Graph::road_grid(4, 4, 7);
        let text = g.to_json().render();
        let back = Graph::from_json(&crate::jsonio::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n, g.n);
        assert_eq!(back.row_ptr, g.row_ptr);
        assert_eq!(back.col, g.col);
        assert_eq!(back.weight, g.weight);
        assert_eq!(back.to_json().render(), text, "codec is byte-stable");
        // A structurally broken record is refused, not returned.
        let broken = text.replacen("\"n\":16", "\"n\":2", 1);
        assert!(Graph::from_json(&crate::jsonio::parse(&broken).unwrap()).is_err());
    }
}
