//! The evaluation workloads (§5.1): work-stealing versions of Pannotia's
//! graph applications, written in KIR against the simulated memory system.
//!
//! * [`graph`] — CSR graphs: DIMACS / MatrixMarket parsers and synthetic
//!   generators matched to the paper's input classes.
//! * [`deque`] — the Cederman–Tsigas-style work-stealing deque: memory
//!   layout + KIR code generation, parameterized by scenario sync flavor.
//! * [`engine`] — the compute engine: gathers per-task graph data through
//!   the timed memory interface, then delegates the batch math to a
//!   [`TileMath`](engine::TileMath) backend (native Rust or the
//!   AOT-compiled XLA artifact via [`crate::runtime`]).
//! * [`pagerank`] / [`sssp`] / [`mis`] — the three applications with their
//!   host drivers and native oracles.
//! * [`bfs`] / [`prodcons`] — two further kernels exercising sync
//!   patterns the graph trio does not (level-synchronous wavefronts,
//!   intra-launch flag handoff).
//! * [`stress`] — the asymmetry-stress family: a synthetic
//!   sharer/stealer kernel with a tunable remote-access ratio, the
//!   `remote-ratio` sweep axis.
//! * [`lock`] — the asymmetric mutex (cf. Liu et al.): owner fast-path
//!   critical sections at wg scope, stealers through remote scope.
//! * [`registry`] — the pluggable workload table: every kernel
//!   self-describes (name, oracle, default chunking, tunable params) and
//!   the runner/CLI/presets/reports resolve through it.
//! * [`driver`] — the shared scenario runner (queue fill, kernel launches,
//!   convergence loops).

pub mod bfs;
pub mod deque;
pub mod driver;
pub mod engine;
pub mod graph;
pub mod lock;
pub mod mis;
pub mod pagerank;
pub mod prodcons;
pub mod registry;
pub mod sssp;
pub mod stress;

pub use driver::{run_scenario, RunResult};
pub use engine::{NativeMath, TileMath, WorkEngine, K_TILE, V_TILE};
pub use graph::Graph;
pub use registry::{Kernel, Params, WorkloadId, WorkloadPreset, WorkloadSize};
