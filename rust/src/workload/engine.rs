//! The compute engine: executes one task (a chunk of vertices) on behalf
//! of a work-group.
//!
//! The engine issues all graph-data memory traffic (row pointers, adjacency
//! lists, neighbor state gathers, result scatters) through the timed
//! [`MemAccess`] interface — that traffic *is* the locality the scenarios
//! fight over. The batch floating-point/integer math on the gathered tiles
//! is delegated to a [`TileMath`] backend:
//!
//! * [`NativeMath`] — straight Rust; used by the figure sweeps (fast).
//! * `PjrtMath` ([`crate::runtime`]) — the AOT-compiled JAX/Pallas
//!   artifacts executed through the PJRT CPU client; used by the
//!   end-to-end examples. Both backends compute identical values (tested).
//!
//! Tiles are fixed-shape `(V_TILE, K_TILE)` — the shape the Pallas kernels
//! are lowered for. Vertices with degree > `K_TILE` span multiple tile
//! rows; their partial results are combined by the engine.

use super::graph::Graph;
use crate::kir::{ComputeEngine, MemAccess};
use crate::mem::Addr;

/// Tile height (vertices per tile row-block).
pub const V_TILE: usize = 64;
/// Tile width (neighbor slots per row).
pub const K_TILE: usize = 32;

/// Compute kinds (KIR `Compute` instruction immediate).
pub const KIND_PAGERANK: u32 = 1;
pub const KIND_SSSP: u32 = 2;
pub const KIND_MIS_SELECT: u32 = 3;
pub const KIND_MIS_EXCLUDE: u32 = 4;
/// Bottom-up level-synchronous BFS (min-plus over unit weights).
pub const KIND_BFS: u32 = 5;
/// Asymmetry-stress cell update (see [`crate::workload::stress`]).
pub const KIND_STRESS: u32 = 6;

/// Distance "infinity" for SSSP (fits i32 so XLA i32 math is exact; large
/// enough that INF + max_weight never wraps).
pub const DIST_INF: u32 = 0x3FFF_FFFF;

/// MIS vertex states.
pub const MIS_UNDECIDED: u32 = 0;
pub const MIS_IN: u32 = 1;
pub const MIS_OUT: u32 = 2;

/// Unique per-vertex priority: a bijective mix of the vertex id (odd
/// multiplier => invertible mod 2^32), so priorities never tie.
#[inline]
pub fn mis_priority(v: u32) -> u32 {
    v.wrapping_mul(0x9E37_79B1).rotate_left(16) ^ v
}

/// One PageRank tile: gathered neighbor contributions.
#[derive(Debug, Clone)]
pub struct PageRankTile {
    /// `contribs[i*K_TILE + k]` = rank[u]/outdeg[u] of the k-th neighbor
    /// of row-vertex i (0.0 when padded).
    pub contribs: Vec<f32>,
    /// Per-row damping bookkeeping handled by the caller.
    pub rows: usize,
}

/// Batch math over gathered tiles. Implementations must be value-identical
/// (the pytest suite pins the Pallas kernels to `ref.py`; the Rust tests
/// pin `PjrtMath` to `NativeMath`).
///
/// The `*_into` variants write into a caller-owned buffer so the hot loop
/// allocates nothing per task; they default to delegating to the
/// `Vec`-returning methods, so backends that only implement the required
/// trio (e.g. `PjrtMath`) keep working unchanged.
pub trait TileMath {
    /// PageRank: per-row sum of contributions, then
    /// `rank = (1-d)/n + d * sum`. Returns `rows` ranks.
    fn pagerank_rows(&mut self, contribs: &[f32], rows: usize, damping: f32, n: u32) -> Vec<f32>;

    /// SSSP min-plus: per-row `min(dist_u[k] + w[k])` over valid slots
    /// (padded slots carry `DIST_INF` + 0). Returns `rows` candidates.
    fn sssp_rows(&mut self, dist_plus_w: &[i32], rows: usize) -> Vec<i32>;

    /// MIS select: row i joins the set iff `my_pri[i]` exceeds every
    /// undecided neighbor's priority (padded slots carry 0).
    fn mis_rows(&mut self, my_pri: &[u32], nbr_pri: &[u32], rows: usize) -> Vec<bool>;

    /// Allocation-free variant of [`pagerank_rows`](Self::pagerank_rows):
    /// clears `out` and fills it with the `rows` ranks.
    fn pagerank_rows_into(
        &mut self,
        contribs: &[f32],
        rows: usize,
        damping: f32,
        n: u32,
        out: &mut Vec<f32>,
    ) {
        out.clear();
        out.extend(self.pagerank_rows(contribs, rows, damping, n));
    }

    /// Allocation-free variant of [`sssp_rows`](Self::sssp_rows).
    fn sssp_rows_into(&mut self, dist_plus_w: &[i32], rows: usize, out: &mut Vec<i32>) {
        out.clear();
        out.extend(self.sssp_rows(dist_plus_w, rows));
    }

    /// Allocation-free variant of [`mis_rows`](Self::mis_rows).
    fn mis_rows_into(&mut self, my_pri: &[u32], nbr_pri: &[u32], rows: usize, out: &mut Vec<bool>) {
        out.clear();
        out.extend(self.mis_rows(my_pri, nbr_pri, rows));
    }
}

/// Pure-Rust tile math. Implements the `*_into` forms directly and defines
/// the `Vec`-returning forms in terms of them, so the native backend never
/// double-allocates.
#[derive(Debug, Default, Clone)]
pub struct NativeMath;

impl TileMath for NativeMath {
    fn pagerank_rows(&mut self, contribs: &[f32], rows: usize, damping: f32, n: u32) -> Vec<f32> {
        let mut out = Vec::with_capacity(rows);
        self.pagerank_rows_into(contribs, rows, damping, n, &mut out);
        out
    }

    fn sssp_rows(&mut self, dist_plus_w: &[i32], rows: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(rows);
        self.sssp_rows_into(dist_plus_w, rows, &mut out);
        out
    }

    fn mis_rows(&mut self, my_pri: &[u32], nbr_pri: &[u32], rows: usize) -> Vec<bool> {
        let mut out = Vec::with_capacity(rows);
        self.mis_rows_into(my_pri, nbr_pri, rows, &mut out);
        out
    }

    fn pagerank_rows_into(
        &mut self,
        contribs: &[f32],
        rows: usize,
        damping: f32,
        n: u32,
        out: &mut Vec<f32>,
    ) {
        assert_eq!(contribs.len(), rows * K_TILE);
        out.clear();
        out.extend((0..rows).map(|i| {
            let s: f32 = contribs[i * K_TILE..(i + 1) * K_TILE].iter().sum();
            (1.0 - damping) / n as f32 + damping * s
        }));
    }

    fn sssp_rows_into(&mut self, dist_plus_w: &[i32], rows: usize, out: &mut Vec<i32>) {
        assert_eq!(dist_plus_w.len(), rows * K_TILE);
        out.clear();
        out.extend((0..rows).map(|i| {
            dist_plus_w[i * K_TILE..(i + 1) * K_TILE]
                .iter()
                .copied()
                .min()
                .unwrap()
        }));
    }

    fn mis_rows_into(&mut self, my_pri: &[u32], nbr_pri: &[u32], rows: usize, out: &mut Vec<bool>) {
        assert_eq!(my_pri.len(), rows);
        assert_eq!(nbr_pri.len(), rows * K_TILE);
        out.clear();
        out.extend((0..rows).map(|i| {
            let max_n = nbr_pri[i * K_TILE..(i + 1) * K_TILE]
                .iter()
                .copied()
                .max()
                .unwrap();
            my_pri[i] > max_n
        }));
    }
}

/// Device-memory addresses of one application's arrays (host-allocated).
/// All-numeric and `Copy`, so the task hot path reads it by value instead
/// of cloning per task.
#[derive(Debug, Clone, Copy, Default)]
pub struct AppLayout {
    pub row_ptr: Addr,
    pub col: Addr,
    pub weight: Addr,
    /// PageRank: contribution in (read), rank out + contribution out
    /// (write). SSSP: dist (read/write). MIS: state + priority arrays.
    pub a0: Addr,
    pub a1: Addr,
    pub a2: Addr,
    /// Per-vertex "changed" flags (u32) driving the host's worklists.
    pub changed: Addr,
    /// Vertices per task chunk.
    pub chunk: u32,
    pub n: u32,
    /// PageRank damping factor bits (f32).
    pub damping_bits: u32,
    /// Workload-specific auxiliary word (stress: pad reads per task).
    pub aux: u32,
    /// Allocator high-water mark after the app's arrays (the scenario
    /// runner places the deques above it).
    pub high_water: u64,
}

/// Reusable per-engine gather/reduce buffers (arena). Tasks clear these
/// instead of allocating fresh `Vec`s, so steady-state task execution
/// performs no heap allocation. Purely a host-side speed concern: buffer
/// reuse never changes the simulated memory traffic or its order.
#[derive(Debug, Default)]
struct Scratch {
    /// Row -> source vertex (SoA side table for partial-row combining).
    rows_v: Vec<u32>,
    /// Gathered f32 tile (PageRank contributions).
    tile_f32: Vec<f32>,
    /// Gathered i32 tile (SSSP / BFS `dist + w` slots).
    tile_i32: Vec<i32>,
    /// MIS per-row own priorities.
    my_pri: Vec<u32>,
    /// MIS gathered neighbor priorities.
    nbr_pri: Vec<u32>,
    /// Tile-math outputs.
    out_f32: Vec<f32>,
    out_i32: Vec<i32>,
    out_bool: Vec<bool>,
    /// Dense per-vertex reductions, indexed `v - lo` over the task chunk
    /// (replaces the old `HashMap<u32, _>` reductions).
    red_f32: Vec<f32>,
    red_u32: Vec<u32>,
    red_i32: Vec<i32>,
    red_state: Vec<u8>,
}

/// The engine: decodes task ids into vertex chunks, gathers through the
/// timed memory path, calls the tile math, scatters results.
pub struct WorkEngine<M: TileMath> {
    pub math: M,
    pub layout: AppLayout,
    scratch: Scratch,
}

impl<M: TileMath> WorkEngine<M> {
    pub fn new(math: M, layout: AppLayout) -> Self {
        Self {
            math,
            layout,
            scratch: Scratch::default(),
        }
    }

    fn chunk_range(&self, task: u64) -> (u32, u32) {
        let lo = task as u32 * self.layout.chunk;
        let hi = (lo + self.layout.chunk).min(self.layout.n);
        (lo, hi)
    }

    /// PageRank task: pull contributions of every neighbor, compute new
    /// rank + new contribution, write both. Returns items (edges).
    fn pagerank(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let (lo, hi) = self.chunk_range(task);
        let damping = f32::from_bits(l.damping_bits);
        let mut items = 0u64;

        self.scratch.rows_v.clear();
        self.scratch.tile_f32.clear();
        for v in lo..hi {
            let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
            let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
            let deg = (rp1 - rp0) as usize;
            items += deg as u64;
            let nrows = deg.div_ceil(K_TILE).max(1);
            for r in 0..nrows {
                self.scratch.rows_v.push(v);
                let mut slots = [0f32; K_TILE];
                for k in 0..K_TILE {
                    let e = rp0 as usize + r * K_TILE + k;
                    if e < rp1 as usize {
                        let u = mem.read_u32(l.col + e as u64 * 4);
                        // contribution_in[u] = rank[u]/outdeg[u], precomputed.
                        slots[k] = mem.read_f32(l.a0 + u as u64 * 4);
                    }
                }
                self.scratch.tile_f32.extend_from_slice(&slots);
            }
        }
        if self.scratch.rows_v.is_empty() {
            return items;
        }
        self.math.pagerank_rows_into(
            &self.scratch.tile_f32,
            self.scratch.rows_v.len(),
            damping,
            l.n,
            &mut self.scratch.out_f32,
        );
        // Combine partial rows: sum of row-sums needs base re-added once.
        // rank_row = base + d*sum_row => rank_v = base + d*Σ sums
        //          = Σ rank_row - (nrows-1)*base.
        // Dense (v - lo)-indexed reduction; rows for a vertex accumulate
        // in ascending row order, matching the gather order exactly, so
        // the f32 sums are bit-identical to the old HashMap reduction.
        let base = (1.0 - damping) / l.n as f32;
        let span = (hi - lo) as usize;
        self.scratch.red_f32.clear();
        self.scratch.red_f32.resize(span, 0.0);
        self.scratch.red_u32.clear();
        self.scratch.red_u32.resize(span, 0);
        for (row, &v) in self.scratch.rows_v.iter().enumerate() {
            let i = (v - lo) as usize;
            self.scratch.red_f32[i] += self.scratch.out_f32[row];
            self.scratch.red_u32[i] += 1;
        }
        for v in lo..hi {
            let i = (v - lo) as usize;
            let nrows = self.scratch.red_u32[i];
            if nrows == 0 {
                continue;
            }
            let rank = self.scratch.red_f32[i] - (nrows - 1) as f32 * base;
            mem.write_f32(l.a1 + v as u64 * 4, rank);
            // New contribution for the next iteration.
            let deg = {
                let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
                let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
                (rp1 - rp0).max(1)
            };
            mem.write_f32(l.a2 + v as u64 * 4, rank / deg as f32);
        }
        items
    }

    /// SSSP task (pull relaxation): `dist[v] = min(dist[v],
    /// min_u(dist[u] + w(u,v)))`; only v's own entry is written (race-free).
    fn sssp(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let (lo, hi) = self.chunk_range(task);
        let mut items = 0u64;

        self.scratch.rows_v.clear();
        self.scratch.tile_i32.clear();
        for v in lo..hi {
            let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
            let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
            let deg = (rp1 - rp0) as usize;
            items += deg as u64;
            let nrows = deg.div_ceil(K_TILE).max(1);
            for r in 0..nrows {
                self.scratch.rows_v.push(v);
                let mut slots = [DIST_INF as i32; K_TILE];
                for k in 0..K_TILE {
                    let e = rp0 as usize + r * K_TILE + k;
                    if e < rp1 as usize {
                        let u = mem.read_u32(l.col + e as u64 * 4);
                        let w = mem.read_u32(l.weight + e as u64 * 4);
                        let du = mem.read_u32(l.a0 + u as u64 * 4);
                        slots[k] = (du.min(DIST_INF) as i32).saturating_add(w as i32);
                    }
                }
                self.scratch.tile_i32.extend_from_slice(&slots);
            }
        }
        if self.scratch.rows_v.is_empty() {
            return items;
        }
        self.math.sssp_rows_into(
            &self.scratch.tile_i32,
            self.scratch.rows_v.len(),
            &mut self.scratch.out_i32,
        );
        // Dense per-vertex min (order-independent).
        let span = (hi - lo) as usize;
        self.scratch.red_i32.clear();
        self.scratch.red_i32.resize(span, i32::MAX);
        self.scratch.red_state.clear();
        self.scratch.red_state.resize(span, 0);
        for (row, &v) in self.scratch.rows_v.iter().enumerate() {
            let i = (v - lo) as usize;
            self.scratch.red_i32[i] = self.scratch.red_i32[i].min(self.scratch.out_i32[row]);
            self.scratch.red_state[i] = 1;
        }
        for v in lo..hi {
            let i = (v - lo) as usize;
            if self.scratch.red_state[i] == 0 {
                continue;
            }
            let cand = self.scratch.red_i32[i];
            let dv = mem.read_u32(l.a0 + v as u64 * 4) as i32;
            if cand < dv {
                mem.write_u32(l.a0 + v as u64 * 4, cand as u32);
                mem.write_u32(l.changed + v as u64 * 4, 1);
            }
        }
        items
    }

    /// MIS select phase: undecided v joins when its priority beats every
    /// undecided neighbor.
    fn mis_select(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let (lo, hi) = self.chunk_range(task);
        let mut items = 0u64;

        self.scratch.rows_v.clear();
        self.scratch.my_pri.clear();
        self.scratch.nbr_pri.clear();
        for v in lo..hi {
            // a0 = state array, a1 = priority array.
            let state = mem.read_u32(l.a0 + v as u64 * 4);
            if state != MIS_UNDECIDED {
                continue;
            }
            let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
            let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
            let deg = (rp1 - rp0) as usize;
            items += deg as u64;
            let pri_v = mem.read_u32(l.a1 + v as u64 * 4);
            let nrows = deg.div_ceil(K_TILE).max(1);
            for r in 0..nrows {
                self.scratch.rows_v.push(v);
                self.scratch.my_pri.push(pri_v);
                let mut slots = [0u32; K_TILE];
                for k in 0..K_TILE {
                    let e = rp0 as usize + r * K_TILE + k;
                    if e < rp1 as usize {
                        let u = mem.read_u32(l.col + e as u64 * 4);
                        let su = mem.read_u32(l.a0 + u as u64 * 4);
                        if su == MIS_UNDECIDED {
                            slots[k] = mem.read_u32(l.a1 + u as u64 * 4);
                        }
                    }
                }
                self.scratch.nbr_pri.extend_from_slice(&slots);
            }
        }
        if self.scratch.rows_v.is_empty() {
            return items;
        }
        self.math.mis_rows_into(
            &self.scratch.my_pri,
            &self.scratch.nbr_pri,
            self.scratch.rows_v.len(),
            &mut self.scratch.out_bool,
        );
        // A vertex joins only if it wins in *all* of its rows.
        // Dense state: 0 = no rows, 1 = winning so far, 2 = lost a row.
        let span = (hi - lo) as usize;
        self.scratch.red_state.clear();
        self.scratch.red_state.resize(span, 0);
        for (row, &v) in self.scratch.rows_v.iter().enumerate() {
            let i = (v - lo) as usize;
            let win = self.scratch.out_bool[row];
            if self.scratch.red_state[i] != 2 {
                self.scratch.red_state[i] = if win { 1 } else { 2 };
            }
        }
        // Winners are recorded in the *newflag* array (a2), NOT the state
        // array: the select phase must race-freely compare priorities
        // against the round-start state snapshot. Writing states here
        // would let later tasks mask a freshly-IN neighbor out of the
        // comparison and elect adjacent vertices (a real Luby-on-GPU
        // pitfall — caught by the validity tests).
        //
        // The scatter walks vertices in ascending order. The old HashMap
        // scatter issued these stores in the map's (seeded, per-process)
        // iteration order, which made simulated timing nondeterministic
        // across processes; ascending order pins it.
        for v in lo..hi {
            if self.scratch.red_state[(v - lo) as usize] == 1 {
                mem.write_u32(l.a2 + v as u64 * 4, 1);
                mem.write_u32(l.changed + v as u64 * 4, 1);
            }
        }
        items
    }

    /// Bottom-up level-synchronous BFS task: an unvisited v scans its
    /// neighbors' depths and takes `min(depth[u]) + 1` (min-plus over
    /// unit weights, via the same tile math as SSSP), but the write is
    /// **level-gated**: only accepted when the candidate equals the
    /// current level (`layout.aux`). The gate is load-bearing — without
    /// it, v could read a *non-optimal* neighbor's freshly-written depth
    /// mid-round and store an overestimate that the write-once "unvisited
    /// only" activation never corrects. With the gate each round
    /// completes exactly one BFS level (a depth-(k-1) entry can only have
    /// been written in an earlier round, where it is exact by induction).
    fn bfs(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let (lo, hi) = self.chunk_range(task);
        let mut items = 0u64;

        self.scratch.rows_v.clear();
        self.scratch.tile_i32.clear();
        for v in lo..hi {
            // a0 = depth array; only unvisited vertices do work.
            if mem.read_u32(l.a0 + v as u64 * 4) != DIST_INF {
                continue;
            }
            let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
            let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
            let deg = (rp1 - rp0) as usize;
            items += deg as u64;
            let nrows = deg.div_ceil(K_TILE).max(1);
            for r in 0..nrows {
                self.scratch.rows_v.push(v);
                let mut slots = [DIST_INF as i32; K_TILE];
                for k in 0..K_TILE {
                    let e = rp0 as usize + r * K_TILE + k;
                    if e < rp1 as usize {
                        let u = mem.read_u32(l.col + e as u64 * 4);
                        let du = mem.read_u32(l.a0 + u as u64 * 4);
                        slots[k] = (du.min(DIST_INF) as i32).saturating_add(1);
                    }
                }
                self.scratch.tile_i32.extend_from_slice(&slots);
            }
        }
        if self.scratch.rows_v.is_empty() {
            return items;
        }
        self.math.sssp_rows_into(
            &self.scratch.tile_i32,
            self.scratch.rows_v.len(),
            &mut self.scratch.out_i32,
        );
        let span = (hi - lo) as usize;
        self.scratch.red_i32.clear();
        self.scratch.red_i32.resize(span, i32::MAX);
        self.scratch.red_state.clear();
        self.scratch.red_state.resize(span, 0);
        for (row, &v) in self.scratch.rows_v.iter().enumerate() {
            let i = (v - lo) as usize;
            self.scratch.red_i32[i] = self.scratch.red_i32[i].min(self.scratch.out_i32[row]);
            self.scratch.red_state[i] = 1;
        }
        for v in lo..hi {
            let i = (v - lo) as usize;
            if self.scratch.red_state[i] == 0 {
                continue;
            }
            let cand = self.scratch.red_i32[i];
            if cand as u32 == l.aux {
                mem.write_u32(l.a0 + v as u64 * 4, cand as u32);
            }
        }
        items
    }

    /// Asymmetry-stress task: task `c` (one cell per task) bumps its own
    /// counter `cells[c]` and xors `aux` words of the shared read-only
    /// pad into `scratch[c]` — the private locality that global-scope
    /// invalidation destroys and selective promotion preserves. Writes
    /// only the task's own entries: race-free under every scenario.
    fn stress(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let c = task as u32;
        // a1 = pad (read-only), a0 = cells, a2 = scratch.
        let mut acc = 0u32;
        for k in 0..l.aux {
            let idx = (c.wrapping_add(k)) % l.n.max(1);
            acc ^= mem.read_u32(l.a1 + idx as u64 * 4);
        }
        let v = mem.read_u32(l.a0 + c as u64 * 4);
        mem.write_u32(l.a0 + c as u64 * 4, v.wrapping_add(1));
        mem.write_u32(l.a2 + c as u64 * 4, acc);
        (l.aux + 2) as u64
    }

    /// MIS merge/exclude phase (separate launch): undecided v joins if its
    /// newflag is set, leaves if any neighbor's newflag is set. Newflags
    /// are written only by the *select* launch and cleared only by the
    /// host between rounds, so this phase reads stable data.
    fn mis_exclude(&mut self, mem: &mut MemAccess<'_>, task: u64) -> u64 {
        let l = self.layout;
        let (lo, hi) = self.chunk_range(task);
        let mut items = 0u64;
        for v in lo..hi {
            let state = mem.read_u32(l.a0 + v as u64 * 4);
            if state != MIS_UNDECIDED {
                continue;
            }
            if mem.read_u32(l.a2 + v as u64 * 4) != 0 {
                mem.write_u32(l.a0 + v as u64 * 4, MIS_IN);
                continue;
            }
            let rp0 = mem.read_u32(l.row_ptr + v as u64 * 4);
            let rp1 = mem.read_u32(l.row_ptr + v as u64 * 4 + 4);
            items += (rp1 - rp0) as u64;
            for e in rp0..rp1 {
                let u = mem.read_u32(l.col + e as u64 * 4);
                if mem.read_u32(l.a2 + u as u64 * 4) != 0 {
                    mem.write_u32(l.a0 + v as u64 * 4, MIS_OUT);
                    mem.write_u32(l.changed + v as u64 * 4, 1);
                    break;
                }
            }
        }
        items
    }
}

impl<M: TileMath> ComputeEngine for WorkEngine<M> {
    fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64 {
        match kind {
            KIND_PAGERANK => self.pagerank(mem, arg),
            KIND_SSSP => self.sssp(mem, arg),
            KIND_MIS_SELECT => self.mis_select(mem, arg),
            KIND_MIS_EXCLUDE => self.mis_exclude(mem, arg),
            KIND_BFS => self.bfs(mem, arg),
            KIND_STRESS => self.stress(mem, arg),
            other => panic!("unknown compute kind {other}"),
        }
    }
}

/// Host-side helpers to lay out a graph's CSR arrays in device memory.
pub fn upload_graph(
    g: &Graph,
    alloc: &mut crate::mem::MemAlloc,
    backing: &mut crate::mem::BackingStore,
) -> (Addr, Addr, Addr) {
    let row_ptr = alloc.alloc((g.n as u64 + 1) * 4);
    let col = alloc.alloc(g.num_edges() as u64 * 4);
    let weight = alloc.alloc(g.num_edges() as u64 * 4);
    for (i, &rp) in g.row_ptr.iter().enumerate() {
        backing.write_u32(row_ptr + i as u64 * 4, rp);
    }
    for (i, (&c, &w)) in g.col.iter().zip(g.weight.iter()).enumerate() {
        backing.write_u32(col + i as u64 * 4, c);
        backing.write_u32(weight + i as u64 * 4, w);
    }
    (row_ptr, col, weight)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_pagerank_rows() {
        let mut m = NativeMath;
        let mut tile = vec![0f32; 2 * K_TILE];
        tile[0] = 0.25;
        tile[1] = 0.25;
        tile[K_TILE] = 0.5;
        let r = m.pagerank_rows(&tile, 2, 0.85, 4);
        let base = 0.15 / 4.0;
        assert!((r[0] - (base + 0.85 * 0.5)).abs() < 1e-6);
        assert!((r[1] - (base + 0.85 * 0.5)).abs() < 1e-6);
    }

    #[test]
    fn native_sssp_rows() {
        let mut m = NativeMath;
        let mut tile = vec![DIST_INF as i32; K_TILE];
        tile[3] = 17;
        tile[9] = 12;
        assert_eq!(m.sssp_rows(&tile, 1), vec![12]);
    }

    #[test]
    fn native_mis_rows() {
        let mut m = NativeMath;
        let mut nbr = vec![0u32; 2 * K_TILE];
        nbr[0] = 100;
        nbr[K_TILE + 1] = 5;
        let wins = m.mis_rows(&[50, 50], &nbr, 2);
        assert_eq!(wins, vec![false, true]);
    }

    #[test]
    fn mis_priorities_unique_and_deterministic() {
        use std::collections::HashSet;
        let set: HashSet<u32> = (0..10_000).map(mis_priority).collect();
        assert_eq!(set.len(), 10_000, "priorities must not collide");
        assert_eq!(mis_priority(42), mis_priority(42));
    }

    /// The provided `*_into` defaults (used by backends that only
    /// implement the `Vec`-returning trio, e.g. `PjrtMath`) must clear the
    /// output buffer and match the direct results.
    #[test]
    fn tile_math_into_defaults_match_direct() {
        struct DelegateOnly;
        impl TileMath for DelegateOnly {
            fn pagerank_rows(&mut self, c: &[f32], rows: usize, d: f32, n: u32) -> Vec<f32> {
                NativeMath.pagerank_rows(c, rows, d, n)
            }
            fn sssp_rows(&mut self, t: &[i32], rows: usize) -> Vec<i32> {
                NativeMath.sssp_rows(t, rows)
            }
            fn mis_rows(&mut self, a: &[u32], b: &[u32], rows: usize) -> Vec<bool> {
                NativeMath.mis_rows(a, b, rows)
            }
        }

        let mut contribs = vec![0f32; 2 * K_TILE];
        contribs[0] = 0.5;
        contribs[K_TILE + 3] = 0.25;
        let mut out_f = vec![9.0f32; 7]; // stale content must be cleared
        DelegateOnly.pagerank_rows_into(&contribs, 2, 0.85, 4, &mut out_f);
        assert_eq!(out_f, NativeMath.pagerank_rows(&contribs, 2, 0.85, 4));

        let mut tile = vec![DIST_INF as i32; K_TILE];
        tile[5] = 3;
        let mut out_i = vec![-1i32; 4];
        DelegateOnly.sssp_rows_into(&tile, 1, &mut out_i);
        assert_eq!(out_i, vec![3]);

        let mut nbr = vec![0u32; K_TILE];
        nbr[0] = 10;
        let mut out_b = vec![false; 9];
        DelegateOnly.mis_rows_into(&[50], &nbr, 1, &mut out_b);
        assert_eq!(out_b, vec![true]);
    }

    /// End-to-end engine task: the dense `(v - lo)`-indexed reduction
    /// combines partial tile rows exactly like the old HashMap reduction,
    /// and the scratch arena is reused (no realloc) across tasks.
    #[test]
    fn pagerank_task_combines_partial_rows_and_reuses_scratch() {
        use crate::config::DeviceConfig;
        use crate::mem::MemSystem;

        let mut mem = MemSystem::new(DeviceConfig::small());
        let (row_ptr, col, a0, a1, a2) = (0x1000u64, 0x2000u64, 0x4000u64, 0x5000u64, 0x6000u64);
        // v0 has K_TILE + 1 edges (spans two tile rows), v1 has one edge.
        let deg0 = K_TILE as u32 + 1;
        {
            let mut acc = MemAccess::new(&mut mem, 0);
            acc.write_u32(row_ptr, 0);
            acc.write_u32(row_ptr + 4, deg0);
            acc.write_u32(row_ptr + 8, deg0 + 1);
            for e in 0..deg0 {
                acc.write_u32(col + e as u64 * 4, 1);
            }
            acc.write_u32(col + deg0 as u64 * 4, 0);
            acc.write_f32(a0, 0.25); // contribution_in[0]
            acc.write_f32(a0 + 4, 0.125); // contribution_in[1]
        }
        let layout = AppLayout {
            row_ptr,
            col,
            weight: 0x3000,
            a0,
            a1,
            a2,
            changed: 0x7000,
            chunk: 2,
            n: 2,
            damping_bits: 0.85f32.to_bits(),
            aux: 0,
            high_water: 0x8000,
        };
        let mut eng = WorkEngine::new(NativeMath, layout);
        let items = {
            let mut acc = MemAccess::new(&mut mem, 0);
            eng.compute(&mut acc, KIND_PAGERANK, 0)
        };
        assert_eq!(items, (deg0 + 1) as u64);

        let base = 0.15f32 / 2.0;
        let expect0 = base + 0.85 * (deg0 as f32 * 0.125);
        let expect1 = base + 0.85 * 0.25;
        let (r0, r1, c0, c1) = {
            let mut acc = MemAccess::new(&mut mem, 0);
            (
                acc.read_f32(a1),
                acc.read_f32(a1 + 4),
                acc.read_f32(a2),
                acc.read_f32(a2 + 4),
            )
        };
        assert!((r0 - expect0).abs() < 1e-5, "rank0 {r0} vs {expect0}");
        assert!((r1 - expect1).abs() < 1e-5, "rank1 {r1} vs {expect1}");
        assert!((c0 - expect0 / deg0 as f32).abs() < 1e-6);
        assert!((c1 - expect1).abs() < 1e-6);

        // Second task run must reuse the grown scratch allocations.
        let ptr = eng.scratch.tile_f32.as_ptr();
        let cap = eng.scratch.tile_f32.capacity();
        assert!(cap >= 2 * K_TILE);
        {
            let mut acc = MemAccess::new(&mut mem, 0);
            eng.compute(&mut acc, KIND_PAGERANK, 0);
        }
        assert_eq!(eng.scratch.tile_f32.as_ptr(), ptr, "tile buffer must be reused");
        assert_eq!(eng.scratch.tile_f32.capacity(), cap);
    }
}
