//! The shared scenario runner: builds the work-stealing kernel for a
//! scenario, distributes per-round task chunks over the deques, launches
//! kernels until the workload converges, and collects the statistics
//! behind Figures 4–6.

use super::deque::{
    emit_advertise_empty, emit_owner_pop, emit_steal, DequeLayout, DequeRegs, SyncFlavor, EMPTY,
};
use super::engine::{AppLayout, TileMath, WorkEngine};
use crate::config::{DeviceConfig, Scenario};
use crate::gpu::Device;
use crate::kir::inst::StatCounter;
use crate::kir::{Asm, Program, Src};
use crate::mem::{BackingStore, MemAlloc};
use crate::sim::Stats;

/// A workload that runs in rounds of kernel launches (the Pannotia apps'
/// host loops).
///
/// The trait's required methods describe the host loop; the provided
/// methods are hooks with the classic work-stealing defaults, overridden
/// by workloads that need a different kernel shape
/// ([`crate::workload::prodcons`]) or a different task-placement policy
/// ([`crate::workload::stress`]).
pub trait Workload {
    /// Compute kinds launched back-to-back each round (MIS: select then
    /// exclude; others: one).
    fn kinds(&self) -> Vec<u32>;
    /// Engine layout for the coming round (addresses may change: buffer
    /// swaps).
    fn layout(&self) -> AppLayout;
    /// Active task chunks for the next round, or `None` when converged.
    fn begin_round(&mut self, backing: &mut BackingStore) -> Option<Vec<u32>>;
    /// Post-round bookkeeping (buffer swap, flag clearing).
    fn end_round(&mut self, backing: &mut BackingStore);
    /// Human-readable name.
    fn name(&self) -> &'static str;

    /// Build the per-round kernel for one compute kind. Default: the
    /// shared work-stealing kernel ([`build_kernel`]).
    fn kernel(
        &self,
        deques: &DequeLayout,
        scenario: Scenario,
        kind: u32,
        ctrl: crate::mem::Addr,
    ) -> Program {
        build_kernel(deques, scenario, kind, ctrl)
    }

    /// Assign this round's active chunks to owning queues. Default:
    /// stable block ownership ([`distribute`]).
    fn place(&self, active: &[u32], num_queues: u32, total_chunks: u32) -> Vec<Vec<u32>> {
        distribute(active, num_queues, total_chunks)
    }

    /// Per-queue deque capacity. Default: the worst case of an even
    /// split; placement policies that concentrate tasks (the stress
    /// kernel's hot set) must return a larger bound.
    fn queue_capacity(&self, total_chunks: u32, num_queues: u32) -> u32 {
        total_chunks.div_ceil(num_queues).max(4)
    }
}

/// Outcome of one scenario run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub scenario: Scenario,
    pub app: &'static str,
    pub stats: Stats,
    pub rounds: u32,
    pub converged: bool,
    /// Harvested sync-event trace; `None` unless the device config had
    /// `trace_capacity > 0`. Observe-only — never feeds `stats`.
    pub trace: Option<Box<crate::sim::CellTrace>>,
}

/// Build the per-round work-stealing kernel.
///
/// Every work-group drains its own deque (owner pops + compute); in
/// stealing scenarios it then scans the other queues round-robin,
/// stealing and executing tasks, guarded by a device-scope **completion
/// counter** (as in the original RSP work-stealing setup): each executed
/// task bumps `done` with a relaxed cmp-scope atomic, and a thief checks
/// `done == total` before every probe so the end-game does not degenerate
/// into 64 × 63 futile remote-op scans.
///
/// `ctrl` is a line holding `[done: u32, total: u32]`, host-reset per
/// launch.
pub fn build_kernel(
    deques: &DequeLayout,
    scenario: Scenario,
    kind: u32,
    ctrl: crate::mem::Addr,
) -> Program {
    use crate::sync::{AtomicOp, MemOrder, Scope};
    let flavor = SyncFlavor::of(scenario);
    let mut a = Asm::new();
    let wg = a.reg();
    let nw = a.reg();
    let qbase = a.reg();
    let task = a.reg();
    let t0 = a.reg();
    let t1 = a.reg();
    let t2 = a.reg();
    let stride = a.reg();
    let victim = a.reg();
    let vbase = a.reg();
    let ctrl_r = a.reg();
    let total = a.reg();

    a.wg_id(wg);
    a.num_wgs(nw);
    a.imm(stride, deques.stride);
    a.mul(qbase, wg, Src::R(stride));
    a.add(qbase, qbase, Src::I(deques.base));
    a.imm(ctrl_r, ctrl);
    if scenario.steals() {
        // `total` is launch-constant; read it once (plain load).
        a.ld(total, ctrl_r, 4, 4);
    }

    // ---- Phase 1: drain own queue ----
    a.label("own_loop");
    let own_regs = DequeRegs { qbase, task, t0, t1, t2 };
    emit_owner_pop(&mut a, &own_regs, flavor, "own");
    a.eq(t0, task, Src::I(EMPTY));
    a.bnz(t0, "own_done");
    a.stat(StatCounter::TaskExecuted);
    a.compute(kind, task);
    if scenario.steals() {
        a.atomic(
            t0,
            AtomicOp::Add,
            ctrl_r,
            Src::I(1),
            Src::I(0),
            MemOrder::Relaxed,
            Scope::Cmp,
        );
    }
    a.br("own_loop");
    a.label("own_done");

    if scenario.steals() {
        // Advertise emptiness so thieves' cheap pre-checks skip this
        // queue (see `emit_advertise_empty`).
        emit_advertise_empty(&mut a, &own_regs);
    }

    if scenario.steals() {
        // ---- Phase 2: guarded steal scan ----
        a.add(victim, wg, Src::I(1));
        a.label("scan");
        // done == total? Then every task has executed: halt.
        a.atomic(
            t0,
            AtomicOp::Load,
            ctrl_r,
            Src::I(0),
            Src::I(0),
            MemOrder::Relaxed,
            Scope::Cmp,
        );
        a.ge_u(t0, t0, Src::R(total));
        a.bnz(t0, "end");
        // victim %= nw; a full cycle without success also ends the scan
        // (no new tasks ever appear in the queues).
        a.alu(crate::kir::AluOp::RemU, victim, victim, Src::R(nw));
        a.eq(t0, victim, Src::R(wg));
        a.bnz(t0, "end");
        a.mul(vbase, victim, Src::R(stride));
        a.add(vbase, vbase, Src::I(deques.base));
        a.label("steal_retry");
        let steal_regs = DequeRegs {
            qbase: vbase,
            task,
            t0,
            t1,
            t2,
        };
        a.stat(StatCounter::StealAttempt);
        emit_steal(&mut a, &steal_regs, flavor, "th");
        a.eq(t0, task, Src::I(EMPTY));
        a.bnz(t0, "steal_failed");
        a.stat(StatCounter::StealSuccess);
        a.stat(StatCounter::TaskExecuted);
        a.compute(kind, task);
        a.atomic(
            t0,
            AtomicOp::Add,
            ctrl_r,
            Src::I(1),
            Src::I(0),
            MemOrder::Relaxed,
            Scope::Cmp,
        );
        // Re-check the counter, keep stealing from this victim.
        a.atomic(
            t0,
            AtomicOp::Load,
            ctrl_r,
            Src::I(0),
            Src::I(0),
            MemOrder::Relaxed,
            Scope::Cmp,
        );
        a.ge_u(t0, t0, Src::R(total));
        a.bnz(t0, "end");
        a.br("steal_retry");
        a.label("steal_failed");
        a.stat(StatCounter::StealFail);
        a.add(victim, victim, Src::I(1));
        a.br("scan");
    }
    a.label("end");
    a.halt();
    a.finish()
}

/// Distribute `active` chunks to their owning queues: chunk `c` belongs to
/// queue `c / chunks_per_queue` — contiguous *block* ownership, stable
/// across rounds. An owner therefore works a contiguous vertex range whose
/// CSR rows, columns and neighbor state share cache lines across its
/// tasks: exactly the locality that global-scope per-pop invalidation
/// destroys (the paper's Baseline penalty) and wg-scope synchronization
/// preserves. Block ownership also clusters SSSP's frontier chunks onto
/// few owners, producing the imbalance that makes stealing pay.
pub fn distribute(active: &[u32], num_queues: u32, total_chunks: u32) -> Vec<Vec<u32>> {
    let cpq = total_chunks.div_ceil(num_queues).max(1);
    let mut per_queue: Vec<Vec<u32>> = vec![Vec::new(); num_queues as usize];
    for &c in active {
        per_queue[(c / cpq).min(num_queues - 1) as usize].push(c);
    }
    per_queue
}

/// Run `workload` under `scenario` on a fresh device whose memory is
/// seeded with `image` (the backing store the workload's `setup` wrote
/// into). Returns the run result and the final memory image (for result
/// extraction / oracle comparison). Host bookkeeping between launches is
/// free, as in the paper's device-side measurements.
pub fn run_scenario_seeded<M: TileMath>(
    cfg: &DeviceConfig,
    scenario: Scenario,
    workload: &mut dyn Workload,
    math: M,
    max_rounds: u32,
    image: BackingStore,
) -> (RunResult, BackingStore) {
    let mut dev = Device::new(cfg.clone(), scenario.protocol());
    dev.mem.backing = image;
    let num_wgs = cfg.total_wgs();

    // Size the deques to the worst case: every chunk active at once.
    let total_chunks = {
        let l = workload.layout();
        l.n.div_ceil(l.chunk)
    };
    let capacity = workload.queue_capacity(total_chunks, num_wgs);
    let mut alloc_probe = MemAlloc::new();
    // The workload allocated its arrays already (from the same address
    // space origin); deques go above the high-water mark. The caller
    // passes the allocator through `workload`'s setup; here we replay a
    // fresh allocator past its reserved range.
    alloc_probe.alloc(workload.layout().high_water);
    let deques = DequeLayout::alloc(&mut alloc_probe, num_wgs, capacity);
    // Control line: [done, total] completion counter.
    let ctrl = alloc_probe.alloc(64);

    // Pre-build one kernel per compute kind.
    let kinds = workload.kinds();
    let programs: Vec<Program> = kinds
        .iter()
        .map(|&k| workload.kernel(&deques, scenario, k, ctrl))
        .collect();

    let mut engine = WorkEngine::new(math, workload.layout());
    let mut rounds = 0;
    let mut converged = false;
    while rounds < max_rounds {
        let Some(active) = workload.begin_round(&mut dev.mem.backing) else {
            converged = true;
            break;
        };
        engine.layout = workload.layout();
        let per_queue = workload.place(&active, num_wgs, total_chunks);
        for prog in &programs {
            for (q, tasks) in per_queue.iter().enumerate() {
                deques.fill(&mut dev.mem.backing, q as u32, tasks);
            }
            // Reset the completion counter for this launch.
            dev.mem.backing.write_u32(ctrl, 0);
            dev.mem.backing.write_u32(ctrl + 4, active.len() as u32);
            dev.launch(prog, num_wgs, &mut engine);
            // Every queue must be fully drained (no task lost).
            for q in 0..num_wgs {
                debug_assert_eq!(
                    deques.remaining(&dev.mem.backing, q),
                    0,
                    "queue {q} not drained"
                );
            }
        }
        workload.end_round(&mut dev.mem.backing);
        rounds += 1;
    }

    let mut stats = dev.take_stats();
    stats.record_rounds(rounds as u64);
    let trace = dev.mem.trace.take_cell();
    (
        RunResult {
            scenario,
            app: workload.name(),
            stats,
            rounds,
            converged,
            trace,
        },
        std::mem::take(&mut dev.mem.backing),
    )
}

/// Convenience wrapper: run from an empty memory image (workloads that
/// seeded their arrays through the device's own backing store).
pub fn run_scenario<M: TileMath>(
    cfg: &DeviceConfig,
    scenario: Scenario,
    workload: &mut dyn Workload,
    math: M,
    max_rounds: u32,
) -> RunResult {
    run_scenario_seeded(cfg, scenario, workload, math, max_rounds, BackingStore::new()).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribute_block_ownership() {
        // 12 chunks over 4 queues: 3 contiguous chunks per queue.
        let a = distribute(&[0, 1, 2, 3, 8, 9, 11], 4, 12);
        assert_eq!(a[0], vec![0, 1, 2]);
        assert_eq!(a[1], vec![3]);
        assert_eq!(a[2], vec![8]);
        assert_eq!(a[3], vec![9, 11]);
        // Same chunk -> same queue in a later round (stable ownership).
        let b = distribute(&[8], 4, 12);
        assert_eq!(b[2], vec![8]);
        // Out-of-range chunk ids clamp to the last queue.
        let c = distribute(&[100], 4, 12);
        assert_eq!(c[3], vec![100]);
    }

    #[test]
    fn kernel_builds_for_all_scenarios() {
        let mut alloc = MemAlloc::new();
        let deques = DequeLayout::alloc(&mut alloc, 4, 8);
        let ctrl = alloc.alloc(64);
        for s in Scenario::ALL {
            let p = build_kernel(&deques, s, 1, ctrl);
            assert!(!p.is_empty());
            let has_steal_code = p.insts.len() > 40;
            assert_eq!(s.steals(), has_steal_code, "{s:?}: {}", p.insts.len());
        }
    }
}
