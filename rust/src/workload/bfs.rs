//! Breadth-first search, ported through the [`Kernel`] registry.
//!
//! Bottom-up level synchronization: every round activates the chunks
//! that still contain *unvisited* vertices; an unvisited vertex scans
//! its neighbors and takes `min(depth) + 1` (min-plus over unit weights,
//! reusing the SSSP tile math). This exercises a sync pattern the three
//! §5.1 graph apps do not: early rounds are dominated by wasted probes
//! on chunks whose wavefront has not arrived — a shrinking, strongly
//! skewed useful-work distribution that concentrates real work on the
//! frontier owners while everyone else steals.
//!
//! Host-loop termination is progress-based: the run ends when every
//! vertex is visited or a round makes no progress (disconnected
//! remainder).

use super::driver::Workload;
use super::engine::{upload_graph, AppLayout, DIST_INF, KIND_BFS};
use super::graph::Graph;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::mem::{Addr, BackingStore, MemAlloc};
use std::collections::BTreeSet;

/// Host-side BFS state.
pub struct Bfs {
    layout: AppLayout,
    depth: Addr,
    n: u32,
    chunk: u32,
    /// BFS level the coming round completes (engine's write gate).
    level: u32,
    /// Unvisited count after the previous round (progress detector).
    prev_unvisited: Option<u32>,
}

impl Bfs {
    pub fn setup(
        g: &Graph,
        alloc: &mut MemAlloc,
        backing: &mut BackingStore,
        chunk: u32,
        source: u32,
    ) -> Self {
        let (row_ptr, col, weight) = upload_graph(g, alloc, backing);
        let n = g.n;
        let depth = alloc.alloc(n as u64 * 4);
        for v in 0..n {
            backing.write_u32(depth + v as u64 * 4, if v == source { 0 } else { DIST_INF });
        }
        let layout = AppLayout {
            row_ptr,
            col,
            weight,
            a0: depth,
            a1: 0,
            a2: 0,
            changed: 0,
            chunk,
            n,
            damping_bits: 0,
            aux: 0,
            high_water: alloc.high_water(),
        };
        Bfs {
            layout,
            depth,
            n,
            chunk,
            level: 1,
            prev_unvisited: None,
        }
    }

    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.n)
            .map(|v| backing.read_u32(self.depth + v as u64 * 4))
            .collect()
    }

    /// Queue-based BFS oracle (DIST_INF for unreachable).
    pub fn oracle(g: &Graph, source: u32) -> Vec<u32> {
        let mut depth = vec![DIST_INF; g.n as usize];
        depth[source as usize] = 0;
        let mut queue = std::collections::VecDeque::from([source]);
        while let Some(v) = queue.pop_front() {
            for (u, _) in g.neighbors(v) {
                if depth[u as usize] == DIST_INF {
                    depth[u as usize] = depth[v as usize] + 1;
                    queue.push_back(u);
                }
            }
        }
        depth
    }
}

impl Workload for Bfs {
    fn kinds(&self) -> Vec<u32> {
        vec![KIND_BFS]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, backing: &mut BackingStore) -> Option<Vec<u32>> {
        let mut chunks = BTreeSet::new();
        let mut unvisited = 0u32;
        for v in 0..self.n {
            if backing.read_u32(self.depth + v as u64 * 4) == DIST_INF {
                unvisited += 1;
                chunks.insert(v / self.chunk);
            }
        }
        // Done: everything visited, or no progress (disconnected rest).
        if unvisited == 0 || self.prev_unvisited == Some(unvisited) {
            return None;
        }
        self.prev_unvisited = Some(unvisited);
        self.layout.aux = self.level;
        Some(chunks.into_iter().collect())
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {
        self.level += 1;
    }

    fn name(&self) -> &'static str {
        "BFS"
    }
}

/// Registry entry.
pub struct BfsKernel;

impl Kernel for BfsKernel {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn display(&self) -> &'static str {
        "BFS"
    }

    fn summary(&self) -> &'static str {
        "breadth-first search, bottom-up level synchronization"
    }

    fn oracle(&self) -> &'static str {
        "exact (queue BFS levels)"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[
            ParamSpec {
                key: "source",
                default: 0.0,
                help: "source vertex",
            },
            ParamSpec {
                key: "chunk",
                default: 8.0,
                help: "vertices per task chunk",
            },
        ]
    }

    fn prepare(&self, size: WorkloadSize, seed: u64, _params: &mut Params) -> Prepared {
        // Low-rewiring small world: long shortest paths (many BFS levels)
        // with a few shortcuts that skew the wavefront.
        // max_rounds covers the zero-shortcut ring-lattice worst case
        // (diameter n/k), so any derived seed converges.
        let (graph, max_rounds) = match size {
            WorkloadSize::Paper => (Graph::small_world(2048, 6, 0.05, seed), 400),
            WorkloadSize::Tiny => (Graph::small_world(192, 4, 0.05, seed), 64),
        };
        Prepared {
            graph: Some(graph),
            max_rounds,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let g = preset.graph();
        let source = preset.params.get_u32("source").min(g.n.saturating_sub(1));
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = Bfs::setup(
            g,
            &mut alloc,
            &mut image,
            preset.params.get_u32("chunk"),
            source,
        );
        let oracle = Bfs::oracle(g, source);
        let (depth, n) = (wl.depth, wl.n);
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                for v in 0..n {
                    let got = mem.read_u32(depth + v as u64 * 4);
                    if got != oracle[v as usize] {
                        return Err(format!(
                            "BFS depth[{v}] = {got}, oracle {}",
                            oracle[v as usize]
                        ));
                    }
                }
                Ok(())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Scenario};
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;

    #[test]
    fn oracle_on_path_graph() {
        let g = Graph::from_edges(4, &[(0, 1, 5), (1, 2, 3), (2, 3, 2)]);
        assert_eq!(Bfs::oracle(&g, 0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn simulated_bfs_exact_all_scenarios() {
        let g = Graph::small_world(96, 4, 0.1, 11);
        let oracle = Bfs::oracle(&g, 0);
        for scenario in Scenario::ALL {
            let mut alloc = MemAlloc::new();
            let mut image = BackingStore::new();
            let mut bfs = Bfs::setup(&g, &mut alloc, &mut image, 8, 0);
            let cfg = DeviceConfig::small();
            let (run, final_mem) =
                run_scenario_seeded(&cfg, scenario, &mut bfs, NativeMath, 64, image);
            assert!(run.converged, "{scenario:?}: BFS must converge");
            assert_eq!(bfs.result(&final_mem), oracle, "{scenario:?}");
        }
    }

    #[test]
    fn disconnected_component_stays_inf_and_converges() {
        let g = Graph::from_edges(5, &[(0, 1, 1), (1, 2, 1), (3, 4, 1)]);
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut bfs = Bfs::setup(&g, &mut alloc, &mut image, 2, 0);
        let cfg = DeviceConfig::small();
        let (run, mem) =
            run_scenario_seeded(&cfg, Scenario::SRSP, &mut bfs, NativeMath, 32, image);
        assert!(run.converged, "no-progress detector must end the loop");
        let d = bfs.result(&mem);
        assert_eq!(&d[..3], &[0, 1, 2]);
        assert_eq!(d[3], DIST_INF);
        assert_eq!(d[4], DIST_INF);
    }
}
