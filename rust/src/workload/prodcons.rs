//! Flag-based producer–consumer, ported through the [`Kernel`] registry.
//!
//! The textbook asymmetric-sharing pattern the deque apps do *not*
//! exercise: per-slot message passing. Work-group `p` (the producer of
//! pair `p`) writes `data[s]` and then publishes it by setting the
//! line-isolated `flag[s]` with a **release store**; work-group `P + p`
//! spins on the flag with **acquire loads**, then reads the data and
//! writes a derived value to `out[s]`.
//!
//! The scope assignment follows the scenario exactly like the deque's
//! [`SyncFlavor`](super::deque::SyncFlavor):
//!
//! * promotion scenarios (RSP/sRSP) — the producer releases at **wg
//!   scope** (L1-local, LR-TBL-recorded under sRSP) and the consumer
//!   polls with **`rem_acq`**: every poll is a remote-scope promotion,
//!   so naive RSP pays a device-wide flush+invalidate *per poll* while
//!   sRSP's LR-TBL lookup answers misses with a one-cycle nop ack;
//! * hLRC — both sides at wg scope, ownership ping-pongs lazily;
//! * scoped-only scenarios — cmp-scope release/acquire pairs.
//!
//! Unlike the round-based apps, synchronization here happens *within*
//! one launch between concurrently-running work-groups, driving the
//! protocol's flag-handoff path rather than its task-claim path.
//!
//! Oracle (exact): `out[s] == data_fn(s) + 1` for every slot.

use super::deque::DequeLayout;
use super::driver::Workload;
use super::engine::AppLayout;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::config::Scenario;
use crate::kir::inst::StatCounter;
use crate::kir::{Asm, Program, Src};
use crate::mem::{Addr, BackingStore, MemAlloc};
use crate::sync::{AtomicOp, MemOrder, Scope};

/// The deterministic per-slot payload (`data[s]`), truncated to u32 by
/// the 4-byte store exactly as the kernel's u64 ALU ops are.
pub fn data_fn(seed: u64, s: u32) -> u32 {
    (u64::from(s)
        .wrapping_mul(2_654_435_761)
        .wrapping_add(seed & 0xFFFF_FFFF)) as u32
}

/// Host-side producer–consumer state.
pub struct ProdCons {
    layout: AppLayout,
    data: Addr,
    flags: Addr,
    out: Addr,
    slots: u32,
    seed: u64,
    done: bool,
}

impl ProdCons {
    pub fn setup(alloc: &mut MemAlloc, backing: &mut BackingStore, slots: u32, seed: u64) -> Self {
        let data = alloc.alloc(slots as u64 * 4);
        // Flags are line-isolated: each is its own sync variable, so a
        // promotion on one slot never drags a neighbor's flag along.
        let flags = alloc.alloc(slots as u64 * 64);
        let out = alloc.alloc(slots as u64 * 4);
        for s in 0..slots {
            backing.write_u32(data + s as u64 * 4, 0);
            backing.write_u32(flags + s as u64 * 64, 0);
            backing.write_u32(out + s as u64 * 4, 0);
        }
        let layout = AppLayout {
            row_ptr: 0,
            col: 0,
            weight: 0,
            a0: data,
            a1: flags,
            a2: out,
            changed: 0,
            chunk: 1,
            n: slots,
            damping_bits: 0,
            aux: 0,
            high_water: alloc.high_water(),
        };
        ProdCons {
            layout,
            data,
            flags,
            out,
            slots,
            seed,
            done: false,
        }
    }

    /// Final consumer outputs.
    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.slots)
            .map(|s| backing.read_u32(self.out + s as u64 * 4))
            .collect()
    }
}

impl Workload for ProdCons {
    fn kinds(&self) -> Vec<u32> {
        // One launch; the custom kernel never issues a Compute op (kind 0
        // would trap in the engine — a canary, not a dispatch target).
        vec![0]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, _backing: &mut BackingStore) -> Option<Vec<u32>> {
        if self.done {
            return None;
        }
        // The kernel derives its slot assignment from wg ids; the deques
        // stay empty.
        Some(Vec::new())
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {
        self.done = true;
    }

    fn name(&self) -> &'static str {
        "PRODCONS"
    }

    /// Custom kernel: per-pair flag handoff instead of deque draining.
    fn kernel(
        &self,
        _deques: &DequeLayout,
        scenario: Scenario,
        _kind: u32,
        _ctrl: Addr,
    ) -> Program {
        build_prodcons_kernel(scenario, self.data, self.flags, self.out, self.slots, self.seed)
    }
}

/// Consumer-side poll flavor.
#[derive(Clone, Copy, PartialEq)]
enum Poll {
    Remote,
    Scoped(Scope),
}

/// Emit the producer/consumer program for `scenario`.
pub fn build_prodcons_kernel(
    scenario: Scenario,
    data: Addr,
    flags: Addr,
    out: Addr,
    slots: u32,
    seed: u64,
) -> Program {
    // Scope pairing per scenario (see module docs): the producer may only
    // stay at wg scope when the protocol can promote (remote ops) or
    // transfer ownership (hLRC); otherwise both sides go through cmp.
    let (prod_scope, poll) = if scenario.remote_ops() {
        (Scope::Wg, Poll::Remote)
    } else if scenario.lazy_transfer() {
        (Scope::Wg, Poll::Scoped(Scope::Wg))
    } else {
        (Scope::Cmp, Poll::Scoped(Scope::Cmp))
    };
    let payload_add = seed & 0xFFFF_FFFF;

    let mut a = Asm::new();
    let wg = a.reg();
    let nw = a.reg();
    let pairs = a.reg();
    let s = a.reg();
    let step = a.reg();
    let addr = a.reg();
    let val = a.reg();
    let t = a.reg();
    let solo = a.reg();

    a.wg_id(wg);
    a.num_wgs(nw);
    a.shr(pairs, nw, Src::I(1));
    a.imm(solo, 0);
    a.bz(pairs, "solo");
    // wg < pairs: producer p = wg.
    a.lt_u(t, wg, Src::R(pairs));
    a.bnz(t, "producer_init");
    // wg < 2*pairs: consumer p = wg - pairs.
    a.shl(t, pairs, Src::I(1));
    a.lt_u(t, wg, Src::R(t));
    a.bnz(t, "consumer_init");
    a.halt(); // odd leftover work-group

    a.label("solo");
    // Single work-group: produce everything, then consume everything.
    a.imm(solo, 1);
    a.imm(s, 0);
    a.imm(step, 1);
    a.br("prod_loop");

    a.label("producer_init");
    a.mov(s, wg);
    a.mov(step, pairs);
    a.br("prod_loop");

    a.label("consumer_init");
    a.alu(crate::kir::AluOp::Sub, s, wg, Src::R(pairs));
    a.mov(step, pairs);
    a.br("cons_loop");

    // ---- producer: data[s] = f(s); flag[s] <-rel- 1 ----
    a.label("prod_loop");
    a.ge_u(t, s, Src::I(u64::from(slots)));
    a.bnz(t, "prod_done");
    a.mul(val, s, Src::I(2_654_435_761));
    a.add(val, val, Src::I(payload_add));
    a.shl(addr, s, Src::I(2));
    a.add(addr, addr, Src::I(data));
    a.st(addr, 0, val, 4);
    a.shl(addr, s, Src::I(6));
    a.add(addr, addr, Src::I(flags));
    a.atomic(
        t,
        AtomicOp::Store,
        addr,
        Src::I(1),
        Src::I(0),
        MemOrder::Release,
        prod_scope,
    );
    a.stat(StatCounter::TaskExecuted);
    a.add(s, s, Src::R(step));
    a.br("prod_loop");
    a.label("prod_done");
    // Solo mode falls through into the consumer sweep.
    a.bz(solo, "end");
    a.imm(s, 0);
    a.imm(step, 1);
    a.br("cons_loop");

    // ---- consumer: spin on flag[s]; out[s] = data[s] + 1 ----
    a.label("cons_loop");
    a.ge_u(t, s, Src::I(u64::from(slots)));
    a.bnz(t, "end");
    a.shl(addr, s, Src::I(6));
    a.add(addr, addr, Src::I(flags));
    a.label("spin");
    match poll {
        Poll::Remote => {
            a.remote_atomic(t, AtomicOp::Load, addr, Src::I(0), Src::I(0), MemOrder::Acquire);
        }
        Poll::Scoped(scope) => {
            a.atomic(
                t,
                AtomicOp::Load,
                addr,
                Src::I(0),
                Src::I(0),
                MemOrder::Acquire,
                scope,
            );
        }
    }
    a.bz(t, "spin");
    a.shl(addr, s, Src::I(2));
    a.add(addr, addr, Src::I(data));
    a.ld(val, addr, 0, 4);
    a.add(val, val, Src::I(1));
    a.shl(addr, s, Src::I(2));
    a.add(addr, addr, Src::I(out));
    a.st(addr, 0, val, 4);
    a.stat(StatCounter::TaskExecuted);
    a.add(s, s, Src::R(step));
    a.br("cons_loop");

    a.label("end");
    a.halt();
    a.finish()
}

/// Registry entry.
pub struct ProdConsKernel;

impl Kernel for ProdConsKernel {
    fn name(&self) -> &'static str {
        "prodcons"
    }

    fn display(&self) -> &'static str {
        "PRODCONS"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["producer-consumer", "flags"]
    }

    fn summary(&self) -> &'static str {
        "flag-based producer/consumer pairs (per-slot message passing)"
    }

    fn oracle(&self) -> &'static str {
        "exact (out == payload + 1 per slot)"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[ParamSpec {
            key: "slots",
            default: 0.0,
            help: "message slots (0 = auto: 48 tiny / 512 paper)",
        }]
    }

    fn prepare(&self, size: WorkloadSize, _seed: u64, params: &mut Params) -> Prepared {
        if params.get("slots") == 0.0 {
            params.set_auto(
                "slots",
                match size {
                    WorkloadSize::Paper => 512.0,
                    WorkloadSize::Tiny => 48.0,
                },
            );
        }
        Prepared {
            graph: None,
            max_rounds: 2,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let slots = preset.params.get_u32("slots").max(1);
        let seed = preset.seed;
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = ProdCons::setup(&mut alloc, &mut image, slots, seed);
        let out = wl.out;
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                for s in 0..slots {
                    let want = data_fn(seed, s).wrapping_add(1);
                    let got = mem.read_u32(out + s as u64 * 4);
                    if got != want {
                        return Err(format!(
                            "PRODCONS out[{s}] = {got:#x}, expected {want:#x} (stale data read)"
                        ));
                    }
                }
                Ok(())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;
    use crate::workload::registry;

    fn run(scenario: Scenario, num_cus: u32, slots: f64) -> Result<(), String> {
        let preset = WorkloadPreset::with_params(
            registry::PRODCONS,
            WorkloadSize::Tiny,
            5,
            &[("slots".into(), slots)],
        )
        .unwrap();
        let inst = preset.instance();
        let mut wl = inst.workload;
        let cfg = DeviceConfig {
            num_cus,
            ..DeviceConfig::small()
        };
        let (r, mem) = run_scenario_seeded(
            &cfg,
            scenario,
            wl.as_mut(),
            NativeMath,
            preset.max_rounds,
            inst.image,
        );
        if !r.converged {
            return Err("did not converge".into());
        }
        (inst.check)(&mem)
    }

    #[test]
    fn handoff_exact_under_every_scenario() {
        for scenario in Scenario::ALL {
            run(scenario, 4, 24.0).unwrap_or_else(|e| panic!("{scenario:?}: {e}"));
        }
        run(Scenario::HLRC, 4, 24.0).unwrap();
    }

    #[test]
    fn degenerate_devices() {
        // 1 wg: solo produce-then-consume; 3 wgs: one idle leftover.
        run(Scenario::SRSP, 1, 16.0).unwrap();
        run(Scenario::SRSP, 3, 16.0).unwrap();
    }

    #[test]
    fn remote_polling_drives_promotions() {
        let preset =
            WorkloadPreset::with_params(registry::PRODCONS, WorkloadSize::Tiny, 5, &[]).unwrap();
        let inst = preset.instance();
        let mut wl = inst.workload;
        let cfg = DeviceConfig::small();
        let (r, _mem) = run_scenario_seeded(
            &cfg,
            Scenario::SRSP,
            wl.as_mut(),
            NativeMath,
            2,
            inst.image,
        );
        assert!(r.stats.remote_acquires > 0, "consumers must poll via rem_acq");
        assert!(r.stats.wg_releases > 0, "producers must release at wg scope");
    }
}
