//! The asymmetry-stress kernel family: a synthetic sharer/stealer
//! workload with a **tunable remote-access ratio** `r` — the axis the
//! paper's argument actually turns on, which the three ported graph apps
//! each bake into one fixed profile.
//!
//! Every task is one cell update (bump `cells[c]`, xor a window of a
//! shared read-only pad into `scratch[c]`). The sweep axis lives in the
//! *placement* policy, not the compute: a deterministic per-task coin
//! with bias `r` marks tasks **remote** — those are concentrated into a
//! small **hot set** of queues, while the rest keep the classic balanced
//! block ownership. Owners drain their balanced share with cheap
//! wg-scope pops; the hot-set surplus is what everyone else must steal
//! through the promotion machinery. So `r` directly dials the fraction
//! of claims that go through remote ops:
//!
//! * `r = 0` — pure local sharing: every protocol degenerates to
//!   wg-scope fast paths, RspNaive and sRSP tie.
//! * `r → 1` — every claim is a steal: RspNaive pays a full
//!   flush+invalidate of *every* L1 per claim (destroying the pad/cell
//!   locality in all of them), while sRSP's LR-TBL/PA-TBL selectivity
//!   drains only the hot owner's sFIFO — the crossover curve of the
//!   `remote-ratio` sweep.
//!
//! `hot_set` sets how many queues absorb the remote tasks (1 = maximum
//! contention on a single local sharer); `migration` rotates the hot set
//! every N rounds, forcing LR-TBL/PA-TBL turnover as the local sharer's
//! L1 changes identity.
//!
//! Correctness oracle (exact, protocol-independent): after R rounds
//! every cell holds exactly R and every scratch word the pad-window xor
//! — each task ran exactly once per round, no claim lost or duplicated.

use super::driver::Workload;
use super::engine::{AppLayout, KIND_STRESS};
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::mem::{Addr, BackingStore, MemAlloc};
use crate::sim::SplitMix64;

/// Deterministic remote-coin for task `c`: true with probability `r`.
/// Independent of queue count and round so the task population is stable
/// across devices and the sweep axis is exactly comparable.
fn is_remote(seed: u64, c: u32, r: f64) -> bool {
    let h = SplitMix64::new(seed ^ 0x5742_1253 ^ u64::from(c)).next_u64();
    (h >> 11) as f64 / (1u64 << 53) as f64 < r
}

/// Pad word `i` (seed-derived, read-only during the run).
fn pad_word(seed: u64, i: u32) -> u32 {
    SplitMix64::new(seed ^ 0x9AD5 ^ u64::from(i)).next_u64() as u32
}

/// Host-side stress state.
pub struct Stress {
    layout: AppLayout,
    cells: Addr,
    scratch: Addr,
    /// Total tasks (= chunks: one cell per task).
    tasks: u32,
    rounds: u32,
    round: u32,
    remote_ratio: f64,
    hot_set: u32,
    migration: u32,
    seed: u64,
}

impl Stress {
    #[allow(clippy::too_many_arguments)]
    pub fn setup(
        alloc: &mut MemAlloc,
        backing: &mut BackingStore,
        tasks: u32,
        rounds: u32,
        work: u32,
        remote_ratio: f64,
        hot_set: u32,
        migration: u32,
        seed: u64,
    ) -> Self {
        let cells = alloc.alloc(tasks as u64 * 4);
        let pad = alloc.alloc(tasks as u64 * 4);
        let scratch = alloc.alloc(tasks as u64 * 4);
        for c in 0..tasks {
            backing.write_u32(cells + c as u64 * 4, 0);
            backing.write_u32(pad + c as u64 * 4, pad_word(seed, c));
            backing.write_u32(scratch + c as u64 * 4, 0);
        }
        let layout = AppLayout {
            row_ptr: 0,
            col: 0,
            weight: 0,
            a0: cells,
            a1: pad,
            a2: scratch,
            changed: 0,
            chunk: 1,
            n: tasks,
            damping_bits: 0,
            aux: work,
            high_water: alloc.high_water(),
        };
        Stress {
            layout,
            cells,
            scratch,
            tasks,
            rounds,
            round: 0,
            remote_ratio,
            hot_set,
            migration,
            seed,
        }
    }

    /// Final cell counters.
    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.tasks)
            .map(|c| backing.read_u32(self.cells + c as u64 * 4))
            .collect()
    }

    /// Expected scratch word for task `c` (pad-window xor).
    pub fn expected_scratch(seed: u64, tasks: u32, work: u32, c: u32) -> u32 {
        let mut acc = 0u32;
        for k in 0..work {
            acc ^= pad_word(seed, c.wrapping_add(k) % tasks.max(1));
        }
        acc
    }
}

impl Workload for Stress {
    fn kinds(&self) -> Vec<u32> {
        vec![KIND_STRESS]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, _backing: &mut BackingStore) -> Option<Vec<u32>> {
        if self.round >= self.rounds {
            return None;
        }
        Some((0..self.tasks).collect())
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {
        self.round += 1;
    }

    fn name(&self) -> &'static str {
        "STRESS"
    }

    /// The sweep axis: remote-marked tasks go to the (possibly migrated)
    /// hot queues, the rest keep stable block ownership.
    fn place(&self, active: &[u32], num_queues: u32, total_chunks: u32) -> Vec<Vec<u32>> {
        let hot = self.hot_set.clamp(1, num_queues);
        let phase = if self.migration == 0 {
            0
        } else {
            (self.round / self.migration) % num_queues
        };
        let cpq = total_chunks.div_ceil(num_queues).max(1);
        let mut per_queue: Vec<Vec<u32>> = vec![Vec::new(); num_queues as usize];
        for &c in active {
            let q = if is_remote(self.seed, c, self.remote_ratio) {
                (phase + c % hot) % num_queues
            } else {
                (c / cpq).min(num_queues - 1)
            };
            per_queue[q as usize].push(c);
        }
        per_queue
    }

    /// The hot set can absorb every task at `r = 1`.
    fn queue_capacity(&self, total_chunks: u32, _num_queues: u32) -> u32 {
        total_chunks.max(4)
    }
}

/// Registry entry for the asymmetry-stress family.
pub struct StressKernel;

impl Kernel for StressKernel {
    fn name(&self) -> &'static str {
        "stress"
    }

    fn display(&self) -> &'static str {
        "STRESS"
    }

    fn summary(&self) -> &'static str {
        "synthetic sharer/stealer with a tunable remote-access ratio"
    }

    fn oracle(&self) -> &'static str {
        "exact (cells == rounds, scratch == pad xor)"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[
            ParamSpec {
                key: "remote_ratio",
                default: 0.0,
                help: "fraction of tasks routed to the hot set (0..1)",
            },
            ParamSpec {
                key: "hot_set",
                default: 2.0,
                help: "queues absorbing the remote tasks",
            },
            ParamSpec {
                key: "migration",
                default: 0.0,
                help: "rotate the hot set every N rounds (0 = never)",
            },
            ParamSpec {
                key: "rounds",
                default: 0.0,
                help: "kernel rounds (0 = auto: 4 tiny / 8 paper)",
            },
            ParamSpec {
                key: "tasks",
                default: 0.0,
                help: "total tasks (0 = auto: 256 tiny / 2048 paper)",
            },
            ParamSpec {
                key: "work",
                default: 8.0,
                help: "shared-pad words read per task (locality food)",
            },
        ]
    }

    fn prepare(&self, size: WorkloadSize, _seed: u64, params: &mut Params) -> Prepared {
        let (auto_rounds, auto_tasks) = match size {
            WorkloadSize::Paper => (8.0, 2048.0),
            WorkloadSize::Tiny => (4.0, 256.0),
        };
        if params.get("rounds") == 0.0 {
            params.set_auto("rounds", auto_rounds);
        }
        if params.get("tasks") == 0.0 {
            params.set_auto("tasks", auto_tasks);
        }
        Prepared {
            graph: None,
            max_rounds: params.get_u32("rounds") + 1,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let p = &preset.params;
        let (tasks, rounds, work) = (
            p.get_u32("tasks").max(1),
            p.get_u32("rounds"),
            p.get_u32("work"),
        );
        let seed = preset.seed;
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = Stress::setup(
            &mut alloc,
            &mut image,
            tasks,
            rounds,
            work,
            p.get("remote_ratio"),
            p.get_u32("hot_set"),
            p.get_u32("migration"),
            seed,
        );
        let (cells, scratch) = (wl.cells, wl.scratch);
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                for c in 0..tasks {
                    let got = mem.read_u32(cells + c as u64 * 4);
                    if got != rounds {
                        return Err(format!(
                            "STRESS cell {c} = {got}, expected {rounds} (claim lost/duplicated)"
                        ));
                    }
                    let want = Stress::expected_scratch(seed, tasks, work, c);
                    let got = mem.read_u32(scratch + c as u64 * 4);
                    if got != want {
                        return Err(format!(
                            "STRESS scratch {c} = {got:#x}, expected {want:#x} (stale pad read)"
                        ));
                    }
                }
                Ok(())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Scenario};
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;
    use crate::workload::registry;

    fn run_ratio(scenario: Scenario, r: f64) -> (crate::workload::driver::RunResult, bool) {
        let preset = WorkloadPreset::with_params(
            registry::STRESS,
            WorkloadSize::Tiny,
            7,
            &[("remote_ratio".into(), r), ("tasks".into(), 96.0)],
        )
        .unwrap();
        let inst = preset.instance();
        let mut wl = inst.workload;
        let cfg = DeviceConfig::small();
        let (run, mem) = run_scenario_seeded(
            &cfg,
            scenario,
            wl.as_mut(),
            NativeMath,
            preset.max_rounds,
            inst.image,
        );
        (run, (inst.check)(&mem).is_ok())
    }

    #[test]
    fn stress_exact_at_ratio_extremes_all_steal_scenarios() {
        for scenario in [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP] {
            for r in [0.0, 0.5, 1.0] {
                let (run, ok) = run_ratio(scenario, r);
                assert!(run.converged, "{scenario:?} r={r}");
                assert!(ok, "{scenario:?} r={r}: oracle failed");
            }
        }
    }

    #[test]
    fn remote_ratio_dials_steal_traffic() {
        let (balanced, _) = run_ratio(Scenario::SRSP, 0.0);
        let (skewed, _) = run_ratio(Scenario::SRSP, 0.9);
        // r=0 is balanced: at most end-of-round skew steals. r=0.9 routes
        // ~90% of tasks through the hot set, so most claims are remote.
        let total = skewed.stats.tasks_executed;
        assert!(
            balanced.stats.tasks_stolen < total / 10,
            "r=0 should steal almost nothing (stole {} of {total})",
            balanced.stats.tasks_stolen
        );
        assert!(
            skewed.stats.tasks_stolen > total / 8,
            "r=0.9 must force heavy stealing (stole {} of {total})",
            skewed.stats.tasks_stolen
        );
        assert!(skewed.stats.remote_acqrels > balanced.stats.remote_acqrels);
    }

    #[test]
    fn remote_coin_is_deterministic_and_biased() {
        let n = 10_000u32;
        for r in [0.0, 0.25, 0.75, 1.0] {
            let hits = (0..n).filter(|&c| is_remote(42, c, r)).count() as f64;
            let frac = hits / n as f64;
            assert!((frac - r).abs() < 0.02, "r={r}: got {frac}");
        }
        assert_eq!(is_remote(9, 123, 0.5), is_remote(9, 123, 0.5));
    }

    #[test]
    fn migration_rotates_the_hot_set() {
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        // r=1: everything is remote, hot_set=1, migrate every round.
        let mut s = Stress::setup(&mut alloc, &mut image, 16, 4, 0, 1.0, 1, 1, 3);
        let active: Vec<u32> = (0..16).collect();
        let q0 = s.place(&active, 4, 16);
        s.round = 1;
        let q1 = s.place(&active, 4, 16);
        let hot0 = q0.iter().position(|q| !q.is_empty()).unwrap();
        let hot1 = q1.iter().position(|q| !q.is_empty()).unwrap();
        assert_eq!(q0[hot0].len(), 16, "hot_set=1 concentrates everything");
        assert_ne!(hot0, hot1, "migration must move the hot queue");
    }
}
