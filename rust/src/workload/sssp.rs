//! Single-source shortest paths (Pannotia SSSP, §5.1: run with a
//! `USA-road-BAY`-class road network).
//!
//! Pull relaxation over a frontier worklist: an active vertex v recomputes
//! `dist[v] = min(dist[v], min_u(dist[u] + w(u,v)))` (writes only its own
//! entry — race-free), sets `changed[v]`, and the host activates the
//! chunks containing neighbors of changed vertices for the next round.
//! The frontier sweep produces the strong, shifting load imbalance that
//! makes SSSP the paper's best case for work stealing (+40% with sRSP).

use super::driver::Workload;
use super::engine::{upload_graph, AppLayout, DIST_INF, KIND_SSSP};
use super::graph::Graph;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::mem::{Addr, BackingStore, MemAlloc};
use std::collections::BTreeSet;

/// Host-side SSSP state.
pub struct Sssp {
    layout: AppLayout,
    dist: Addr,
    changed: Addr,
    n: u32,
    chunk: u32,
    source: u32,
    graph_adj: Vec<Vec<u32>>,
    /// Chunks to process next round (None before the first round).
    next_active: Option<Vec<u32>>,
    first: bool,
}

impl Sssp {
    pub fn setup(
        g: &Graph,
        alloc: &mut MemAlloc,
        backing: &mut BackingStore,
        chunk: u32,
        source: u32,
    ) -> Self {
        let (row_ptr, col, weight) = upload_graph(g, alloc, backing);
        let n = g.n;
        let dist = alloc.alloc(n as u64 * 4);
        let changed = alloc.alloc(n as u64 * 4);
        for v in 0..n {
            backing.write_u32(dist + v as u64 * 4, if v == source { 0 } else { DIST_INF });
        }
        let layout = AppLayout {
            row_ptr,
            col,
            weight,
            a0: dist,
            a1: 0,
            a2: 0,
            changed,
            chunk,
            n,
            damping_bits: 0,
            aux: 0,
            high_water: alloc.high_water(),
        };
        let graph_adj = (0..n)
            .map(|v| g.neighbors(v).map(|(u, _)| u).collect())
            .collect();
        Sssp {
            layout,
            dist,
            changed,
            n,
            chunk,
            source,
            graph_adj,
            next_active: None,
            first: true,
        }
    }

    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.n)
            .map(|v| backing.read_u32(self.dist + v as u64 * 4))
            .collect()
    }

    /// Dijkstra oracle (exact distances; DIST_INF for unreachable).
    pub fn oracle(g: &Graph, source: u32) -> Vec<u32> {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let mut dist = vec![DIST_INF; g.n as usize];
        dist[source as usize] = 0;
        let mut heap = BinaryHeap::new();
        heap.push(Reverse((0u32, source)));
        while let Some(Reverse((d, v))) = heap.pop() {
            if d > dist[v as usize] {
                continue;
            }
            for (u, w) in g.neighbors(v) {
                let nd = d + w;
                if nd < dist[u as usize] {
                    dist[u as usize] = nd;
                    heap.push(Reverse((nd, u)));
                }
            }
        }
        dist
    }

    fn chunk_of(&self, v: u32) -> u32 {
        v / self.chunk
    }
}

impl Workload for Sssp {
    fn kinds(&self) -> Vec<u32> {
        vec![KIND_SSSP]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, backing: &mut BackingStore) -> Option<Vec<u32>> {
        if self.first {
            self.first = false;
            // Kick off: activate the chunks holding the source's neighbors.
            let mut chunks = BTreeSet::new();
            for &u in &self.graph_adj[self.source as usize] {
                chunks.insert(self.chunk_of(u));
            }
            chunks.insert(self.chunk_of(self.source));
            return Some(chunks.into_iter().collect());
        }
        // Activate chunks containing neighbors of vertices that changed
        // last round; clear the flags.
        let mut chunks = BTreeSet::new();
        for v in 0..self.n {
            if backing.read_u32(self.changed + v as u64 * 4) != 0 {
                backing.write_u32(self.changed + v as u64 * 4, 0);
                for &u in &self.graph_adj[v as usize] {
                    chunks.insert(self.chunk_of(u));
                }
            }
        }
        if chunks.is_empty() {
            None // converged
        } else {
            Some(chunks.into_iter().collect())
        }
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {}

    fn name(&self) -> &'static str {
        "SSSP"
    }
}

/// Registry entry (§5.1: SSSP on a `USA-road-BAY`-class road grid).
pub struct SsspKernel;

impl Kernel for SsspKernel {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn display(&self) -> &'static str {
        "SSSP"
    }

    fn summary(&self) -> &'static str {
        "single-source shortest paths, frontier pull relaxation"
    }

    fn oracle(&self) -> &'static str {
        "exact (Dijkstra)"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[
            ParamSpec {
                key: "source",
                default: 0.0,
                help: "source vertex",
            },
            ParamSpec {
                key: "chunk",
                default: 8.0,
                help: "vertices per task chunk",
            },
        ]
    }

    fn prepare(&self, size: WorkloadSize, seed: u64, _params: &mut Params) -> Prepared {
        let (graph, max_rounds) = match size {
            WorkloadSize::Paper => (Graph::road_grid(64, 64, seed), 400),
            WorkloadSize::Tiny => (Graph::road_grid(16, 16, seed), 200),
        };
        Prepared {
            graph: Some(graph),
            max_rounds,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let g = preset.graph();
        let source = preset.params.get_u32("source").min(g.n.saturating_sub(1));
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = Sssp::setup(
            g,
            &mut alloc,
            &mut image,
            preset.params.get_u32("chunk"),
            source,
        );
        let oracle = Sssp::oracle(g, source);
        let (dist, n) = (wl.dist, wl.n);
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                for v in 0..n {
                    let got = mem.read_u32(dist + v as u64 * 4);
                    if got != oracle[v as usize] {
                        return Err(format!(
                            "SSSP dist[{v}] = {got}, oracle {}",
                            oracle[v as usize]
                        ));
                    }
                }
                Ok(())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Scenario};
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;

    #[test]
    fn oracle_on_path_graph() {
        let g = Graph::from_edges(4, &[(0, 1, 5), (1, 2, 3), (2, 3, 2)]);
        assert_eq!(Sssp::oracle(&g, 0), vec![0, 5, 8, 10]);
    }

    #[test]
    fn simulated_sssp_exact_all_scenarios() {
        let g = Graph::road_grid(12, 12, 3);
        let oracle = Sssp::oracle(&g, 0);
        for scenario in Scenario::ALL {
            let mut alloc = MemAlloc::new();
            let mut image = BackingStore::new();
            let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 8, 0);
            let cfg = DeviceConfig::small();
            let (run, final_mem) =
                run_scenario_seeded(&cfg, scenario, &mut sssp, NativeMath, 1000, image);
            assert!(run.converged, "{scenario:?}: SSSP must converge");
            assert_eq!(
                sssp.result(&final_mem),
                oracle,
                "{scenario:?}: distances must be exact"
            );
        }
    }

    #[test]
    fn unreachable_stays_inf() {
        // Two disconnected components.
        let g = Graph::from_edges(4, &[(0, 1, 1), (2, 3, 1)]);
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let mut sssp = Sssp::setup(&g, &mut alloc, &mut image, 2, 0);
        let cfg = DeviceConfig::small();
        let (_, final_mem) = run_scenario_seeded(
            &cfg,
            Scenario::SRSP,
            &mut sssp,
            NativeMath,
            100,
            image,
        );
        let d = sssp.result(&final_mem);
        assert_eq!(d[1], 1);
        assert_eq!(d[2], DIST_INF);
        assert_eq!(d[3], DIST_INF);
    }
}
