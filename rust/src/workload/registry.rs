//! The pluggable workload registry: every evaluation kernel registers a
//! [`Kernel`] implementation in [`REGISTRY`] and self-describes — name,
//! aliases, oracle kind, default chunking, tunable parameters — so the
//! runner, the CLI (`srsp list-workloads`, `--app <name>`, `--param k=v`),
//! the presets and the reports all resolve workloads through one table
//! instead of matching on a hard-coded enum.
//!
//! Adding a workload is now a registry entry: implement [`Kernel`] next to
//! the workload (see `pagerank.rs` for the smallest example, `stress.rs`
//! for one with parameters and custom task placement) and push it into
//! [`REGISTRY`]. Nothing else in the harness, CLI or report layers needs
//! to change.

use std::fmt;

use super::driver::Workload;
use super::graph::Graph;
use crate::mem::BackingStore;

// The generic parameter machinery is shared with the sync-protocol
// registry; re-exported under the historical workload paths.
pub use crate::params::{ParamSpec, Params};

/// Scale of a preset run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadSize {
    /// Unit-test scale (seconds on 4 CUs).
    Tiny,
    /// Bench scale for the 64-CU figure runs.
    Paper,
}

/// The classic workload-generation seed used by every paper-figure
/// preset. Runs that do not ask for explicit seeding reproduce the
/// figures byte-for-byte with this value.
pub const DEFAULT_SEED: u64 = 0xC0FFEE;

/// Input + bounds produced by [`Kernel::prepare`] for one (size, seed,
/// params) triple.
pub struct Prepared {
    /// Generated input graph (`None` for synthetic non-graph kernels).
    pub graph: Option<Graph>,
    /// Host-loop round bound handed to the scenario driver.
    pub max_rounds: u32,
}

/// A ready-to-run workload instance: the host-side state, the seeded
/// initial memory image, and the oracle check over the final image.
pub struct Instance {
    pub workload: Box<dyn Workload>,
    pub image: BackingStore,
    /// Validate the final (post-run) memory against the native oracle.
    pub check: Box<dyn Fn(&BackingStore) -> Result<(), String> + Send>,
}

/// A registered evaluation kernel. Implementations live next to their
/// workload and self-describe everything the harness layers need.
pub trait Kernel: Sync {
    /// Canonical CLI name (`--app <name>`), lower-case.
    fn name(&self) -> &'static str;
    /// Display/report label (`PRK`, `SSSP`, ...).
    fn display(&self) -> &'static str;
    /// Extra accepted CLI spellings.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }
    /// One-line description for `srsp list-workloads`.
    fn summary(&self) -> &'static str;
    /// Human description of the oracle (`exact (Dijkstra)`, ...).
    fn oracle(&self) -> &'static str;
    /// Tunable parameters (empty when the kernel has none).
    fn params(&self) -> &'static [ParamSpec] {
        &[]
    }
    /// Generate the input and size-dependent bounds; may materialize
    /// auto defaults into `params` (visible to [`Kernel::instantiate`]).
    fn prepare(&self, size: WorkloadSize, seed: u64, params: &mut Params) -> Prepared;
    /// Build the runnable instance (host state + memory image + oracle).
    fn instantiate(&self, preset: &WorkloadPreset) -> Instance;
}

/// The static workload table. Order is load-bearing: a workload's index
/// is its [`WorkloadId::ord`], which feeds per-cell seed derivation — new
/// workloads append, existing ones never reorder.
pub static REGISTRY: &[&dyn Kernel] = &[
    &super::pagerank::PageRankKernel,
    &super::sssp::SsspKernel,
    &super::mis::MisKernel,
    &super::stress::StressKernel,
    &super::bfs::BfsKernel,
    &super::prodcons::ProdConsKernel,
    &super::lock::LockKernel,
];

/// Stable handle to a registered workload (index into [`REGISTRY`]).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkloadId(usize);

/// The three Pannotia apps of the paper's §5.1 evaluation.
pub const PRK: WorkloadId = WorkloadId(0);
pub const SSSP: WorkloadId = WorkloadId(1);
pub const MIS: WorkloadId = WorkloadId(2);
/// The asymmetry-stress kernel family (remote-ratio sweep axis).
pub const STRESS: WorkloadId = WorkloadId(3);
pub const BFS: WorkloadId = WorkloadId(4);
pub const PRODCONS: WorkloadId = WorkloadId(5);
/// The asymmetric-mutex workload (Liu et al.-style fast/slow lock paths).
pub const LOCK: WorkloadId = WorkloadId(6);

impl WorkloadId {
    pub fn kernel(self) -> &'static dyn Kernel {
        REGISTRY[self.0]
    }

    /// Stable ordinal used for seed derivation (recorded seeds in saved
    /// reports depend on it; equals the registry index).
    pub fn ord(self) -> u64 {
        self.0 as u64
    }

    pub fn name(self) -> &'static str {
        self.kernel().name()
    }

    pub fn display(self) -> &'static str {
        self.kernel().display()
    }
}

impl fmt::Debug for WorkloadId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.display())
    }
}

impl fmt::Display for WorkloadId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every registered workload, in registry order.
pub fn all() -> impl Iterator<Item = WorkloadId> {
    (0..REGISTRY.len()).map(WorkloadId)
}

/// Resolve a CLI name (canonical or alias, case-insensitive).
pub fn resolve(name: &str) -> Option<WorkloadId> {
    let lower = name.to_ascii_lowercase();
    all().find(|id| {
        let k = id.kernel();
        k.name() == lower || k.aliases().contains(&lower.as_str())
    })
}

/// A fully-specified workload instance: which kernel, at what scale,
/// from which seed, with which parameters — plus the pre-generated input
/// (shared read-only across the scenarios of one grid cell).
pub struct WorkloadPreset {
    pub id: WorkloadId,
    pub size: WorkloadSize,
    /// Seed the input was generated from (recorded in reports).
    pub seed: u64,
    /// Resolved parameters (defaults + `--param` overrides).
    pub params: Params,
    pub graph: Option<Graph>,
    pub max_rounds: u32,
}

impl WorkloadPreset {
    /// Classic figure preset: default parameters, classic seed.
    pub fn new(id: WorkloadId, size: WorkloadSize) -> Self {
        Self::new_seeded(id, size, DEFAULT_SEED)
    }

    /// Default parameters with an explicit generator seed (the
    /// scenario-matrix runner derives one per grid cell).
    pub fn new_seeded(id: WorkloadId, size: WorkloadSize, seed: u64) -> Self {
        Self::with_params(id, size, seed, &[]).expect("empty overrides cannot fail")
    }

    /// Full form: explicit parameter overrides (`--param k=v`).
    pub fn with_params(
        id: WorkloadId,
        size: WorkloadSize,
        seed: u64,
        overrides: &[(String, f64)],
    ) -> Result<Self, String> {
        let kernel = id.kernel();
        let mut params = Params::resolve(kernel.params(), overrides)
            .map_err(|e| format!("{}: {e}", kernel.name()))?;
        let prepared = kernel.prepare(size, seed, &mut params);
        Ok(WorkloadPreset {
            id,
            size,
            seed,
            params,
            graph: prepared.graph,
            max_rounds: prepared.max_rounds,
        })
    }

    /// Override the input graph (e.g. a real DIMACS file).
    pub fn with_graph(mut self, g: Graph) -> Self {
        self.graph = Some(g);
        self
    }

    /// The graph input; panics for non-graph kernels (workload-author
    /// bug: only graph kernels may call this from `instantiate`).
    pub fn graph(&self) -> &Graph {
        self.graph
            .as_ref()
            .unwrap_or_else(|| panic!("{} has no graph input", self.id.name()))
    }

    /// The remote-ratio sweep coordinate: `Some(r)` iff this workload
    /// declares a `remote_ratio` parameter (the stress family). Reports
    /// surface it as a first-class column so protocol × r curves can be
    /// plotted straight from the CSV.
    pub fn remote_ratio(&self) -> Option<f64> {
        self.id
            .kernel()
            .params()
            .iter()
            .find(|s| s.key == "remote_ratio")
            .map(|s| self.params.get(s.key))
    }

    /// Build the runnable instance (workload + image + oracle check).
    pub fn instance(&self) -> Instance {
        self.id.kernel().instantiate(self)
    }

    /// Instantiate without the oracle (figure pipelines).
    pub fn instantiate(&self) -> (Box<dyn Workload>, BackingStore) {
        let inst = self.instance();
        (inst.workload, inst.image)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let mut seen = BTreeSet::new();
        for id in all() {
            let k = id.kernel();
            assert!(seen.insert(k.name()), "duplicate name {}", k.name());
            assert_eq!(resolve(k.name()), Some(id));
            assert_eq!(resolve(&k.name().to_uppercase()), Some(id));
            for alias in k.aliases() {
                assert_eq!(resolve(alias), Some(id), "alias {alias}");
            }
        }
        assert_eq!(resolve("bogus"), None);
    }

    #[test]
    fn classic_ordinals_stable() {
        // Saved report seeds depend on these; never reorder.
        assert_eq!(PRK.ord(), 0);
        assert_eq!(SSSP.ord(), 1);
        assert_eq!(MIS.ord(), 2);
        assert_eq!(resolve("prk"), Some(PRK));
        assert_eq!(resolve("pagerank"), Some(PRK));
        assert_eq!(resolve("sssp"), Some(SSSP));
        assert_eq!(resolve("mis"), Some(MIS));
        assert_eq!(resolve("stress"), Some(STRESS));
        assert_eq!(resolve("bfs"), Some(BFS));
        assert_eq!(resolve("prodcons"), Some(PRODCONS));
        assert_eq!(resolve("lock"), Some(LOCK));
        assert_eq!(all().count(), 7);
    }

    #[test]
    fn preset_rejects_unknown_param() {
        let err =
            WorkloadPreset::with_params(STRESS, WorkloadSize::Tiny, 1, &[("nope".into(), 1.0)])
                .unwrap_err();
        assert!(err.contains("stress"), "{err}");
    }
}
