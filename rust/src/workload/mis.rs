//! Maximal Independent Set (§5.1: run with a `caidaRouterLevel`-class
//! power-law graph).
//!
//! Luby-style with unique deterministic priorities
//! ([`mis_priority`](super::engine::mis_priority)): each round runs two
//! kernels — *select* (an undecided vertex joins when its priority beats
//! every undecided neighbor) and *exclude* (an undecided vertex leaves
//! when a neighbor is IN). Both phases write only the vertex's own state:
//! race-free under every scenario.

use super::driver::Workload;
use super::engine::{
    mis_priority, upload_graph, AppLayout, KIND_MIS_EXCLUDE, KIND_MIS_SELECT, MIS_IN,
    MIS_UNDECIDED,
};
use super::graph::Graph;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::mem::{Addr, BackingStore, MemAlloc};
use std::collections::BTreeSet;

/// Host-side MIS state.
pub struct Mis {
    layout: AppLayout,
    state: Addr,
    newflag: Addr,
    n: u32,
    chunk: u32,
}

impl Mis {
    pub fn setup(g: &Graph, alloc: &mut MemAlloc, backing: &mut BackingStore, chunk: u32) -> Self {
        let (row_ptr, col, weight) = upload_graph(g, alloc, backing);
        let n = g.n;
        let state = alloc.alloc(n as u64 * 4);
        let priority = alloc.alloc(n as u64 * 4);
        let newflag = alloc.alloc(n as u64 * 4);
        let changed = alloc.alloc(n as u64 * 4);
        for v in 0..n {
            backing.write_u32(state + v as u64 * 4, MIS_UNDECIDED);
            backing.write_u32(priority + v as u64 * 4, mis_priority(v));
        }
        let layout = AppLayout {
            row_ptr,
            col,
            weight,
            a0: state,
            a1: priority,
            a2: newflag,
            changed,
            chunk,
            n,
            damping_bits: 0,
            aux: 0,
            high_water: alloc.high_water(),
        };
        Mis {
            layout,
            state,
            newflag,
            n,
            chunk,
        }
    }

    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.n)
            .map(|v| backing.read_u32(self.state + v as u64 * 4))
            .collect()
    }

    /// Set membership (IN vertices).
    pub fn members(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.n)
            .filter(|&v| backing.read_u32(self.state + v as u64 * 4) == MIS_IN)
            .collect()
    }

    /// Validity check: independent (no two IN vertices adjacent) and
    /// maximal (every OUT/undecided vertex has an IN neighbor).
    pub fn validate_mis(g: &Graph, state: &[u32]) -> Result<(), String> {
        for v in 0..g.n {
            match state[v as usize] {
                s if s == MIS_IN => {
                    for (u, _) in g.neighbors(v) {
                        if state[u as usize] == MIS_IN {
                            return Err(format!("adjacent IN pair {v},{u}"));
                        }
                    }
                }
                s if s == MIS_UNDECIDED => return Err(format!("vertex {v} undecided")),
                _ => {
                    if !g.neighbors(v).any(|(u, _)| state[u as usize] == MIS_IN) {
                        return Err(format!("OUT vertex {v} has no IN neighbor (not maximal)"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Serial oracle with the same priorities: the greedy MIS over
    /// priority order — identical to the fixed point of the parallel
    /// rounds (unique priorities make Luby deterministic).
    pub fn oracle(g: &Graph) -> Vec<u32> {
        let mut order: Vec<u32> = (0..g.n).collect();
        order.sort_by_key(|&v| std::cmp::Reverse(mis_priority(v)));
        let mut state = vec![MIS_UNDECIDED; g.n as usize];
        for v in order {
            if state[v as usize] == MIS_UNDECIDED {
                state[v as usize] = MIS_IN;
                for (u, _) in g.neighbors(v) {
                    if state[u as usize] == MIS_UNDECIDED {
                        state[u as usize] = super::engine::MIS_OUT;
                    }
                }
            }
        }
        state
    }

    fn chunk_of(&self, v: u32) -> u32 {
        v / self.chunk
    }
}

impl Workload for Mis {
    fn kinds(&self) -> Vec<u32> {
        vec![KIND_MIS_SELECT, KIND_MIS_EXCLUDE]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, backing: &mut BackingStore) -> Option<Vec<u32>> {
        // Active chunks: those still containing undecided vertices.
        let mut chunks = BTreeSet::new();
        for v in 0..self.n {
            if backing.read_u32(self.state + v as u64 * 4) == MIS_UNDECIDED {
                chunks.insert(self.chunk_of(v));
            }
        }
        if chunks.is_empty() {
            None
        } else {
            Some(chunks.into_iter().collect())
        }
    }

    fn end_round(&mut self, backing: &mut BackingStore) {
        // Clear newflags for the next round (host-side, free: the merge
        // launch already applied them to the state array).
        for v in 0..self.n {
            backing.write_u32(self.newflag + v as u64 * 4, 0);
        }
    }

    fn name(&self) -> &'static str {
        "MIS"
    }
}

/// Registry entry (§5.1: MIS on a `caidaRouterLevel`-class power-law
/// graph).
pub struct MisKernel;

impl Kernel for MisKernel {
    fn name(&self) -> &'static str {
        "mis"
    }

    fn display(&self) -> &'static str {
        "MIS"
    }

    fn summary(&self) -> &'static str {
        "maximal independent set, two-phase deterministic Luby"
    }

    fn oracle(&self) -> &'static str {
        "exact (greedy over priorities) + validity"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[ParamSpec {
            key: "chunk",
            default: 8.0,
            help: "vertices per task chunk",
        }]
    }

    fn prepare(&self, size: WorkloadSize, seed: u64, _params: &mut Params) -> Prepared {
        let (graph, max_rounds) = match size {
            WorkloadSize::Paper => (Graph::power_law(4096, 3, seed), 64),
            WorkloadSize::Tiny => (Graph::power_law(256, 2, seed), 32),
        };
        Prepared {
            graph: Some(graph),
            max_rounds,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let g = preset.graph().clone();
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = Mis::setup(&g, &mut alloc, &mut image, preset.params.get_u32("chunk"));
        let oracle = Mis::oracle(&g);
        let (state, n) = (wl.state, wl.n);
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                let got: Vec<u32> = (0..n).map(|v| mem.read_u32(state + v as u64 * 4)).collect();
                Mis::validate_mis(&g, &got)?;
                if got == oracle {
                    Ok(())
                } else {
                    Err("MIS differs from the deterministic-Luby oracle".into())
                }
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Scenario};
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;

    #[test]
    fn oracle_is_valid_mis() {
        let g = Graph::power_law(200, 2, 5);
        let state = Mis::oracle(&g);
        Mis::validate_mis(&g, &state).unwrap();
    }

    #[test]
    fn simulated_mis_matches_oracle_all_scenarios() {
        let g = Graph::power_law(160, 2, 9);
        let oracle = Mis::oracle(&g);
        for scenario in Scenario::ALL {
            let mut alloc = MemAlloc::new();
            let mut image = BackingStore::new();
            let mut mis = Mis::setup(&g, &mut alloc, &mut image, 8);
            let cfg = DeviceConfig::small();
            let (run, final_mem) =
                run_scenario_seeded(&cfg, scenario, &mut mis, NativeMath, 200, image);
            assert!(run.converged, "{scenario:?}: MIS must converge");
            let state = mis.result(&final_mem);
            Mis::validate_mis(&g, &state).unwrap();
            assert_eq!(state, oracle, "{scenario:?}: deterministic Luby must match greedy");
        }
    }
}
