//! PageRank (Pannotia PRK, §5.1: run with a `cond-mat-2003`-class
//! small-world graph).
//!
//! Pull formulation with double-buffered contributions:
//! `rank[v] = (1-d)/n + d * Σ_{u∈N(v)} contrib_in[u]`,
//! `contrib_out[v] = rank[v]/deg(v)`. Every chunk is active every
//! iteration; buffers swap between launches. Race-free: a task writes only
//! its own vertices.

use super::driver::Workload;
use super::engine::{upload_graph, AppLayout, KIND_PAGERANK, K_TILE};
use super::graph::Graph;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::mem::{Addr, BackingStore, MemAlloc};

pub const DAMPING: f32 = 0.85;

/// Host-side PageRank state.
pub struct PageRank {
    layout: AppLayout,
    /// Rank output array.
    rank: Addr,
    /// Contribution buffers (swap roles each iteration).
    contrib_a: Addr,
    contrib_b: Addr,
    n: u32,
    iters: u32,
    round: u32,
    total_chunks: u32,
}

impl PageRank {
    /// Allocate and initialize device arrays for `g`; run `iters`
    /// iterations with `chunk` vertices per task.
    pub fn setup(
        g: &Graph,
        alloc: &mut MemAlloc,
        backing: &mut BackingStore,
        chunk: u32,
        iters: u32,
    ) -> Self {
        let (row_ptr, col, weight) = upload_graph(g, alloc, backing);
        let n = g.n;
        let rank = alloc.alloc(n as u64 * 4);
        let contrib_a = alloc.alloc(n as u64 * 4);
        let contrib_b = alloc.alloc(n as u64 * 4);
        let changed = alloc.alloc(n as u64 * 4);
        let r0 = 1.0f32 / n as f32;
        for v in 0..n {
            backing.write_f32(rank + v as u64 * 4, r0);
            backing.write_f32(contrib_a + v as u64 * 4, r0 / g.degree(v).max(1) as f32);
        }
        let layout = AppLayout {
            row_ptr,
            col,
            weight,
            a0: contrib_a, // in
            a1: rank,      // out
            a2: contrib_b, // contribution out
            changed,
            chunk,
            n,
            damping_bits: DAMPING.to_bits(),
            aux: 0,
            high_water: alloc.high_water(),
        };
        PageRank {
            layout,
            rank,
            contrib_a,
            contrib_b,
            n,
            iters,
            round: 0,
            total_chunks: n.div_ceil(chunk),
        }
    }

    /// Final ranks (host-visible after the last kernel barrier).
    pub fn result(&self, backing: &BackingStore) -> Vec<f32> {
        (0..self.n)
            .map(|v| backing.read_f32(self.rank + v as u64 * 4))
            .collect()
    }

    /// Reference power iteration replicating the engine's tiling (K_TILE
    /// row sums, partial-row combination) so results match closely.
    pub fn oracle(g: &Graph, iters: u32) -> Vec<f32> {
        let n = g.n;
        let base = (1.0 - DAMPING) / n as f32;
        let mut contrib: Vec<f32> = (0..n)
            .map(|v| (1.0 / n as f32) / g.degree(v).max(1) as f32)
            .collect();
        let mut rank = vec![1.0f32 / n as f32; n as usize];
        for _ in 0..iters {
            let mut new_contrib = vec![0f32; n as usize];
            for v in 0..n {
                let nbrs: Vec<u32> = g.neighbors(v).map(|(u, _)| u).collect();
                // Tile-shaped partial sums, as the engine computes them.
                let mut acc = 0f32;
                let nrows = nbrs.len().div_ceil(K_TILE).max(1);
                for r in 0..nrows {
                    let mut s = 0f32;
                    for k in 0..K_TILE {
                        if let Some(&u) = nbrs.get(r * K_TILE + k) {
                            s += contrib[u as usize];
                        }
                    }
                    acc += base + DAMPING * s;
                }
                let rv = acc - (nrows as f32 - 1.0) * base;
                rank[v as usize] = rv;
                new_contrib[v as usize] = rv / g.degree(v).max(1) as f32;
            }
            contrib = new_contrib;
        }
        rank
    }
}

impl Workload for PageRank {
    fn kinds(&self) -> Vec<u32> {
        vec![KIND_PAGERANK]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, _backing: &mut BackingStore) -> Option<Vec<u32>> {
        if self.round >= self.iters {
            return None;
        }
        Some((0..self.total_chunks).collect())
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {
        self.round += 1;
        // Swap contribution buffers.
        std::mem::swap(&mut self.contrib_a, &mut self.contrib_b);
        self.layout.a0 = self.contrib_a;
        self.layout.a2 = self.contrib_b;
    }

    fn name(&self) -> &'static str {
        "PRK"
    }
}

/// Registry entry (§5.1: PRK on a `cond-mat-2003`-class small-world
/// graph).
pub struct PageRankKernel;

impl Kernel for PageRankKernel {
    fn name(&self) -> &'static str {
        "prk"
    }

    fn display(&self) -> &'static str {
        "PRK"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["pagerank"]
    }

    fn summary(&self) -> &'static str {
        "PageRank, pull formulation with double-buffered contributions"
    }

    fn oracle(&self) -> &'static str {
        "L1-norm < 1e-3 vs tiled power iteration"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[
            ParamSpec {
                key: "iters",
                default: 0.0,
                help: "power iterations (0 = auto: 3 tiny / 6 paper)",
            },
            ParamSpec {
                key: "chunk",
                default: 8.0,
                help: "vertices per task chunk",
            },
        ]
    }

    fn prepare(&self, size: WorkloadSize, seed: u64, params: &mut Params) -> Prepared {
        let (graph, iters) = match size {
            WorkloadSize::Paper => (Graph::small_world(4096, 8, 0.1, seed), 6.0),
            WorkloadSize::Tiny => (Graph::small_world(256, 4, 0.1, seed), 3.0),
        };
        if !params.is_explicit("iters") || params.get("iters") == 0.0 {
            params.set_auto("iters", iters);
        }
        Prepared {
            graph: Some(graph),
            // One round per power iteration; the bound must track an
            // explicit `--param iters` or large values could never
            // converge within it.
            max_rounds: params.get_u32("iters") + 1,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let g = preset.graph();
        let iters = preset.params.get_u32("iters");
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = PageRank::setup(
            g,
            &mut alloc,
            &mut image,
            preset.params.get_u32("chunk"),
            iters,
        );
        let oracle = PageRank::oracle(g, iters);
        let (rank, n) = (wl.rank, wl.n);
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                let diff: f32 = (0..n)
                    .map(|v| (mem.read_f32(rank + v as u64 * 4) - oracle[v as usize]).abs())
                    .sum();
                if diff < 1e-3 {
                    Ok(())
                } else {
                    Err(format!("PRK ranks deviate from oracle by {diff}"))
                }
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Scenario};
    use crate::workload::driver::run_scenario;
    use crate::workload::engine::NativeMath;

    fn l1_norm_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    #[test]
    fn oracle_sums_to_one() {
        let g = Graph::small_world(256, 4, 0.1, 7);
        let r = PageRank::oracle(&g, 10);
        let sum: f32 = r.iter().sum();
        assert!((sum - 1.0).abs() < 0.02, "rank mass ~1, got {sum}");
    }

    #[test]
    fn simulated_pagerank_matches_oracle_all_scenarios() {
        let g = Graph::small_world(192, 4, 0.2, 11);
        let oracle = PageRank::oracle(&g, 4);
        for scenario in Scenario::ALL {
            let mut alloc = MemAlloc::new();
            let mut image = BackingStore::new();
            let mut prk = PageRank::setup(&g, &mut alloc, &mut image, 16, 4);
            let cfg = DeviceConfig::small();
            let (run, final_mem) = crate::workload::driver::run_scenario_seeded(
                &cfg, scenario, &mut prk, NativeMath, 64, image,
            );
            assert!(run.converged, "{scenario:?} must finish");
            let result = prk.result(&final_mem);
            let d = l1_norm_diff(&result, &oracle);
            assert!(
                d < 1e-4,
                "{scenario:?}: PageRank deviates from oracle by {d}"
            );
        }
    }
}
