//! The asymmetric-mutex workload (in the spirit of Liu et al.,
//! *Asymmetry-aware Scalable Locking*), ported through the [`Kernel`]
//! registry: per-lock critical sections with a **fast path** for the
//! lock's local sharer and a **slow path** for stealers.
//!
//! Each of `locks` line-isolated locks guards one line-isolated counter.
//! Lock `l` is *owned* by work-group `l % nw`; its owner performs
//! `own_iters` critical sections through the fast path, and work-group
//! `(l + 1) % nw` — the designated stealer — performs `steal_iters`
//! critical sections through the slow path. Inside every critical
//! section the counter is updated with plain (non-atomic) load/add/store,
//! so the oracle (`counter[l] == own_iters + steal_iters`, exact) proves
//! *mutual exclusion and visibility*, not just atomicity of the lock ops
//! themselves.
//!
//! The scope assignment follows the scenario exactly like the deque's
//! [`SyncFlavor`](super::deque::SyncFlavor):
//!
//! * promotion scenarios (RSP/sRSP/srsp-adaptive) — the owner spins on a
//!   **wg-scope CAS** (L1-local once the line is resident; the release
//!   store is LR-TBL-recorded under sRSP) and stealers acquire/release
//!   with **`rem_acq`/`rem_rel`**: every lock handoff is a remote-scope
//!   promotion, the paper's §4 running example as a workload;
//! * hLRC — both paths at wg scope, ownership ping-pongs lazily;
//! * scoped-only scenarios — both paths at cmp scope (a wg-scope owner
//!   with a cmp-scope stealer would be racy on non-coherent L1s: the
//!   owner's release could sit unflushed in its sFIFO while the stealer's
//!   L2 CAS reads the stale unlocked value).
//!
//! Every scenario performs the *same* critical sections — only the sync
//! flavor differs — so one oracle validates all of them and vs-Baseline
//! ratios compare identical work.

use super::deque::DequeLayout;
use super::driver::Workload;
use super::engine::AppLayout;
use super::registry::{Instance, Kernel, ParamSpec, Params, Prepared, WorkloadPreset, WorkloadSize};
use crate::config::Scenario;
use crate::kir::inst::StatCounter;
use crate::kir::{AluOp, Asm, Program, Src};
use crate::mem::{Addr, BackingStore, MemAlloc};
use crate::sync::{AtomicOp, MemOrder, Scope};

/// Host-side asymmetric-mutex state.
pub struct Lock {
    layout: AppLayout,
    locks_addr: Addr,
    counters: Addr,
    locks: u32,
    own_iters: u32,
    steal_iters: u32,
    done: bool,
}

impl Lock {
    pub fn setup(
        alloc: &mut MemAlloc,
        backing: &mut BackingStore,
        locks: u32,
        own_iters: u32,
        steal_iters: u32,
    ) -> Self {
        // Locks and counters are line-isolated: each lock is its own sync
        // variable, and a counter update never drags a neighbor's lock
        // line through a promotion.
        let locks_addr = alloc.alloc(locks as u64 * 64);
        let counters = alloc.alloc(locks as u64 * 64);
        for l in 0..locks {
            backing.write_u32(locks_addr + l as u64 * 64, 0);
            backing.write_u32(counters + l as u64 * 64, 0);
        }
        let layout = AppLayout {
            row_ptr: 0,
            col: 0,
            weight: 0,
            a0: locks_addr,
            a1: counters,
            a2: 0,
            changed: 0,
            chunk: 1,
            n: locks,
            damping_bits: 0,
            aux: 0,
            high_water: alloc.high_water(),
        };
        Lock {
            layout,
            locks_addr,
            counters,
            locks,
            own_iters,
            steal_iters,
            done: false,
        }
    }

    /// Final per-lock counters.
    pub fn result(&self, backing: &BackingStore) -> Vec<u32> {
        (0..self.locks)
            .map(|l| backing.read_u32(self.counters + l as u64 * 64))
            .collect()
    }
}

impl Workload for Lock {
    fn kinds(&self) -> Vec<u32> {
        // One launch; the custom kernel never issues a Compute op (kind 0
        // would trap in the engine — a canary, not a dispatch target).
        vec![0]
    }

    fn layout(&self) -> AppLayout {
        self.layout
    }

    fn begin_round(&mut self, _backing: &mut BackingStore) -> Option<Vec<u32>> {
        if self.done {
            return None;
        }
        // The kernel derives lock ownership from wg ids; the deques stay
        // empty.
        Some(Vec::new())
    }

    fn end_round(&mut self, _backing: &mut BackingStore) {
        self.done = true;
    }

    fn name(&self) -> &'static str {
        "LOCK"
    }

    /// Custom kernel: fast/slow-path critical sections instead of deque
    /// draining.
    fn kernel(
        &self,
        _deques: &DequeLayout,
        scenario: Scenario,
        _kind: u32,
        _ctrl: Addr,
    ) -> Program {
        build_lock_kernel(
            scenario,
            self.locks_addr,
            self.counters,
            self.locks,
            self.own_iters,
            self.steal_iters,
        )
    }
}

/// How a slow-path (stealer) critical section acquires/releases.
#[derive(Clone, Copy)]
enum SlowPath {
    Remote,
    Scoped(Scope),
}

/// Emit the asymmetric-mutex program for `scenario`.
pub fn build_lock_kernel(
    scenario: Scenario,
    locks_addr: Addr,
    counters: Addr,
    locks: u32,
    own_iters: u32,
    steal_iters: u32,
) -> Program {
    // Scope pairing per scenario (see module docs).
    let (owner_scope, slow) = if scenario.remote_ops() {
        (Scope::Wg, SlowPath::Remote)
    } else if scenario.lazy_transfer() {
        (Scope::Wg, SlowPath::Scoped(Scope::Wg))
    } else {
        (Scope::Cmp, SlowPath::Scoped(Scope::Cmp))
    };

    let mut a = Asm::new();
    let wg = a.reg();
    let nw = a.reg();
    let l = a.reg();
    let c = a.reg();
    let i = a.reg();
    let old = a.reg();
    let val = a.reg();
    let lock = a.reg();
    let ctr = a.reg();

    a.wg_id(wg);
    a.num_wgs(nw);
    a.imm(l, 0);

    a.label("locks_loop");
    a.ge_u(c, l, Src::I(u64::from(locks)));
    a.bnz(c, "end");
    a.shl(lock, l, Src::I(6));
    a.add(lock, lock, Src::I(locks_addr));
    a.shl(ctr, l, Src::I(6));
    a.add(ctr, ctr, Src::I(counters));

    // ---- fast path: the owner (wg == l % nw) ----
    a.alu(AluOp::RemU, c, l, Src::R(nw));
    a.eq(c, c, Src::R(wg));
    a.bz(c, "not_owner");
    a.imm(i, 0);
    a.label("own_cs");
    a.ge_u(c, i, Src::I(u64::from(own_iters)));
    a.bnz(c, "not_owner");
    a.label("own_spin");
    a.atomic(
        old,
        AtomicOp::Cas,
        lock,
        Src::I(1),
        Src::I(0),
        MemOrder::Acquire,
        owner_scope,
    );
    a.bnz(old, "own_spin");
    // Critical section: plain load/add/store on the guarded counter.
    a.ld(val, ctr, 0, 4);
    a.add(val, val, Src::I(1));
    a.st(ctr, 0, val, 4);
    a.atomic(
        old,
        AtomicOp::Store,
        lock,
        Src::I(0),
        Src::I(0),
        MemOrder::Release,
        owner_scope,
    );
    a.stat(StatCounter::TaskExecuted);
    a.add(i, i, Src::I(1));
    a.br("own_cs");
    a.label("not_owner");

    // ---- slow path: the designated stealer (wg == (l + 1) % nw) ----
    a.add(c, l, Src::I(1));
    a.alu(AluOp::RemU, c, c, Src::R(nw));
    a.eq(c, c, Src::R(wg));
    a.bz(c, "next_lock");
    a.imm(i, 0);
    a.label("steal_cs");
    a.ge_u(c, i, Src::I(u64::from(steal_iters)));
    a.bnz(c, "next_lock");
    a.stat(StatCounter::StealAttempt);
    a.label("steal_spin");
    match slow {
        SlowPath::Remote => {
            a.remote_atomic(old, AtomicOp::Cas, lock, Src::I(1), Src::I(0), MemOrder::Acquire);
        }
        SlowPath::Scoped(scope) => {
            a.atomic(
                old,
                AtomicOp::Cas,
                lock,
                Src::I(1),
                Src::I(0),
                MemOrder::Acquire,
                scope,
            );
        }
    }
    a.bnz(old, "steal_spin");
    a.ld(val, ctr, 0, 4);
    a.add(val, val, Src::I(1));
    a.st(ctr, 0, val, 4);
    match slow {
        SlowPath::Remote => {
            a.remote_atomic(old, AtomicOp::Store, lock, Src::I(0), Src::I(0), MemOrder::Release);
        }
        SlowPath::Scoped(scope) => {
            a.atomic(
                old,
                AtomicOp::Store,
                lock,
                Src::I(0),
                Src::I(0),
                MemOrder::Release,
                scope,
            );
        }
    }
    a.stat(StatCounter::StealSuccess);
    a.stat(StatCounter::TaskExecuted);
    a.add(i, i, Src::I(1));
    a.br("steal_cs");

    a.label("next_lock");
    a.add(l, l, Src::I(1));
    a.br("locks_loop");
    a.label("end");
    a.halt();
    a.finish()
}

/// Registry entry for the asymmetric mutex.
pub struct LockKernel;

impl Kernel for LockKernel {
    fn name(&self) -> &'static str {
        "lock"
    }

    fn display(&self) -> &'static str {
        "LOCK"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["mutex", "asym-lock"]
    }

    fn summary(&self) -> &'static str {
        "asymmetric mutexes: owner fast path, stealers through remote scope"
    }

    fn oracle(&self) -> &'static str {
        "exact (counter == own_iters + steal_iters per lock)"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &[
            ParamSpec {
                key: "locks",
                default: 0.0,
                help: "mutex count (0 = auto: 12 tiny / 96 paper)",
            },
            ParamSpec {
                key: "own_iters",
                default: 6.0,
                help: "fast-path critical sections per lock (the local sharer)",
            },
            ParamSpec {
                key: "steal_iters",
                default: 2.0,
                help: "slow-path critical sections per lock (the stealer)",
            },
        ]
    }

    fn prepare(&self, size: WorkloadSize, _seed: u64, params: &mut Params) -> Prepared {
        if params.get("locks") == 0.0 {
            params.set_auto(
                "locks",
                match size {
                    WorkloadSize::Paper => 96.0,
                    WorkloadSize::Tiny => 12.0,
                },
            );
        }
        Prepared {
            graph: None,
            max_rounds: 2,
        }
    }

    fn instantiate(&self, preset: &WorkloadPreset) -> Instance {
        let p = &preset.params;
        let (locks, own_iters, steal_iters) = (
            p.get_u32("locks").max(1),
            p.get_u32("own_iters"),
            p.get_u32("steal_iters"),
        );
        let mut alloc = MemAlloc::new();
        let mut image = BackingStore::new();
        let wl = Lock::setup(&mut alloc, &mut image, locks, own_iters, steal_iters);
        let counters = wl.counters;
        let want = own_iters + steal_iters;
        Instance {
            workload: Box::new(wl),
            image,
            check: Box::new(move |mem| {
                for l in 0..locks {
                    let got = mem.read_u32(counters + l as u64 * 64);
                    if got != want {
                        return Err(format!(
                            "LOCK counter {l} = {got}, expected {want} \
                             (mutual exclusion or visibility broken)"
                        ));
                    }
                }
                Ok(())
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;
    use crate::workload::driver::run_scenario_seeded;
    use crate::workload::engine::NativeMath;
    use crate::workload::registry;

    fn run(
        scenario: Scenario,
        num_cus: u32,
        overrides: &[(String, f64)],
    ) -> Result<crate::workload::driver::RunResult, String> {
        let preset =
            WorkloadPreset::with_params(registry::LOCK, WorkloadSize::Tiny, 5, overrides).unwrap();
        let inst = preset.instance();
        let mut wl = inst.workload;
        let cfg = DeviceConfig {
            num_cus,
            ..DeviceConfig::small()
        };
        let (r, mem) = run_scenario_seeded(
            &cfg,
            scenario,
            wl.as_mut(),
            NativeMath,
            preset.max_rounds,
            inst.image,
        );
        if !r.converged {
            return Err("did not converge".into());
        }
        (inst.check)(&mem)?;
        Ok(r)
    }

    #[test]
    fn exact_under_every_scenario() {
        for scenario in Scenario::ALL {
            run(scenario, 4, &[]).unwrap_or_else(|e| panic!("{scenario:?}: {e}"));
        }
        run(Scenario::HLRC, 4, &[]).unwrap();
        run(Scenario::SRSP_ADAPTIVE, 4, &[]).unwrap();
    }

    #[test]
    fn degenerate_devices() {
        // 1 wg: owner and stealer coincide (the slow path issues remote
        // ops from the owner's own CU — the §4.2 same-CU shortcut).
        run(Scenario::SRSP, 1, &[]).unwrap();
        // More wgs than locks: surplus wgs idle.
        run(Scenario::SRSP, 4, &[("locks".into(), 2.0)]).unwrap();
    }

    #[test]
    fn slow_path_drives_remote_promotions() {
        let r = run(Scenario::SRSP, 4, &[]).unwrap();
        assert!(
            r.stats.remote_acquires > 0 && r.stats.remote_releases > 0,
            "stealers must take the lock through remote scope"
        );
        assert!(r.stats.wg_releases > 0, "owners must release at wg scope");
        assert!(
            r.stats.tasks_stolen > 0,
            "slow-path critical sections count as steals"
        );
    }

    #[test]
    fn srsp_promotes_fewer_lines_than_naive() {
        let rsp = run(Scenario::RSP, 4, &[]).unwrap();
        let srsp = run(Scenario::SRSP, 4, &[]).unwrap();
        assert!(
            srsp.stats.lines_invalidated < rsp.stats.lines_invalidated,
            "selective promotion must not flash every L1 per handoff \
             ({} vs {})",
            srsp.stats.lines_invalidated,
            rsp.stats.lines_invalidated
        );
    }
}
