//! The work-stealing queue (§5.1): one consume-only task queue per
//! work-group, laid out in simulated memory and operated by generated KIR
//! code.
//!
//! Tasks are pre-filled by the host before each launch (the per-iteration
//! worklists of the Pannotia apps); the device only *consumes*. Claims go
//! through a single **claim counter** per queue (`next`): the owner claims
//! with a wg-scope AcqRel fetch-add — an L1-local operation on the fast
//! path, recorded in the LR-TBL because it releases — and a thief claims
//! with a `rem_ar` fetch-add whose promotion machinery (selective-flush of
//! the owner's counter, PA-TBL arming of the owner's next acquire) makes
//! the two sides linearize at the L2. `count` is launch-constant, so a
//! stale view can only ever be *optimistic* (a stale-low `next` escalates
//! to the promoting claim, which resolves the truth at the L2).
//!
//! This is the consume-only specialization of the Cederman–Tsigas GPU
//! work-stealing queue: with no device-side enqueues, the two-ended deque
//! degenerates to index claiming, which sidesteps the classic
//! stale-`bottom` double-claim hazard that plagues ABP-style deques on
//! non-coherent caches while preserving the paper's asymmetric-sharing
//! pattern exactly (the counter is THE sync variable: owner-local fast
//! path, remote-scope promotion on steal).
//!
//! Memory layout per queue (line-isolated):
//!
//! ```text
//! +0   next  (u32, claim counter; THE sync variable)
//! +64  count (u32, host-written task count, launch-constant)
//! +128 tasks (u32 × capacity)
//! ```

use crate::config::Scenario;
use crate::kir::{Asm, Reg, Src};
use crate::mem::{Addr, BackingStore, MemAlloc, LINE};
use crate::sync::{AtomicOp, MemOrder, Scope};

/// Sentinel returned in the task register when the pop/steal failed.
pub const EMPTY: u64 = u32::MAX as u64;

/// Host-side description of the queue array.
#[derive(Debug, Clone)]
pub struct DequeLayout {
    pub base: Addr,
    pub capacity: u32,
    pub num_queues: u32,
    /// Bytes between consecutive queues (line multiple).
    pub stride: u64,
}

impl DequeLayout {
    /// Allocate `num_queues` queues of `capacity` tasks each.
    pub fn alloc(alloc: &mut MemAlloc, num_queues: u32, capacity: u32) -> Self {
        let tasks_bytes = capacity as u64 * 4;
        let stride = (128 + tasks_bytes).div_ceil(LINE) * LINE;
        let base = alloc.alloc(stride * num_queues as u64);
        DequeLayout {
            base,
            capacity,
            num_queues,
            stride,
        }
    }

    pub fn next_addr(&self, q: u32) -> Addr {
        self.base + q as u64 * self.stride
    }

    pub fn count_addr(&self, q: u32) -> Addr {
        self.next_addr(q) + 64
    }

    pub fn tasks_addr(&self, q: u32) -> Addr {
        self.next_addr(q) + 128
    }

    /// Host: fill queue `q` with `tasks` before a launch (next = 0,
    /// count = len). Panics if over capacity.
    pub fn fill(&self, mem: &mut BackingStore, q: u32, tasks: &[u32]) {
        assert!(tasks.len() <= self.capacity as usize, "queue overflow");
        mem.write_u32(self.next_addr(q), 0);
        mem.write_u32(self.count_addr(q), tasks.len() as u32);
        for (i, &t) in tasks.iter().enumerate() {
            assert!(t != EMPTY as u32, "task id collides with EMPTY sentinel");
            mem.write_u32(self.tasks_addr(q) + i as u64 * 4, t);
        }
    }

    /// Host: unclaimed tasks in queue `q` (post-kernel check; `next` may
    /// overshoot `count` by failed claims).
    pub fn remaining(&self, mem: &BackingStore, q: u32) -> i64 {
        let n = mem.read_u32(self.next_addr(q)) as i64;
        let c = mem.read_u32(self.count_addr(q)) as i64;
        (c - n).max(0)
    }
}

/// How a thief claims from a victim queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StealKind {
    /// `rem_ar` remote-scope promotion (RSP / sRSP).
    Remote,
    /// cmp-scope AcqRel fetch-add (Steal-only).
    Cmp,
    /// wg-scope AcqRel fetch-add; the protocol (hLRC) transfers
    /// ownership lazily.
    Local,
}

/// Owner/steal sync flavor derived from the scenario.
#[derive(Debug, Clone, Copy)]
pub struct SyncFlavor {
    /// Scope for the owner's claim fetch-add.
    pub owner_scope: Scope,
    /// How thieves claim.
    pub steal: StealKind,
}

impl SyncFlavor {
    pub fn of(s: Scenario) -> Self {
        SyncFlavor {
            owner_scope: if s.local_owner_sync() {
                Scope::Wg
            } else {
                Scope::Cmp
            },
            steal: if s.remote_ops() {
                StealKind::Remote
            } else if s.lazy_transfer() {
                StealKind::Local
            } else {
                StealKind::Cmp
            },
        }
    }
}

/// Registers used by the queue codegen (caller allocates).
pub struct DequeRegs {
    /// In: base address of the queue (its `next` counter).
    pub qbase: Reg,
    /// Out: task id or EMPTY.
    pub task: Reg,
    /// Scratch.
    pub t0: Reg,
    pub t1: Reg,
    pub t2: Reg,
}

/// Emit the owner claim. On fall-through `regs.task` holds the task id or
/// [`EMPTY`]. Labels are suffixed with `tag` for uniqueness.
///
/// ```text
/// i = fetch_add_acq_rel(next, 1)   // owner_scope (wg on the fast path)
/// if i >= count: task = EMPTY
/// else:          task = tasks[i]
/// ```
///
/// The AcqRel ordering is load-bearing: the release records the counter in
/// the LR-TBL (so a thief's promotion can selectively flush exactly up to
/// this claim), and the acquire consults the PA-TBL (so the claim after a
/// steal is promoted to the L2 and cannot double-claim).
pub fn emit_owner_pop(a: &mut Asm, regs: &DequeRegs, flavor: SyncFlavor, tag: &str) {
    let l_empty = format!("pop_empty_{tag}");
    let l_done = format!("pop_done_{tag}");
    let (qbase, task, i, c, t) = (regs.qbase, regs.task, regs.t0, regs.t1, regs.t2);

    a.atomic(
        i,
        AtomicOp::Add,
        qbase,
        Src::I(1),
        Src::I(0),
        MemOrder::AcqRel,
        flavor.owner_scope,
    );
    // count is launch-constant: plain load.
    a.ld(c, qbase, 64, 4);
    a.ge_u(t, i, Src::R(c));
    a.bnz(t, &l_empty);
    a.shl(t, i, Src::I(2));
    a.add(t, t, Src::R(qbase));
    a.ld(task, t, 128, 4);
    a.br(&l_done);
    a.label(&l_empty);
    a.imm(task, EMPTY);
    a.label(&l_done);
}

/// Emit the "advertise emptiness" sequence: publish the exhausted claim
/// counter at device scope (`next = count`, relaxed cmp-scope store) so
/// thieves' plain pre-checks read `next >= count` fresh from the L2 and
/// skip the promoting claim. One L2 store per owner per launch; must only
/// be emitted after the owner's pop observed EMPTY.
pub fn emit_advertise_empty(a: &mut Asm, regs: &DequeRegs) {
    let (qbase, _task, _i, c, t) = (regs.qbase, regs.task, regs.t0, regs.t1, regs.t2);
    a.ld(c, qbase, 64, 4); // count (launch-constant)
    a.atomic(
        t,
        AtomicOp::Store,
        qbase,
        Src::R(c),
        Src::I(0),
        MemOrder::Relaxed,
        Scope::Cmp,
    );
}

/// Emit the steal against a victim queue whose base address is in
/// `regs.qbase`. On fall-through `regs.task` = task id or [`EMPTY`].
///
/// ```text
/// n = load(next); c = load(count)       // plain pre-check (cheap)
/// if n >= c: task = EMPTY               // stale n is only ever LOW, so
///                                       //  a "full" view escalates and
///                                       //  the promoting claim decides
/// i = rem_ar fetch_add(next, 1)         // or cmp-scope AcqRel add
/// if i >= c: task = EMPTY               // overshoot: queue was drained
/// else:      task = tasks[i]
/// ```
///
/// The pre-check matters at scale: queues the host filled empty read
/// fresh (`n >= c`) from the L2 and cost two plain loads instead of a
/// full remote-scope promotion; only plausibly-nonempty victims pay for
/// the promoting fetch-add.
pub fn emit_steal(a: &mut Asm, regs: &DequeRegs, flavor: SyncFlavor, tag: &str) {
    let l_empty = format!("steal_empty_{tag}");
    let l_done = format!("steal_done_{tag}");
    let (qbase, task, i, c, t) = (regs.qbase, regs.task, regs.t0, regs.t1, regs.t2);

    // Cheap plain pre-check.
    a.ld(i, qbase, 0, 4);
    a.ld(c, qbase, 64, 4);
    a.ge_u(t, i, Src::R(c));
    a.bnz(t, &l_empty);

    // Promoting claim.
    match flavor.steal {
        StealKind::Remote => {
            a.remote_atomic(i, AtomicOp::Add, qbase, Src::I(1), Src::I(0), MemOrder::AcqRel);
        }
        StealKind::Cmp => {
            a.atomic(
                i,
                AtomicOp::Add,
                qbase,
                Src::I(1),
                Src::I(0),
                MemOrder::AcqRel,
                Scope::Cmp,
            );
        }
        StealKind::Local => {
            a.atomic(
                i,
                AtomicOp::Add,
                qbase,
                Src::I(1),
                Src::I(0),
                MemOrder::AcqRel,
                Scope::Wg,
            );
        }
    }
    // Re-read count (fresh after the acquire; constant anyway).
    a.ld(c, qbase, 64, 4);
    a.ge_u(t, i, Src::R(c));
    a.bnz(t, &l_empty);
    a.shl(t, i, Src::I(2));
    a.add(t, t, Src::R(qbase));
    a.ld(task, t, 128, 4);
    a.br(&l_done);

    a.label(&l_empty);
    a.imm(task, EMPTY);
    a.label(&l_done);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Protocol};
    use crate::gpu::Device;
    use crate::kir::Asm;
    use crate::mem::MemAlloc;

    /// Kernel: owner claims everything from its own queue, summing task
    /// ids into out[wg].
    fn owner_drain_kernel(
        layout: &DequeLayout,
        flavor: SyncFlavor,
        out: Addr,
    ) -> crate::kir::Program {
        let mut a = Asm::new();
        let qbase = a.reg();
        let task = a.reg();
        let t0 = a.reg();
        let t1 = a.reg();
        let t2 = a.reg();
        let wg = a.reg();
        let sum = a.reg();
        let addr = a.reg();
        let stride = a.reg();

        a.wg_id(wg);
        a.imm(stride, layout.stride);
        a.mul(qbase, wg, Src::R(stride));
        a.add(qbase, qbase, Src::I(layout.base));
        a.imm(sum, 0);
        a.label("loop");
        let regs = DequeRegs { qbase, task, t0, t1, t2 };
        emit_owner_pop(&mut a, &regs, flavor, "d");
        // if task == EMPTY: done
        a.eq(t0, task, Src::I(EMPTY));
        a.bnz(t0, "end");
        a.add(sum, sum, Src::R(task));
        a.br("loop");
        a.label("end");
        a.shl(addr, wg, Src::I(3));
        a.add(addr, addr, Src::I(out));
        a.st(addr, 0, sum, 8);
        a.halt();
        a.finish()
    }

    #[test]
    fn owner_drains_own_queue_exactly() {
        for scenario in [Scenario::BASELINE, Scenario::SCOPE_ONLY, Scenario::SRSP] {
            let mut alloc = MemAlloc::new();
            let layout = DequeLayout::alloc(&mut alloc, 4, 32);
            let out = alloc.alloc(4 * 8);
            let mut dev = Device::new(DeviceConfig::small(), scenario.protocol());
            // Queue q gets tasks q*10 .. q*10+q (varying lengths).
            let mut expect = [0u64; 4];
            for q in 0..4u32 {
                let tasks: Vec<u32> = (0..=q).map(|i| q * 10 + i).collect();
                expect[q as usize] = tasks.iter().map(|&t| t as u64).sum();
                layout.fill(&mut dev.mem.backing, q, &tasks);
            }
            let prog = owner_drain_kernel(&layout, SyncFlavor::of(scenario), out);
            dev.launch_simple(&prog, 4);
            for q in 0..4u32 {
                assert_eq!(
                    dev.mem.backing.read_u64(out + q as u64 * 8),
                    expect[q as usize],
                    "{scenario:?}: queue {q} sum"
                );
                assert_eq!(layout.remaining(&dev.mem.backing, q), 0);
            }
        }
    }

    /// Kernel: wg0 drains its own queue; wgs 1..N steal from queue 0.
    /// Each wg accumulates the *sum* of claimed task ids; the grand total
    /// must equal the fill total exactly (no loss, no duplication).
    fn contention_kernel(
        layout: &DequeLayout,
        flavor: SyncFlavor,
        out: Addr,
    ) -> crate::kir::Program {
        let mut a = Asm::new();
        let qbase = a.reg();
        let task = a.reg();
        let t0 = a.reg();
        let t1 = a.reg();
        let t2 = a.reg();
        let wg = a.reg();
        let sum = a.reg();
        let addr = a.reg();

        a.wg_id(wg);
        a.imm(qbase, layout.next_addr(0));
        a.imm(sum, 0);
        let regs = DequeRegs { qbase, task, t0, t1, t2 };

        a.bnz(wg, "thief");
        // wg0: owner drains.
        a.label("own_loop");
        emit_owner_pop(&mut a, &regs, flavor, "o");
        a.eq(t0, task, Src::I(EMPTY));
        a.bnz(t0, "end");
        a.add(sum, sum, Src::R(task));
        a.br("own_loop");

        a.label("thief");
        emit_steal(&mut a, &regs, flavor, "s");
        a.eq(t0, task, Src::I(EMPTY));
        a.bnz(t0, "end");
        a.add(sum, sum, Src::R(task));
        a.br("thief");

        a.label("end");
        a.shl(addr, wg, Src::I(3));
        a.add(addr, addr, Src::I(out));
        a.st(addr, 0, sum, 8);
        a.halt();
        a.finish()
    }

    #[test]
    fn owner_and_thieves_claim_each_task_exactly_once() {
        for scenario in [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP] {
            let mut alloc = MemAlloc::new();
            let layout = DequeLayout::alloc(&mut alloc, 1, 64);
            let out = alloc.alloc(4 * 8);
            let mut dev = Device::new(DeviceConfig::small(), scenario.protocol());
            let tasks: Vec<u32> = (1..=40).collect();
            let total: u64 = tasks.iter().map(|&t| t as u64).sum();
            layout.fill(&mut dev.mem.backing, 0, &tasks);
            let prog = contention_kernel(&layout, SyncFlavor::of(scenario), out);
            dev.launch_simple(&prog, 4);
            let grand: u64 = (0..4).map(|w| dev.mem.backing.read_u64(out + w * 8)).sum();
            assert_eq!(grand, total, "{scenario:?}: tasks lost or duplicated");
            assert_eq!(layout.remaining(&dev.mem.backing, 0), 0);
        }
    }

    #[test]
    fn steals_actually_happen_under_rsp() {
        let mut alloc = MemAlloc::new();
        let layout = DequeLayout::alloc(&mut alloc, 1, 64);
        let out = alloc.alloc(4 * 8);
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        layout.fill(&mut dev.mem.backing, 0, &(1..=40).collect::<Vec<_>>());
        let prog = contention_kernel(&layout, SyncFlavor::of(Scenario::SRSP), out);
        dev.launch_simple(&prog, 4);
        assert!(
            dev.mem.stats.remote_acqrels > 0,
            "thieves must claim with rem_ar"
        );
        // At least one task went to a thief.
        let thief_sum: u64 = (1..4).map(|w| dev.mem.backing.read_u64(out + w * 8)).sum();
        assert!(thief_sum > 0, "no task was stolen");
    }

    #[test]
    fn layout_line_isolated() {
        let mut alloc = MemAlloc::new();
        let layout = DequeLayout::alloc(&mut alloc, 3, 16);
        assert_eq!(layout.next_addr(0) % LINE, 0);
        assert_eq!(layout.count_addr(0) - layout.next_addr(0), 64);
        assert!(layout.next_addr(1) >= layout.tasks_addr(0) + 16 * 4);
    }
}
