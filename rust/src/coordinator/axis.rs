//! The pluggable sweep-axis registry: every parameter-sweep dimension
//! registers a [`SweepAxis`] implementation in [`AXES`] and
//! self-describes — name, aliases, summary, value domain, default grid
//! points — plus the one hook that matters: how a grid point
//! **specializes a cell** before execution (override the device CU
//! count, set a workload parameter, set a protocol parameter). The CLI
//! (`srsp list-axes`, `sweep --axis a1,a2`, `--points axis=v1,v2`), the
//! [`SweepPlan`](crate::coordinator::SweepPlan) cross-product and the
//! generic [`run_sweep`](crate::harness::runner::Runner::run_sweep) all
//! resolve axes through this one table; no sweep-specific code path
//! exists per axis.
//!
//! This completes the registry trilogy: workloads
//! ([`Kernel`](crate::workload::registry::Kernel)), protocols
//! ([`SyncProtocol`](crate::sync::protocol::SyncProtocol)), and now
//! sweep axes. Adding an axis is a registry entry: implement
//! [`SweepAxis`] below (see [`HotSetAxis`] for the smallest example)
//! and push it into [`AXES`]. Nothing in the coordinator, runner,
//! report or CLI layers needs to change.

use std::fmt;

/// How one grid point specializes the cell it lands on, accumulated by
/// applying every axis of a [`SweepPlan`](crate::coordinator::SweepPlan)
/// combo in order. The runner consumes this verbatim: it never knows
/// *which* axes produced the spec.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CellSpec {
    /// Device CU-count override (`None` = the runner's configured size).
    pub num_cus: Option<u32>,
    /// Workload-parameter overrides, appended after the user's `--param`
    /// list (an axis owns its key, so it wins).
    pub params: Vec<(String, f64)>,
    /// Protocol-parameter overrides, appended after the user's
    /// `--proto-param` list (same precedence rule).
    pub proto_params: Vec<(String, f64)>,
}

/// A registered sweep axis. Implementations self-describe everything the
/// plan, CLI and report layers need; grid points are `f64` (integer-
/// valued axes range-check in [`SweepAxis::check_point`] and render
/// without a fraction via `f64`'s `Display`).
pub trait SweepAxis: Sync {
    /// Canonical CLI name (`--axis <name>`), lower-case, kebab-case.
    fn name(&self) -> &'static str;
    /// Extra accepted CLI spellings.
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }
    /// One-line description for `srsp list-axes`.
    fn summary(&self) -> &'static str;
    /// Human description of the value domain for `list-axes` and errors.
    fn domain(&self) -> &'static str;
    /// The default grid points a plan uses when `--points` is absent.
    fn default_points(&self) -> &'static [f64];
    /// Range/type-check one grid point.
    fn check_point(&self, v: f64) -> Result<(), String>;
    /// The workload parameter this axis drives, when it drives one; a
    /// plan refuses a swept workload whose kernel does not declare it.
    fn required_param(&self) -> Option<&'static str> {
        None
    }
    /// Specialize one grid cell for point `v`.
    fn apply(&self, v: f64, spec: &mut CellSpec);
}

/// Check that `v` is a non-negative whole number no larger than `u32`
/// holds (the shared domain of the count-valued axes).
fn check_count(v: f64, at_least: f64) -> Result<(), String> {
    if !v.is_finite() || v.fract() != 0.0 || v < at_least || v > f64::from(u32::MAX) {
        return Err(format!("expected a whole number >= {at_least}, got {v}"));
    }
    Ok(())
}

/// The remote-access-ratio axis (`r` of the stress family): the fraction
/// of tasks routed into the hot set and claimed through the promotion
/// machinery — the contention-asymmetry dial the paper's argument turns
/// on.
pub struct RemoteRatioAxis;

impl SweepAxis for RemoteRatioAxis {
    fn name(&self) -> &'static str {
        "remote-ratio"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["remote_ratio", "ratio", "r"]
    }

    fn summary(&self) -> &'static str {
        "fraction of tasks claimed through remote-scope promotion"
    }

    fn domain(&self) -> &'static str {
        "ratio in [0, 1]"
    }

    fn default_points(&self) -> &'static [f64] {
        &[0.0, 0.05, 0.1, 0.2, 0.4, 0.8]
    }

    fn check_point(&self, v: f64) -> Result<(), String> {
        if !(0.0..=1.0).contains(&v) {
            return Err(format!("{v} is outside [0, 1]"));
        }
        Ok(())
    }

    fn required_param(&self) -> Option<&'static str> {
        Some("remote_ratio")
    }

    fn apply(&self, v: f64, spec: &mut CellSpec) {
        spec.params.push(("remote_ratio".to_string(), v));
    }
}

/// The device-size axis: the paper evaluates at 64 CUs; sweeping the
/// count plots the Fig. 4 crossover against scale instead.
pub struct CuCountAxis;

impl SweepAxis for CuCountAxis {
    fn name(&self) -> &'static str {
        "cu-count"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["cu_count", "cu"]
    }

    fn summary(&self) -> &'static str {
        "device size in Compute Units"
    }

    fn domain(&self) -> &'static str {
        "whole number >= 1"
    }

    fn default_points(&self) -> &'static [f64] {
        &[4.0, 8.0, 16.0, 32.0, 64.0]
    }

    fn check_point(&self, v: f64) -> Result<(), String> {
        check_count(v, 1.0)
    }

    fn apply(&self, v: f64, spec: &mut CellSpec) {
        spec.num_cus = Some(v as u32);
    }
}

/// The hot-set-size axis: how many queues absorb the remote tasks
/// (1 = maximum contention on a single local sharer).
pub struct HotSetAxis;

impl SweepAxis for HotSetAxis {
    fn name(&self) -> &'static str {
        "hot-set"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["hot_set", "hot"]
    }

    fn summary(&self) -> &'static str {
        "queues absorbing the remote tasks (contention width)"
    }

    fn domain(&self) -> &'static str {
        "whole number >= 1"
    }

    fn default_points(&self) -> &'static [f64] {
        &[1.0, 2.0, 4.0, 8.0]
    }

    fn check_point(&self, v: f64) -> Result<(), String> {
        check_count(v, 1.0)
    }

    fn required_param(&self) -> Option<&'static str> {
        Some("hot_set")
    }

    fn apply(&self, v: f64, spec: &mut CellSpec) {
        spec.params.push(("hot_set".to_string(), v));
    }
}

/// The hot-set-migration axis: rotate the hot set every N rounds
/// (0 = never), forcing LR-TBL/PA-TBL turnover as the local sharer's L1
/// changes identity.
pub struct MigrationAxis;

impl SweepAxis for MigrationAxis {
    fn name(&self) -> &'static str {
        "migration"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["migrate"]
    }

    fn summary(&self) -> &'static str {
        "rotate the hot set every N rounds (0 = never)"
    }

    fn domain(&self) -> &'static str {
        "whole number >= 0"
    }

    fn default_points(&self) -> &'static [f64] {
        &[0.0, 1.0, 2.0, 4.0]
    }

    fn check_point(&self, v: f64) -> Result<(), String> {
        check_count(v, 0.0)
    }

    fn required_param(&self) -> Option<&'static str> {
        Some("migration")
    }

    fn apply(&self, v: f64, spec: &mut CellSpec) {
        spec.params.push(("migration".to_string(), v));
    }
}

/// The LR-TBL capacity axis: the first **protocol-parameter** axis —
/// `apply` drives [`CellSpec::proto_params`] instead of a workload
/// parameter or the device size. Sweeping the table through undersized
/// capacities (0 disables it: every selective-flush request degenerates
/// to a conservative full flush) reproduces the Fig. 5-style
/// table-pressure study: sRSP's selectivity, and therefore its L2-
/// traffic edge, collapses as overflows force eager behavior.
pub struct LrTblEntriesAxis;

impl SweepAxis for LrTblEntriesAxis {
    fn name(&self) -> &'static str {
        "lr-tbl-entries"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["lr_tbl_entries", "lr-tbl"]
    }

    fn summary(&self) -> &'static str {
        "LR-TBL capacity in entries (0 disables selective tracking)"
    }

    fn domain(&self) -> &'static str {
        "whole number >= 0"
    }

    fn default_points(&self) -> &'static [f64] {
        &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0]
    }

    fn check_point(&self, v: f64) -> Result<(), String> {
        check_count(v, 0.0)
    }

    fn apply(&self, v: f64, spec: &mut CellSpec) {
        spec.proto_params.push(("lr_tbl_entries".to_string(), v));
    }
}

/// The static axis table. Order is load-bearing for the stable [`AxisId`]
/// constants below: new axes append, existing ones never reorder.
pub static AXES: &[&dyn SweepAxis] = &[
    &RemoteRatioAxis,
    &CuCountAxis,
    &HotSetAxis,
    &MigrationAxis,
    &LrTblEntriesAxis,
];

/// Stable handle to a registered sweep axis (index into [`AXES`]),
/// mirroring [`WorkloadId`](crate::workload::registry::WorkloadId) and
/// [`Protocol`](crate::sync::protocol::Protocol).
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AxisId(usize);

/// The protocol × r crossover axis of the stress family.
pub const REMOTE_RATIO: AxisId = AxisId(0);
/// The protocol × device-size crossover axis.
pub const CU_COUNT: AxisId = AxisId(1);
/// The contention-width axis (registry-only entry).
pub const HOT_SET: AxisId = AxisId(2);
/// The hot-set-rotation axis (registry-only entry).
pub const MIGRATION: AxisId = AxisId(3);
/// The LR-TBL table-pressure axis (first proto-param axis).
pub const LR_TBL_ENTRIES: AxisId = AxisId(4);

impl AxisId {
    /// The registered implementation behind this handle.
    pub fn axis(self) -> &'static dyn SweepAxis {
        AXES[self.0]
    }

    pub fn name(self) -> &'static str {
        self.axis().name()
    }
}

impl fmt::Debug for AxisId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl fmt::Display for AxisId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Every registered axis, in registry order.
pub fn all() -> impl Iterator<Item = AxisId> {
    (0..AXES.len()).map(AxisId)
}

/// Resolve a CLI name (canonical or alias, case-insensitive).
pub fn resolve(name: &str) -> Option<AxisId> {
    let lower = name.to_ascii_lowercase();
    all().find(|id| {
        let a = id.axis();
        a.name() == lower || a.aliases().contains(&lower.as_str())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn registry_names_unique_and_resolvable() {
        let mut seen = BTreeSet::new();
        for id in all() {
            let a = id.axis();
            assert!(seen.insert(a.name()), "duplicate name {}", a.name());
            assert_eq!(resolve(a.name()), Some(id));
            assert_eq!(resolve(&a.name().to_uppercase()), Some(id));
            for alias in a.aliases() {
                assert_eq!(resolve(alias), Some(id), "alias {alias}");
            }
        }
        assert_eq!(resolve("bogus"), None);
        // "cus" stays the classic scaling sweep's CLI keyword; no axis
        // may claim it or `--axis cus` becomes ambiguous.
        assert_eq!(resolve("cus"), None);
    }

    #[test]
    fn classic_handles_stable() {
        assert_eq!(REMOTE_RATIO.name(), "remote-ratio");
        assert_eq!(CU_COUNT.name(), "cu-count");
        assert_eq!(HOT_SET.name(), "hot-set");
        assert_eq!(MIGRATION.name(), "migration");
        assert_eq!(LR_TBL_ENTRIES.name(), "lr-tbl-entries");
        assert_eq!(all().count(), 5);
    }

    #[test]
    fn default_points_pass_their_own_checks() {
        for id in all() {
            let a = id.axis();
            assert!(!a.default_points().is_empty(), "{}", a.name());
            for &v in a.default_points() {
                a.check_point(v)
                    .unwrap_or_else(|e| panic!("{} default {v}: {e}", a.name()));
            }
        }
    }

    #[test]
    fn point_checks_reject_out_of_domain_values() {
        assert!(REMOTE_RATIO.axis().check_point(1.5).is_err());
        assert!(REMOTE_RATIO.axis().check_point(-0.1).is_err());
        assert!(REMOTE_RATIO.axis().check_point(1.0).is_ok());
        assert!(CU_COUNT.axis().check_point(0.0).is_err());
        assert!(CU_COUNT.axis().check_point(2.5).is_err());
        assert!(CU_COUNT.axis().check_point(f64::NAN).is_err());
        assert!(CU_COUNT.axis().check_point(8.0).is_ok());
        assert!(HOT_SET.axis().check_point(0.0).is_err());
        assert!(MIGRATION.axis().check_point(0.0).is_ok());
        assert!(LR_TBL_ENTRIES.axis().check_point(0.0).is_ok());
        assert!(LR_TBL_ENTRIES.axis().check_point(2.5).is_err());
        assert!(LR_TBL_ENTRIES.axis().check_point(-1.0).is_err());
    }

    #[test]
    fn apply_specializes_the_expected_cell_field() {
        let mut spec = CellSpec::default();
        REMOTE_RATIO.axis().apply(0.4, &mut spec);
        CU_COUNT.axis().apply(8.0, &mut spec);
        HOT_SET.axis().apply(1.0, &mut spec);
        MIGRATION.axis().apply(2.0, &mut spec);
        LR_TBL_ENTRIES.axis().apply(4.0, &mut spec);
        assert_eq!(spec.num_cus, Some(8));
        assert_eq!(
            spec.params,
            vec![
                ("remote_ratio".to_string(), 0.4),
                ("hot_set".to_string(), 1.0),
                ("migration".to_string(), 2.0),
            ]
        );
        // The proto-param axis drives the protocol override channel, not
        // the workload one.
        assert_eq!(spec.proto_params, vec![("lr_tbl_entries".to_string(), 4.0)]);
    }

    #[test]
    fn param_axes_declare_their_workload_key() {
        assert_eq!(REMOTE_RATIO.axis().required_param(), Some("remote_ratio"));
        assert_eq!(HOT_SET.axis().required_param(), Some("hot_set"));
        assert_eq!(MIGRATION.axis().required_param(), Some("migration"));
        assert_eq!(CU_COUNT.axis().required_param(), None);
        // A proto-param axis constrains the protocol, not the workload:
        // any swept app is acceptable.
        assert_eq!(LR_TBL_ENTRIES.axis().required_param(), None);
    }
}
