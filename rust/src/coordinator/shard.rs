//! Stage 2 of the distributed pipeline: partition an [`ExecutionPlan`]
//! into deterministic, self-contained [`ShardSpec`]s.
//!
//! A shard is the unit of *subprocess* executor placement: `sweep
//! --workers N` writes each shard to a file and spawns `srsp worker
//! --shard <file>` on it. (In-process `--jobs N` instead feeds one
//! all-cells shard through a shared work-stealing queue — see
//! `harness::runner::execute_plan`.)
//! Partitioning deals cells out **boustrophedon** (rows of N cells,
//! alternating left-to-right and right-to-left): adjacent grid cells —
//! the scenarios of one sweep combo, or one app's cells of a coverage
//! grid — land on different shards, which spreads the expensive
//! large-CU cells across executors without any dynamic queue, and the
//! alternation keeps a shard from locking onto one scenario when N
//! divides the per-combo scenario count (plain `i mod N` striping would
//! hand one shard every sRSP cell at `--jobs 3`). The assignment is a
//! pure function of `(plan, N)`, so the same plan and worker count
//! always produce identical shards — the report-level determinism gate
//! (`--workers 2` byte-identical to `--jobs 4`) builds on this.
//!
//! Each [`ShardSpec`] embeds the full execution context (device config,
//! scale, validation mode) plus its cells tagged with their **global
//! grid index**; the merge stage reassembles rows by that index, so
//! executors never need to agree on anything but the plan file.

use crate::config::DeviceConfig;
use crate::jsonio::{self, Json};
use crate::workload::registry::WorkloadSize;

use super::{size_from_name, size_to_name, ExecutionPlan, PlannedCell, PLAN_VERSION};

/// One executor's slice of an [`ExecutionPlan`] — self-contained, JSON-
/// serializable, deterministic for a given `(plan, num_shards)`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardSpec {
    /// This shard's index in `0..num_shards`.
    pub shard: usize,
    /// How many shards the plan was partitioned into.
    pub num_shards: usize,
    /// Cell count of the whole plan (the merge stage's completeness
    /// denominator).
    pub total_cells: usize,
    /// Device template; `num_cus` is overridden per cell.
    pub cfg: DeviceConfig,
    pub size: WorkloadSize,
    pub validate: bool,
    /// Result-cache directory the coordinator runs against, when any —
    /// a `--workers` child opens the same store so the whole fleet
    /// shares one cache (`--cache`/`--no-cache` on the worker override).
    pub cache_dir: Option<String>,
    /// `(global grid index, cell)` pairs, ascending by index.
    pub cells: Vec<(usize, PlannedCell)>,
}

/// Partition `plan` into `num_shards` boustrophedon-dealt shards
/// (clamped to `1..=cell count`, so a 2-cell plan asked for 8 shards
/// yields 2). Cell `i` sits at column `i mod N` of row `i / N`; even
/// rows deal columns forward, odd rows backward.
pub fn partition(plan: &ExecutionPlan, num_shards: usize) -> Vec<ShardSpec> {
    let n = num_shards.clamp(1, plan.cells.len().max(1));
    let mut shards: Vec<ShardSpec> = (0..n)
        .map(|i| ShardSpec {
            shard: i,
            num_shards: n,
            total_cells: plan.cells.len(),
            cfg: plan.cfg.clone(),
            size: plan.size,
            validate: plan.validate,
            cache_dir: None,
            cells: Vec::with_capacity(plan.cells.len().div_ceil(n)),
        })
        .collect();
    for (i, cell) in plan.cells.iter().enumerate() {
        let (row, col) = (i / n, i % n);
        let shard = if row % 2 == 0 { col } else { n - 1 - col };
        shards[shard].cells.push((i, cell.clone()));
    }
    shards
}

impl ShardSpec {
    /// Serialize to the `srsp worker --shard <file>` format.
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("plan_version".into(), Json::u32(PLAN_VERSION)),
            ("shard".into(), Json::usize(self.shard)),
            ("num_shards".into(), Json::usize(self.num_shards)),
            ("total_cells".into(), Json::usize(self.total_cells)),
            ("device".into(), self.cfg.to_json()),
            ("size".into(), Json::str(size_to_name(self.size))),
            ("validate".into(), Json::Bool(self.validate)),
            (
                "cache_dir".into(),
                match &self.cache_dir {
                    Some(d) => Json::str(d.clone()),
                    None => Json::Null,
                },
            ),
            (
                "cells".into(),
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|(i, c)| {
                            Json::Obj(vec![
                                ("index".into(), Json::usize(*i)),
                                ("cell".into(), c.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .render()
    }

    /// Parse a shard file; loud on malformation, version drift, or
    /// indices outside the declared plan shape.
    pub fn from_json(text: &str) -> Result<ShardSpec, String> {
        let v = jsonio::parse(text)?;
        let version = v.get("plan_version")?.as_u32()?;
        if version != PLAN_VERSION {
            return Err(format!(
                "shard file is version {version}, this binary speaks {PLAN_VERSION}"
            ));
        }
        let shard = v.get("shard")?.as_usize()?;
        let num_shards = v.get("num_shards")?.as_usize()?;
        let total_cells = v.get("total_cells")?.as_usize()?;
        if num_shards == 0 || shard >= num_shards {
            return Err(format!(
                "shard index {shard} is outside the declared {num_shards} shard(s)"
            ));
        }
        let mut cells = Vec::new();
        for (i, entry) in v.get("cells")?.arr()?.iter().enumerate() {
            let index = entry
                .get("index")
                .and_then(|x| x.as_usize())
                .map_err(|e| format!("cell {i}: {e}"))?;
            if index >= total_cells {
                return Err(format!(
                    "cell {i}: grid index {index} is outside the declared {total_cells} cell(s)"
                ));
            }
            let cell =
                PlannedCell::from_json(entry.get("cell")?).map_err(|e| format!("cell {i}: {e}"))?;
            cells.push((index, cell));
        }
        Ok(ShardSpec {
            shard,
            num_shards,
            total_cells,
            cfg: DeviceConfig::from_json(v.get("device")?)?,
            size: size_from_name(v.get("size")?.as_str()?)?,
            validate: v.get("validate")?.as_bool()?,
            cache_dir: match v.get("cache_dir")? {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            },
            cells,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{axis, ExecutionPlan, Runner, Seeding, SweepPlan};
    use crate::workload::registry;

    fn tiny_plan() -> ExecutionPlan {
        let runner = Runner {
            seeding: Seeding::PerCell(3),
            validate: true,
            ..Runner::new(
                DeviceConfig {
                    num_cus: 4,
                    ..DeviceConfig::small()
                },
                WorkloadSize::Tiny,
                1,
            )
        };
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap();
        ExecutionPlan::lower_sweep(&runner, &plan)
    }

    #[test]
    fn partition_is_deterministic_striped_and_complete() {
        let plan = tiny_plan(); // 6 cells
        assert_eq!(plan.cells.len(), 6);
        let shards = partition(&plan, 4);
        assert_eq!(shards, partition(&plan, 4), "same plan + count → same shards");
        assert_eq!(shards.len(), 4);
        // Boustrophedon: even rows deal forward, odd rows backward.
        for s in &shards {
            assert_eq!(s.num_shards, 4);
            assert_eq!(s.total_cells, 6);
            for (i, _) in &s.cells {
                let (row, col) = (i / 4, i % 4);
                let want = if row % 2 == 0 { col } else { 3 - col };
                assert_eq!(want, s.shard, "cell {i}");
            }
        }
        // The alternation breaks scenario/shard alignment: with 3 shards
        // and 3 scenarios per combo, plain striping would pin each shard
        // to one scenario; here shard 0 sees both ends of the row.
        let three = partition(&plan, 3);
        let scenarios: Vec<_> = three[0].cells.iter().map(|(_, c)| c.cell.scenario).collect();
        assert_eq!(three[0].cells.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 5]);
        assert_ne!(scenarios[0], scenarios[1], "shard must mix scenarios");
        // Complete and disjoint.
        let mut seen: Vec<usize> = shards
            .iter()
            .flat_map(|s| s.cells.iter().map(|(i, _)| *i))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<_>>());
        // Shard counts clamp to the cell count; one shard carries all.
        assert_eq!(partition(&plan, 99).len(), 6);
        let single = partition(&plan, 1);
        assert_eq!(single.len(), 1);
        assert_eq!(single[0].cells.len(), 6);
        assert_eq!(partition(&plan, 0).len(), 1, "0 treated as 1");
    }

    #[test]
    fn shard_spec_json_round_trips() {
        let plan = tiny_plan();
        for spec in partition(&plan, 3) {
            let text = spec.to_json();
            assert_eq!(ShardSpec::from_json(&text).unwrap(), spec);
        }
    }

    #[test]
    fn shard_files_reject_drift() {
        let plan = tiny_plan();
        let spec = &partition(&plan, 2)[1];
        let text = spec.to_json();
        let wrong = text.replacen("\"plan_version\":2", "\"plan_version\":0", 1);
        assert!(ShardSpec::from_json(&wrong).unwrap_err().contains("version"));
        let wrong = text.replacen("\"shard\":1", "\"shard\":5", 1);
        assert!(ShardSpec::from_json(&wrong)
            .unwrap_err()
            .contains("outside the declared"));
        let wrong = text.replacen("\"total_cells\":6", "\"total_cells\":1", 1);
        assert!(ShardSpec::from_json(&wrong)
            .unwrap_err()
            .contains("outside the declared"));
        assert!(ShardSpec::from_json("{}").is_err());
    }
}
