//! Layer-3 coordination: the **distribution policy** of the evaluation
//! grids.
//!
//! The paper's evaluation is a protocol × app × CU-count grid (plus the
//! stress kernel's protocol × remote-ratio axis). This module owns
//! everything about *which* cells exist and in *what order*, and how
//! workload seeds derive per cell — the policy half of the split. The
//! execution half (OS-thread sharding, oracle validation, result
//! reassembly) lives in [`crate::harness::runner`] and consumes these
//! cells; every grid cell is an isolated single-threaded simulation, so
//! the two halves meet only at the `Cell` type.

use crate::config::Scenario;
use crate::sim::SplitMix64;
use crate::sync::protocol;
use crate::workload::registry::{self, WorkloadId, DEFAULT_SEED};

// Execution-side types, re-exported under the coordination name the CLI
// and future distributed backends build on.
pub use crate::harness::runner::{into_run_results, run_validated, CellResult, Runner};

/// One cell of the protocol × app × CU-count grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    pub app: WorkloadId,
    pub scenario: Scenario,
    pub num_cus: u32,
}

/// How workload-generation seeds are assigned to grid cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Seeding {
    /// Every cell uses the same seed — the classic figure presets
    /// (`DEFAULT_SEED` reproduces the paper figures byte-for-byte).
    Shared(u64),
    /// Each (app, CU-count) pair derives its own seed from a base value
    /// via [`SplitMix64`]; scenarios still share the graph (ratios need
    /// shared inputs).
    PerCell(u64),
}

impl Default for Seeding {
    fn default() -> Self {
        Seeding::Shared(DEFAULT_SEED)
    }
}

impl Seeding {
    /// The workload seed for `cell`. Derivation uses the workload's
    /// stable registry ordinal and deliberately ignores the scenario:
    /// all scenarios of one app at one CU count must share an input or
    /// vs-Baseline ratios would compare different problems.
    pub fn seed_for(self, cell: &Cell) -> u64 {
        match self {
            Seeding::Shared(seed) => seed,
            Seeding::PerCell(base) => {
                let tag = ((cell.app.ord() + 1) << 32) | u64::from(cell.num_cus);
                SplitMix64::new(base ^ tag).next_u64()
            }
        }
    }
}

/// The three Pannotia apps of the paper's §5.1 figures, in figure order.
pub fn classic_apps() -> [WorkloadId; 3] {
    [registry::PRK, registry::SSSP, registry::MIS]
}

/// The classic §5.1 figure grid (three apps × five scenarios) at one CU
/// count, in stable app-major order.
pub fn classic_grid(num_cus: u32) -> Vec<Cell> {
    grid(&classic_apps(), num_cus)
}

/// The scenarios the coverage grids (`validate`, `ci-smoke`) run: the
/// paper's five plus the canonical scenario of every further registered
/// protocol (hlrc, srsp-adaptive, ...), resolved through the protocol
/// registry — a protocol added there is covered here with no change.
pub fn coverage_scenarios() -> Vec<Scenario> {
    let mut scenarios: Vec<Scenario> = Scenario::ALL.to_vec();
    for p in protocol::all() {
        let s = Scenario::for_protocol(p);
        if !scenarios.contains(&s) {
            scenarios.push(s);
        }
    }
    scenarios
}

/// Every registered workload × every coverage scenario at one CU count,
/// in stable registry-major order (the `validate`/`ci-smoke` grid).
pub fn full_grid(num_cus: u32) -> Vec<Cell> {
    let apps: Vec<WorkloadId> = registry::all().collect();
    grid_over(&apps, &coverage_scenarios(), num_cus)
}

/// App-major grid over an explicit app list (the paper's five scenarios).
pub fn grid(apps: &[WorkloadId], num_cus: u32) -> Vec<Cell> {
    grid_over(apps, &Scenario::ALL, num_cus)
}

/// The shared app-major cell constructor behind every coverage grid.
fn grid_over(apps: &[WorkloadId], scenarios: &[Scenario], num_cus: u32) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(apps.len() * scenarios.len());
    for &app in apps {
        for &scenario in scenarios {
            cells.push(Cell {
                app,
                scenario,
                num_cus,
            });
        }
    }
    cells
}

/// The flattened cell list for a CU-count scaling sweep (classic apps).
pub fn scaling_cells(cus: &[u32]) -> Vec<Cell> {
    cus.iter().flat_map(|&n| classic_grid(n)).collect()
}

/// The three scenarios whose protocols the remote-ratio sweep compares:
/// global-scope stealing (ScopedOnly), naive promotion (RspNaive) and
/// selective promotion (Srsp).
pub const RATIO_SCENARIOS: [Scenario; 3] = [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP];

/// The default remote-ratio sample points of the sweep axis.
pub const RATIO_POINTS: [f64; 6] = [0.0, 0.05, 0.1, 0.2, 0.4, 0.8];

/// The protocol × remote-ratio grid, ratio-major (all protocols of one
/// `r` adjacent, mirroring the report's row grouping).
pub fn remote_ratio_grid(points: &[f64]) -> Vec<(Scenario, f64)> {
    let mut cells = Vec::with_capacity(points.len() * RATIO_SCENARIOS.len());
    for &r in points {
        for s in RATIO_SCENARIOS {
            cells.push((s, r));
        }
    }
    cells
}

/// The default CU-count sample points of the `cu-count` sweep axis (the
/// paper evaluates at 64; the crossover is plotted against the rest).
pub const CU_POINTS: [u32; 5] = [4, 8, 16, 32, 64];

/// The protocol × CU-count grid, CU-major (all protocols of one device
/// size adjacent), mirroring [`remote_ratio_grid`] on the scaling axis —
/// the Fig. 4 crossover plotted against CU count.
pub fn cu_count_grid(points: &[u32]) -> Vec<(Scenario, u32)> {
    let mut cells = Vec::with_capacity(points.len() * RATIO_SCENARIOS.len());
    for &n in points {
        for s in RATIO_SCENARIOS {
            cells.push((s, n));
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_grid_covers_every_pair() {
        let g = classic_grid(8);
        assert_eq!(g.len(), 3 * Scenario::ALL.len());
        for app in classic_apps() {
            for scenario in Scenario::ALL {
                assert!(g.iter().any(|c| c.app == app && c.scenario == scenario));
            }
        }
        assert!(g.iter().all(|c| c.num_cus == 8));
    }

    #[test]
    fn full_grid_covers_every_registered_workload_and_protocol() {
        let g = full_grid(4);
        assert_eq!(g.len(), registry::all().count() * coverage_scenarios().len());
        for id in registry::all() {
            assert!(g.iter().any(|c| c.app == id));
        }
        // Every registered protocol's canonical scenario is covered.
        for p in protocol::all() {
            let s = Scenario::for_protocol(p);
            assert!(g.iter().any(|c| c.scenario == s), "{}", p.name());
        }
    }

    #[test]
    fn coverage_scenarios_extend_the_paper_five() {
        let cov = coverage_scenarios();
        assert_eq!(&cov[..5], &Scenario::ALL);
        assert!(cov.contains(&Scenario::HLRC));
        assert!(cov.contains(&Scenario::SRSP_ADAPTIVE));
        // No duplicates.
        for (i, a) in cov.iter().enumerate() {
            assert!(!cov[i + 1..].contains(a), "{a:?} appears twice");
        }
    }

    #[test]
    fn cu_count_grid_is_cu_major() {
        let g = cu_count_grid(&[8, 64]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], (Scenario::STEAL_ONLY, 8));
        assert_eq!(g[2], (Scenario::SRSP, 8));
        assert_eq!(g[3], (Scenario::STEAL_ONLY, 64));
    }

    #[test]
    fn per_cell_seeds_share_graphs_across_scenarios() {
        let cell = |app, scenario, num_cus| Cell {
            app,
            scenario,
            num_cus,
        };
        let s = Seeding::PerCell(42);
        let base = s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4));
        // Deterministic.
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4)));
        // Scenario must NOT change the seed (ratios need shared inputs).
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::SRSP, 4)));
        // App and CU count must.
        assert_ne!(base, s.seed_for(&cell(registry::SSSP, Scenario::BASELINE, 4)));
        assert_ne!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 8)));
        // A different base diverges; shared seeding ignores the cell.
        let other_base = Seeding::PerCell(43);
        assert_ne!(
            base,
            other_base.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4))
        );
        let shared = Seeding::Shared(7);
        assert_eq!(7, shared.seed_for(&cell(registry::MIS, Scenario::RSP, 64)));
    }

    #[test]
    fn remote_ratio_grid_is_ratio_major() {
        let g = remote_ratio_grid(&[0.0, 0.5]);
        assert_eq!(g.len(), 6);
        assert_eq!(g[0], (Scenario::STEAL_ONLY, 0.0));
        assert_eq!(g[2], (Scenario::SRSP, 0.0));
        assert_eq!(g[3], (Scenario::STEAL_ONLY, 0.5));
    }
}
