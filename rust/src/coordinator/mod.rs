//! Layer-3 coordination: the **distribution policy** of the evaluation
//! grids.
//!
//! The paper's evaluation is a protocol × app × CU-count grid plus a
//! family of parameter sweeps (remote ratio, device size, hot-set width,
//! migration period — see [`axis`]). This module owns everything about
//! *which* cells exist and in *what order*, and how workload seeds derive
//! per cell — the policy half of the split. The execution half
//! (shard execution, oracle validation, result reassembly) lives in
//! [`crate::harness::runner`] and consumes these cells; every grid cell
//! is an isolated single-threaded simulation, so the two halves meet
//! only at the `Cell` and [`SweepPlan`] types.
//!
//! Evaluation runs flow through an explicit four-stage pipeline with
//! serializable boundaries:
//!
//! 1. **plan** — this module lowers a [`SweepPlan`] or plain cell list
//!    into a self-contained [`ExecutionPlan`] (seeds derived, parameter
//!    overrides folded in, no borrowed state);
//! 2. **shard** — [`shard::partition`] splits it into deterministic
//!    [`ShardSpec`](shard::ShardSpec)s;
//! 3. **execute** — each shard runs in-process (`--jobs`, one thread per
//!    shard) or as an `srsp worker --shard <file>` subprocess
//!    (`--workers`) emitting a
//!    [`PartialReport`](crate::harness::report::PartialReport);
//! 4. **merge** — [`Report::merge`](crate::harness::report::Report::merge)
//!    reassembles partial reports in grid order, byte-identical to the
//!    single-process run for any worker count.

pub mod axis;
pub mod cache;
pub mod serve;
pub mod shard;
pub mod wire;

use crate::config::{DeviceConfig, Scenario};
use crate::jsonio::{self, Json};
use crate::sim::SplitMix64;
use crate::sync::protocol;
use crate::workload::registry::{self, WorkloadId, WorkloadSize, DEFAULT_SEED};

use axis::{AxisId, CellSpec};

// Execution-side types, re-exported under the coordination name the CLI
// and future distributed backends build on.
pub use crate::harness::runner::{into_run_results, run_validated, CellResult, Runner};

/// One cell of the protocol × app × CU-count grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    pub app: WorkloadId,
    pub scenario: Scenario,
    pub num_cus: u32,
}

/// How workload-generation seeds are assigned to grid cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Seeding {
    /// Every cell uses the same seed — the classic figure presets
    /// (`DEFAULT_SEED` reproduces the paper figures byte-for-byte).
    Shared(u64),
    /// Each (app, CU-count) pair derives its own seed from a base value
    /// via [`SplitMix64`]; scenarios still share the graph (ratios need
    /// shared inputs).
    PerCell(u64),
}

impl Default for Seeding {
    fn default() -> Self {
        Seeding::Shared(DEFAULT_SEED)
    }
}

impl Seeding {
    /// The workload seed for `cell`. Derivation uses the workload's
    /// stable registry ordinal and deliberately ignores the scenario:
    /// all scenarios of one app at one CU count must share an input or
    /// vs-Baseline ratios would compare different problems.
    pub fn seed_for(self, cell: &Cell) -> u64 {
        match self {
            Seeding::Shared(seed) => seed,
            Seeding::PerCell(base) => {
                let tag = ((cell.app.ord() + 1) << 32) | u64::from(cell.num_cus);
                SplitMix64::new(base ^ tag).next_u64()
            }
        }
    }
}

/// The three Pannotia apps of the paper's §5.1 figures, in figure order.
pub fn classic_apps() -> [WorkloadId; 3] {
    [registry::PRK, registry::SSSP, registry::MIS]
}

/// The classic §5.1 figure grid (three apps × five scenarios) at one CU
/// count, in stable app-major order.
pub fn classic_grid(num_cus: u32) -> Vec<Cell> {
    grid(&classic_apps(), num_cus)
}

/// The scenarios the coverage grids (`validate`, `ci-smoke`) run: the
/// paper's five plus the canonical scenario of every further registered
/// protocol (hlrc, srsp-adaptive, ...), resolved through the protocol
/// registry — a protocol added there is covered here with no change.
pub fn coverage_scenarios() -> Vec<Scenario> {
    let mut scenarios: Vec<Scenario> = Scenario::ALL.to_vec();
    for p in protocol::all() {
        let s = Scenario::for_protocol(p);
        if !scenarios.contains(&s) {
            scenarios.push(s);
        }
    }
    scenarios
}

/// Every registered workload × every coverage scenario at one CU count,
/// in stable registry-major order (the `validate`/`ci-smoke` grid).
pub fn full_grid(num_cus: u32) -> Vec<Cell> {
    let apps: Vec<WorkloadId> = registry::all().collect();
    grid_over(&apps, &coverage_scenarios(), num_cus)
}

/// App-major grid over an explicit app list (the paper's five scenarios).
pub fn grid(apps: &[WorkloadId], num_cus: u32) -> Vec<Cell> {
    grid_over(apps, &Scenario::ALL, num_cus)
}

/// The shared app-major cell constructor behind every coverage grid.
fn grid_over(apps: &[WorkloadId], scenarios: &[Scenario], num_cus: u32) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(apps.len() * scenarios.len());
    for &app in apps {
        for &scenario in scenarios {
            cells.push(Cell {
                app,
                scenario,
                num_cus,
            });
        }
    }
    cells
}

/// The flattened cell list for a CU-count scaling sweep (classic apps).
pub fn scaling_cells(cus: &[u32]) -> Vec<Cell> {
    cus.iter().flat_map(|&n| classic_grid(n)).collect()
}

/// The three scenarios whose protocols every parameter sweep compares:
/// global-scope stealing (ScopedOnly), naive promotion (RspNaive) and
/// selective promotion (Srsp).
pub const RATIO_SCENARIOS: [Scenario; 3] = [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP];

/// The most axes one sweep composes (a surface plus one extra slice —
/// beyond that the cross-product grid outgrows a single host even with
/// `--workers`; multi-host transport is the ROADMAP follow-on).
pub const MAX_SWEEP_AXES: usize = 3;

/// A composed parameter sweep: one workload swept over the cross-product
/// grid of 1–[`MAX_SWEEP_AXES`] registered [`axis`] entries, each cell
/// run under every comparison scenario. This is the *policy* object the
/// generic [`Runner::run_sweep`] executes — which axes, which points,
/// which scenarios, in what order — and the only sweep construct in the
/// crate: single-axis sweeps are just one-axis plans.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub app: WorkloadId,
    /// The scenarios every grid combo runs (default [`RATIO_SCENARIOS`]).
    pub scenarios: Vec<Scenario>,
    axes: Vec<AxisId>,
    /// Grid points per axis, parallel to `axes`.
    points: Vec<Vec<f64>>,
}

impl SweepPlan {
    /// A plan over `axes` with each axis's registry default points.
    /// Rejects an empty or oversized axis list, duplicate axes, and a
    /// workload that does not declare a parameter some axis drives.
    pub fn new(app: WorkloadId, axes: &[AxisId]) -> Result<SweepPlan, String> {
        if axes.is_empty() {
            return Err("a sweep needs at least one axis".into());
        }
        if axes.len() > MAX_SWEEP_AXES {
            return Err(format!(
                "a sweep composes at most {MAX_SWEEP_AXES} axes, got {}",
                axes.len()
            ));
        }
        for (i, a) in axes.iter().enumerate() {
            if axes[i + 1..].contains(a) {
                return Err(format!("duplicate sweep axis '{}'", a.name()));
            }
            if let Some(param) = a.axis().required_param() {
                if !app.kernel().params().iter().any(|p| p.key == param) {
                    return Err(format!(
                        "workload '{}' has no {param} parameter (axis {}; try --app stress)",
                        app.name(),
                        a.name()
                    ));
                }
            }
        }
        Ok(SweepPlan {
            app,
            scenarios: RATIO_SCENARIOS.to_vec(),
            axes: axes.to_vec(),
            points: axes
                .iter()
                .map(|a| a.axis().default_points().to_vec())
                .collect(),
        })
    }

    /// Replace one axis's grid points (`--points axis=v1,v2,...`). The
    /// axis must be part of the plan and every point must pass the
    /// axis's own domain check.
    pub fn with_points(mut self, axis: AxisId, points: Vec<f64>) -> Result<SweepPlan, String> {
        let Some(i) = self.axes.iter().position(|a| *a == axis) else {
            let selected: Vec<&str> = self.axes.iter().map(|a| a.name()).collect();
            return Err(format!(
                "--points {} applies to an axis in --axis (selected: {}); the sweep would \
                 ignore it",
                axis.name(),
                selected.join(", ")
            ));
        };
        if points.is_empty() {
            return Err(format!("--points {} needs at least one point", axis.name()));
        }
        for &v in &points {
            axis.axis()
                .check_point(v)
                .map_err(|e| format!("--points {}: {e}", axis.name()))?;
        }
        self.points[i] = points;
        Ok(self)
    }

    /// The composed axes, in grid-nesting order (first = outermost).
    pub fn axes(&self) -> &[AxisId] {
        &self.axes
    }

    /// The grid points of `axis` (panics when the axis is not in the
    /// plan — caller bug, the constructor validated membership).
    pub fn points(&self, axis: AxisId) -> &[f64] {
        let i = self
            .axes
            .iter()
            .position(|a| *a == axis)
            .unwrap_or_else(|| panic!("axis '{}' is not part of this plan", axis.name()));
        &self.points[i]
    }

    /// The cross-product grid, first axis outermost, in stable
    /// coordinate-major order (a one-axis remote-ratio plan reproduces
    /// the historical ratio-major order exactly; cu-count likewise).
    pub fn combos(&self) -> Vec<SweepCombo> {
        let mut combos = vec![SweepCombo::default()];
        for (axis, points) in self.axes.iter().zip(&self.points) {
            let mut next = Vec::with_capacity(combos.len() * points.len());
            for combo in &combos {
                for &v in points {
                    let mut c = combo.clone();
                    c.coords.push((*axis, v));
                    axis.axis().apply(v, &mut c.spec);
                    next.push(c);
                }
            }
            combos = next;
        }
        combos
    }
}

/// One point of a [`SweepPlan`]'s cross-product grid: the coordinate on
/// every composed axis, plus the accumulated cell specialization.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepCombo {
    /// `(axis, value)` per composed axis, in plan order.
    pub coords: Vec<(AxisId, f64)>,
    pub spec: CellSpec,
}

impl SweepCombo {
    /// The coordinate on `axis`, when the plan composes it.
    pub fn coord(&self, axis: AxisId) -> Option<f64> {
        self.coords.iter().find(|(a, _)| *a == axis).map(|(_, v)| *v)
    }

    /// The long-format report rendering of the coordinates
    /// (`axis=v;...` — `;`-separated like the parameter columns, so the
    /// CSV stays quoting-free).
    pub fn axis_values(&self) -> String {
        let parts: Vec<String> = self
            .coords
            .iter()
            .map(|(a, v)| format!("{}={v}", a.name()))
            .collect();
        parts.join(";")
    }
}

/// Version tag of the [`ExecutionPlan`]/[`shard::ShardSpec`] file format;
/// a worker refuses a file from a different coordinator generation
/// instead of misreading it. v2 added the optional `cache_dir` a shard
/// carries so `--workers` children share the coordinator's result cache.
pub const PLAN_VERSION: u32 = 2;

/// One fully-lowered cell of an [`ExecutionPlan`]: the grid coordinates
/// plus everything a sweep axis contributed, with the workload seed
/// already derived. Self-contained — a worker process rebuilds the
/// exact preset from `(app, size, seed, params)` with no access to the
/// coordinator's [`Seeding`] or CLI state.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedCell {
    pub cell: Cell,
    /// The workload seed this cell's input generates from.
    pub seed: u64,
    /// Full workload-parameter override list the preset builds from: the
    /// runner's `--param` list first, axis contributions appended after
    /// (an axis owns its key, so it wins).
    pub params: Vec<(String, f64)>,
    /// Axis-contributed protocol-parameter overrides, appended after the
    /// device config's own (`--proto-param`) list — same precedence rule.
    pub proto_params: Vec<(String, f64)>,
    /// Long-format sweep coordinates for the report (empty off-sweep).
    pub axis_values: String,
}

impl PlannedCell {
    pub(crate) fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("app".into(), Json::str(self.cell.app.name())),
            ("scenario".into(), Json::str(self.cell.scenario.name())),
            ("cus".into(), Json::u32(self.cell.num_cus)),
            ("seed".into(), Json::u64(self.seed)),
            ("params".into(), jsonio::pairs_to_json(&self.params)),
            ("proto_params".into(), jsonio::pairs_to_json(&self.proto_params)),
            ("axis_values".into(), Json::str(self.axis_values.clone())),
        ])
    }

    pub(crate) fn from_json(v: &Json) -> Result<PlannedCell, String> {
        let app_name = v.get("app")?.as_str()?;
        let app = registry::resolve(app_name)
            .ok_or_else(|| format!("unknown workload '{app_name}' in plan"))?;
        let scenario_name = v.get("scenario")?.as_str()?;
        let scenario = Scenario::from_name(scenario_name)
            .ok_or_else(|| format!("unknown scenario '{scenario_name}' in plan"))?;
        Ok(PlannedCell {
            cell: Cell {
                app,
                scenario,
                num_cus: v.get("cus")?.as_u32()?,
            },
            seed: v.get("seed")?.as_u64()?,
            params: jsonio::pairs_from_json(v.get("params")?)?,
            proto_params: jsonio::pairs_from_json(v.get("proto_params")?)?,
            axis_values: v.get("axis_values")?.as_str()?.to_string(),
        })
    }
}

pub(crate) fn size_to_name(size: WorkloadSize) -> &'static str {
    match size {
        WorkloadSize::Tiny => "tiny",
        WorkloadSize::Paper => "paper",
    }
}

pub(crate) fn size_from_name(name: &str) -> Result<WorkloadSize, String> {
    match name {
        "tiny" => Ok(WorkloadSize::Tiny),
        "paper" => Ok(WorkloadSize::Paper),
        other => Err(format!("unknown workload size '{other}'")),
    }
}

/// Stage 1 of the distributed pipeline: a fully-lowered, self-contained
/// evaluation run. Everything execution needs is inline — device config,
/// scale, validation mode and the per-cell seeds/overrides — so the plan
/// serializes to JSON and crosses process (and eventually host)
/// boundaries. Every sweep-execution path lowers to this type; there is
/// no other way to run a grid.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    /// Device template; `num_cus` is overridden per cell.
    pub cfg: DeviceConfig,
    pub size: WorkloadSize,
    /// Check every cell against its native oracle.
    pub validate: bool,
    /// Cells in grid order — the order the merged report presents.
    pub cells: Vec<PlannedCell>,
}

impl ExecutionPlan {
    /// Lower a plain cell list (the figure/coverage grids): per-cell
    /// seeds from the runner's [`Seeding`], the runner's `--param` list
    /// on every cell, no axis contributions.
    pub fn lower_cells(runner: &Runner, cells: &[Cell]) -> ExecutionPlan {
        let planned = cells
            .iter()
            .map(|&cell| PlannedCell {
                cell,
                seed: runner.seeding.seed_for(&cell),
                params: runner.params.clone(),
                proto_params: Vec::new(),
                axis_values: String::new(),
            })
            .collect();
        ExecutionPlan {
            cfg: runner.cfg.clone(),
            size: runner.size,
            validate: runner.validate,
            cells: planned,
        }
    }

    /// Lower a [`SweepPlan`]: the cross-product grid of the plan's axes,
    /// every combo run under every plan scenario, in combo-major order.
    /// Seeds ignore the scenario and any parameter-only coordinate
    /// (those sweeps vary placement over one shared task population);
    /// per-cell seeding derives a distinct input per device size.
    pub fn lower_sweep(runner: &Runner, plan: &SweepPlan) -> ExecutionPlan {
        let mut cells = Vec::new();
        for combo in plan.combos() {
            let num_cus = combo.spec.num_cus.unwrap_or(runner.cfg.num_cus);
            let seed = runner.seeding.seed_for(&Cell {
                app: plan.app,
                scenario: Scenario::SRSP,
                num_cus,
            });
            let mut params = runner.params.clone();
            params.extend_from_slice(&combo.spec.params);
            for &scenario in &plan.scenarios {
                cells.push(PlannedCell {
                    cell: Cell {
                        app: plan.app,
                        scenario,
                        num_cus,
                    },
                    seed,
                    params: params.clone(),
                    proto_params: combo.spec.proto_params.clone(),
                    axis_values: combo.axis_values(),
                });
            }
        }
        ExecutionPlan {
            cfg: runner.cfg.clone(),
            size: runner.size,
            validate: runner.validate,
            cells,
        }
    }

    /// Serialize to the stage-boundary JSON file format.
    pub fn to_json(&self) -> String {
        Json::Obj(vec![
            ("plan_version".into(), Json::u32(PLAN_VERSION)),
            ("device".into(), self.cfg.to_json()),
            ("size".into(), Json::str(size_to_name(self.size))),
            ("validate".into(), Json::Bool(self.validate)),
            (
                "cells".into(),
                Json::Arr(self.cells.iter().map(PlannedCell::to_json).collect()),
            ),
        ])
        .render()
    }

    /// Parse a stage-boundary JSON file; loud on any malformation.
    pub fn from_json(text: &str) -> Result<ExecutionPlan, String> {
        let v = jsonio::parse(text)?;
        let version = v.get("plan_version")?.as_u32()?;
        if version != PLAN_VERSION {
            return Err(format!(
                "plan file is version {version}, this binary speaks {PLAN_VERSION}"
            ));
        }
        let mut cells = Vec::new();
        for (i, c) in v.get("cells")?.arr()?.iter().enumerate() {
            cells.push(PlannedCell::from_json(c).map_err(|e| format!("cell {i}: {e}"))?);
        }
        Ok(ExecutionPlan {
            cfg: DeviceConfig::from_json(v.get("device")?)?,
            size: size_from_name(v.get("size")?.as_str()?)?,
            validate: v.get("validate")?.as_bool()?,
            cells,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_grid_covers_every_pair() {
        let g = classic_grid(8);
        assert_eq!(g.len(), 3 * Scenario::ALL.len());
        for app in classic_apps() {
            for scenario in Scenario::ALL {
                assert!(g.iter().any(|c| c.app == app && c.scenario == scenario));
            }
        }
        assert!(g.iter().all(|c| c.num_cus == 8));
    }

    #[test]
    fn full_grid_covers_every_registered_workload_and_protocol() {
        let g = full_grid(4);
        assert_eq!(g.len(), registry::all().count() * coverage_scenarios().len());
        for id in registry::all() {
            assert!(g.iter().any(|c| c.app == id));
        }
        // Every registered protocol's canonical scenario is covered.
        for p in protocol::all() {
            let s = Scenario::for_protocol(p);
            assert!(g.iter().any(|c| c.scenario == s), "{}", p.name());
        }
    }

    #[test]
    fn coverage_scenarios_extend_the_paper_five() {
        let cov = coverage_scenarios();
        assert_eq!(&cov[..5], &Scenario::ALL);
        assert!(cov.contains(&Scenario::HLRC));
        assert!(cov.contains(&Scenario::SRSP_ADAPTIVE));
        // No duplicates.
        for (i, a) in cov.iter().enumerate() {
            assert!(!cov[i + 1..].contains(a), "{a:?} appears twice");
        }
    }

    #[test]
    fn per_cell_seeds_share_graphs_across_scenarios() {
        let cell = |app, scenario, num_cus| Cell {
            app,
            scenario,
            num_cus,
        };
        let s = Seeding::PerCell(42);
        let base = s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4));
        // Deterministic.
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4)));
        // Scenario must NOT change the seed (ratios need shared inputs).
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::SRSP, 4)));
        // App and CU count must.
        assert_ne!(base, s.seed_for(&cell(registry::SSSP, Scenario::BASELINE, 4)));
        assert_ne!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 8)));
        // A different base diverges; shared seeding ignores the cell.
        let other_base = Seeding::PerCell(43);
        assert_ne!(
            base,
            other_base.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4))
        );
        let shared = Seeding::Shared(7);
        assert_eq!(7, shared.seed_for(&cell(registry::MIS, Scenario::RSP, 64)));
    }

    #[test]
    fn one_axis_plan_reproduces_the_ratio_major_order() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].coord(axis::REMOTE_RATIO), Some(0.0));
        assert_eq!(combos[1].coord(axis::REMOTE_RATIO), Some(0.5));
        assert_eq!(combos[1].spec.params, vec![("remote_ratio".to_string(), 0.5)]);
        assert_eq!(combos[1].spec.num_cus, None);
        assert_eq!(combos[1].axis_values(), "remote-ratio=0.5");
        assert_eq!(plan.scenarios, RATIO_SCENARIOS.to_vec());
    }

    #[test]
    fn cu_count_plan_overrides_the_device_size() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![8.0, 64.0])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].spec.num_cus, Some(8));
        assert_eq!(combos[1].spec.num_cus, Some(64));
        assert!(combos[1].spec.params.is_empty());
        assert_eq!(combos[1].axis_values(), "cu-count=64");
    }

    #[test]
    fn composed_plan_cross_product_first_axis_outermost() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![4.0, 8.0])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 4);
        let flat: Vec<(f64, u32)> = combos
            .iter()
            .map(|c| (c.coord(axis::REMOTE_RATIO).unwrap(), c.spec.num_cus.unwrap()))
            .collect();
        assert_eq!(flat, vec![(0.0, 4), (0.0, 8), (0.5, 4), (0.5, 8)]);
        assert_eq!(combos[3].axis_values(), "remote-ratio=0.5;cu-count=8");
        assert_eq!(combos[3].spec.params, vec![("remote_ratio".to_string(), 0.5)]);
    }

    #[test]
    fn plan_defaults_come_from_the_registry() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::HOT_SET]).unwrap();
        assert_eq!(plan.points(axis::HOT_SET), axis::HOT_SET.axis().default_points());
        assert_eq!(plan.combos().len(), axis::HOT_SET.axis().default_points().len());
    }

    #[test]
    fn lowered_sweep_is_self_contained_and_round_trips() {
        use crate::harness::presets::WorkloadSize;

        let runner = Runner {
            jobs: 2,
            seeding: Seeding::PerCell(7),
            size: WorkloadSize::Tiny,
            validate: true,
            params: vec![("tasks".to_string(), 32.0)],
            cfg: DeviceConfig {
                num_cus: 4,
                proto_params: vec![("lr_tbl_entries".to_string(), 2.0)],
                ..DeviceConfig::small()
            },
        };
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![2.0, 4.0])
            .unwrap();
        let lowered = ExecutionPlan::lower_sweep(&runner, &plan);
        assert_eq!(lowered.cells.len(), 2 * 2 * RATIO_SCENARIOS.len());
        assert!(lowered.validate);
        assert_eq!(lowered.cfg.proto_params.len(), 1, "--proto-param travels in cfg");
        // Combo-major order: all scenarios of one grid point adjacent;
        // runner --param first, then the axis override (axis wins).
        let first = &lowered.cells[0];
        assert_eq!(first.cell.scenario, RATIO_SCENARIOS[0]);
        assert_eq!(first.cell.num_cus, 2);
        assert_eq!(
            first.params,
            vec![("tasks".to_string(), 32.0), ("remote_ratio".to_string(), 0.0)]
        );
        assert_eq!(first.axis_values, "remote-ratio=0;cu-count=2");
        // Scenarios of one combo share a seed; device size reseeds.
        assert_eq!(lowered.cells[0].seed, lowered.cells[2].seed);
        assert_ne!(lowered.cells[0].seed, lowered.cells[3].seed);
        // The serialized boundary reproduces the plan exactly.
        let back = ExecutionPlan::from_json(&lowered.to_json()).unwrap();
        assert_eq!(back, lowered);
    }

    #[test]
    fn lowered_cells_match_the_runner_policy() {
        use crate::harness::presets::WorkloadSize;

        let runner = Runner::new(DeviceConfig::small(), WorkloadSize::Tiny, 1);
        let cells = classic_grid(4);
        let lowered = ExecutionPlan::lower_cells(&runner, &cells);
        assert_eq!(lowered.cells.len(), cells.len());
        for (p, c) in lowered.cells.iter().zip(&cells) {
            assert_eq!(p.cell, *c);
            assert_eq!(p.seed, runner.seeding.seed_for(c));
            assert!(p.params.is_empty() && p.proto_params.is_empty());
            assert_eq!(p.axis_values, "");
        }
        let back = ExecutionPlan::from_json(&lowered.to_json()).unwrap();
        assert_eq!(back, lowered);
    }

    #[test]
    fn plan_files_reject_version_and_name_drift() {
        use crate::harness::presets::WorkloadSize;

        let runner = Runner::new(DeviceConfig::small(), WorkloadSize::Tiny, 1);
        let lowered = ExecutionPlan::lower_cells(&runner, &classic_grid(4));
        let text = lowered.to_json();
        let wrong_version = text.replacen("\"plan_version\":2", "\"plan_version\":999", 1);
        assert!(ExecutionPlan::from_json(&wrong_version)
            .unwrap_err()
            .contains("version"));
        let wrong_app = text.replacen("\"app\":\"prk\"", "\"app\":\"bogus\"", 1);
        assert!(ExecutionPlan::from_json(&wrong_app)
            .unwrap_err()
            .contains("unknown workload"));
        assert!(ExecutionPlan::from_json("not json").is_err());
    }

    #[test]
    fn plan_rejects_bad_axis_lists_and_points() {
        let dup = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT, axis::CU_COUNT]);
        assert!(dup.unwrap_err().contains("duplicate"), "duplicate axes");
        let none = SweepPlan::new(registry::STRESS, &[]);
        assert!(none.is_err());
        let four = SweepPlan::new(
            registry::STRESS,
            &[axis::REMOTE_RATIO, axis::CU_COUNT, axis::HOT_SET, axis::MIGRATION],
        );
        assert!(four.unwrap_err().contains("at most"), "too many axes");
        // A workload without the driven parameter is refused up front.
        let err = SweepPlan::new(registry::PRK, &[axis::REMOTE_RATIO]).unwrap_err();
        assert!(err.contains("has no remote_ratio parameter"), "{err}");
        // Points for an axis outside the plan, and out-of-domain points.
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO]).unwrap();
        assert!(plan.clone().with_points(axis::CU_COUNT, vec![4.0]).is_err());
        assert!(plan.clone().with_points(axis::REMOTE_RATIO, vec![1.5]).is_err());
        assert!(plan.with_points(axis::REMOTE_RATIO, vec![]).is_err());
    }
}
