//! Layer-3 coordination facade.
//!
//! The paper's evaluation is a protocol × app × CU-count grid; the
//! machinery that shards that grid over OS threads lives in
//! [`crate::harness::runner`] and is re-exported here under the
//! coordination name the CLI and future distributed backends build on.
//! Every grid cell is an isolated single-threaded simulation, so the
//! coordinator's only job is deterministic work distribution: stable
//! cell order, per-cell seed derivation and grid-order result assembly.

pub use crate::harness::runner::{
    full_grid, into_run_results, run_validated, Cell, CellResult, Runner, Seeding,
};
