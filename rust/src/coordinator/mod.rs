//! Layer-3 coordination: the **distribution policy** of the evaluation
//! grids.
//!
//! The paper's evaluation is a protocol × app × CU-count grid plus a
//! family of parameter sweeps (remote ratio, device size, hot-set width,
//! migration period — see [`axis`]). This module owns everything about
//! *which* cells exist and in *what order*, and how workload seeds derive
//! per cell — the policy half of the split. The execution half
//! (OS-thread sharding, oracle validation, result reassembly) lives in
//! [`crate::harness::runner`] and consumes these cells; every grid cell
//! is an isolated single-threaded simulation, so the two halves meet
//! only at the `Cell` and [`SweepPlan`] types.

pub mod axis;

use crate::config::Scenario;
use crate::sim::SplitMix64;
use crate::sync::protocol;
use crate::workload::registry::{self, WorkloadId, DEFAULT_SEED};

use axis::{AxisId, CellSpec};

// Execution-side types, re-exported under the coordination name the CLI
// and future distributed backends build on.
pub use crate::harness::runner::{into_run_results, run_validated, CellResult, Runner};

/// One cell of the protocol × app × CU-count grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cell {
    pub app: WorkloadId,
    pub scenario: Scenario,
    pub num_cus: u32,
}

/// How workload-generation seeds are assigned to grid cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Seeding {
    /// Every cell uses the same seed — the classic figure presets
    /// (`DEFAULT_SEED` reproduces the paper figures byte-for-byte).
    Shared(u64),
    /// Each (app, CU-count) pair derives its own seed from a base value
    /// via [`SplitMix64`]; scenarios still share the graph (ratios need
    /// shared inputs).
    PerCell(u64),
}

impl Default for Seeding {
    fn default() -> Self {
        Seeding::Shared(DEFAULT_SEED)
    }
}

impl Seeding {
    /// The workload seed for `cell`. Derivation uses the workload's
    /// stable registry ordinal and deliberately ignores the scenario:
    /// all scenarios of one app at one CU count must share an input or
    /// vs-Baseline ratios would compare different problems.
    pub fn seed_for(self, cell: &Cell) -> u64 {
        match self {
            Seeding::Shared(seed) => seed,
            Seeding::PerCell(base) => {
                let tag = ((cell.app.ord() + 1) << 32) | u64::from(cell.num_cus);
                SplitMix64::new(base ^ tag).next_u64()
            }
        }
    }
}

/// The three Pannotia apps of the paper's §5.1 figures, in figure order.
pub fn classic_apps() -> [WorkloadId; 3] {
    [registry::PRK, registry::SSSP, registry::MIS]
}

/// The classic §5.1 figure grid (three apps × five scenarios) at one CU
/// count, in stable app-major order.
pub fn classic_grid(num_cus: u32) -> Vec<Cell> {
    grid(&classic_apps(), num_cus)
}

/// The scenarios the coverage grids (`validate`, `ci-smoke`) run: the
/// paper's five plus the canonical scenario of every further registered
/// protocol (hlrc, srsp-adaptive, ...), resolved through the protocol
/// registry — a protocol added there is covered here with no change.
pub fn coverage_scenarios() -> Vec<Scenario> {
    let mut scenarios: Vec<Scenario> = Scenario::ALL.to_vec();
    for p in protocol::all() {
        let s = Scenario::for_protocol(p);
        if !scenarios.contains(&s) {
            scenarios.push(s);
        }
    }
    scenarios
}

/// Every registered workload × every coverage scenario at one CU count,
/// in stable registry-major order (the `validate`/`ci-smoke` grid).
pub fn full_grid(num_cus: u32) -> Vec<Cell> {
    let apps: Vec<WorkloadId> = registry::all().collect();
    grid_over(&apps, &coverage_scenarios(), num_cus)
}

/// App-major grid over an explicit app list (the paper's five scenarios).
pub fn grid(apps: &[WorkloadId], num_cus: u32) -> Vec<Cell> {
    grid_over(apps, &Scenario::ALL, num_cus)
}

/// The shared app-major cell constructor behind every coverage grid.
fn grid_over(apps: &[WorkloadId], scenarios: &[Scenario], num_cus: u32) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(apps.len() * scenarios.len());
    for &app in apps {
        for &scenario in scenarios {
            cells.push(Cell {
                app,
                scenario,
                num_cus,
            });
        }
    }
    cells
}

/// The flattened cell list for a CU-count scaling sweep (classic apps).
pub fn scaling_cells(cus: &[u32]) -> Vec<Cell> {
    cus.iter().flat_map(|&n| classic_grid(n)).collect()
}

/// The three scenarios whose protocols every parameter sweep compares:
/// global-scope stealing (ScopedOnly), naive promotion (RspNaive) and
/// selective promotion (Srsp).
pub const RATIO_SCENARIOS: [Scenario; 3] = [Scenario::STEAL_ONLY, Scenario::RSP, Scenario::SRSP];

/// The most axes one sweep composes (a surface plus one extra slice —
/// beyond that the cross-product grid outgrows a single host; ROADMAP's
/// distribution item picks it up from there).
pub const MAX_SWEEP_AXES: usize = 3;

/// A composed parameter sweep: one workload swept over the cross-product
/// grid of 1–[`MAX_SWEEP_AXES`] registered [`axis`] entries, each cell
/// run under every comparison scenario. This is the *policy* object the
/// generic [`Runner::run_sweep`] executes — which axes, which points,
/// which scenarios, in what order — and the only sweep construct in the
/// crate: single-axis sweeps are just one-axis plans.
#[derive(Debug, Clone)]
pub struct SweepPlan {
    pub app: WorkloadId,
    /// The scenarios every grid combo runs (default [`RATIO_SCENARIOS`]).
    pub scenarios: Vec<Scenario>,
    axes: Vec<AxisId>,
    /// Grid points per axis, parallel to `axes`.
    points: Vec<Vec<f64>>,
}

impl SweepPlan {
    /// A plan over `axes` with each axis's registry default points.
    /// Rejects an empty or oversized axis list, duplicate axes, and a
    /// workload that does not declare a parameter some axis drives.
    pub fn new(app: WorkloadId, axes: &[AxisId]) -> Result<SweepPlan, String> {
        if axes.is_empty() {
            return Err("a sweep needs at least one axis".into());
        }
        if axes.len() > MAX_SWEEP_AXES {
            return Err(format!(
                "a sweep composes at most {MAX_SWEEP_AXES} axes, got {}",
                axes.len()
            ));
        }
        for (i, a) in axes.iter().enumerate() {
            if axes[i + 1..].contains(a) {
                return Err(format!("duplicate sweep axis '{}'", a.name()));
            }
            if let Some(param) = a.axis().required_param() {
                if !app.kernel().params().iter().any(|p| p.key == param) {
                    return Err(format!(
                        "workload '{}' has no {param} parameter (axis {}; try --app stress)",
                        app.name(),
                        a.name()
                    ));
                }
            }
        }
        Ok(SweepPlan {
            app,
            scenarios: RATIO_SCENARIOS.to_vec(),
            axes: axes.to_vec(),
            points: axes
                .iter()
                .map(|a| a.axis().default_points().to_vec())
                .collect(),
        })
    }

    /// Replace one axis's grid points (`--points axis=v1,v2,...`). The
    /// axis must be part of the plan and every point must pass the
    /// axis's own domain check.
    pub fn with_points(mut self, axis: AxisId, points: Vec<f64>) -> Result<SweepPlan, String> {
        let Some(i) = self.axes.iter().position(|a| *a == axis) else {
            let selected: Vec<&str> = self.axes.iter().map(|a| a.name()).collect();
            return Err(format!(
                "--points {} applies to an axis in --axis (selected: {}); the sweep would \
                 ignore it",
                axis.name(),
                selected.join(", ")
            ));
        };
        if points.is_empty() {
            return Err(format!("--points {} needs at least one point", axis.name()));
        }
        for &v in &points {
            axis.axis()
                .check_point(v)
                .map_err(|e| format!("--points {}: {e}", axis.name()))?;
        }
        self.points[i] = points;
        Ok(self)
    }

    /// The composed axes, in grid-nesting order (first = outermost).
    pub fn axes(&self) -> &[AxisId] {
        &self.axes
    }

    /// The grid points of `axis` (panics when the axis is not in the
    /// plan — caller bug, the constructor validated membership).
    pub fn points(&self, axis: AxisId) -> &[f64] {
        let i = self
            .axes
            .iter()
            .position(|a| *a == axis)
            .unwrap_or_else(|| panic!("axis '{}' is not part of this plan", axis.name()));
        &self.points[i]
    }

    /// The cross-product grid, first axis outermost, in stable
    /// coordinate-major order (a one-axis remote-ratio plan reproduces
    /// the historical ratio-major order exactly; cu-count likewise).
    pub fn combos(&self) -> Vec<SweepCombo> {
        let mut combos = vec![SweepCombo::default()];
        for (axis, points) in self.axes.iter().zip(&self.points) {
            let mut next = Vec::with_capacity(combos.len() * points.len());
            for combo in &combos {
                for &v in points {
                    let mut c = combo.clone();
                    c.coords.push((*axis, v));
                    axis.axis().apply(v, &mut c.spec);
                    next.push(c);
                }
            }
            combos = next;
        }
        combos
    }
}

/// One point of a [`SweepPlan`]'s cross-product grid: the coordinate on
/// every composed axis, plus the accumulated cell specialization.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepCombo {
    /// `(axis, value)` per composed axis, in plan order.
    pub coords: Vec<(AxisId, f64)>,
    pub spec: CellSpec,
}

impl SweepCombo {
    /// The coordinate on `axis`, when the plan composes it.
    pub fn coord(&self, axis: AxisId) -> Option<f64> {
        self.coords.iter().find(|(a, _)| *a == axis).map(|(_, v)| *v)
    }

    /// The long-format report rendering of the coordinates
    /// (`axis=v;...` — `;`-separated like the parameter columns, so the
    /// CSV stays quoting-free).
    pub fn axis_values(&self) -> String {
        let parts: Vec<String> = self
            .coords
            .iter()
            .map(|(a, v)| format!("{}={v}", a.name()))
            .collect();
        parts.join(";")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classic_grid_covers_every_pair() {
        let g = classic_grid(8);
        assert_eq!(g.len(), 3 * Scenario::ALL.len());
        for app in classic_apps() {
            for scenario in Scenario::ALL {
                assert!(g.iter().any(|c| c.app == app && c.scenario == scenario));
            }
        }
        assert!(g.iter().all(|c| c.num_cus == 8));
    }

    #[test]
    fn full_grid_covers_every_registered_workload_and_protocol() {
        let g = full_grid(4);
        assert_eq!(g.len(), registry::all().count() * coverage_scenarios().len());
        for id in registry::all() {
            assert!(g.iter().any(|c| c.app == id));
        }
        // Every registered protocol's canonical scenario is covered.
        for p in protocol::all() {
            let s = Scenario::for_protocol(p);
            assert!(g.iter().any(|c| c.scenario == s), "{}", p.name());
        }
    }

    #[test]
    fn coverage_scenarios_extend_the_paper_five() {
        let cov = coverage_scenarios();
        assert_eq!(&cov[..5], &Scenario::ALL);
        assert!(cov.contains(&Scenario::HLRC));
        assert!(cov.contains(&Scenario::SRSP_ADAPTIVE));
        // No duplicates.
        for (i, a) in cov.iter().enumerate() {
            assert!(!cov[i + 1..].contains(a), "{a:?} appears twice");
        }
    }

    #[test]
    fn per_cell_seeds_share_graphs_across_scenarios() {
        let cell = |app, scenario, num_cus| Cell {
            app,
            scenario,
            num_cus,
        };
        let s = Seeding::PerCell(42);
        let base = s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4));
        // Deterministic.
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4)));
        // Scenario must NOT change the seed (ratios need shared inputs).
        assert_eq!(base, s.seed_for(&cell(registry::PRK, Scenario::SRSP, 4)));
        // App and CU count must.
        assert_ne!(base, s.seed_for(&cell(registry::SSSP, Scenario::BASELINE, 4)));
        assert_ne!(base, s.seed_for(&cell(registry::PRK, Scenario::BASELINE, 8)));
        // A different base diverges; shared seeding ignores the cell.
        let other_base = Seeding::PerCell(43);
        assert_ne!(
            base,
            other_base.seed_for(&cell(registry::PRK, Scenario::BASELINE, 4))
        );
        let shared = Seeding::Shared(7);
        assert_eq!(7, shared.seed_for(&cell(registry::MIS, Scenario::RSP, 64)));
    }

    #[test]
    fn one_axis_plan_reproduces_the_ratio_major_order() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].coord(axis::REMOTE_RATIO), Some(0.0));
        assert_eq!(combos[1].coord(axis::REMOTE_RATIO), Some(0.5));
        assert_eq!(combos[1].spec.params, vec![("remote_ratio".to_string(), 0.5)]);
        assert_eq!(combos[1].spec.num_cus, None);
        assert_eq!(combos[1].axis_values(), "remote-ratio=0.5");
        assert_eq!(plan.scenarios, RATIO_SCENARIOS.to_vec());
    }

    #[test]
    fn cu_count_plan_overrides_the_device_size() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![8.0, 64.0])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 2);
        assert_eq!(combos[0].spec.num_cus, Some(8));
        assert_eq!(combos[1].spec.num_cus, Some(64));
        assert!(combos[1].spec.params.is_empty());
        assert_eq!(combos[1].axis_values(), "cu-count=64");
    }

    #[test]
    fn composed_plan_cross_product_first_axis_outermost() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO, axis::CU_COUNT])
            .unwrap()
            .with_points(axis::REMOTE_RATIO, vec![0.0, 0.5])
            .unwrap()
            .with_points(axis::CU_COUNT, vec![4.0, 8.0])
            .unwrap();
        let combos = plan.combos();
        assert_eq!(combos.len(), 4);
        let flat: Vec<(f64, u32)> = combos
            .iter()
            .map(|c| (c.coord(axis::REMOTE_RATIO).unwrap(), c.spec.num_cus.unwrap()))
            .collect();
        assert_eq!(flat, vec![(0.0, 4), (0.0, 8), (0.5, 4), (0.5, 8)]);
        assert_eq!(combos[3].axis_values(), "remote-ratio=0.5;cu-count=8");
        assert_eq!(combos[3].spec.params, vec![("remote_ratio".to_string(), 0.5)]);
    }

    #[test]
    fn plan_defaults_come_from_the_registry() {
        let plan = SweepPlan::new(registry::STRESS, &[axis::HOT_SET]).unwrap();
        assert_eq!(plan.points(axis::HOT_SET), axis::HOT_SET.axis().default_points());
        assert_eq!(plan.combos().len(), axis::HOT_SET.axis().default_points().len());
    }

    #[test]
    fn plan_rejects_bad_axis_lists_and_points() {
        let dup = SweepPlan::new(registry::STRESS, &[axis::CU_COUNT, axis::CU_COUNT]);
        assert!(dup.unwrap_err().contains("duplicate"), "duplicate axes");
        let none = SweepPlan::new(registry::STRESS, &[]);
        assert!(none.is_err());
        let four = SweepPlan::new(
            registry::STRESS,
            &[axis::REMOTE_RATIO, axis::CU_COUNT, axis::HOT_SET, axis::MIGRATION],
        );
        assert!(four.unwrap_err().contains("at most"), "too many axes");
        // A workload without the driven parameter is refused up front.
        let err = SweepPlan::new(registry::PRK, &[axis::REMOTE_RATIO]).unwrap_err();
        assert!(err.contains("has no remote_ratio parameter"), "{err}");
        // Points for an axis outside the plan, and out-of-domain points.
        let plan = SweepPlan::new(registry::STRESS, &[axis::REMOTE_RATIO]).unwrap();
        assert!(plan.clone().with_points(axis::CU_COUNT, vec![4.0]).is_err());
        assert!(plan.clone().with_points(axis::REMOTE_RATIO, vec![1.5]).is_err());
        assert!(plan.with_points(axis::REMOTE_RATIO, vec![]).is_err());
    }
}
