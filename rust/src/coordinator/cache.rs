//! Content-addressed, persistent result cache for the evaluation
//! pipeline — the "monitor" that lets a sweep skip its heavyweight path.
//!
//! The paper's protocol pays full-synchronization cost only when a
//! remote access actually needs it; this module applies the same
//! asymmetric-cost argument one level up. Re-simulating a grid cell is
//! expensive, looking its result up is cheap, so a run that can *prove*
//! a cell's inputs are unchanged skips the simulation entirely. Proof is
//! content addressing: the cache key is the rendered JSON of everything
//! that determines a cell's row — [`PLAN_VERSION`], the report schema
//! version, the **effective** [`DeviceConfig`] (per-cell CU count and
//! protocol-parameter overrides folded in, exactly as the executor
//! builds it), the workload size, the validate flag and the full
//! [`PlannedCell`]. All of those serialize through the existing
//! exhaustive-destructure codecs, so a new config field fails to compile
//! until its codec — and therefore the fingerprint — accounts for it.
//!
//! Two layers share one on-disk store (a directory of JSONL segments):
//!
//! 1. **cell layer** — fingerprint → lossless [`ReportRow`] (the same
//!    raw-token codec the [`PartialReport`](crate::harness::report::PartialReport)
//!    boundary uses), inserted only for oracle-validated cells;
//! 2. **preset layer** — fingerprint → serialized workload preset
//!    (resolved parameters + generated graph + round bound), so sweeps
//!    that vary only protocol parameters generate each input exactly
//!    once *across invocations*, not once per run.
//!
//! The store is loud and fail-soft: corrupt lines, foreign cache
//! versions and unknown record kinds are skipped with a stderr warning
//! (never trusted, never fatal), fingerprint collisions with differing
//! keys are reported and treated as misses, and every stored row must
//! round-trip through the `jsonio` codec to the identical token stream
//! before it is accepted — a lossy row can never poison the store.
//!
//! The [`serve`](super::serve) coordinator consults the same keys
//! before scheduling: a warm cell is answered inside the coordinator
//! and never dispatched to a worker, and fresh oracle-validated rows
//! acked by the fleet are inserted back under identical keys — the
//! service and local `--cache` runs share one store, byte-for-byte.

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::config::DeviceConfig;
use crate::harness::report::{
    check_row_round_trip, row_value_from_json, row_value_to_json, ReportRow, REPORT_SCHEMA,
};
use crate::jsonio::{self, Json};
use crate::workload::graph::Graph;
use crate::workload::registry::{self, Params, WorkloadId, WorkloadPreset, WorkloadSize};

use super::{size_to_name, PlannedCell, PLAN_VERSION};

/// Version tag of the cache record format itself. Bump it whenever the
/// record layout changes **or** a workload generator's output changes
/// for the same `(size, seed, params)` triple — stored presets and rows
/// from the old generation must stop matching. `srsp cache verify`
/// regenerates every stored preset and is the drift detector when in
/// doubt.
pub const CACHE_VERSION: u32 = 1;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv1a(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = seed;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// 128-bit content fingerprint of a rendered key, as 32 hex chars: two
/// FNV-1a passes with independent offsets. The fingerprint is only an
/// *index* — every store entry carries its full key text and lookups
/// compare the text exactly, so a collision degrades to a loud miss,
/// never a wrong row.
pub fn fingerprint(key_text: &str) -> String {
    let a = fnv1a(FNV_OFFSET, key_text.as_bytes());
    let b = fnv1a(FNV_OFFSET ^ 0x9e3779b97f4a7c15, key_text.as_bytes());
    format!("{a:016x}{b:016x}")
}

/// Hit/miss accounting for one run, summed across layers and shards.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub preset_reuses: u64,
}

impl CacheCounters {
    /// Cell-layer lookups performed (hits + misses).
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fold another run's (or shard's) counters into this one. The
    /// exhaustive destructure is the drift guard: a new counter that is
    /// not summed here no longer compiles.
    pub fn add(&mut self, other: &CacheCounters) {
        let CacheCounters {
            hits,
            misses,
            preset_reuses,
        } = other;
        self.hits += hits;
        self.misses += misses;
        self.preset_reuses += preset_reuses;
    }

    pub fn to_json(&self) -> Json {
        let CacheCounters {
            hits,
            misses,
            preset_reuses,
        } = self;
        Json::Obj(vec![
            ("hits".into(), Json::u64(*hits)),
            ("misses".into(), Json::u64(*misses)),
            ("preset_reuses".into(), Json::u64(*preset_reuses)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CacheCounters, String> {
        Ok(CacheCounters {
            hits: v.get("hits")?.as_u64()?,
            misses: v.get("misses")?.as_u64()?,
            preset_reuses: v.get("preset_reuses")?.as_u64()?,
        })
    }
}

/// The cell-layer cache key: everything that determines one cell's
/// report row. The device config is the **effective** one — per-cell CU
/// count and the cell's protocol-parameter overrides folded in exactly
/// as `run_planned_cell` builds it — so two plans whose templates differ
/// only in fields a cell overrides still share the cell's entry, and a
/// template change that *does* reach the cell forces a miss.
pub fn cell_key(cfg: &DeviceConfig, size: WorkloadSize, validate: bool, pc: &PlannedCell) -> Json {
    let mut eff = DeviceConfig {
        num_cus: pc.cell.num_cus,
        ..cfg.clone()
    };
    eff.proto_params.extend_from_slice(&pc.proto_params);
    Json::Obj(vec![
        ("cache_version".into(), Json::u32(CACHE_VERSION)),
        ("plan_version".into(), Json::u32(PLAN_VERSION)),
        ("report_version".into(), Json::u32(REPORT_SCHEMA.version)),
        ("kind".into(), Json::str("cell")),
        ("device".into(), eff.to_json()),
        ("size".into(), Json::str(size_to_name(size))),
        ("validate".into(), Json::Bool(validate)),
        ("cell".into(), pc.to_json()),
    ])
}

/// The preset-layer cache key: everything workload generation consumes.
/// Device config deliberately excluded — inputs depend only on
/// `(app, size, seed, parameter overrides)`.
pub fn preset_key(app: WorkloadId, size: WorkloadSize, seed: u64, overrides: &[(String, f64)]) -> Json {
    Json::Obj(vec![
        ("cache_version".into(), Json::u32(CACHE_VERSION)),
        ("plan_version".into(), Json::u32(PLAN_VERSION)),
        ("kind".into(), Json::str("preset")),
        ("app".into(), Json::str(app.name())),
        ("size".into(), Json::str(size_to_name(size))),
        ("seed".into(), Json::u64(seed)),
        ("params".into(), jsonio::pairs_to_json(overrides)),
    ])
}

struct CellEntry {
    /// Full rendered key text; lookups compare this exactly.
    key: String,
    row: ReportRow,
}

struct PresetEntry {
    key: String,
    /// `(key, value, explicit)` triples of the resolved parameters.
    params: Vec<(String, f64, bool)>,
    max_rounds: u32,
    graph: Option<Graph>,
}

#[derive(Default)]
struct StoreInner {
    cells: BTreeMap<String, CellEntry>,
    presets: BTreeMap<String, PresetEntry>,
    segments: usize,
    skipped: usize,
    counters: CacheCounters,
    writer: Option<BufWriter<File>>,
}

/// Counts and sizes `srsp cache stats` presents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreSummary {
    pub segments: usize,
    pub cells: usize,
    pub presets: usize,
    /// Corrupt / foreign-version / unknown-kind lines skipped at open.
    pub skipped: usize,
}

/// The on-disk store: a directory of append-only JSONL segments (one
/// per writing process, `segment-<pid>.jsonl`) plus a `runs.jsonl` of
/// per-run counter records. Opening scans every segment into memory;
/// inserts append to this process's own segment, so concurrent worker
/// processes sharing one directory never interleave writes within a
/// line. Lookups and inserts are `&self` (internally locked) so shard
/// threads can share one store.
pub struct CacheStore {
    dir: PathBuf,
    inner: Mutex<StoreInner>,
}

impl CacheStore {
    /// Open (creating if needed) the store at `dir`, scanning all
    /// existing segments. Corrupt or foreign lines are skipped loudly.
    pub fn open(dir: &str) -> Result<CacheStore, String> {
        let dir_path = PathBuf::from(dir);
        fs::create_dir_all(&dir_path)
            .map_err(|e| format!("cache: cannot create directory '{dir}': {e}"))?;
        let mut inner = StoreInner::default();
        let mut names: Vec<PathBuf> = fs::read_dir(&dir_path)
            .map_err(|e| format!("cache: cannot read directory '{dir}': {e}"))?
            .filter_map(|entry| entry.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().is_some_and(|x| x == "jsonl")
                    && p.file_name().is_some_and(|n| n != "runs.jsonl")
            })
            .collect();
        names.sort();
        for path in names {
            inner.segments += 1;
            let text = fs::read_to_string(&path)
                .map_err(|e| format!("cache: cannot read '{}': {e}", path.display()))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                if let Err(why) = scan_line(&mut inner, line) {
                    eprintln!(
                        "cache: skipping {}:{}: {why}",
                        path.display(),
                        lineno + 1
                    );
                    inner.skipped += 1;
                }
            }
        }
        Ok(CacheStore {
            dir: dir_path,
            inner: Mutex::new(inner),
        })
    }

    /// The directory this store persists to.
    pub fn dir(&self) -> &str {
        self.dir.to_str().unwrap_or(".")
    }

    pub fn summary(&self) -> StoreSummary {
        let inner = self.inner.lock().unwrap();
        StoreSummary {
            segments: inner.segments,
            cells: inner.cells.len(),
            presets: inner.presets.len(),
            skipped: inner.skipped,
        }
    }

    /// Drain this store's hit/miss counters (accumulated by lookups
    /// since the last take).
    pub fn take_counters(&self) -> CacheCounters {
        std::mem::take(&mut self.inner.lock().unwrap().counters)
    }

    /// Cell-layer lookup. A fingerprint match with differing key text is
    /// a collision: reported loudly, counted as a miss, never served.
    pub fn lookup_cell(&self, key: &Json) -> Option<ReportRow> {
        let key_text = key.render();
        let fp = fingerprint(&key_text);
        let mut inner = self.inner.lock().unwrap();
        let found = match inner.cells.get(&fp) {
            Some(e) if e.key == key_text => Some(e.row.clone()),
            Some(_) => {
                eprintln!("cache: fingerprint collision on {fp} (differing keys); treating as a miss");
                None
            }
            None => None,
        };
        match &found {
            Some(_) => inner.counters.hits += 1,
            None => inner.counters.misses += 1,
        }
        found
    }

    /// Insert one validated cell row. Panics if the row is lossy (the
    /// poison-prevention invariant); IO failures are loud but non-fatal
    /// — the run's own results are unaffected.
    pub fn insert_cell(&self, key: &Json, row: &ReportRow) {
        if let Err(e) = check_row_round_trip(row) {
            panic!("cache: refusing to store a lossy report row: {e}");
        }
        let key_text = key.render();
        let fp = fingerprint(&key_text);
        let mut inner = self.inner.lock().unwrap();
        match inner.cells.get(&fp) {
            Some(e) if e.key == key_text => return, // already stored
            Some(_) => {
                eprintln!("cache: fingerprint collision on {fp} (differing keys); not storing");
                return;
            }
            None => {}
        }
        let line = Json::Obj(vec![
            ("cache_version".into(), Json::u32(CACHE_VERSION)),
            ("kind".into(), Json::str("cell")),
            ("fp".into(), Json::str(fp.clone())),
            ("key".into(), jsonio::parse(&key_text).expect("a rendered key re-parses")),
            ("row".into(), row_value_to_json(row)),
        ]);
        append_line(&mut inner, &self.dir, &line);
        inner.cells.insert(
            fp,
            CellEntry {
                key: key_text,
                row: row.clone(),
            },
        );
    }

    /// Preset-layer lookup: rebuild a [`WorkloadPreset`] from a stored
    /// record. A record whose parameters no longer rehydrate against the
    /// current registry spec (parameter added/removed since it was
    /// stored) is reported and treated as a miss — the caller falls back
    /// to cold generation.
    pub fn load_preset(
        &self,
        key: &Json,
        id: WorkloadId,
        size: WorkloadSize,
        seed: u64,
    ) -> Option<WorkloadPreset> {
        let key_text = key.render();
        let fp = fingerprint(&key_text);
        let mut inner = self.inner.lock().unwrap();
        let built = match inner.presets.get(&fp) {
            Some(e) if e.key == key_text => {
                match Params::rehydrate(id.kernel().params(), &e.params) {
                    Ok(params) => Some(WorkloadPreset {
                        id,
                        size,
                        seed,
                        params,
                        graph: e.graph.clone(),
                        max_rounds: e.max_rounds,
                    }),
                    Err(why) => {
                        eprintln!(
                            "cache: stored preset for '{}' no longer matches the registry \
                             ({why}); regenerating",
                            id.name()
                        );
                        None
                    }
                }
            }
            Some(_) => {
                eprintln!("cache: fingerprint collision on {fp} (differing keys); treating as a miss");
                None
            }
            None => None,
        };
        if built.is_some() {
            inner.counters.preset_reuses += 1;
        }
        built
    }

    /// Persist one generated preset.
    pub fn insert_preset(&self, key: &Json, preset: &WorkloadPreset) {
        let key_text = key.render();
        let fp = fingerprint(&key_text);
        let mut inner = self.inner.lock().unwrap();
        match inner.presets.get(&fp) {
            Some(e) if e.key == key_text => return,
            Some(_) => {
                eprintln!("cache: fingerprint collision on {fp} (differing keys); not storing");
                return;
            }
            None => {}
        }
        let params = preset.params.entries();
        let line = Json::Obj(vec![
            ("cache_version".into(), Json::u32(CACHE_VERSION)),
            ("kind".into(), Json::str("preset")),
            ("fp".into(), Json::str(fp.clone())),
            ("key".into(), jsonio::parse(&key_text).expect("a rendered key re-parses")),
            ("params".into(), params_to_json(&params)),
            ("max_rounds".into(), Json::u32(preset.max_rounds)),
            (
                "graph".into(),
                match &preset.graph {
                    Some(g) => g.to_json(),
                    None => Json::Null,
                },
            ),
        ]);
        append_line(&mut inner, &self.dir, &line);
        inner.presets.insert(
            fp,
            PresetEntry {
                key: key_text,
                params: params
                    .into_iter()
                    .map(|(k, v, e)| (k.to_string(), v, e))
                    .collect(),
                max_rounds: preset.max_rounds,
                graph: preset.graph.clone(),
            },
        );
    }

    /// Integrity check over every stored entry: fingerprints must match
    /// their keys, rows must round-trip losslessly, and presets must be
    /// byte-identical to a fresh regeneration (the generator-drift
    /// detector). Ok carries a human summary; Err lists every issue.
    pub fn verify(&self) -> Result<String, String> {
        let inner = self.inner.lock().unwrap();
        let mut issues = Vec::new();
        for (fp, e) in &inner.cells {
            if fingerprint(&e.key) != *fp {
                issues.push(format!("cell {fp}: stored fingerprint does not match its key"));
            }
            if let Err(why) = check_row_round_trip(&e.row) {
                issues.push(format!("cell {fp}: {why}"));
            }
        }
        for (fp, e) in &inner.presets {
            if fingerprint(&e.key) != *fp {
                issues.push(format!("preset {fp}: stored fingerprint does not match its key"));
                continue;
            }
            match verify_preset(e) {
                Ok(()) => {}
                Err(why) => issues.push(format!("preset {fp}: {why}")),
            }
        }
        if issues.is_empty() {
            Ok(format!(
                "verified {} cell row(s) and {} preset(s): all fingerprints match, rows \
                 round-trip losslessly, presets regenerate byte-identically",
                inner.cells.len(),
                inner.presets.len()
            ))
        } else {
            Err(issues.join("\n"))
        }
    }
}

/// Regenerate a stored preset from its own key and compare — any
/// difference means a workload generator changed since the entry was
/// written (time to bump [`CACHE_VERSION`]).
fn verify_preset(e: &PresetEntry) -> Result<(), String> {
    let key = jsonio::parse(&e.key)?;
    let app_name = key.get("app")?.as_str()?;
    let id = registry::resolve(app_name)
        .ok_or_else(|| format!("unknown workload '{app_name}' in stored key"))?;
    let size = super::size_from_name(key.get("size")?.as_str()?)?;
    let seed = key.get("seed")?.as_u64()?;
    let overrides = jsonio::pairs_from_json(key.get("params")?)?;
    let fresh = WorkloadPreset::with_params(id, size, seed, &overrides)
        .map_err(|why| format!("stored key no longer resolves: {why}"))?;
    let fresh_params = fresh.params.entries();
    let same_params = fresh_params.len() == e.params.len()
        && fresh_params
            .iter()
            .zip(&e.params)
            .all(|((fk, fv, fe), (sk, sv, se))| fk == sk && fv == sv && fe == se);
    if !same_params {
        return Err("stored parameters differ from a fresh resolve (registry drift)".into());
    }
    if fresh.max_rounds != e.max_rounds {
        return Err(format!(
            "stored max_rounds {} differs from regenerated {} (generator drift)",
            e.max_rounds, fresh.max_rounds
        ));
    }
    let fresh_graph = fresh.graph.as_ref().map(|g| g.to_json().render());
    let stored_graph = e.graph.as_ref().map(|g| g.to_json().render());
    if fresh_graph != stored_graph {
        return Err("stored graph differs from a fresh generation (generator drift)".into());
    }
    Ok(())
}

fn params_to_json(entries: &[(&'static str, f64, bool)]) -> Json {
    Json::Arr(
        entries
            .iter()
            .map(|(k, v, explicit)| {
                Json::Arr(vec![Json::str(*k), Json::f64(*v), Json::Bool(*explicit)])
            })
            .collect(),
    )
}

fn params_from_json(v: &Json) -> Result<Vec<(String, f64, bool)>, String> {
    let mut out = Vec::new();
    for item in v.arr()? {
        let triple = item.arr()?;
        if triple.len() != 3 {
            return Err(format!(
                "parameter entry must be [key, value, explicit], got {} element(s)",
                triple.len()
            ));
        }
        out.push((
            triple[0].as_str()?.to_string(),
            triple[1].as_f64()?,
            triple[2].as_bool()?,
        ));
    }
    Ok(out)
}

/// Parse one segment line into the in-memory maps. Errors bubble to the
/// caller, which skips the line loudly.
fn scan_line(inner: &mut StoreInner, line: &str) -> Result<(), String> {
    let v = jsonio::parse(line)?;
    let version = v.get("cache_version")?.as_u32()?;
    if version != CACHE_VERSION {
        return Err(format!(
            "record is cache version {version}, this binary speaks {CACHE_VERSION}"
        ));
    }
    let kind = v.get("kind")?.as_str()?.to_string();
    let fp = v.get("fp")?.as_str()?.to_string();
    // Re-render the parsed key: raw number tokens survive the parse, so
    // this reproduces the original rendering exactly.
    let key_text = v.get("key")?.render();
    match kind.as_str() {
        "cell" => {
            let row = row_value_from_json(v.get("row")?)?;
            match inner.cells.get(&fp) {
                Some(e) if e.key == key_text => {} // duplicate append, keep first
                Some(_) => {
                    eprintln!(
                        "cache: fingerprint collision on {fp} across segments; keeping the first entry"
                    );
                }
                None => {
                    inner.cells.insert(fp, CellEntry { key: key_text, row });
                }
            }
        }
        "preset" => {
            let params = params_from_json(v.get("params")?)?;
            let max_rounds = v.get("max_rounds")?.as_u32()?;
            let graph = match v.get("graph")? {
                Json::Null => None,
                other => Some(Graph::from_json(other)?),
            };
            match inner.presets.get(&fp) {
                Some(e) if e.key == key_text => {}
                Some(_) => {
                    eprintln!(
                        "cache: fingerprint collision on {fp} across segments; keeping the first entry"
                    );
                }
                None => {
                    inner.presets.insert(
                        fp,
                        PresetEntry {
                            key: key_text,
                            params,
                            max_rounds,
                            graph,
                        },
                    );
                }
            }
        }
        other => return Err(format!("unknown record kind '{other}'")),
    }
    Ok(())
}

/// Append one record to this process's segment, opening it lazily (a
/// warm run that inserts nothing creates no files). IO errors are loud
/// but non-fatal.
fn append_line(inner: &mut StoreInner, dir: &Path, line: &Json) {
    if inner.writer.is_none() {
        let path = dir.join(format!("segment-{}.jsonl", std::process::id()));
        match OpenOptions::new().create(true).append(true).open(&path) {
            Ok(f) => {
                inner.segments += 1;
                inner.writer = Some(BufWriter::new(f));
            }
            Err(e) => {
                eprintln!("cache: cannot open '{}' for append: {e}", path.display());
                return;
            }
        }
    }
    let writer = inner.writer.as_mut().expect("writer opened above");
    let mut text = line.render();
    text.push('\n');
    if let Err(e) = writer.write_all(text.as_bytes()).and_then(|()| writer.flush()) {
        eprintln!("cache: write failed: {e}");
    }
}

/// Append one run's counters to `<dir>/runs.jsonl` (`srsp cache stats`
/// reads them back). Best-effort: failures are loud but never fail the
/// run that produced the counters.
pub fn record_run(dir: &str, counters: &CacheCounters) {
    let path = PathBuf::from(dir).join("runs.jsonl");
    let mut line = Json::Obj(vec![
        ("cache_version".into(), Json::u32(CACHE_VERSION)),
        ("kind".into(), Json::str("run")),
    ]);
    if let Json::Obj(fields) = &mut line {
        if let Json::Obj(counter_fields) = counters.to_json() {
            fields.extend(counter_fields);
        }
    }
    let mut text = line.render();
    text.push('\n');
    let result = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| f.write_all(text.as_bytes()));
    if let Err(e) = result {
        eprintln!("cache: cannot record run stats in '{}': {e}", path.display());
    }
}

/// All recorded per-run counters, oldest first. Corrupt lines are
/// skipped loudly; a missing file is an empty history.
pub fn run_records(dir: &str) -> Vec<CacheCounters> {
    let path = PathBuf::from(dir).join("runs.jsonl");
    let Ok(text) = fs::read_to_string(&path) else {
        return Vec::new();
    };
    let mut records = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let parsed = jsonio::parse(line).and_then(|v| CacheCounters::from_json(&v));
        match parsed {
            Ok(c) => records.push(c),
            Err(why) => {
                eprintln!("cache: skipping {}:{}: {why}", path.display(), lineno + 1);
            }
        }
    }
    records
}

/// Delete the store's own files (`segment-*.jsonl` and `runs.jsonl`),
/// leaving anything foreign in place with a note. Returns a summary.
pub fn clear(dir: &str) -> Result<String, String> {
    let dir_path = PathBuf::from(dir);
    let entries = fs::read_dir(&dir_path)
        .map_err(|e| format!("cache: cannot read directory '{dir}': {e}"))?;
    let mut removed = 0usize;
    let mut foreign = Vec::new();
    for entry in entries.filter_map(|e| e.ok()) {
        let path = entry.path();
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or("")
            .to_string();
        let ours = name == "runs.jsonl"
            || (name.starts_with("segment-") && name.ends_with(".jsonl"));
        if ours {
            fs::remove_file(&path)
                .map_err(|e| format!("cache: cannot remove '{}': {e}", path.display()))?;
            removed += 1;
        } else {
            foreign.push(name);
        }
    }
    let mut summary = format!("removed {removed} cache file(s) from {dir}");
    if !foreign.is_empty() {
        summary.push_str(&format!(
            "; left {} foreign file(s) in place: {}",
            foreign.len(),
            foreign.join(", ")
        ));
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Cell;
    use crate::config::Scenario;

    fn scratch(name: &str) -> String {
        let dir = std::env::temp_dir().join(format!(
            "srsp-cache-unit-{}-{name}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir.to_str().unwrap().to_string()
    }

    fn planned_cell(seed: u64) -> PlannedCell {
        PlannedCell {
            cell: Cell {
                app: registry::STRESS,
                scenario: Scenario::SRSP,
                num_cus: 4,
            },
            seed,
            params: vec![("remote_ratio".to_string(), 0.5)],
            proto_params: Vec::new(),
            axis_values: "remote-ratio=0.5".to_string(),
        }
    }

    #[test]
    fn fingerprints_are_stable_and_discriminating() {
        let a = fingerprint("hello");
        assert_eq!(a.len(), 32);
        assert_eq!(a, fingerprint("hello"), "deterministic");
        assert_ne!(a, fingerprint("hello!"));
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn counters_sum_exhaustively() {
        let mut a = CacheCounters {
            hits: 1,
            misses: 2,
            preset_reuses: 3,
        };
        let b = CacheCounters {
            hits: 10,
            misses: 20,
            preset_reuses: 30,
        };
        a.add(&b);
        assert_eq!(a.hits, 11);
        assert_eq!(a.misses, 22);
        assert_eq!(a.preset_reuses, 33);
        assert_eq!(a.lookups(), 33);
        let back = CacheCounters::from_json(&a.to_json()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn cell_keys_embed_every_version_gate() {
        let cfg = DeviceConfig::small();
        let key = cell_key(&cfg, WorkloadSize::Tiny, true, &planned_cell(7)).render();
        assert!(key.contains(&format!("\"cache_version\":{CACHE_VERSION}")), "{key}");
        assert!(key.contains(&format!("\"plan_version\":{PLAN_VERSION}")), "{key}");
        assert!(
            key.contains(&format!("\"report_version\":{}", REPORT_SCHEMA.version)),
            "{key}"
        );
        // Per-cell CU count reaches the effective device config.
        let mut other = planned_cell(7);
        other.cell.num_cus = 8;
        assert_ne!(key, cell_key(&cfg, WorkloadSize::Tiny, true, &other).render());
        // Seed and params discriminate too.
        assert_ne!(key, cell_key(&cfg, WorkloadSize::Tiny, true, &planned_cell(8)).render());
    }

    #[test]
    fn preset_round_trips_through_the_store() {
        let dir = scratch("preset");
        let overrides = vec![("remote_ratio".to_string(), 0.25)];
        let key = preset_key(registry::STRESS, WorkloadSize::Tiny, 11, &overrides);
        let preset =
            WorkloadPreset::with_params(registry::STRESS, WorkloadSize::Tiny, 11, &overrides)
                .unwrap();
        {
            let store = CacheStore::open(&dir).unwrap();
            assert!(store
                .load_preset(&key, registry::STRESS, WorkloadSize::Tiny, 11)
                .is_none());
            store.insert_preset(&key, &preset);
        }
        // A second process generation: reopen from disk.
        let store = CacheStore::open(&dir).unwrap();
        let back = store
            .load_preset(&key, registry::STRESS, WorkloadSize::Tiny, 11)
            .expect("stored preset reloads");
        assert_eq!(back.params, preset.params);
        assert_eq!(back.max_rounds, preset.max_rounds);
        assert_eq!(back.seed, 11);
        assert_eq!(
            back.graph.as_ref().map(|g| g.to_json().render()),
            preset.graph.as_ref().map(|g| g.to_json().render())
        );
        assert_eq!(store.take_counters().preset_reuses, 1);
        assert!(store.verify().is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_foreign_lines_are_skipped_loudly() {
        let dir = scratch("corrupt");
        fs::create_dir_all(&dir).unwrap();
        fs::write(
            PathBuf::from(&dir).join("segment-zz.jsonl"),
            "this is not json\n{\"cache_version\":999,\"kind\":\"cell\"}\n\
             {\"cache_version\":1,\"kind\":\"martian\",\"fp\":\"00\",\"key\":{}}\n",
        )
        .unwrap();
        let store = CacheStore::open(&dir).unwrap();
        let s = store.summary();
        assert_eq!(s.skipped, 3, "every bad line skipped");
        assert_eq!(s.cells, 0);
        assert_eq!(s.presets, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_removes_only_store_files() {
        let dir = scratch("clear");
        fs::create_dir_all(&dir).unwrap();
        fs::write(PathBuf::from(&dir).join("segment-1.jsonl"), "").unwrap();
        fs::write(PathBuf::from(&dir).join("runs.jsonl"), "").unwrap();
        fs::write(PathBuf::from(&dir).join("keepme.txt"), "foreign").unwrap();
        let summary = clear(&dir).unwrap();
        assert!(summary.contains("removed 2"), "{summary}");
        assert!(summary.contains("keepme.txt"), "{summary}");
        assert!(PathBuf::from(&dir).join("keepme.txt").exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_records_round_trip() {
        let dir = scratch("runs");
        fs::create_dir_all(&dir).unwrap();
        let counters = CacheCounters {
            hits: 6,
            misses: 0,
            preset_reuses: 2,
        };
        record_run(&dir, &counters);
        record_run(&dir, &CacheCounters::default());
        let records = run_records(&dir);
        assert_eq!(records.len(), 2);
        assert_eq!(records[0], counters);
        assert_eq!(records[1], CacheCounters::default());
        let _ = fs::remove_dir_all(&dir);
    }
}
