//! The resilient sweep service: `srsp serve` / `srsp work` /
//! `srsp submit`.
//!
//! The coordinator (`serve`) accepts queued sweep requests from
//! `submit` clients and dispatches them to a fleet of persistent `work`
//! processes over the [`wire`](super::wire) protocol. It is the
//! long-running face of the same plan → shard → execute → merge
//! pipeline every local run uses:
//!
//! - **accept** — a `request` envelope carries a fully-lowered
//!   [`ExecutionPlan`] (the submit client lowers exactly like a local
//!   sweep, so the coordinator never re-derives seeds or parameters);
//! - **warm probe** — with `--cache`, every cell is looked up in the
//!   PR 8 [`CacheStore`] *before* scheduling: a warm cell is answered
//!   inside the coordinator and never reaches the dispatch queue;
//! - **dispatch** — cold cells are bin-packed into synthetic
//!   single-shard [`ShardSpec`] batches by estimated cost (LPT: the
//!   heaviest cell goes to the lightest batch with room, so one
//!   64-CU cell does not ride with a queue of cheap ones) and dealt to
//!   whichever worker asks first; batch capacity is `--shard-cells`,
//!   or with `--shard-cells auto` is sized from the fleet's observed
//!   per-batch ack times against the deadline; each dispatched batch
//!   is guarded by a per-batch ack deadline (`--deadline`);
//! - **retry** — a worker that dies, hangs past the deadline, or acks
//!   garbage fails its batch: the batch is split in half and re-queued
//!   until the per-batch attempt budget (`--retries` beyond the first
//!   try) is spent, after which the whole job fails loudly. Re-execution
//!   is idempotent — shards are deterministic and rows land by global
//!   grid index, first copy wins;
//! - **stream + merge** — the submit client receives `progress` frames
//!   as batches land and finally one all-covering [`PartialReport`];
//!   `Report::merge` on it reproduces the `--jobs 1` local run
//!   byte-for-byte (the wire reuses the lossless `jsonio` row codec
//!   end to end);
//! - **drain** — with `--max-jobs N` the coordinator stops accepting
//!   after N jobs, finishes what is queued, summarizes, and exits.
//!
//! Fresh oracle-validated rows acked by workers are inserted into the
//! coordinator's store under the same [`cache::cell_key`]s a local
//! `--cache` sweep writes, so a warm resubmit — or a later local run
//! against the same directory — dispatches nothing.

use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::config::DeviceConfig;
use crate::harness::report::{check_row_round_trip, PartialReport, ReportRow};
use crate::harness::runner::{cell_layer_active, execute_shard, execute_shard_cached};
use crate::workload::registry::{self, WorkloadSize};

use super::cache::{self, CacheCounters, CacheStore};
use super::shard::ShardSpec;
use super::wire::{Envelope, Framed, RecvError};
use super::{ExecutionPlan, PlannedCell};

/// Batch-capacity policy for dispatch (`--shard-cells`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardCells {
    /// At most this many cells per dispatched batch.
    Fixed(usize),
    /// Size batches from the fleet's observed ack throughput: target a
    /// quarter of the ack deadline per batch, so a slowing fleet gets
    /// smaller batches (fewer cells forfeited per deadline miss) and a
    /// fast one amortizes dispatch overhead over more cells. Before the
    /// first ack there is nothing to size against; batches start at the
    /// fixed default of 4 cells.
    Auto,
}

/// Coordinator configuration (the `srsp serve` flags, resolved).
pub struct ServeOpts {
    /// TCP address to bind (`host:port`; port 0 picks a free port —
    /// the bound address is announced on stderr either way).
    pub listen: String,
    /// Per-batch ack deadline: a dispatched batch not acked within it
    /// fails (and re-dispatches, budget permitting). Also bounds how
    /// long a fresh connection may sit silent before its hello.
    pub deadline: Duration,
    /// Re-dispatch budget per batch beyond the first attempt.
    pub retries: u32,
    /// Batch capacity policy.
    pub shard_cells: ShardCells,
    /// Drain and exit after this many accepted jobs (`None`: serve
    /// forever).
    pub max_jobs: Option<u64>,
    /// Result-cache directory for the warm probe / fresh-row inserts.
    pub cache_dir: Option<String>,
}

/// The execution shape a job's cells share (from its plan) — what a
/// synthetic batch [`ShardSpec`] and the cache keys are built from.
struct JobShape {
    cfg: DeviceConfig,
    size: WorkloadSize,
    validate: bool,
}

/// One accepted sweep request, tracked until its streamer hands the
/// final partial to the submit client.
struct JobState {
    shape: JobShape,
    total: usize,
    /// Rows land here by global grid index (warm rows at creation,
    /// acked rows as batches complete).
    slots: Vec<Option<ReportRow>>,
    done: usize,
    /// Cells answered from the cache without dispatching.
    warm: usize,
    /// Cells that entered the dispatch queue.
    dispatched: usize,
    /// Monotonic batch-id source (retries mint fresh ids, so a stale
    /// ack can never satisfy a re-dispatched batch).
    next_batch: u64,
    /// Set when a batch exhausts its retry budget; fails the whole job.
    failed: Option<String>,
}

/// One dispatchable unit: a cost-balanced batch of a job's cold cells.
struct Task {
    job: u64,
    batch: u64,
    /// Dispatch attempts already spent on these cells.
    attempts: u32,
    /// Summed [`cell_cost`] of the cells — the denominator the observed
    /// ack time is normalized by.
    cost: u64,
    cells: Vec<(usize, PlannedCell)>,
}

#[derive(Default)]
struct Shared {
    queue: VecDeque<Task>,
    jobs: BTreeMap<u64, JobState>,
    next_job: u64,
    started: u64,
    completed: u64,
    failed_jobs: u64,
    cells_executed: u64,
    cells_warm: u64,
    retries_total: u64,
    /// Observed dispatch→ack wall time summed over delivered batches,
    /// and the model cost those batches carried. Their ratio is the
    /// fleet's nanos-per-cost-unit — what `--shard-cells auto` sizes
    /// fresh batches against.
    ack_nanos: u64,
    ack_cost: u64,
    shutdown: bool,
}

/// Estimated relative cost of simulating one cell. Sim wall time scales
/// with CU count (more agents per cycle), workload scale, and how much
/// traffic the app's kernel generates per CU; the weights only need to
/// rank cells well enough that LPT packing beats a blind chunk — they
/// are never report data. Unknown (future) apps weigh as the heaviest.
fn cell_cost(size: WorkloadSize, pc: &PlannedCell) -> u64 {
    let app = match pc.cell.app {
        registry::STRESS | registry::PRODCONS | registry::LOCK => 1,
        registry::SSSP | registry::MIS | registry::BFS => 3,
        _ => 4, // PRK and anything future: graph-sized frontier every iteration
    };
    let scale = match size {
        WorkloadSize::Tiny => 1,
        WorkloadSize::Paper => 64,
    };
    (pc.cell.num_cus as u64).max(1) * scale * app
}

/// LPT bin-pack `misses` into batches of at most `max_cells` cells:
/// heaviest estimated cell first, into the lightest batch with room
/// (ties on batch order). Within a batch cells are restored to
/// ascending grid order — the shard convention workers and `deliver`
/// both assume. A pure function of `(misses, size, max_cells)`, so a
/// resubmitted plan packs identically.
fn pack_batches(
    misses: Vec<(usize, PlannedCell)>,
    size: WorkloadSize,
    max_cells: usize,
) -> Vec<(u64, Vec<(usize, PlannedCell)>)> {
    let max_cells = max_cells.max(1);
    let bins = misses.len().div_ceil(max_cells).max(1);
    let costs: Vec<u64> = misses.iter().map(|(_, pc)| cell_cost(size, pc)).collect();
    let mut order: Vec<usize> = (0..misses.len()).collect();
    order.sort_by_key(|&k| (std::cmp::Reverse(costs[k]), misses[k].0));
    let mut packed: Vec<(u64, Vec<usize>)> = vec![(0, Vec::new()); bins];
    for k in order {
        let mut best: Option<usize> = None;
        for (i, (load, members)) in packed.iter().enumerate() {
            if members.len() < max_cells && best.map_or(true, |b| *load < packed[b].0) {
                best = Some(i);
            }
        }
        let b = best.expect("bin count times capacity covers every cell");
        packed[b].0 += costs[k];
        packed[b].1.push(k);
    }
    let mut misses: Vec<Option<(usize, PlannedCell)>> = misses.into_iter().map(Some).collect();
    packed
        .into_iter()
        .filter(|(_, members)| !members.is_empty())
        .map(|(load, mut members)| {
            members.sort_unstable();
            let cells = members
                .iter()
                .map(|&k| misses[k].take().expect("bins are disjoint"))
                .collect();
            (load, cells)
        })
        .collect()
}

struct Coord {
    shared: Mutex<Shared>,
    /// Signaled when the queue gains a task (or shutdown flips).
    work_ready: Condvar,
    /// Signaled when any job makes progress or fails.
    job_tick: Condvar,
    store: Option<CacheStore>,
    opts: ServeOpts,
    addr: SocketAddr,
}

/// Run the coordinator until drained (`--max-jobs`) or killed. One
/// thread per connection; workers and submitters share one listener.
pub fn serve(opts: ServeOpts) -> Result<(), String> {
    let listener =
        TcpListener::bind(&opts.listen).map_err(|e| format!("{}: {e}", opts.listen))?;
    let addr = listener
        .local_addr()
        .map_err(|e| format!("{}: {e}", opts.listen))?;
    let store = match &opts.cache_dir {
        Some(dir) => Some(CacheStore::open(dir)?),
        None => None,
    };
    eprintln!("serve: listening on {addr}");
    if let Some(dir) = &opts.cache_dir {
        eprintln!("serve: answering warm cells from result cache {dir}");
    }
    let coord = Arc::new(Coord {
        shared: Mutex::new(Shared::default()),
        work_ready: Condvar::new(),
        job_tick: Condvar::new(),
        store,
        opts,
        addr,
    });
    let mut handles = Vec::new();
    for conn in listener.incoming() {
        if coord.shared.lock().unwrap().shutdown {
            // The drain nudge (or any straggler) lands here; the
            // connection drops unanswered.
            break;
        }
        match conn {
            Ok(stream) => {
                let c = Arc::clone(&coord);
                handles.push(thread::spawn(move || handle_connection(stream, &c)));
            }
            Err(e) => eprintln!("serve: accept failed: {e}"),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let s = coord.shared.lock().unwrap();
    eprintln!(
        "serve: drained after {} job(s): {} cell(s) executed, {} served warm, \
         {} batch retry(s), {} job failure(s)",
        s.completed, s.cells_executed, s.cells_warm, s.retries_total, s.failed_jobs
    );
    Ok(())
}

fn handle_connection(stream: TcpStream, coord: &Coord) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    let mut framed = match Framed::new(stream) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("serve: {peer}: {e}");
            return;
        }
    };
    // A connection that never says hello must not pin its thread past
    // the drain join — the handshake shares the batch deadline.
    let _ = framed.set_read_timeout(Some(coord.opts.deadline));
    let first = framed.recv();
    let _ = framed.set_read_timeout(None);
    match first {
        Ok(Envelope::Hello { role }) if role == "work" => {
            if framed.send(&Envelope::Hello { role: "serve".into() }).is_err() {
                return;
            }
            eprintln!("serve: worker connected from {peer}");
            worker_loop(&mut framed, coord, &peer);
            eprintln!("serve: worker {peer} disconnected");
        }
        Ok(Envelope::Hello { role }) if role == "submit" => {
            if framed.send(&Envelope::Hello { role: "serve".into() }).is_err() {
                return;
            }
            if let Err(e) = submit_loop(&mut framed, coord, &peer) {
                eprintln!("serve: submit {peer}: {e}");
            }
        }
        Ok(Envelope::Hello { role }) => {
            let _ = framed.send(&Envelope::Error {
                msg: format!("unknown hello role '{role}' (expected work or submit)"),
            });
        }
        Ok(_) => {
            let _ = framed.send(&Envelope::Error {
                msg: "expected a hello envelope first".into(),
            });
        }
        Err(RecvError::Closed) => {}
        Err(RecvError::TimedOut) => eprintln!("serve: {peer}: no hello within the deadline"),
        Err(RecvError::Fatal(e)) => {
            // Version mismatches and malformed frames answer loudly so
            // a stale or confused peer sees *why* it was dropped.
            eprintln!("serve: {peer}: {e}");
            let _ = framed.send(&Envelope::Error { msg: e });
        }
    }
}

/// Serve-side loop for one connected worker: pull a task, dispatch it as
/// a batch, await the ack under the deadline. Any failure fails the
/// in-flight task (triggering the retry policy) and drops the
/// connection — the re-dispatched batch goes to a surviving worker.
fn worker_loop(framed: &mut Framed, coord: &Coord, peer: &str) {
    loop {
        let (task, spec) = {
            let mut s = coord.shared.lock().unwrap();
            let task = loop {
                if s.shutdown {
                    break None;
                }
                match s.queue.pop_front() {
                    Some(t) if s.jobs.get(&t.job).is_some_and(|j| j.failed.is_none()) => {
                        break Some(t)
                    }
                    // A task of a failed or finished job: drop it.
                    Some(_) => continue,
                    None => s = coord.work_ready.wait(s).unwrap(),
                }
            };
            let Some(task) = task else { return };
            let job = s.jobs.get(&task.job).expect("live task implies live job");
            let spec = ShardSpec {
                shard: 0,
                num_shards: 1,
                total_cells: job.total,
                cfg: job.shape.cfg.clone(),
                size: job.shape.size,
                validate: job.shape.validate,
                // The store never crosses the wire: warm cells were
                // answered before scheduling and fresh rows are inserted
                // on ack, so workers need no filesystem shared with the
                // coordinator.
                cache_dir: None,
                cells: task.cells.clone(),
            };
            (task, spec)
        };
        eprintln!(
            "serve: job {} batch {} → {peer}: {} cell(s), cost {} (attempt {} of {})",
            task.job,
            task.batch,
            task.cells.len(),
            task.cost,
            task.attempts + 1,
            coord.opts.retries + 1
        );
        let dispatched_at = Instant::now();
        if framed
            .send(&Envelope::Batch { job: task.job, batch: task.batch, spec })
            .is_err()
        {
            fail_task(coord, task, &format!("worker {peer} vanished before dispatch"));
            return;
        }
        if framed.set_read_timeout(Some(coord.opts.deadline)).is_err() {
            fail_task(coord, task, &format!("worker {peer}: cannot arm the ack deadline"));
            return;
        }
        let received = framed.recv();
        let _ = framed.set_read_timeout(None);
        match received {
            Ok(Envelope::Ack { job, batch, partial })
                if job == task.job && batch == task.batch =>
            {
                if let Err(e) = deliver(coord, &task, &partial) {
                    let msg = format!("worker {peer} acked a bad batch: {e}");
                    let _ = framed.send(&Envelope::Error { msg: msg.clone() });
                    fail_task(coord, task, &msg);
                    return;
                }
                record_ack(coord, &task, dispatched_at.elapsed());
            }
            Ok(Envelope::Error { msg }) => {
                fail_task(coord, task, &format!("worker {peer} reported: {msg}"));
                return;
            }
            Ok(_) => {
                let msg = format!("worker {peer} broke the batch/ack protocol");
                let _ = framed.send(&Envelope::Error { msg: msg.clone() });
                fail_task(coord, task, &msg);
                return;
            }
            Err(RecvError::Closed) => {
                fail_task(coord, task, &format!("worker {peer} died mid-batch"));
                return;
            }
            Err(RecvError::TimedOut) => {
                fail_task(
                    coord,
                    task,
                    &format!("worker {peer} missed the {:?} ack deadline", coord.opts.deadline),
                );
                return;
            }
            Err(RecvError::Fatal(e)) => {
                fail_task(coord, task, &format!("worker {peer}: {e}"));
                return;
            }
        }
    }
}

/// Land an acked batch: verify it covers exactly the dispatched cells
/// with lossless rows, fill the job's slots (first copy wins — retries
/// are idempotent), and insert fresh oracle-validated rows into the
/// store under the same keys a local `--cache` sweep writes.
fn deliver(coord: &Coord, task: &Task, partial: &PartialReport) -> Result<(), String> {
    if partial.rows.len() != task.cells.len() {
        return Err(format!(
            "{} row(s) for a {}-cell batch",
            partial.rows.len(),
            task.cells.len()
        ));
    }
    for ((want, _), (got, row)) in task.cells.iter().zip(&partial.rows) {
        if want != got {
            return Err(format!("row for grid index {got} where {want} was dispatched"));
        }
        check_row_round_trip(row)?;
    }
    let mut s = coord.shared.lock().unwrap();
    s.cells_executed += task.cells.len() as u64;
    let Some(job) = s.jobs.get_mut(&task.job) else {
        return Ok(());
    };
    if job.failed.is_some() {
        return Ok(());
    }
    let warm_store = coord
        .store
        .as_ref()
        .filter(|_| cell_layer_active(job.shape.validate, &job.shape.cfg));
    for ((i, pc), (_, row)) in task.cells.iter().zip(&partial.rows) {
        if job.slots[*i].is_none() {
            job.slots[*i] = Some(row.clone());
            job.done += 1;
        }
        if let Some(store) = warm_store {
            if row.validated == Some(true) {
                store.insert_cell(
                    &cache::cell_key(&job.shape.cfg, job.shape.size, job.shape.validate, pc),
                    row,
                );
            }
        }
    }
    coord.job_tick.notify_all();
    Ok(())
}

/// Feed one delivered batch's observed dispatch→ack wall time into the
/// throughput estimate `--shard-cells auto` sizes against.
fn record_ack(coord: &Coord, task: &Task, elapsed: Duration) {
    let mut s = coord.shared.lock().unwrap();
    s.ack_nanos += (elapsed.as_nanos() as u64).max(1);
    s.ack_cost += task.cost.max(1);
}

/// Resolve `--shard-cells auto` for one job: from the fleet's observed
/// nanos-per-cost-unit, pick the cell count whose mean-cost batch runs
/// an estimated quarter of the ack deadline — comfortably inside it,
/// with headroom for stragglers and cost-model error. Clamped to
/// `[1, 64]`; before any batch has acked it falls back to the fixed
/// default of 4.
fn auto_batch_cells(
    s: &Shared,
    deadline: Duration,
    misses: &[(usize, PlannedCell)],
    size: WorkloadSize,
) -> usize {
    const DEFAULT: usize = 4;
    const MAX: usize = 64;
    if s.ack_cost == 0 || misses.is_empty() {
        return DEFAULT;
    }
    let mean_cost = misses.iter().map(|(_, pc)| cell_cost(size, pc)).sum::<u64>() as f64
        / misses.len() as f64;
    let nanos_per_cost = s.ack_nanos as f64 / s.ack_cost as f64;
    let target = deadline.as_nanos() as f64 / 4.0;
    let cells = target / (nanos_per_cost * mean_cost.max(1.0));
    (cells as usize).clamp(1, MAX)
}

/// Apply the retry policy to a failed dispatch: within budget, split a
/// multi-cell batch in half (a poisonous cell isolates itself) and
/// re-queue at the front under fresh batch ids; over budget, fail the
/// whole job loudly.
fn fail_task(coord: &Coord, task: Task, why: &str) {
    let mut s = coord.shared.lock().unwrap();
    {
        let Some(job) = s.jobs.get_mut(&task.job) else { return };
        if job.failed.is_some() {
            return;
        }
        if task.attempts >= coord.opts.retries {
            job.failed = Some(format!(
                "job {}: batch {} failed on all {} attempt(s): {why}",
                task.job,
                task.batch,
                task.attempts + 1
            ));
            eprintln!("serve: {}", job.failed.as_deref().unwrap());
            coord.job_tick.notify_all();
            return;
        }
    }
    let attempts = task.attempts + 1;
    let halves: Vec<Vec<(usize, PlannedCell)>> = if task.cells.len() > 1 {
        let mid = task.cells.len() / 2;
        vec![task.cells[..mid].to_vec(), task.cells[mid..].to_vec()]
    } else {
        vec![task.cells]
    };
    let (ids, size) = {
        let job = s.jobs.get_mut(&task.job).expect("checked above");
        let mut ids = Vec::with_capacity(halves.len());
        for _ in &halves {
            job.next_batch += 1;
            ids.push(job.next_batch);
        }
        (ids, job.shape.size)
    };
    eprintln!(
        "serve: job {} batch {}: {why}; re-dispatching as {} batch(es) (attempt {} of {})",
        task.job,
        task.batch,
        halves.len(),
        attempts + 1,
        coord.opts.retries + 1
    );
    s.retries_total += 1;
    for (cells, batch) in halves.into_iter().zip(ids) {
        let cost = cells.iter().map(|(_, pc)| cell_cost(size, pc)).sum();
        s.queue.push_front(Task { job: task.job, batch, attempts, cost, cells });
    }
    coord.work_ready.notify_all();
}

/// Serve-side loop for one submit client: accept the request, create the
/// job, stream progress until it completes or fails, ship the result.
fn submit_loop(framed: &mut Framed, coord: &Coord, peer: &str) -> Result<(), String> {
    framed.set_read_timeout(Some(coord.opts.deadline))?;
    let plan = match framed.recv() {
        Ok(Envelope::Request { plan }) => plan,
        Ok(_) => return Err("expected a request envelope after the hello".into()),
        Err(RecvError::Closed) => return Ok(()),
        Err(RecvError::TimedOut) => return Err("no request arrived within the deadline".into()),
        Err(RecvError::Fatal(e)) => return Err(e),
    };
    framed.set_read_timeout(None)?;
    let id = match create_job(coord, plan) {
        Ok(id) => id,
        Err(e) => {
            let _ = framed.send(&Envelope::Error { msg: e.clone() });
            return Err(e);
        }
    };
    eprintln!("serve: job {id} accepted from {peer}");
    match stream_job(framed, coord, id) {
        Ok(partial) => {
            eprintln!(
                "serve: job {id} complete: {} cell(s) ({} warm, {} dispatched)",
                partial.total_cells, partial.cache.hits, partial.cache.misses
            );
            let sent = framed.send(&Envelope::Report { job: id, partial });
            finish_job(coord, id, true);
            sent
        }
        Err(e) => {
            let _ = framed.send(&Envelope::Error { msg: e.clone() });
            finish_job(coord, id, false);
            Err(e)
        }
    }
}

/// Accept a lowered plan as a job: probe the cache for warm cells,
/// LPT-pack the misses into cost-balanced tasks, enqueue them, wake the
/// fleet.
fn create_job(coord: &Coord, plan: ExecutionPlan) -> Result<u64, String> {
    if plan.cells.is_empty() {
        return Err("the submitted plan contains no cells".into());
    }
    let total = plan.cells.len();
    let probe = coord
        .store
        .as_ref()
        .filter(|_| cell_layer_active(plan.validate, &plan.cfg));
    let mut slots: Vec<Option<ReportRow>> = (0..total).map(|_| None).collect();
    let mut misses: Vec<(usize, PlannedCell)> = Vec::new();
    let mut warm = 0usize;
    for (i, pc) in plan.cells.iter().enumerate() {
        let hit = probe.and_then(|store| {
            store.lookup_cell(&cache::cell_key(&plan.cfg, plan.size, plan.validate, pc))
        });
        match hit {
            Some(row) => {
                slots[i] = Some(row);
                warm += 1;
            }
            None => misses.push((i, pc.clone())),
        }
    }
    let mut s = coord.shared.lock().unwrap();
    if s.shutdown || coord.opts.max_jobs.is_some_and(|m| s.started >= m) {
        return Err("the coordinator is draining and accepts no further jobs".into());
    }
    s.started += 1;
    s.next_job += 1;
    let id = s.next_job;
    s.cells_warm += warm as u64;
    let max_cells = match coord.opts.shard_cells {
        ShardCells::Fixed(n) => n.max(1),
        ShardCells::Auto => auto_batch_cells(&s, coord.opts.deadline, &misses, plan.size),
    };
    let mut job = JobState {
        shape: JobShape { cfg: plan.cfg, size: plan.size, validate: plan.validate },
        total,
        slots,
        done: warm,
        warm,
        dispatched: misses.len(),
        next_batch: 0,
        failed: None,
    };
    let batches = pack_batches(misses, plan.size, max_cells);
    eprintln!(
        "serve: job {id}: {total} cell(s) ({warm} warm, {} to dispatch in {} batch(es), \
         {max_cells} cell(s)/batch cap)",
        job.dispatched,
        batches.len()
    );
    for (cost, cells) in batches {
        job.next_batch += 1;
        s.queue.push_back(Task { job: id, batch: job.next_batch, attempts: 0, cost, cells });
    }
    s.jobs.insert(id, job);
    coord.work_ready.notify_all();
    // An all-warm job is born complete; wake its own streamer too.
    coord.job_tick.notify_all();
    Ok(id)
}

/// Stream `progress` frames to the submit client as batches land, then
/// assemble the finished job as one all-covering partial (or surface
/// the job's failure).
fn stream_job(framed: &mut Framed, coord: &Coord, id: u64) -> Result<PartialReport, String> {
    let mut last_done = usize::MAX;
    loop {
        let (done, total, warm, dispatched, failed) = {
            let mut s = coord.shared.lock().unwrap();
            loop {
                let job = s.jobs.get(&id).expect("the job lives until finish_job");
                if job.failed.is_some() || job.done != last_done {
                    break;
                }
                s = coord.job_tick.wait(s).unwrap();
            }
            let job = s.jobs.get(&id).expect("the job lives until finish_job");
            (job.done, job.total, job.warm, job.dispatched, job.failed.clone())
        };
        if let Some(e) = failed {
            return Err(e);
        }
        last_done = done;
        // Progress is advisory: a vanished submit client must not stall
        // the fleet, so send failures are ignored and the job runs on
        // (its fresh rows still warm the cache for a resubmit).
        let _ = framed.send(&Envelope::Progress { job: id, done, total, warm, dispatched });
        if done == total {
            let mut s = coord.shared.lock().unwrap();
            let job = s.jobs.get_mut(&id).expect("the job lives until finish_job");
            let rows: Vec<(usize, ReportRow)> = job
                .slots
                .iter_mut()
                .enumerate()
                .map(|(i, r)| (i, r.take().expect("a complete job has every row")))
                .collect();
            let counters = CacheCounters {
                hits: job.warm as u64,
                misses: job.dispatched as u64,
                preset_reuses: 0,
            };
            return Ok(PartialReport::from_grid(rows, counters));
        }
    }
}

/// Retire a finished job: bookkeeping, per-job cache run record, and —
/// once `--max-jobs` jobs have all retired — flip the drain switch and
/// nudge the accept loop awake so `serve` can exit.
fn finish_job(coord: &Coord, id: u64, succeeded: bool) {
    let (record, drained) = {
        let mut s = coord.shared.lock().unwrap();
        let job = s.jobs.remove(&id);
        s.completed += 1;
        if !succeeded {
            s.failed_jobs += 1;
        }
        s.queue.retain(|t| t.job != id);
        let drained = coord.opts.max_jobs.is_some_and(|m| s.started >= m)
            && s.jobs.is_empty();
        if drained && !s.shutdown {
            s.shutdown = true;
            coord.work_ready.notify_all();
        }
        let record = job.filter(|_| succeeded).map(|j| CacheCounters {
            hits: j.warm as u64,
            misses: j.dispatched as u64,
            preset_reuses: 0,
        });
        (record, drained)
    };
    if let (Some(store), Some(counters)) = (&coord.store, record) {
        // One runs.jsonl record per job, like a local cached sweep —
        // `srsp cache stats` reports served jobs the same way.
        cache::record_run(store.dir(), &counters);
    }
    if drained {
        // The accept loop blocks in `incoming()`; a throwaway local
        // connection makes it observe the shutdown flag and exit.
        let _ = TcpStream::connect(coord.addr);
    }
}

/// `srsp work`: the persistent remote executor. Dials the coordinator
/// and executes dispatched batches — through the shared result-cache
/// path when this worker was given its own `--cache` — until the
/// coordinator drains (clean exit) or the connection breaks.
///
/// `die_after`: deterministic fault injection for the retry path — the
/// worker exits abruptly (status 3) on batch `n+1`, *after* simulating
/// it but *before* acking. From the coordinator's view that is the
/// worst-timed death: work done, results lost mid-shard.
pub fn run_worker(
    addr: &str,
    cache_dir: Option<&str>,
    die_after: Option<u64>,
) -> Result<(), String> {
    let store = match cache_dir {
        Some(dir) => Some(CacheStore::open(dir)?),
        None => None,
    };
    let mut framed = connect(addr, "work")?;
    eprintln!("work: connected to {addr}");
    let mut acked: u64 = 0;
    loop {
        match framed.recv() {
            Ok(Envelope::Batch { job, batch, spec }) => {
                eprintln!("work: job {job} batch {batch}: {} cell(s) ...", spec.cells.len());
                let partial = execute_batch(&spec, store.as_ref());
                if die_after.is_some_and(|n| acked >= n) {
                    eprintln!("work: --die-after {acked}: dying before the ack");
                    std::process::exit(3);
                }
                framed.send(&Envelope::Ack { job, batch, partial })?;
                acked += 1;
            }
            Ok(Envelope::Error { msg }) => return Err(format!("coordinator: {msg}")),
            Ok(_) => return Err("coordinator broke the batch/ack protocol".into()),
            Err(RecvError::Closed) => {
                eprintln!("work: coordinator drained; {acked} batch(es) executed");
                return Ok(());
            }
            Err(RecvError::TimedOut) => return Err("the connection timed out".into()),
            Err(RecvError::Fatal(e)) => return Err(e),
        }
    }
}

/// Execute one dispatched batch through the same shard executors every
/// local path uses.
fn execute_batch(spec: &ShardSpec, store: Option<&CacheStore>) -> PartialReport {
    match store {
        Some(store) => {
            let (outcomes, counters) = execute_shard_cached(spec, store);
            PartialReport::from_outcomes(spec, &outcomes, counters)
        }
        None => PartialReport::from_shard(spec, &execute_shard(spec)),
    }
}

/// `srsp submit`: ship one lowered plan to the coordinator, stream its
/// progress to stderr, and return the job's single all-covering
/// [`PartialReport`] — `Report::merge(&[partial])` reproduces the
/// byte-identical local report.
pub fn submit(addr: &str, plan: &ExecutionPlan) -> Result<PartialReport, String> {
    let mut framed = connect(addr, "submit")?;
    framed.send(&Envelope::Request { plan: plan.clone() })?;
    loop {
        match framed.recv() {
            Ok(Envelope::Progress { done, total, warm, dispatched, .. }) => {
                eprintln!(
                    "submit: {done}/{total} cell(s) done ({warm} warm, {dispatched} dispatched)"
                );
            }
            Ok(Envelope::Report { partial, .. }) => {
                eprintln!(
                    "submit: job complete: {} cell(s) ({} warm, {} dispatched)",
                    partial.total_cells, partial.cache.hits, partial.cache.misses
                );
                return Ok(partial);
            }
            Ok(Envelope::Error { msg }) => return Err(format!("coordinator: {msg}")),
            Ok(_) => return Err("coordinator broke the request/report protocol".into()),
            Err(RecvError::Closed) => {
                return Err("coordinator closed the connection mid-job".into())
            }
            Err(RecvError::TimedOut) => return Err("the connection timed out".into()),
            Err(RecvError::Fatal(e)) => return Err(e),
        }
    }
}

/// Dial the coordinator and complete the hello handshake as `role`.
fn connect(addr: &str, role: &str) -> Result<Framed, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("{addr}: {e}"))?;
    let mut framed = Framed::new(stream)?;
    framed.send(&Envelope::Hello { role: role.into() })?;
    match framed.recv() {
        Ok(Envelope::Hello { .. }) => Ok(framed),
        Ok(Envelope::Error { msg }) => Err(format!("coordinator: {msg}")),
        Ok(_) => Err("coordinator answered the hello with a non-hello envelope".into()),
        Err(RecvError::Closed) => {
            Err("coordinator closed the connection during the handshake".into())
        }
        Err(RecvError::TimedOut) => Err("the handshake timed out".into()),
        Err(RecvError::Fatal(e)) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scenario;
    use crate::coordinator::Cell;

    fn pc(index: usize, num_cus: u32) -> (usize, PlannedCell) {
        (
            index,
            PlannedCell {
                cell: Cell { app: registry::STRESS, scenario: Scenario::SRSP, num_cus },
                seed: 1,
                params: vec![],
                proto_params: vec![],
                axis_values: String::new(),
            },
        )
    }

    #[test]
    fn cost_model_scales_with_cus_size_and_app() {
        let (_, light) = pc(0, 2);
        let (_, heavy) = pc(1, 64);
        assert_eq!(cell_cost(WorkloadSize::Tiny, &light), 2);
        assert_eq!(cell_cost(WorkloadSize::Tiny, &heavy), 64);
        assert_eq!(cell_cost(WorkloadSize::Paper, &light), 2 * 64);
        let mut graph = heavy.clone();
        graph.cell.app = registry::PRK;
        assert!(cell_cost(WorkloadSize::Tiny, &graph) > cell_cost(WorkloadSize::Tiny, &heavy));
    }

    #[test]
    fn lpt_packing_splits_heavy_cells_across_batches() {
        // Two 64-CU cells among cheap ones: a blind 3-cell chunking puts
        // both heavies in one batch; LPT lands one in each.
        let misses = vec![pc(0, 64), pc(1, 2), pc(2, 2), pc(3, 2), pc(4, 2), pc(5, 64)];
        let batches = pack_batches(misses.clone(), WorkloadSize::Tiny, 3);
        assert_eq!(batches.len(), 2);
        for (cost, cells) in &batches {
            assert!(cells.len() <= 3);
            assert_eq!(
                cells.iter().filter(|(_, p)| p.cell.num_cus == 64).count(),
                1,
                "each batch carries exactly one heavy cell"
            );
            let want: u64 = cells.iter().map(|(_, p)| cell_cost(WorkloadSize::Tiny, p)).sum();
            assert_eq!(*cost, want);
            // Within a batch, cells stay in ascending grid order.
            let idx: Vec<usize> = cells.iter().map(|(i, _)| *i).collect();
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            assert_eq!(idx, sorted);
        }
        // Complete and disjoint over the input.
        let mut seen: Vec<usize> =
            batches.iter().flat_map(|(_, c)| c.iter().map(|(i, _)| *i)).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<_>>());
        // Pure function of the input: packing again packs identically.
        let again = pack_batches(misses, WorkloadSize::Tiny, 3);
        for ((ca, ba), (cb, bb)) in batches.iter().zip(&again) {
            assert_eq!(ca, cb);
            assert_eq!(ba, bb);
        }
    }

    #[test]
    fn auto_sizing_tracks_observed_throughput() {
        let misses = vec![pc(0, 10), pc(1, 10)];
        let deadline = Duration::from_secs(40); // target: 10 s per batch
        // No acks yet: the fixed default.
        let s = Shared::default();
        assert_eq!(auto_batch_cells(&s, deadline, &misses, WorkloadSize::Tiny), 4);
        // Fast fleet (1 ms per cost unit): a mean-cost-10 cell runs 10 ms,
        // so ~1000 cells fit the target -- clamped to the 64 cap.
        let mut s = Shared::default();
        s.ack_nanos = 1_000_000_000;
        s.ack_cost = 1_000;
        assert_eq!(auto_batch_cells(&s, deadline, &misses, WorkloadSize::Tiny), 64);
        // Slow fleet (10 s per cost unit): even one cell overshoots; the
        // floor keeps batches dispatchable.
        s.ack_nanos = 10_000_000_000;
        s.ack_cost = 1;
        assert_eq!(auto_batch_cells(&s, deadline, &misses, WorkloadSize::Tiny), 1);
        // Mid fleet: 50 ms per cost unit, 0.5 s per mean cell -> 20 cells.
        s.ack_nanos = 50_000_000;
        s.ack_cost = 1;
        assert_eq!(auto_batch_cells(&s, deadline, &misses, WorkloadSize::Tiny), 20);
    }
}
