//! The sweep-service wire protocol: version-gated JSON envelopes over
//! TCP, newline-framed.
//!
//! `srsp serve`, `srsp work` and `srsp submit` speak this protocol over
//! plain [`std::net`] sockets — no external dependencies. Every frame is
//! one line of compact JSON (the [`jsonio`] renderer never emits a raw
//! newline, so a line is always exactly one envelope) carrying a
//! `wire_version` field; a peer from a different binary generation is
//! refused loudly, never misread. The payloads reuse the pipeline's
//! existing lossless codecs verbatim — an [`ExecutionPlan`] rides a
//! `request`, a [`ShardSpec`] rides a `batch`, a [`PartialReport`] rides
//! an `ack` or the final `report` — so a sweep that crosses the wire
//! merges byte-identical to one that never left the process.
//!
//! Conversation shape (client speaks first):
//!
//! ```text
//! work   → hello{role:"work"}    ← hello{role:"serve"}
//!        ← batch{job,batch,spec} → ack{job,batch,partial}   (repeats)
//! submit → hello{role:"submit"}  ← hello{role:"serve"}
//!        → request{plan}         ← progress{...}* then report{partial}
//! any error on either side       ← error{msg}, connection dropped
//! ```

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::harness::report::PartialReport;
use crate::jsonio::{self, Json};

use super::shard::ShardSpec;
use super::ExecutionPlan;

/// Version tag carried by every envelope; bumped on any change to the
/// frame shapes. A mismatched peer is refused during decode, so a stale
/// worker can never execute (or ack) a frame it misunderstands.
pub const WIRE_VERSION: u32 = 1;

/// One wire frame. The pipeline artifacts are embedded as JSON values
/// (not nested strings) by re-parsing their own lossless renderings, so
/// a frame stays one readable object and the artifact codecs remain the
/// single source of truth for their shapes.
#[derive(Debug, Clone)]
pub enum Envelope {
    /// Connection opener, both directions: the client names its role
    /// (`work` or `submit`), the coordinator answers with `serve`.
    Hello { role: String },
    /// submit → serve: run this lowered plan as one job.
    Request { plan: ExecutionPlan },
    /// serve → work: execute this synthetic single-shard batch.
    Batch {
        job: u64,
        batch: u64,
        spec: ShardSpec,
    },
    /// work → serve: the batch's results, lossless.
    Ack {
        job: u64,
        batch: u64,
        partial: PartialReport,
    },
    /// serve → submit: job progress as batches land.
    Progress {
        job: u64,
        done: usize,
        total: usize,
        warm: usize,
        dispatched: usize,
    },
    /// serve → submit: the finished job as one all-covering partial —
    /// `Report::merge` on it reproduces the local run byte-for-byte.
    Report { job: u64, partial: PartialReport },
    /// Either direction: the peer broke the protocol; connection drops.
    Error { msg: String },
}

/// Re-parse an artifact's own rendering into a [`Json`] value for
/// embedding. The artifact codecs only emit what [`jsonio`] parses, so
/// a failure here is a codec bug, not an input condition.
fn embed(text: &str) -> Json {
    jsonio::parse(text).expect("artifact codecs render valid JSON")
}

impl Envelope {
    /// Render as one compact single-line JSON frame (no trailing
    /// newline; the transport adds the frame delimiter).
    pub fn to_json(&self) -> String {
        let mut fields = vec![("wire_version".into(), Json::u32(WIRE_VERSION))];
        match self {
            Envelope::Hello { role } => {
                fields.push(("kind".into(), Json::str("hello")));
                fields.push(("role".into(), Json::str(role.clone())));
            }
            Envelope::Request { plan } => {
                fields.push(("kind".into(), Json::str("request")));
                fields.push(("plan".into(), embed(&plan.to_json())));
            }
            Envelope::Batch { job, batch, spec } => {
                fields.push(("kind".into(), Json::str("batch")));
                fields.push(("job".into(), Json::u64(*job)));
                fields.push(("batch".into(), Json::u64(*batch)));
                fields.push(("spec".into(), embed(&spec.to_json())));
            }
            Envelope::Ack {
                job,
                batch,
                partial,
            } => {
                fields.push(("kind".into(), Json::str("ack")));
                fields.push(("job".into(), Json::u64(*job)));
                fields.push(("batch".into(), Json::u64(*batch)));
                fields.push(("partial".into(), embed(&partial.to_json())));
            }
            Envelope::Progress {
                job,
                done,
                total,
                warm,
                dispatched,
            } => {
                fields.push(("kind".into(), Json::str("progress")));
                fields.push(("job".into(), Json::u64(*job)));
                fields.push(("done".into(), Json::usize(*done)));
                fields.push(("total".into(), Json::usize(*total)));
                fields.push(("warm".into(), Json::usize(*warm)));
                fields.push(("dispatched".into(), Json::usize(*dispatched)));
            }
            Envelope::Report { job, partial } => {
                fields.push(("kind".into(), Json::str("report")));
                fields.push(("job".into(), Json::u64(*job)));
                fields.push(("partial".into(), embed(&partial.to_json())));
            }
            Envelope::Error { msg } => {
                fields.push(("kind".into(), Json::str("error")));
                fields.push(("msg".into(), Json::str(msg.clone())));
            }
        }
        Json::Obj(fields).render()
    }

    /// Decode one frame; loud on malformation, a wire version this
    /// binary does not speak, or an unknown envelope kind. The embedded
    /// artifacts go back through their own versioned `from_json` codecs,
    /// so plan/report schema drift is caught with the same messages the
    /// file-based pipeline prints.
    pub fn from_json(text: &str) -> Result<Envelope, String> {
        let v = jsonio::parse(text).map_err(|e| format!("malformed wire frame: {e}"))?;
        let version = v
            .get("wire_version")
            .and_then(|x| x.as_u32())
            .map_err(|e| format!("malformed wire frame: {e}"))?;
        if version != WIRE_VERSION {
            return Err(format!(
                "peer speaks wire version {version}, this binary speaks {WIRE_VERSION}"
            ));
        }
        let kind = v.get("kind")?.as_str()?;
        match kind {
            "hello" => Ok(Envelope::Hello {
                role: v.get("role")?.as_str()?.to_string(),
            }),
            "request" => Ok(Envelope::Request {
                plan: ExecutionPlan::from_json(&v.get("plan")?.render())?,
            }),
            "batch" => Ok(Envelope::Batch {
                job: v.get("job")?.as_u64()?,
                batch: v.get("batch")?.as_u64()?,
                spec: ShardSpec::from_json(&v.get("spec")?.render())?,
            }),
            "ack" => Ok(Envelope::Ack {
                job: v.get("job")?.as_u64()?,
                batch: v.get("batch")?.as_u64()?,
                partial: PartialReport::from_json(&v.get("partial")?.render())?,
            }),
            "progress" => Ok(Envelope::Progress {
                job: v.get("job")?.as_u64()?,
                done: v.get("done")?.as_usize()?,
                total: v.get("total")?.as_usize()?,
                warm: v.get("warm")?.as_usize()?,
                dispatched: v.get("dispatched")?.as_usize()?,
            }),
            "report" => Ok(Envelope::Report {
                job: v.get("job")?.as_u64()?,
                partial: PartialReport::from_json(&v.get("partial")?.render())?,
            }),
            "error" => Ok(Envelope::Error {
                msg: v.get("msg")?.as_str()?.to_string(),
            }),
            other => Err(format!("unknown wire envelope kind '{other}'")),
        }
    }
}

/// Why a [`Framed::recv`] returned no envelope. `Closed` and `TimedOut`
/// are ordinary fleet events (a worker died, a worker hung) the
/// coordinator's retry policy consumes; `Fatal` is a protocol violation
/// that drops the connection.
#[derive(Debug)]
pub enum RecvError {
    /// The peer closed the connection (EOF).
    Closed,
    /// No complete frame arrived within the configured read deadline.
    TimedOut,
    /// I/O failure or an undecodable frame.
    Fatal(String),
}

/// A newline-framed envelope transport over one [`TcpStream`]. Reader
/// and writer are duplicated handles on the same socket, so a read
/// deadline set via [`Framed::set_read_timeout`] never blocks sends.
pub struct Framed {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Framed {
    pub fn new(stream: TcpStream) -> Result<Framed, String> {
        let reader = stream
            .try_clone()
            .map_err(|e| format!("cannot clone the connection: {e}"))?;
        Ok(Framed {
            writer: stream,
            reader: BufReader::new(reader),
        })
    }

    /// Write one envelope frame and flush it onto the wire.
    pub fn send(&mut self, envelope: &Envelope) -> Result<(), String> {
        let mut line = envelope.to_json();
        line.push('\n');
        self.writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.flush())
            .map_err(|e| format!("send failed: {e}"))
    }

    /// Bound how long [`Framed::recv`] blocks for the next frame; `None`
    /// waits forever. The deadline covers one whole frame: a peer that
    /// trickles half a line then stalls times out like a silent one.
    pub fn set_read_timeout(&mut self, deadline: Option<Duration>) -> Result<(), String> {
        self.reader
            .get_ref()
            .set_read_timeout(deadline)
            .map_err(|e| format!("cannot set the read deadline: {e}"))
    }

    /// Read and decode the next frame.
    pub fn recv(&mut self) -> Result<Envelope, RecvError> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => Err(RecvError::Closed),
            Ok(_) => Envelope::from_json(line.trim_end_matches(['\r', '\n']))
                .map_err(RecvError::Fatal),
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                Err(RecvError::TimedOut)
            }
            Err(e) => Err(RecvError::Fatal(format!("receive failed: {e}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn envelopes_round_trip() {
        let hello = Envelope::Hello {
            role: "work".into(),
        };
        let text = hello.to_json();
        assert!(!text.contains('\n'), "frames must be single-line");
        match Envelope::from_json(&text).unwrap() {
            Envelope::Hello { role } => assert_eq!(role, "work"),
            other => panic!("decoded {other:?}"),
        }
        let progress = Envelope::Progress {
            job: 3,
            done: 2,
            total: 6,
            warm: 1,
            dispatched: 5,
        };
        match Envelope::from_json(&progress.to_json()).unwrap() {
            Envelope::Progress {
                job,
                done,
                total,
                warm,
                dispatched,
            } => {
                assert_eq!((job, done, total, warm, dispatched), (3, 2, 6, 1, 5));
            }
            other => panic!("decoded {other:?}"),
        }
        let err = Envelope::Error {
            msg: "boom".into(),
        };
        match Envelope::from_json(&err.to_json()).unwrap() {
            Envelope::Error { msg } => assert_eq!(msg, "boom"),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn version_gate_and_malformed_frames_are_loud() {
        let text = Envelope::Hello {
            role: "submit".into(),
        }
        .to_json();
        let wrong = text.replacen(
            &format!("\"wire_version\":{WIRE_VERSION}"),
            "\"wire_version\":0",
            1,
        );
        let e = Envelope::from_json(&wrong).unwrap_err();
        assert!(e.contains("wire version"), "{e}");
        let e = Envelope::from_json("this is not a frame").unwrap_err();
        assert!(e.contains("malformed wire frame"), "{e}");
        let unknown = text.replacen("\"kind\":\"hello\"", "\"kind\":\"warble\"", 1);
        let e = Envelope::from_json(&unknown).unwrap_err();
        assert!(e.contains("unknown wire envelope kind"), "{e}");
    }
}
