//! # srsp — scalable Remote-Scope-Promotion for asymmetric GPU synchronization
//!
//! This crate reproduces the system of *"sRSP: GPUlarda Asimetrik Senkronizasyon
//! İçin Yeni Ölçeklenebilir Bir Çözüm"* (Yılmazer-Metin, 2022): a scalable
//! hardware implementation of Remote Scope Promotion (RSP, Orr et al.
//! ASPLOS'15) for GPU scoped synchronization, evaluated with work-stealing
//! graph workloads.
//!
//! The paper's testbed (the gem5-APU timing simulator) is rebuilt here as a
//! cycle-approximate, **value-accurate** GPU memory-hierarchy simulator:
//!
//! * [`mem`] — L1 write-combining caches with sFIFO dirty tracking, a shared
//!   banked L2, a channelled DRAM model and the flat backing store.
//! * [`sync`] — scoped acquire/release semantics and the pluggable
//!   protocol registry: one module per protocol (scoped baseline, naive
//!   RSP, sRSP, hLRC, adaptive sRSP) behind the
//!   [`SyncProtocol`](sync::SyncProtocol) trait, sharing one scoped-op
//!   core.
//! * [`kir`] — a small kernel IR (the HSAIL analog): registers, ALU ops,
//!   branches, scoped/remote atomics; workloads are real programs executed
//!   against the simulated memory system.
//! * [`gpu`] — the device model: compute units, work-group dispatch, the
//!   per-CU memory interface.
//! * [`workload`] — Cederman–Tsigas work-stealing deques (written in KIR),
//!   CSR graphs (DIMACS/MatrixMarket parsers + synthetic generators) and the
//!   three Pannotia-derived apps: PageRank, SSSP, MIS, each with a native
//!   oracle.
//! * [`runtime`] — the PJRT bridge: loads the JAX/Pallas-authored,
//!   AOT-lowered HLO artifacts and serves as the simulator's compute engine.
//! * [`harness`] — the five evaluation scenarios and the regeneration of the
//!   paper's Table 1 and Figures 4–6.
//!
//! Python (JAX + Pallas) appears only at build time — `make artifacts`
//! lowers the compute kernels to `artifacts/*.hlo.txt`; the Rust binary is
//! self-contained afterwards.

pub mod config;
pub mod coordinator;
pub mod gpu;
pub mod harness;
pub mod jsonio;
pub mod kir;
pub mod mem;
pub mod params;
pub mod proptest;
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod workload;

pub use config::{DeviceConfig, Protocol, Scenario};
pub use sim::Cycle;
