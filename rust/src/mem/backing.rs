//! The flat backing store (system memory image) and a bump allocator for
//! laying out workload data structures in the simulated address space.

use super::{byte_mask, line_of, offset_in_line, Addr, LineAddr, LINE};
use std::collections::HashMap;

/// Ground-truth memory below the L2. Sparse: untouched lines read as zero.
#[derive(Debug, Default, Clone)]
pub struct BackingStore {
    lines: HashMap<LineAddr, [u8; 64]>,
}

impl BackingStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Read a full line (zeros if never written).
    pub fn read_line(&self, line: LineAddr) -> [u8; 64] {
        self.lines.get(&line).copied().unwrap_or([0u8; 64])
    }

    /// Write the bytes selected by `mask` into a line.
    pub fn write_line_masked(&mut self, line: LineAddr, mask: u64, data: &[u8; 64]) {
        if mask == 0 {
            return;
        }
        let entry = self.lines.entry(line).or_insert([0u8; 64]);
        for i in 0..64 {
            if mask & (1 << i) != 0 {
                entry[i] = data[i];
            }
        }
    }

    /// Direct (host) read of `len <= 8` bytes at `addr`; must not straddle
    /// a line. Used by host drivers and oracles, never by simulated code.
    pub fn read_bytes(&self, addr: Addr, len: usize) -> u64 {
        let line = self.read_line(line_of(addr));
        let off = offset_in_line(addr);
        debug_assert!(off + len <= 64);
        let mut v = 0u64;
        for i in 0..len {
            v |= (line[off + i] as u64) << (8 * i);
        }
        v
    }

    /// Direct (host) write of `len <= 8` bytes at `addr`.
    pub fn write_bytes(&mut self, addr: Addr, len: usize, value: u64) {
        let off = offset_in_line(addr);
        debug_assert!(off + len <= 64);
        let mut data = [0u8; 64];
        for i in 0..len {
            data[off + i] = (value >> (8 * i)) as u8;
        }
        self.write_line_masked(line_of(addr), byte_mask(off, len), &data);
    }

    pub fn read_u32(&self, addr: Addr) -> u32 {
        self.read_bytes(addr, 4) as u32
    }

    pub fn write_u32(&mut self, addr: Addr, v: u32) {
        self.write_bytes(addr, 4, v as u64);
    }

    pub fn read_u64(&self, addr: Addr) -> u64 {
        self.read_bytes(addr, 8)
    }

    pub fn write_u64(&mut self, addr: Addr, v: u64) {
        self.write_bytes(addr, 8, v);
    }

    pub fn read_f32(&self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_f32(&mut self, addr: Addr, v: f32) {
        self.write_u32(addr, v.to_bits());
    }

    /// Number of materialized lines (diagnostics).
    pub fn touched_lines(&self) -> usize {
        self.lines.len()
    }
}

/// Line-aligned bump allocator for the simulated address space.
///
/// Address 0 is reserved (never handed out) so null-pointer bugs in KIR
/// programs are catchable.
#[derive(Debug)]
pub struct MemAlloc {
    next: Addr,
}

impl Default for MemAlloc {
    fn default() -> Self {
        Self { next: LINE }
    }
}

impl MemAlloc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `bytes` bytes aligned to a cache line; returns base address.
    pub fn alloc(&mut self, bytes: u64) -> Addr {
        let base = self.next;
        let lines = bytes.div_ceil(LINE).max(1);
        self.next += lines * LINE;
        base
    }

    /// Allocate an array of `n` elements of `elem_size` bytes.
    pub fn alloc_array(&mut self, n: u64, elem_size: u64) -> Addr {
        self.alloc(n * elem_size)
    }

    /// Allocate with padding so the region starts on a fresh line *and* the
    /// next allocation cannot share its last line (always true here since
    /// allocations are line-granular).
    pub fn alloc_isolated(&mut self, bytes: u64) -> Addr {
        self.alloc(bytes)
    }

    /// Total bytes reserved so far.
    pub fn high_water(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_default_and_rmw() {
        let mut m = BackingStore::new();
        assert_eq!(m.read_u32(100), 0);
        m.write_u32(100, 0xDEAD_BEEF);
        assert_eq!(m.read_u32(100), 0xDEAD_BEEF);
        // Neighbouring bytes untouched.
        assert_eq!(m.read_u32(104), 0);
        assert_eq!(m.read_u32(96), 0);
    }

    #[test]
    fn u64_round_trip_across_offsets() {
        let mut m = BackingStore::new();
        for off in [0u64, 8, 16, 56] {
            let addr = 640 + off;
            m.write_u64(addr, 0x0102_0304_0506_0708);
            assert_eq!(m.read_u64(addr), 0x0102_0304_0506_0708);
        }
    }

    #[test]
    fn f32_round_trip() {
        let mut m = BackingStore::new();
        m.write_f32(4, 3.25);
        assert_eq!(m.read_f32(4), 3.25);
    }

    #[test]
    fn masked_line_write() {
        let mut m = BackingStore::new();
        let mut data = [0u8; 64];
        data[3] = 0xAB;
        m.write_line_masked(5, 1 << 3, &data);
        let line = m.read_line(5);
        assert_eq!(line[3], 0xAB);
        assert_eq!(line[2], 0);
    }

    #[test]
    fn alloc_line_aligned_disjoint() {
        let mut a = MemAlloc::new();
        let x = a.alloc(4);
        let y = a.alloc(100);
        let z = a.alloc(1);
        assert_eq!(x % LINE, 0);
        assert_eq!(y % LINE, 0);
        assert!(x >= LINE, "address 0 reserved");
        assert!(y >= x + LINE);
        assert!(z >= y + 2 * LINE); // 100 bytes -> 2 lines
    }
}
