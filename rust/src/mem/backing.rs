//! The flat backing store (system memory image) and a bump allocator for
//! laying out workload data structures in the simulated address space.

use super::{
    line_of, line_read, line_write, merge_masked, offset_in_line, Addr, LineAddr, LineData, LINE,
    ZERO_LINE,
};
use std::collections::HashMap;

/// Ground-truth memory below the L2. Sparse: untouched lines read as zero.
#[derive(Debug, Default, Clone)]
pub struct BackingStore {
    lines: HashMap<LineAddr, LineData>,
}

impl BackingStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Read a full line (zeros if never written).
    pub fn read_line(&self, line: LineAddr) -> LineData {
        self.lines.get(&line).copied().unwrap_or(ZERO_LINE)
    }

    /// Write the bytes selected by `mask` into a line.
    pub fn write_line_masked(&mut self, line: LineAddr, mask: u64, data: &LineData) {
        if mask == 0 {
            return;
        }
        let entry = self.lines.entry(line).or_insert(ZERO_LINE);
        merge_masked(entry, data, mask);
    }

    /// Direct (host) read of `len <= 8` bytes at `addr`; must not straddle
    /// a line. Used by host drivers and oracles, never by simulated code.
    pub fn read_bytes(&self, addr: Addr, len: usize) -> u64 {
        let off = offset_in_line(addr);
        debug_assert!(off + len <= 64);
        match self.lines.get(&line_of(addr)) {
            Some(line) => line_read(line, off, len),
            None => 0,
        }
    }

    /// Direct (host) write of `len <= 8` bytes at `addr`.
    pub fn write_bytes(&mut self, addr: Addr, len: usize, value: u64) {
        let off = offset_in_line(addr);
        debug_assert!(off + len <= 64);
        let entry = self.lines.entry(line_of(addr)).or_insert(ZERO_LINE);
        line_write(entry, off, len, value);
    }

    pub fn read_u32(&self, addr: Addr) -> u32 {
        self.read_bytes(addr, 4) as u32
    }

    pub fn write_u32(&mut self, addr: Addr, v: u32) {
        self.write_bytes(addr, 4, v as u64);
    }

    pub fn read_u64(&self, addr: Addr) -> u64 {
        self.read_bytes(addr, 8)
    }

    pub fn write_u64(&mut self, addr: Addr, v: u64) {
        self.write_bytes(addr, 8, v);
    }

    pub fn read_f32(&self, addr: Addr) -> f32 {
        f32::from_bits(self.read_u32(addr))
    }

    pub fn write_f32(&mut self, addr: Addr, v: f32) {
        self.write_u32(addr, v.to_bits());
    }

    /// Number of materialized lines (diagnostics).
    pub fn touched_lines(&self) -> usize {
        self.lines.len()
    }
}

/// Line-aligned bump allocator for the simulated address space.
///
/// Address 0 is reserved (never handed out) so null-pointer bugs in KIR
/// programs are catchable.
#[derive(Debug)]
pub struct MemAlloc {
    next: Addr,
}

impl Default for MemAlloc {
    fn default() -> Self {
        Self { next: LINE }
    }
}

impl MemAlloc {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `bytes` bytes aligned to a cache line; returns base address.
    pub fn alloc(&mut self, bytes: u64) -> Addr {
        let base = self.next;
        let lines = bytes.div_ceil(LINE).max(1);
        self.next += lines * LINE;
        base
    }

    /// Allocate an array of `n` elements of `elem_size` bytes.
    pub fn alloc_array(&mut self, n: u64, elem_size: u64) -> Addr {
        self.alloc(n * elem_size)
    }

    /// Allocate with padding so the region starts on a fresh line *and* the
    /// next allocation cannot share its last line (always true here since
    /// allocations are line-granular).
    pub fn alloc_isolated(&mut self, bytes: u64) -> Addr {
        self.alloc(bytes)
    }

    /// Total bytes reserved so far.
    pub fn high_water(&self) -> u64 {
        self.next
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_default_and_rmw() {
        let mut m = BackingStore::new();
        assert_eq!(m.read_u32(100), 0);
        m.write_u32(100, 0xDEAD_BEEF);
        assert_eq!(m.read_u32(100), 0xDEAD_BEEF);
        // Neighbouring bytes untouched.
        assert_eq!(m.read_u32(104), 0);
        assert_eq!(m.read_u32(96), 0);
    }

    #[test]
    fn u64_round_trip_across_offsets() {
        let mut m = BackingStore::new();
        for off in [0u64, 8, 16, 56] {
            let addr = 640 + off;
            m.write_u64(addr, 0x0102_0304_0506_0708);
            assert_eq!(m.read_u64(addr), 0x0102_0304_0506_0708);
        }
    }

    #[test]
    fn f32_round_trip() {
        let mut m = BackingStore::new();
        m.write_f32(4, 3.25);
        assert_eq!(m.read_f32(4), 3.25);
    }

    #[test]
    fn masked_line_write() {
        let mut m = BackingStore::new();
        let mut data = ZERO_LINE;
        line_write(&mut data, 3, 1, 0xAB);
        m.write_line_masked(5, 1 << 3, &data);
        let line = m.read_line(5);
        assert_eq!(line_read(&line, 3, 1), 0xAB);
        assert_eq!(line_read(&line, 2, 1), 0);
    }

    #[test]
    fn alloc_line_aligned_disjoint() {
        let mut a = MemAlloc::new();
        let x = a.alloc(4);
        let y = a.alloc(100);
        let z = a.alloc(1);
        assert_eq!(x % LINE, 0);
        assert_eq!(y % LINE, 0);
        assert!(x >= LINE, "address 0 reserved");
        assert!(y >= x + LINE);
        assert!(z >= y + 2 * LINE); // 100 bytes -> 2 lines
    }
}
