//! `MemSystem`: the composed, timed GPU memory hierarchy.
//!
//! Owns every per-CU L1 (with its sFIFO, LR-TBL and PA-TBL), the shared
//! banked L2 (with its own sFIFO), the DRAM channels and the backing store.
//! Exposes *mechanical* timed primitives — reads, writes, atomics at either
//! level, flush / selective-flush / invalidate — that the protocol engines
//! in [`crate::sync::engine`] orchestrate into scoped and remote
//! synchronization operations.
//!
//! Every primitive takes a start cycle and returns a completion cycle;
//! functional state is updated immediately (the event loop processes
//! operations in cycle order, which serializes them).

use super::cache::{DrainStep, WcCache, Writeback};
use super::timing::{Banked, Resource};
use super::{
    byte_mask, line_of, line_write, offset_in_line, Addr, BackingStore, LineAddr, LineData,
    Ticket, ZERO_LINE,
};
use crate::config::DeviceConfig;
use crate::sim::{Cycle, Stats, TraceKind, TraceSink};
use crate::sync::scope::AtomicOp;
use crate::sync::tables::{LrTbl, PaTbl};
use std::collections::HashMap;

/// Timing class of one planned (compute-engine) access; see the planned
/// access section of [`MemSystem`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannedAccess {
    /// L1 hit at plan time. Re-validated at replay: if the line was
    /// invalidated in between (e.g. by naive RSP's all-L1 broadcasts),
    /// the replay converts it to a miss — so invalidation storms are
    /// priced against in-flight work, not just future planning.
    Hit { line: LineAddr, mask: u64 },
    /// L1 miss serviced by the L2 (and DRAM when `dram`); `wbs` victim
    /// writebacks accompanied it.
    Miss { line: LineAddr, dram: bool, wbs: u8 },
    /// Store (posted); `wbs` overflow/victim writebacks accompanied it.
    Write { line: LineAddr, wbs: u8 },
}

/// Per-CU slice of the memory system: private L1 + its link + the sRSP
/// tables attached to the L1 controller.
pub struct CuSide {
    pub l1: WcCache,
    /// L1 access port (one op per cycle).
    pub port: Resource,
    /// Crossbar link to the L2.
    pub link: Resource,
    pub lr_tbl: LrTbl,
    pub pa_tbl: PaTbl,
}

/// The full memory system.
pub struct MemSystem {
    pub cfg: DeviceConfig,
    pub backing: BackingStore,
    cus: Vec<CuSide>,
    l2: WcCache,
    l2_banks: Banked,
    /// Lines locked by an in-flight remote atomic: accesses stall until the
    /// recorded cycle (§4.2: the L2 must lock the sync variable's block).
    l2_locks: HashMap<LineAddr, Cycle>,
    /// hLRC ownership registry at the L2 (extension protocol, §6 related
    /// work): sync-variable address → owning CU. Bounded; registering
    /// past capacity evicts the oldest entry (its owner must flush —
    /// the replacement-policy sensitivity the paper criticizes).
    hlrc_registry: Vec<(Addr, u32)>,
    /// Registry capacity (entries). Reuses the Table-1 flavor of "small
    /// hardware structure": 2 × num_cus by default.
    hlrc_capacity: usize,
    dram: Banked,
    pub stats: Stats,
    /// Sync-event trace sink (observe-only; disabled unless
    /// [`DeviceConfig::trace_capacity`] > 0). Protocol engines and the
    /// hierarchy itself emit into it; the driver harvests per cell.
    pub trace: TraceSink,
    /// Resolved sync-protocol parameters (`--proto-param` overlaid on the
    /// selected protocol's registry spec). Populated by
    /// [`Device::new`](crate::gpu::Device::new); a bare `MemSystem` keeps
    /// the empty default and protocol hooks fall back to their spec
    /// defaults via [`Params::get_or`](crate::params::Params::get_or).
    pub proto_params: crate::params::Params,
}

impl MemSystem {
    pub fn new(cfg: DeviceConfig) -> Self {
        cfg.validate().expect("invalid device config");
        let cus = (0..cfg.num_cus)
            .map(|_| CuSide {
                l1: WcCache::new(cfg.l1_sets(), cfg.l1_ways, cfg.l1_sfifo),
                port: Resource::new(),
                link: Resource::new(),
                lr_tbl: LrTbl::new(cfg.lr_tbl_entries),
                pa_tbl: PaTbl::new(cfg.pa_tbl_entries),
            })
            .collect();
        Self {
            l2: WcCache::new(cfg.l2_sets(), cfg.l2_ways, cfg.l2_sfifo),
            l2_banks: Banked::new(cfg.l2_banks),
            l2_locks: HashMap::new(),
            hlrc_registry: Vec::new(),
            hlrc_capacity: 2 * cfg.num_cus as usize,
            dram: Banked::new(cfg.dram_channels),
            backing: BackingStore::new(),
            stats: Stats::new(),
            trace: TraceSink::new(cfg.trace_capacity, cfg.num_cus),
            proto_params: crate::params::Params::default(),
            cus,
            cfg,
        }
    }

    // ------------------------------------------------------------------
    // hLRC ownership registry (extension protocol)
    // ------------------------------------------------------------------

    /// Current owner of a registered sync variable.
    pub fn hlrc_owner(&self, addr: Addr) -> Option<u32> {
        self.hlrc_registry.iter().find(|e| e.0 == addr).map(|e| e.1)
    }

    /// Claim ownership of `addr` for `cu`. Returns the evicted entry when
    /// the registry was full (its owner must be flushed by the caller).
    pub fn hlrc_claim(&mut self, addr: Addr, cu: u32) -> Option<(Addr, u32)> {
        if let Some(e) = self.hlrc_registry.iter_mut().find(|e| e.0 == addr) {
            e.1 = cu;
            return None;
        }
        let evicted = if self.hlrc_registry.len() >= self.hlrc_capacity {
            Some(self.hlrc_registry.remove(0)) // FIFO eviction
        } else {
            None
        };
        self.hlrc_registry.push((addr, cu));
        evicted
    }

    /// Drop all registrations owned by `cu` (on full L1 invalidate: the
    /// cache loses its exclusively-held sync lines).
    pub fn hlrc_drop_owner(&mut self, cu: u32) {
        self.hlrc_registry.retain(|e| e.1 != cu);
    }

    pub fn num_cus(&self) -> u32 {
        self.cfg.num_cus
    }

    pub fn cu(&self, cu: u32) -> &CuSide {
        &self.cus[cu as usize]
    }

    pub fn cu_mut(&mut self, cu: u32) -> &mut CuSide {
        &mut self.cus[cu as usize]
    }

    // ------------------------------------------------------------------
    // DRAM
    // ------------------------------------------------------------------

    fn dram_fetch(&mut self, line: LineAddr, at: Cycle) -> (LineData, Cycle) {
        self.stats.dram_reads += 1;
        let start = self.dram.acquire(line, at, self.cfg.dram_occupancy);
        (self.backing.read_line(line), start + self.cfg.dram_latency)
    }

    fn dram_write(&mut self, wb: &Writeback, at: Cycle) -> Cycle {
        self.stats.dram_writes += 1;
        let start = self.dram.acquire(wb.line, at, self.cfg.dram_occupancy);
        self.backing.write_line_masked(wb.line, wb.mask, &wb.data);
        start + self.cfg.dram_latency
    }

    // ------------------------------------------------------------------
    // L2 level
    // ------------------------------------------------------------------

    /// Stall until any lock on `line` is released.
    fn lock_wait(&self, line: LineAddr, at: Cycle) -> Cycle {
        match self.l2_locks.get(&line) {
            Some(&until) => at.max(until),
            None => at,
        }
    }

    /// Lock `line` until `until` (remote atomic in flight).
    pub fn lock_l2_line(&mut self, line: LineAddr, until: Cycle) {
        let e = self.l2_locks.entry(line).or_insert(0);
        *e = (*e).max(until);
    }

    /// Make every byte of `line` valid in L2 (fetch+merge from DRAM on
    /// miss/partial). Returns data-ready cycle.
    fn l2_ensure_full(&mut self, line: LineAddr, at: Cycle) -> Cycle {
        if self.l2.full_line(line).is_some() {
            self.stats.l2_hits += 1;
            return at;
        }
        self.stats.l2_misses += 1;
        let (data, t) = self.dram_fetch(line, at);
        let out = self.l2.fill(line, data);
        if let Some(victim) = out.victim_wb {
            self.dram_write(&victim, t);
        }
        t
    }

    /// Read a full line through the L2 (L1 miss path). Returns the line
    /// image and the data-ready cycle.
    fn l2_read_line(&mut self, line: LineAddr, at: Cycle) -> (LineData, Cycle) {
        self.stats.l2_accesses += 1;
        let at = self.lock_wait(line, at);
        let start = self.l2_banks.acquire(line, at, self.cfg.l2_bank_occupancy);
        let t = self.l2_ensure_full(line, start) + self.cfg.l2_latency;
        let data = self.l2.full_line(line).expect("ensured full");
        (data, t)
    }

    /// Accept a masked writeback into the L2 (write-combining, no
    /// allocate-fill). Returns acceptance cycle.
    fn l2_accept_writeback(&mut self, wb: &Writeback, at: Cycle) -> Cycle {
        self.stats.l2_accesses += 1;
        let at = self.lock_wait(wb.line, at);
        let start = self.l2_banks.acquire(wb.line, at, self.cfg.l2_bank_occupancy);
        let out = self.l2.write_masked(wb.line, wb.mask, &wb.data);
        let mut done = start + self.cfg.l2_bank_occupancy;
        if let Some(ov) = out.overflow_wb {
            done = done.max(self.dram_write(&ov, done));
        }
        if let Some(victim) = out.victim_wb {
            self.dram_write(&victim, done);
        }
        done
    }

    /// Atomic RMW performed *at the L2* (cmp scope). The requesting CU's L1
    /// copy of the line is dropped first (dirty bytes merged into L2) so the
    /// L1 cannot serve stale data later and the RMW sees this CU's writes.
    pub fn l2_atomic(
        &mut self,
        cu: u32,
        addr: Addr,
        op: AtomicOp,
        operand: u32,
        cmp: u32,
        at: Cycle,
    ) -> (u32, Cycle) {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        debug_assert!(off + 4 <= 64);

        // Drop own copy; push dirty bytes down ahead of the RMW.
        let mut t = at;
        if let Some(wb) = self.cus[cu as usize].l1.invalidate_line(line) {
            t = self.writeback_to_l2(cu, &wb, t);
        }
        // Traverse the crossbar to reach the L2.
        let t = {
            let start = self.cus[cu as usize].link.acquire(t, self.cfg.xbar_occupancy);
            start + self.cfg.xbar_latency
        };
        let t = self.lock_wait(line, t);
        self.stats.l2_accesses += 1;
        self.stats.l2_atomics += 1;
        let start = self.l2_banks.acquire(line, t, self.cfg.l2_bank_occupancy);
        let t = self.l2_ensure_full(line, start) + self.cfg.l2_latency;
        let old = self.l2.read_bytes(line, off, 4) as u32;
        let (new, result) = op.apply(old, operand, cmp);
        if op.writes_given(old, operand, cmp) {
            let mut data = ZERO_LINE;
            line_write(&mut data, off, 4, new as u64);
            let out = self.l2.write_masked(line, byte_mask(off, 4), &data);
            if let Some(ov) = out.overflow_wb {
                self.dram_write(&ov, t);
            }
            if let Some(victim) = out.victim_wb {
                self.dram_write(&victim, t);
            }
        }
        // Result returns over the crossbar.
        (result, t + self.cfg.xbar_latency)
    }

    // ------------------------------------------------------------------
    // L1 level
    // ------------------------------------------------------------------

    /// Route one writeback from an L1 down to the L2.
    fn writeback_to_l2(&mut self, cu: u32, wb: &Writeback, at: Cycle) -> Cycle {
        self.stats.l1_writebacks += 1;
        let start = self.cus[cu as usize]
            .link
            .acquire(at, self.cfg.xbar_occupancy);
        self.l2_accept_writeback(wb, start + self.cfg.xbar_latency)
    }

    /// Plain load of `len <= 8` bytes (must not straddle a line).
    ///
    /// Hot path: the dominant L1-hit case is a single bounds-checked CU
    /// index, one port acquire and one [`probe_read`](WcCache::probe_read)
    /// (itself O(1) via the cache's verified last-line hint) — no
    /// redundant `has_bytes` + `read_bytes` double lookup.
    pub fn l1_read(&mut self, cu: u32, addr: Addr, len: usize, at: Cycle) -> (u64, Cycle) {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        let mask = byte_mask(off, len);
        let cu_slot = &mut self.cus[cu as usize];
        let t0 = cu_slot.port.acquire(at, 1);

        if let Some(v) = cu_slot.l1.probe_read(line, off, len, mask) {
            self.stats.l1_hits += 1;
            return (v, t0 + self.cfg.l1_latency);
        }
        self.stats.l1_misses += 1;
        // Miss: through the crossbar to the L2, fill, then read.
        let t1 = t0 + self.cfg.l1_latency;
        let start = self.cus[cu as usize].link.acquire(t1, self.cfg.xbar_occupancy);
        let (data, t2) = self.l2_read_line(line, start + self.cfg.xbar_latency);
        let out = self.cus[cu as usize].l1.fill(line, data);
        if let Some(victim) = out.victim_wb {
            self.writeback_to_l2(cu, &victim, t2);
        }
        let v = self.cus[cu as usize].l1.read_bytes(line, off, len);
        (v, t2 + self.cfg.xbar_latency)
    }

    /// Plain store of `len <= 8` bytes. Posted: completes at L1 latency;
    /// overflow/victim writebacks occupy the downstream resources without
    /// blocking the store.
    pub fn l1_write(&mut self, cu: u32, addr: Addr, len: usize, value: u64, at: Cycle) -> Cycle {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        self.stats.l1_writes += 1;
        let t0 = self.cus[cu as usize].port.acquire(at, 1);
        let out = self.cus[cu as usize].l1.write_bytes(line, off, len, value);
        let done = t0 + self.cfg.l1_latency;
        if let Some(wb) = out.overflow_wb {
            self.writeback_to_l2(cu, &wb, done);
        }
        if let Some(wb) = out.victim_wb {
            self.writeback_to_l2(cu, &wb, done);
        }
        done
    }

    /// Record a store's sFIFO ticket (needed by wg-scope releases for the
    /// LR-TBL). Same semantics as [`l1_write`](Self::l1_write) but returns
    /// the ticket of the sFIFO entry tracking the line (existing entry's
    /// position is *refreshed* per §4.1 when the line was already dirty —
    /// we return the current frontier in that case, which conservatively
    /// covers the line).
    pub fn l1_write_ticketed(
        &mut self,
        cu: u32,
        addr: Addr,
        len: usize,
        value: u64,
        at: Cycle,
    ) -> (Ticket, Cycle) {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        self.stats.l1_writes += 1;
        let t0 = self.cus[cu as usize].port.acquire(at, 1);
        let out = self.cus[cu as usize].l1.write_bytes(line, off, len, value);
        let done = t0 + self.cfg.l1_latency;
        if let Some(wb) = out.overflow_wb {
            self.writeback_to_l2(cu, &wb, done);
        }
        if let Some(wb) = out.victim_wb {
            self.writeback_to_l2(cu, &wb, done);
        }
        let ticket = out.ticket.unwrap_or_else(|| {
            // Line already dirty: its entry is somewhere in the FIFO.
            // Draining to frontier-1 is guaranteed to cover it.
            self.cus[cu as usize].l1.sfifo.frontier().saturating_sub(1)
        });
        (ticket, done)
    }

    /// Atomic RMW performed *at the L1* (wg scope). Fills the line on miss.
    pub fn l1_atomic(
        &mut self,
        cu: u32,
        addr: Addr,
        op: AtomicOp,
        operand: u32,
        cmp: u32,
        at: Cycle,
    ) -> (u32, Ticket, Cycle) {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        let mask = byte_mask(off, 4);
        let t0 = self.cus[cu as usize].port.acquire(at, 1);

        let mut t = t0 + self.cfg.l1_latency;
        if !self.cus[cu as usize].l1.has_bytes(line, mask) {
            self.stats.l1_misses += 1;
            let start = self.cus[cu as usize].link.acquire(t, self.cfg.xbar_occupancy);
            let (data, t2) = self.l2_read_line(line, start + self.cfg.xbar_latency);
            let out = self.cus[cu as usize].l1.fill(line, data);
            if let Some(victim) = out.victim_wb {
                self.writeback_to_l2(cu, &victim, t2);
            }
            t = t2 + self.cfg.xbar_latency;
        } else {
            self.stats.l1_hits += 1;
        }
        let old = self.cus[cu as usize].l1.read_bytes(line, off, 4) as u32;
        let (new, result) = op.apply(old, operand, cmp);
        let mut ticket = self.cus[cu as usize].l1.sfifo.frontier().saturating_sub(1);
        if op.writes_given(old, operand, cmp) {
            let out = self.cus[cu as usize].l1.write_bytes(line, off, 4, new as u64);
            if let Some(tk) = out.ticket {
                ticket = tk;
            }
            if let Some(wb) = out.overflow_wb {
                self.writeback_to_l2(cu, &wb, t);
            }
            if let Some(wb) = out.victim_wb {
                self.writeback_to_l2(cu, &wb, t);
            }
        }
        (result, ticket, t)
    }

    // ------------------------------------------------------------------
    // Flush / invalidate (the heavy operations)
    // ------------------------------------------------------------------

    /// Drain the L1's sFIFO: all of it (`upto == None`, a cache-flush) or
    /// up to a ticket (sRSP selective-flush). Returns completion cycle.
    pub fn flush_l1(&mut self, cu: u32, upto: Option<Ticket>, at: Cycle) -> Cycle {
        let mut t_pop = at;
        let mut done = at;
        loop {
            // Each sFIFO pop occupies the L1 port for a cycle.
            let step = self.cus[cu as usize].l1.drain_step(upto);
            match step {
                DrainStep::Done => break,
                DrainStep::Stale => {
                    t_pop = self.cus[cu as usize].port.acquire(t_pop, 1) + 1;
                    done = done.max(t_pop);
                }
                DrainStep::Writeback(wb) => {
                    t_pop = self.cus[cu as usize].port.acquire(t_pop, 1) + 1;
                    self.stats.lines_flushed += 1;
                    let t_wb = self.writeback_to_l2(cu, &wb, t_pop);
                    done = done.max(t_wb);
                }
            }
        }
        done
    }

    /// Full cache-flush of an L1 (drain entire sFIFO). Global-release path.
    ///
    /// The trace event is stamped at the flush's *completion* cycle (the
    /// drain can take hundreds of cycles; stamping the start made flushes
    /// look instantaneous on the timeline).
    pub fn full_flush_l1(&mut self, cu: u32, at: Cycle) -> Cycle {
        self.stats.l1_flushes += 1;
        let pending = self.cus[cu as usize].l1.sfifo_pending() as u64;
        let t = self.flush_l1(cu, None, at);
        self.trace.emit(t, cu, TraceKind::L1Flush, 0, pending);
        t
    }

    /// Full invalidate of an L1: drain dirty, then one-cycle flash
    /// invalidate. Clears LR-TBL and PA-TBL (§4.4). Global-acquire path.
    pub fn invalidate_l1(&mut self, cu: u32, at: Cycle) -> Cycle {
        self.stats.l1_invalidates += 1;
        let t = self.full_flush_l1(cu, at);
        let side = &mut self.cus[cu as usize];
        debug_assert_eq!(side.l1.dirty_line_count(), 0);
        let dropped = side.l1.flash_invalidate();
        self.stats.lines_invalidated += dropped;
        side.lr_tbl.clear();
        side.pa_tbl.clear();
        let done = t + 1;
        // Stamped at completion (after the embedded flush + the one-cycle
        // flash invalidate), matching the L1Flush convention above.
        self.trace.emit(done, cu, TraceKind::L1Invalidate, 0, dropped);
        // hLRC: the cache can no longer hold its sync lines exclusively.
        self.hlrc_drop_owner(cu);
        done
    }

    // ------------------------------------------------------------------
    // System scope (completeness; unused by the paper's workloads)
    // ------------------------------------------------------------------

    /// Drain the L2's sFIFO to DRAM (system-scope release path).
    pub fn full_flush_l2(&mut self, at: Cycle) -> Cycle {
        let mut t_pop = at;
        let mut done = at;
        loop {
            match self.l2.drain_step(None) {
                DrainStep::Done => break,
                DrainStep::Stale => {
                    t_pop += 1;
                    done = done.max(t_pop);
                }
                DrainStep::Writeback(wb) => {
                    t_pop += 1;
                    let t_wb = self.dram_write(&wb, t_pop);
                    done = done.max(t_wb);
                }
            }
        }
        self.stats.bump("l2_flushes", 1);
        done
    }

    /// Invalidate the L2 (system-scope acquire path).
    pub fn invalidate_l2(&mut self, at: Cycle) -> Cycle {
        let t = self.full_flush_l2(at);
        let dropped = self.l2.flash_invalidate();
        self.stats.bump("l2_lines_invalidated", dropped);
        t + 1
    }

    // ------------------------------------------------------------------
    // Host access (kernel boundaries; never on the simulated timing path)
    // ------------------------------------------------------------------

    /// Kernel-end semantics: every L1 is flushed and invalidated, the L2 is
    /// flushed to the backing store. Afterwards the host sees every device
    /// write via [`BackingStore`] reads. Returns the completion cycle.
    pub fn kernel_end_barrier(&mut self, at: Cycle) -> Cycle {
        let mut done = at;
        for cu in 0..self.cfg.num_cus {
            done = done.max(self.invalidate_l1(cu, at));
        }
        let t = self.full_flush_l2(done);
        self.l2.flash_invalidate();
        self.l2_locks.clear();
        t
    }

    /// Debug/diagnostic invariant sweep. Panics on violation.
    pub fn check_invariants(&self) {
        for (i, side) in self.cus.iter().enumerate() {
            assert!(
                side.l1.check_dirty_subset_of_sfifo(),
                "CU{i}: dirty line not tracked by sFIFO"
            );
            if let Some(max) = side.lr_tbl.max_ticket() {
                assert!(
                    max < side.l1.sfifo.frontier(),
                    "CU{i}: LR-TBL ticket beyond sFIFO frontier"
                );
            }
        }
        assert!(
            self.l2.check_dirty_subset_of_sfifo(),
            "L2: dirty line not tracked by sFIFO"
        );
    }

    // ------------------------------------------------------------------
    // Planned accesses (compute-engine traffic)
    //
    // A `Compute` KIR op issues hundreds of dependent accesses. Executing
    // them atomically inside one event would reserve the *shared*
    // resources (L2 banks, DRAM channels) far into the simulated future
    // and serialize every other CU behind them. Instead the engine
    // *plans*: functional effects (values, cache state, hit/miss stats)
    // happen immediately, and each access's timing class is recorded; the
    // interpreter then *replays* a few accesses per event, so contention
    // is resolved in global time order.
    // ------------------------------------------------------------------

    /// Functional L2 full-line fetch (no timing). Returns data + whether
    /// DRAM was involved.
    fn l2_line_functional(&mut self, line: LineAddr) -> (LineData, bool) {
        self.stats.l2_accesses += 1;
        if let Some(data) = self.l2.full_line(line) {
            self.stats.l2_hits += 1;
            return (data, false);
        }
        self.stats.l2_misses += 1;
        self.stats.dram_reads += 1;
        let data = self.backing.read_line(line);
        let out = self.l2.fill(line, data);
        if let Some(victim) = out.victim_wb {
            self.stats.dram_writes += 1;
            self.backing.write_line_masked(victim.line, victim.mask, &victim.data);
        }
        let data = self.l2.full_line(line).expect("just filled");
        (data, true)
    }

    /// Functional writeback into the L2 (no timing).
    fn l2_accept_writeback_functional(&mut self, wb: &Writeback) {
        self.stats.l2_accesses += 1;
        self.stats.l1_writebacks += 1;
        let out = self.l2.write_masked(wb.line, wb.mask, &wb.data);
        if let Some(ov) = out.overflow_wb {
            self.stats.dram_writes += 1;
            self.backing.write_line_masked(ov.line, ov.mask, &ov.data);
        }
        if let Some(victim) = out.victim_wb {
            self.stats.dram_writes += 1;
            self.backing.write_line_masked(victim.line, victim.mask, &victim.data);
        }
    }

    /// Plan a load: functional effect now, timing class for replay.
    ///
    /// The hit case is a single `probe_read` (O(1) via the L1's verified
    /// last-line hint); the miss case installs the fill, which primes the
    /// hint so the trailing `read_bytes` does not re-scan the set.
    pub fn plan_read(&mut self, cu: u32, addr: Addr, len: usize) -> (u64, PlannedAccess) {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        let mask = byte_mask(off, len);
        if let Some(v) = self.cus[cu as usize].l1.probe_read(line, off, len, mask) {
            self.stats.l1_hits += 1;
            return (v, PlannedAccess::Hit { line, mask });
        }
        self.stats.l1_misses += 1;
        let (data, dram) = self.l2_line_functional(line);
        let out = self.cus[cu as usize].l1.fill(line, data);
        let wb = if let Some(victim) = out.victim_wb {
            self.l2_accept_writeback_functional(&victim);
            1
        } else {
            0
        };
        let v = self.cus[cu as usize].l1.read_bytes(line, off, len);
        (v, PlannedAccess::Miss { line, dram, wbs: wb })
    }

    /// Plan a store: functional effect now, timing class for replay.
    pub fn plan_write(&mut self, cu: u32, addr: Addr, len: usize, value: u64) -> PlannedAccess {
        let line = line_of(addr);
        let off = offset_in_line(addr);
        self.stats.l1_writes += 1;
        let out = self.cus[cu as usize].l1.write_bytes(line, off, len, value);
        let mut wbs = 0u8;
        if let Some(wb) = out.overflow_wb {
            self.l2_accept_writeback_functional(&wb);
            wbs += 1;
        }
        if let Some(wb) = out.victim_wb {
            self.l2_accept_writeback_functional(&wb);
            wbs += 1;
        }
        PlannedAccess::Write { line, wbs }
    }

    /// Replay one planned access at `at`, charging the resources its
    /// class touched. Returns the completion cycle.
    pub fn replay_access(&mut self, cu: u32, acc: PlannedAccess, at: Cycle) -> Cycle {
        match acc {
            PlannedAccess::Hit { line, mask } => {
                if !self.cus[cu as usize].l1.has_bytes(line, mask) {
                    // Line lost to an invalidation since planning: this
                    // access actually misses. Refill functionally and
                    // charge the miss path.
                    self.stats.l1_hits = self.stats.l1_hits.saturating_sub(1);
                    self.stats.l1_misses += 1;
                    self.stats.bump("replay_converted_misses", 1);
                    let (data, dram) = self.l2_line_functional(line);
                    let out = self.cus[cu as usize].l1.fill(line, data);
                    let wbs = if let Some(victim) = out.victim_wb {
                        self.l2_accept_writeback_functional(&victim);
                        1
                    } else {
                        0
                    };
                    return self.replay_access(cu, PlannedAccess::Miss { line, dram, wbs }, at);
                }
                let t0 = self.cus[cu as usize].port.acquire(at, 1);
                t0 + self.cfg.l1_latency
            }
            PlannedAccess::Miss { line, dram, wbs } => {
                let t0 = self.cus[cu as usize].port.acquire(at, 1) + self.cfg.l1_latency;
                let t1 = {
                    let start = self.cus[cu as usize].link.acquire(t0, self.cfg.xbar_occupancy);
                    start + self.cfg.xbar_latency
                };
                let t1 = self.lock_wait(line, t1);
                let start = self.l2_banks.acquire(line, t1, self.cfg.l2_bank_occupancy);
                let mut t2 = start + self.cfg.l2_latency;
                if dram {
                    let ds = self.dram.acquire(line, t2, self.cfg.dram_occupancy);
                    t2 = ds + self.cfg.dram_latency;
                }
                // Victim writebacks occupy the link + a bank in background.
                for _ in 0..wbs {
                    let s = self.cus[cu as usize].link.acquire(t2, self.cfg.xbar_occupancy);
                    self.l2_banks
                        .acquire(line, s + self.cfg.xbar_latency, self.cfg.l2_bank_occupancy);
                }
                t2 + self.cfg.xbar_latency
            }
            PlannedAccess::Write { line, wbs } => {
                let t0 = self.cus[cu as usize].port.acquire(at, 1);
                let done = t0 + self.cfg.l1_latency;
                for _ in 0..wbs {
                    let s = self.cus[cu as usize].link.acquire(done, self.cfg.xbar_occupancy);
                    self.l2_banks
                        .acquire(line, s + self.cfg.xbar_latency, self.cfg.l2_bank_occupancy);
                }
                done
            }
        }
    }

    /// Crossbar hop: latency + link occupancy for a control message
    /// to/from a CU (used by broadcast promotions).
    pub fn xbar_hop(&mut self, cu: u32, at: Cycle) -> Cycle {
        let start = self.cus[cu as usize].link.acquire(at, self.cfg.xbar_occupancy);
        start + self.cfg.xbar_latency
    }

    /// One L2 bank touch for a control message (broadcast fan-out point).
    pub fn l2_control_hop(&mut self, line: LineAddr, at: Cycle) -> Cycle {
        let start = self.l2_banks.acquire(line, at, self.cfg.l2_bank_occupancy);
        start + self.cfg.l2_bank_occupancy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceConfig;

    fn sys() -> MemSystem {
        MemSystem::new(DeviceConfig::small())
    }

    #[test]
    fn read_miss_then_hit() {
        let mut m = sys();
        m.backing.write_u32(0x1000, 42);
        let (v, t1) = m.l1_read(0, 0x1000, 4, 0);
        assert_eq!(v, 42);
        assert_eq!(m.stats.l1_misses, 1);
        let (v2, t2) = m.l1_read(0, 0x1000, 4, t1);
        assert_eq!(v2, 42);
        assert_eq!(m.stats.l1_hits, 1);
        assert!(t2 - t1 < t1, "hit much faster than miss");
    }

    #[test]
    fn write_then_read_same_cu() {
        let mut m = sys();
        let t = m.l1_write(0, 0x2000, 4, 7, 0);
        let (v, _) = m.l1_read(0, 0x2000, 4, t);
        assert_eq!(v, 7);
        // Dirty data NOT visible in backing store yet.
        assert_eq!(m.backing.read_u32(0x2000), 0);
    }

    #[test]
    fn dirty_data_invisible_to_other_cu_until_flush() {
        let mut m = sys();
        let t = m.l1_write(0, 0x3000, 4, 99, 0);
        // CU1 reads: misses to L2, which has no idea about CU0's dirty line.
        let (v, t2) = m.l1_read(1, 0x3000, 4, t);
        assert_eq!(v, 0, "non-coherent caches: stale read expected");
        // Flush CU0, then CU1 must *invalidate* (else it hits its stale copy).
        let t3 = m.full_flush_l1(0, t2);
        let t4 = m.invalidate_l1(1, t3);
        let (v2, _) = m.l1_read(1, 0x3000, 4, t4);
        assert_eq!(v2, 99);
    }

    #[test]
    fn l1_atomic_local_rmw() {
        let mut m = sys();
        m.backing.write_u32(0x100, 5);
        let (old, _tk, t) = m.l1_atomic(0, 0x100, AtomicOp::Add, 3, 0, 0);
        assert_eq!(old, 5);
        let (v, _) = m.l1_read(0, 0x100, 4, t);
        assert_eq!(v, 8);
        // Still local: backing unchanged.
        assert_eq!(m.backing.read_u32(0x100), 5);
    }

    #[test]
    fn l2_atomic_visible_across_cus() {
        let mut m = sys();
        let (old0, t0) = m.l2_atomic(0, 0x200, AtomicOp::Add, 1, 0, 0);
        let (old1, _) = m.l2_atomic(1, 0x200, AtomicOp::Add, 1, 0, t0);
        assert_eq!(old0, 0);
        assert_eq!(old1, 1, "L2 atomics are globally ordered");
    }

    #[test]
    fn l2_atomic_merges_own_dirty_first() {
        let mut m = sys();
        // CU0 writes locally (dirty in L1), then does an L2 CAS on the
        // same word: the CAS must observe its own dirty value.
        let t = m.l1_write(0, 0x300, 4, 10, 0);
        let (old, _) = m.l2_atomic(0, 0x300, AtomicOp::Cas, 11, 10, t);
        assert_eq!(old, 10, "own dirty write must be visible to own L2 RMW");
    }

    #[test]
    fn selective_flush_stops_at_ticket() {
        let mut m = sys();
        let (tk, t) = m.l1_write_ticketed(0, 0x400, 4, 1, 0);
        let t = m.l1_write(0, 0x440, 4, 2, t);
        // Selective flush to the first write's ticket: 0x400 written back,
        // 0x440 still dirty.
        let t = m.flush_l1(0, Some(tk), t);
        assert_eq!(m.stats.lines_flushed, 1);
        assert!(m.cu(0).l1.is_dirty(line_of(0x440)));
        let _ = t;
    }

    #[test]
    fn invalidate_clears_tables_and_lines() {
        let mut m = sys();
        let (tk, t) = m.l1_write_ticketed(0, 0x500, 4, 1, 0);
        m.cu_mut(0).lr_tbl.record(0x500, tk);
        m.cu_mut(0).pa_tbl.record(0x500);
        let t = m.invalidate_l1(0, t);
        assert!(m.cu(0).lr_tbl.is_empty());
        assert!(m.cu(0).pa_tbl.is_empty());
        assert_eq!(m.cu(0).l1.valid_line_count(), 0);
        assert!(t > 0);
        // Data reached the L2 (not lost).
        let (v, _) = m.l1_read(1, 0x500, 4, t);
        assert_eq!(v, 1);
    }

    #[test]
    fn kernel_end_makes_writes_host_visible() {
        let mut m = sys();
        let t = m.l1_write(2, 0x600, 4, 123, 0);
        m.kernel_end_barrier(t);
        assert_eq!(m.backing.read_u32(0x600), 123);
        m.check_invariants();
    }

    #[test]
    fn l2_line_lock_delays_access() {
        let mut m = sys();
        m.lock_l2_line(line_of(0x700), 1000);
        let (_v, t) = m.l1_read(0, 0x700, 4, 0);
        assert!(t >= 1000, "read must wait for the line lock, got {t}");
    }

    #[test]
    fn sfifo_overflow_writes_back_in_background() {
        let mut m = sys();
        let mut t = 0;
        // More distinct dirty lines than sFIFO entries (16).
        for i in 0..32u64 {
            t = m.l1_write(0, 0x8000 + i * 64, 4, i, t);
        }
        assert!(m.stats.l1_writebacks >= 16, "overflow must drain oldest");
        m.check_invariants();
    }

    #[test]
    fn invariants_hold_under_mixed_traffic() {
        let mut m = sys();
        let mut t = 0;
        for i in 0..200u64 {
            let addr = 0x1000 + ((i * 97) % 4096 & !7); // 8-byte aligned

            if i % 3 == 0 {
                t = m.l1_write((i % 4) as u32, addr, 4, i, t);
            } else {
                let (_, tt) = m.l1_read(((i + 1) % 4) as u32, addr, 4, t);
                t = tt;
            }
            if i % 7 == 0 {
                let (_, _, tt) = m.l1_atomic((i % 4) as u32, addr & !63, AtomicOp::Add, 1, 0, t);
                t = tt;
            }
        }
        m.check_invariants();
    }
}
