//! Set-associative, write-combining, no-allocate-on-write cache with sFIFO
//! dirty tracking — the paper's L1 *and* L2 protocol (Table 1).
//!
//! * **No-allocate-on-write**: a store miss does not fetch the line; it
//!   allocates a write-combining entry whose only valid bytes are the ones
//!   written (per-byte `valid`/`dirty` masks).
//! * **sFIFO**: every clean→dirty transition pushes the line address; a
//!   full FIFO writes back the oldest entry (QuickRelease overflow).
//!   Entries whose line was cleaned early (replacement victim) go stale and
//!   are skipped during drains.
//! * Value-accurate: lines carry real bytes, so an un-synchronized reader
//!   genuinely observes stale data.

use std::cell::Cell;

use super::sfifo::{Sfifo, SfifoEntry};
use super::{line_read, line_write, merge_masked, LineAddr, LineData, Ticket, ZERO_LINE};

/// One cache line: per-byte valid and dirty masks plus data. The data
/// lives as eight 64-bit words ([`LineData`]) so masked merges are
/// word-wise `(old & !m) | (new & m)` instead of 64 per-byte branches.
#[derive(Debug, Clone)]
pub struct Line {
    pub addr: LineAddr,
    /// Bit i set ⇒ byte i holds meaningful data.
    pub valid: u64,
    /// Bit i set ⇒ byte i modified locally, not yet written back.
    /// Invariant: `dirty ⊆ valid`.
    pub dirty: u64,
    pub data: LineData,
}

/// Dirty bytes leaving a cache, headed to the next level.
#[derive(Debug, Clone)]
pub struct Writeback {
    pub line: LineAddr,
    pub mask: u64,
    pub data: LineData,
}

/// Result of one drain step (sFIFO pop).
#[derive(Debug)]
pub enum DrainStep {
    /// FIFO empty / no entry at or below the requested ticket.
    Done,
    /// Popped a stale entry (line already clean or evicted): no writeback.
    Stale,
    /// Popped a live entry: write these bytes back.
    Writeback(Writeback),
}

/// Outcome of a store.
#[derive(Debug, Default)]
pub struct WriteOutcome {
    /// Ticket if this store dirtied a clean/absent line (sFIFO push).
    pub ticket: Option<Ticket>,
    /// Writeback forced by sFIFO overflow.
    pub overflow_wb: Option<Writeback>,
    /// Writeback of a replacement victim's dirty bytes.
    pub victim_wb: Option<Writeback>,
}

/// Outcome of a fill (miss response installation).
#[derive(Debug, Default)]
pub struct FillOutcome {
    pub victim_wb: Option<Writeback>,
}

/// Write-combining cache.
#[derive(Debug)]
pub struct WcCache {
    sets: usize,
    ways: usize,
    set_mask: u64,
    /// `sets * ways` slots, set-major.
    slots: Vec<Option<Line>>,
    /// LRU stamps parallel to `slots`.
    stamps: Vec<u64>,
    clock: u64,
    /// Last-touched `(line, slot)` hint for [`Self::find`]: spatial
    /// locality makes consecutive accesses overwhelmingly hit the same
    /// line, so the hint skips the way-scan on the dominant path. The
    /// hint is *verified* against the slot before use — a stale hint is
    /// never wrong, only slow — and cleared on invalidations for
    /// hygiene. Purely a lookup accelerator: observable behaviour is
    /// identical with or without it.
    last: Cell<Option<(LineAddr, usize)>>,
    pub sfifo: Sfifo,
}

impl WcCache {
    pub fn new(sets: u32, ways: u32, sfifo_capacity: u32) -> Self {
        assert!(sets > 0 && sets.is_power_of_two());
        assert!(ways > 0);
        let n = (sets * ways) as usize;
        Self {
            sets: sets as usize,
            ways: ways as usize,
            set_mask: (sets - 1) as u64,
            slots: vec![None; n],
            stamps: vec![0; n],
            clock: 0,
            last: Cell::new(None),
            sfifo: Sfifo::new(sfifo_capacity as usize),
        }
    }

    #[inline]
    fn set_of(&self, line: LineAddr) -> usize {
        (line & self.set_mask) as usize
    }

    #[inline]
    fn set_range(&self, line: LineAddr) -> std::ops::Range<usize> {
        let s = self.set_of(line) * self.ways;
        s..s + self.ways
    }

    fn find(&self, line: LineAddr) -> Option<usize> {
        // Verified fast path: trust the hint only if the slot still holds
        // exactly this line.
        if let Some((l, i)) = self.last.get() {
            if l == line && matches!(&self.slots[i], Some(x) if x.addr == line) {
                return Some(i);
            }
        }
        let hit = self
            .set_range(line)
            .find(|&i| matches!(&self.slots[i], Some(l) if l.addr == line));
        if let Some(i) = hit {
            self.last.set(Some((line, i)));
        }
        hit
    }

    #[inline]
    fn touch(&mut self, slot: usize) {
        self.clock += 1;
        self.stamps[slot] = self.clock;
    }

    /// Pick a victim slot in the set of `line`: an invalid slot if any,
    /// else the LRU way. Returns (slot, evicted dirty bytes).
    fn victim_slot(&mut self, line: LineAddr) -> (usize, Option<Writeback>) {
        let range = self.set_range(line);
        // Prefer an empty way.
        if let Some(i) = range.clone().find(|&i| self.slots[i].is_none()) {
            return (i, None);
        }
        // Evict LRU.
        let lru = range.min_by_key(|&i| self.stamps[i]).unwrap();
        self.last.set(None);
        let old = self.slots[lru].take().unwrap();
        let wb = (old.dirty != 0).then(|| Writeback {
            line: old.addr,
            mask: old.dirty,
            data: old.data,
        });
        // Any sFIFO entry for the victim goes stale (lazy invalidation).
        (lru, wb)
    }

    /// Does the cache hold all bytes in `mask` for `line`?
    pub fn has_bytes(&self, line: LineAddr, mask: u64) -> bool {
        match self.find(line) {
            Some(i) => self.slots[i].as_ref().unwrap().valid & mask == mask,
            None => false,
        }
    }

    /// Combined probe + read for the hot path: one way-scan instead of
    /// the `has_bytes` + `read_bytes` pair. Returns `None` when the line
    /// is absent or the requested bytes are not all valid.
    pub fn probe_read(&mut self, line: LineAddr, off: usize, len: usize, mask: u64) -> Option<u64> {
        let i = self.find(line)?;
        let l = self.slots[i].as_ref().unwrap();
        if l.valid & mask != mask {
            return None;
        }
        let v = line_read(&l.data, off, len);
        self.touch(i);
        Some(v)
    }

    /// Is the line present at all (any valid byte)?
    pub fn present(&self, line: LineAddr) -> bool {
        self.find(line).is_some()
    }

    /// Is the line dirty?
    pub fn is_dirty(&self, line: LineAddr) -> bool {
        self.find(line)
            .is_some_and(|i| self.slots[i].as_ref().unwrap().dirty != 0)
    }

    /// Read bytes covered by `mask` (caller must have checked
    /// [`has_bytes`](Self::has_bytes)); bumps LRU.
    pub fn read_bytes(&mut self, line: LineAddr, off: usize, len: usize) -> u64 {
        let i = self.find(line).expect("read_bytes: line not present");
        self.touch(i);
        let l = self.slots[i].as_ref().unwrap();
        line_read(&l.data, off, len)
    }

    /// Store `len <= 8` bytes at in-line offset `off`. Write-combining,
    /// no-allocate: a miss creates a partial line.
    pub fn write_bytes(
        &mut self,
        line: LineAddr,
        off: usize,
        len: usize,
        value: u64,
    ) -> WriteOutcome {
        let mut data = ZERO_LINE;
        line_write(&mut data, off, len, value);
        self.write_masked(line, super::byte_mask(off, len), &data)
    }

    /// Store the bytes selected by `mask` (general form, used for
    /// writebacks arriving from an upper level). Write-combining,
    /// no-allocate.
    pub fn write_masked(&mut self, line: LineAddr, mask: u64, data: &LineData) -> WriteOutcome {
        debug_assert!(mask != 0);
        let mut out = WriteOutcome::default();

        let slot = match self.find(line) {
            Some(i) => i,
            None => {
                let (i, wb) = self.victim_slot(line);
                out.victim_wb = wb;
                self.slots[i] = Some(Line {
                    addr: line,
                    valid: 0,
                    dirty: 0,
                    data: ZERO_LINE,
                });
                i
            }
        };
        self.touch(slot);
        self.last.set(Some((line, slot)));
        let l = self.slots[slot].as_mut().unwrap();
        merge_masked(&mut l.data, data, mask);
        l.valid |= mask;
        let was_dirty = l.dirty != 0;
        l.dirty |= mask;
        if !was_dirty {
            // Clean → dirty: track in sFIFO.
            let (ticket, evicted) = self.sfifo.push(line);
            out.ticket = Some(ticket);
            if let Some(e) = evicted {
                out.overflow_wb = self.clean_line(e.line);
            }
        }
        out
    }

    /// Full line data; `None` unless every byte is valid.
    pub fn full_line(&mut self, line: LineAddr) -> Option<LineData> {
        let i = self.find(line)?;
        let l = self.slots[i].as_ref().unwrap();
        if l.valid == u64::MAX {
            let data = l.data;
            self.touch(i);
            Some(data)
        } else {
            None
        }
    }

    /// Install a full line fetched from the next level, preserving local
    /// dirty bytes (they are newer than the fill).
    pub fn fill(&mut self, line: LineAddr, fill_data: LineData) -> FillOutcome {
        let mut out = FillOutcome::default();
        let slot = match self.find(line) {
            Some(i) => i,
            None => {
                let (i, wb) = self.victim_slot(line);
                out.victim_wb = wb;
                self.slots[i] = Some(Line {
                    addr: line,
                    valid: 0,
                    dirty: 0,
                    data: ZERO_LINE,
                });
                i
            }
        };
        self.touch(slot);
        self.last.set(Some((line, slot)));
        let l = self.slots[slot].as_mut().unwrap();
        // Take fill bytes everywhere the line is not dirty (local dirty
        // bytes are newer than the fill).
        merge_masked(&mut l.data, &fill_data, !l.dirty);
        l.valid = u64::MAX;
        out
    }

    /// Clean a line's dirty bytes, returning them for writeback.
    fn clean_line(&mut self, line: LineAddr) -> Option<Writeback> {
        let i = self.find(line)?;
        let l = self.slots[i].as_mut().unwrap();
        if l.dirty == 0 {
            return None;
        }
        let wb = Writeback {
            line,
            mask: l.dirty,
            data: l.data,
        };
        l.dirty = 0;
        Some(wb)
    }

    /// One drain step: pop the oldest sFIFO entry at or below `upto`
    /// (or any entry if `upto` is `None`).
    pub fn drain_step(&mut self, upto: Option<Ticket>) -> DrainStep {
        let entry: Option<SfifoEntry> = match upto {
            Some(t) => self.sfifo.pop_if_at_most(t),
            None => self.sfifo.pop(),
        };
        match entry {
            None => DrainStep::Done,
            Some(e) => match self.clean_line(e.line) {
                Some(wb) => DrainStep::Writeback(wb),
                None => DrainStep::Stale,
            },
        }
    }

    /// Drop the line entirely (used before an L2-scope atomic so the L1
    /// cannot serve stale data afterwards). Dirty bytes are returned.
    pub fn invalidate_line(&mut self, line: LineAddr) -> Option<Writeback> {
        let i = self.find(line)?;
        self.last.set(None);
        let l = self.slots[i].take().unwrap();
        (l.dirty != 0).then(|| Writeback {
            line,
            mask: l.dirty,
            data: l.data,
        })
    }

    /// Flash-invalidate: drop every line in one cycle. All dirty data must
    /// already be drained — enforced here.
    ///
    /// Returns the number of valid lines discarded (locality lost).
    pub fn flash_invalidate(&mut self) -> u64 {
        self.last.set(None);
        let mut dropped = 0;
        for s in &mut self.slots {
            if let Some(l) = s {
                assert_eq!(l.dirty, 0, "flash_invalidate with dirty line {:#x}", l.addr);
                dropped += 1;
                *s = None;
            }
        }
        self.sfifo.clear();
        dropped
    }

    /// Number of sFIFO entries pending drain — the work a full flush
    /// faces right now (diagnostics / trace detail).
    pub fn sfifo_pending(&self) -> usize {
        self.sfifo.len()
    }

    /// Number of dirty lines (invariant checks / diagnostics).
    pub fn dirty_line_count(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|l| l.dirty != 0)
            .count()
    }

    pub fn valid_line_count(&self) -> usize {
        self.slots.iter().flatten().count()
    }

    /// Iterate dirty lines (for invariant checks).
    pub fn dirty_lines(&self) -> impl Iterator<Item = LineAddr> + '_ {
        self.slots
            .iter()
            .flatten()
            .filter(|l| l.dirty != 0)
            .map(|l| l.addr)
    }

    /// Invariant: every dirty line has a (non-stale) sFIFO entry.
    pub fn check_dirty_subset_of_sfifo(&self) -> bool {
        use std::collections::HashSet;
        let tracked: HashSet<LineAddr> = self.sfifo.iter().map(|e| e.line).collect();
        self.dirty_lines().all(|l| tracked.contains(&l))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> WcCache {
        WcCache::new(4, 2, 8)
    }

    #[test]
    fn write_miss_allocates_partial_line() {
        let mut c = cache();
        let out = c.write_bytes(5, 0, 4, 0xAABBCCDD);
        assert!(out.ticket.is_some());
        assert!(c.has_bytes(5, 0xF));
        assert!(!c.has_bytes(5, 0xFF)); // bytes 4..8 not valid
        assert_eq!(c.read_bytes(5, 0, 4), 0xAABBCCDD);
        assert!(c.is_dirty(5));
    }

    #[test]
    fn write_combining_single_sfifo_entry() {
        let mut c = cache();
        let t1 = c.write_bytes(5, 0, 4, 1).ticket;
        let t2 = c.write_bytes(5, 4, 4, 2).ticket;
        assert!(t1.is_some());
        assert!(t2.is_none(), "already-dirty line must not re-push");
        assert_eq!(c.sfifo.len(), 1);
    }

    #[test]
    fn fill_preserves_dirty_bytes() {
        let mut c = cache();
        c.write_bytes(9, 0, 4, 0x11111111);
        let mut fill = [u64::MAX; 8];
        line_write(&mut fill, 0, 1, 0xEE);
        c.fill(9, fill);
        // Dirty bytes kept, rest from fill.
        assert_eq!(c.read_bytes(9, 0, 4), 0x11111111);
        assert_eq!(c.read_bytes(9, 4, 4), 0xFFFFFFFF);
        assert!(c.has_bytes(9, u64::MAX));
    }

    #[test]
    fn sfifo_overflow_forces_writeback() {
        let mut c = WcCache::new(64, 4, 2); // tiny sFIFO, roomy cache
        c.write_bytes(1, 0, 4, 1);
        c.write_bytes(2, 0, 4, 2);
        let out = c.write_bytes(3, 0, 4, 3);
        let wb = out.overflow_wb.expect("oldest dirty line written back");
        assert_eq!(wb.line, 1);
        assert!(!c.is_dirty(1), "line cleaned by overflow");
        assert!(c.present(1), "overflow cleans, does not evict");
    }

    #[test]
    fn replacement_evicts_lru_and_writes_back() {
        let mut c = WcCache::new(1, 2, 16); // one set, two ways
        c.write_bytes(10, 0, 4, 1);
        c.write_bytes(20, 0, 4, 2);
        c.read_bytes(10, 0, 4); // 10 is MRU now
        let out = c.write_bytes(30, 0, 4, 3);
        let wb = out.victim_wb.expect("dirty LRU victim written back");
        assert_eq!(wb.line, 20);
        assert!(!c.present(20));
        assert!(c.present(10) && c.present(30));
    }

    #[test]
    fn stale_sfifo_entry_skipped_on_drain() {
        let mut c = WcCache::new(1, 2, 16);
        c.write_bytes(10, 0, 4, 1);
        c.write_bytes(20, 0, 4, 2);
        c.write_bytes(30, 0, 4, 3); // evicts 10 (dirty) -> sFIFO entry stale
        assert!(matches!(c.drain_step(None), DrainStep::Stale));
        // Next two entries live.
        assert!(matches!(c.drain_step(None), DrainStep::Writeback(_)));
        assert!(matches!(c.drain_step(None), DrainStep::Writeback(_)));
        assert!(matches!(c.drain_step(None), DrainStep::Done));
        assert_eq!(c.dirty_line_count(), 0);
    }

    #[test]
    fn selective_drain_stops_at_ticket() {
        let mut c = cache();
        let t0 = c.write_bytes(1, 0, 4, 1).ticket.unwrap();
        let _t1 = c.write_bytes(2, 0, 4, 2).ticket.unwrap();
        match c.drain_step(Some(t0)) {
            DrainStep::Writeback(wb) => assert_eq!(wb.line, 1),
            other => panic!("expected writeback, got {other:?}"),
        }
        assert!(matches!(c.drain_step(Some(t0)), DrainStep::Done));
        assert!(c.is_dirty(2), "younger write stays dirty");
    }

    #[test]
    fn flash_invalidate_requires_clean() {
        let mut c = cache();
        c.write_bytes(1, 0, 4, 1);
        while !matches!(c.drain_step(None), DrainStep::Done) {}
        let dropped = c.flash_invalidate();
        assert_eq!(dropped, 1);
        assert!(!c.present(1));
    }

    #[test]
    #[should_panic(expected = "flash_invalidate with dirty line")]
    fn flash_invalidate_panics_if_dirty() {
        let mut c = cache();
        c.write_bytes(1, 0, 4, 1);
        c.flash_invalidate();
    }

    #[test]
    fn invalidate_line_returns_dirty() {
        let mut c = cache();
        c.write_bytes(7, 0, 8, 0x1122334455667788);
        let wb = c.invalidate_line(7).unwrap();
        assert_eq!(wb.mask, 0xFF);
        assert!(!c.present(7));
        assert!(c.invalidate_line(7).is_none());
    }

    #[test]
    fn last_line_hint_set_on_find_and_cleared_on_invalidate() {
        let mut c = cache();
        c.write_bytes(5, 0, 4, 1);
        let slot = c.find(5).unwrap();
        assert_eq!(c.last.get(), Some((5, slot)));
        c.invalidate_line(5);
        assert_eq!(c.last.get(), None, "invalidate must drop the hint");
        assert!(!c.present(5));
    }

    #[test]
    fn last_line_hint_cleared_on_flash_invalidate() {
        let mut c = cache();
        c.write_bytes(5, 0, 4, 1);
        while !matches!(c.drain_step(None), DrainStep::Done) {}
        assert!(c.last.get().is_some());
        c.flash_invalidate();
        assert_eq!(c.last.get(), None, "flush must drop the hint");
        assert!(!c.present(5));
    }

    #[test]
    fn stale_hint_after_eviction_is_verified_not_trusted() {
        let mut c = WcCache::new(1, 1, 16); // one slot: every write evicts
        c.write_bytes(10, 0, 4, 1);
        assert!(c.present(10)); // hint -> (10, 0)
        c.write_bytes(20, 0, 4, 2); // evicts 10; slot 0 now holds 20
        assert!(!c.present(10), "hint for 10 must not claim a false hit");
        assert_eq!(c.read_bytes(20, 0, 4), 2);
        assert_eq!(c.last.get(), Some((20, 0)));
    }

    #[test]
    fn dirty_subset_of_sfifo_invariant() {
        let mut c = cache();
        for i in 0..20 {
            c.write_bytes(i, (i as usize * 4) % 60, 4, i);
            assert!(c.check_dirty_subset_of_sfifo());
        }
    }
}
