//! Contention primitives: next-free-cycle resources.
//!
//! The simulator computes each operation's completion time eagerly while
//! processing events in cycle order; shared components (L1 ports, L2 banks,
//! DRAM channels, crossbar links) are modeled as resources that serialize
//! occupancy. This is the standard analytic-contention approximation — it
//! captures queueing delay and bandwidth ceilings without split
//! transactions.

use crate::sim::Cycle;

/// A single-server resource: one request at a time, each holding it for an
/// occupancy interval.
#[derive(Debug, Default, Clone)]
pub struct Resource {
    next_free: Cycle,
    /// Total busy cycles (utilization accounting).
    busy: u64,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Acquire the resource no earlier than `at`, holding it `occupancy`
    /// cycles. Returns the cycle service *starts* (>= `at`).
    pub fn acquire(&mut self, at: Cycle, occupancy: u64) -> Cycle {
        let start = self.next_free.max(at);
        self.next_free = start + occupancy;
        self.busy += occupancy;
        start
    }

    /// When the resource frees up next.
    pub fn next_free(&self) -> Cycle {
        self.next_free
    }

    pub fn busy_cycles(&self) -> u64 {
        self.busy
    }

    pub fn reset(&mut self) {
        self.next_free = 0;
        self.busy = 0;
    }
}

/// A bank-interleaved resource array (L2 banks, DRAM channels). Requests
/// hash to a bank by line address.
#[derive(Debug, Clone)]
pub struct Banked {
    banks: Vec<Resource>,
    mask: u64,
}

impl Banked {
    /// `n` must be a power of two (validated by `DeviceConfig`).
    pub fn new(n: u32) -> Self {
        assert!(n > 0 && n.is_power_of_two());
        Self {
            banks: vec![Resource::new(); n as usize],
            mask: (n - 1) as u64,
        }
    }

    /// Bank index for a line address.
    #[inline]
    pub fn bank_of(&self, line: u64) -> usize {
        (line & self.mask) as usize
    }

    /// Acquire the bank serving `line` from `at` for `occupancy` cycles;
    /// returns service start.
    pub fn acquire(&mut self, line: u64, at: Cycle, occupancy: u64) -> Cycle {
        let b = self.bank_of(line);
        self.banks[b].acquire(at, occupancy)
    }

    pub fn num_banks(&self) -> usize {
        self.banks.len()
    }

    pub fn busy_cycles(&self) -> u64 {
        self.banks.iter().map(|b| b.busy_cycles()).sum()
    }

    pub fn reset(&mut self) {
        for b in &mut self.banks {
            b.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_overlapping_requests() {
        let mut r = Resource::new();
        assert_eq!(r.acquire(10, 5), 10); // free at 15
        assert_eq!(r.acquire(12, 5), 15); // queued behind
        assert_eq!(r.acquire(30, 5), 30); // idle gap
        assert_eq!(r.busy_cycles(), 15);
    }

    #[test]
    fn banked_parallelism() {
        let mut b = Banked::new(2);
        // Lines 0 and 1 hit different banks: no queueing.
        assert_eq!(b.acquire(0, 10, 4), 10);
        assert_eq!(b.acquire(1, 10, 4), 10);
        // Same bank queues.
        assert_eq!(b.acquire(2, 10, 4), 14);
    }

    #[test]
    fn bank_hash_is_line_interleaved() {
        let b = Banked::new(8);
        assert_eq!(b.bank_of(0), 0);
        assert_eq!(b.bank_of(7), 7);
        assert_eq!(b.bank_of(8), 0);
    }

    #[test]
    #[should_panic]
    fn non_pow2_banks_rejected() {
        Banked::new(3);
    }
}
