//! The simulated GPU memory system.
//!
//! Value-accurate and cycle-approximate: caches hold real bytes (so stale
//! reads genuinely return stale data — the litmus tests depend on it), and
//! timing comes from per-component latencies plus banked next-free-cycle
//! contention.
//!
//! Hierarchy (paper §2, Table 1): per-CU L1 data caches (write-combining,
//! no-allocate-on-write, sFIFO dirty tracking) → shared banked L2 (also
//! write-combining with its own sFIFO) → channelled DRAM over the flat
//! [`BackingStore`].

pub mod backing;
pub mod cache;
pub mod hierarchy;
pub mod sfifo;
pub mod timing;

pub use backing::{BackingStore, MemAlloc};
pub use cache::{Line, WcCache};
pub use hierarchy::MemSystem;
pub use sfifo::{Sfifo, Ticket};
pub use timing::{Banked, Resource};

/// Byte address in the flat simulated address space.
pub type Addr = u64;

/// Cache-line granularity address (addr >> 6).
pub type LineAddr = u64;

/// Line size in bytes (fixed at 64, per Table 1).
pub const LINE: u64 = 64;
pub const LINE_SHIFT: u32 = 6;

/// Line address of a byte address.
#[inline]
pub fn line_of(addr: Addr) -> LineAddr {
    addr >> LINE_SHIFT
}

/// Byte offset within a line.
#[inline]
pub fn offset_in_line(addr: Addr) -> usize {
    (addr & (LINE - 1)) as usize
}

/// Byte mask (one bit per byte of a 64-byte line) covering `len` bytes at
/// in-line offset `off`.
#[inline]
pub fn byte_mask(off: usize, len: usize) -> u64 {
    debug_assert!(off + len <= 64, "access straddles a line: off={off} len={len}");
    if len == 64 {
        u64::MAX
    } else {
        ((1u64 << len) - 1) << off
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_math() {
        assert_eq!(line_of(0), 0);
        assert_eq!(line_of(63), 0);
        assert_eq!(line_of(64), 1);
        assert_eq!(offset_in_line(64 + 5), 5);
    }

    #[test]
    fn masks() {
        assert_eq!(byte_mask(0, 4), 0xF);
        assert_eq!(byte_mask(4, 4), 0xF0);
        assert_eq!(byte_mask(0, 64), u64::MAX);
        assert_eq!(byte_mask(60, 4), 0xF << 60);
    }

    #[test]
    #[should_panic]
    fn straddle_panics_in_debug() {
        byte_mask(62, 4);
    }
}
