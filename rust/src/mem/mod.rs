//! The simulated GPU memory system.
//!
//! Value-accurate and cycle-approximate: caches hold real bytes (so stale
//! reads genuinely return stale data — the litmus tests depend on it), and
//! timing comes from per-component latencies plus banked next-free-cycle
//! contention.
//!
//! Hierarchy (paper §2, Table 1): per-CU L1 data caches (write-combining,
//! no-allocate-on-write, sFIFO dirty tracking) → shared banked L2 (also
//! write-combining with its own sFIFO) → channelled DRAM over the flat
//! [`BackingStore`].

pub mod backing;
pub mod cache;
pub mod hierarchy;
pub mod sfifo;
pub mod timing;

pub use backing::{BackingStore, MemAlloc};
pub use cache::{Line, WcCache};
pub use hierarchy::MemSystem;
pub use sfifo::{Sfifo, Ticket};
pub use timing::{Banked, Resource};

/// Byte address in the flat simulated address space.
pub type Addr = u64;

/// Cache-line granularity address (addr >> 6).
pub type LineAddr = u64;

/// Line size in bytes (fixed at 64, per Table 1).
pub const LINE: u64 = 64;
pub const LINE_SHIFT: u32 = 6;

/// Line address of a byte address.
#[inline]
pub fn line_of(addr: Addr) -> LineAddr {
    addr >> LINE_SHIFT
}

/// Byte offset within a line.
#[inline]
pub fn offset_in_line(addr: Addr) -> usize {
    (addr & (LINE - 1)) as usize
}

/// Byte mask (one bit per byte of a 64-byte line) covering `len` bytes at
/// in-line offset `off`.
#[inline]
pub fn byte_mask(off: usize, len: usize) -> u64 {
    debug_assert!(off + len <= 64, "access straddles a line: off={off} len={len}");
    if len == 64 {
        u64::MAX
    } else {
        ((1u64 << len) - 1) << off
    }
}

/// A 64-byte line as eight little-endian words: line byte `k` is byte
/// `k % 8` of word `k / 8`. The word layout lets masked merges run as
/// eight 64-bit ops instead of a 64-iteration per-byte loop, while
/// `line_read`/`line_write` keep the byte-addressed view the access
/// paths need.
pub type LineData = [u64; 8];

/// All-zero line.
pub const ZERO_LINE: LineData = [0u64; 8];

/// Expand an 8-bit per-byte mask into a 64-bit word where each set bit
/// becomes a full 0xFF byte: bit `i` of `m8` → bits `8i..8i+8`.
/// Branchless bit-spread (0→0, 4-bit and 2-bit interleave steps), then a
/// multiply fans each seed bit out across its byte.
#[inline]
pub fn expand8(m8: u64) -> u64 {
    debug_assert!(m8 <= 0xFF);
    let mut x = (m8 | (m8 << 28)) & 0x0000_000F_0000_000F;
    x = (x | (x << 14)) & 0x0003_0003_0003_0003;
    x = (x | (x << 7)) & 0x0101_0101_0101_0101;
    x * 0xFF
}

/// Read `len <= 8` bytes at in-line offset `off` as a little-endian
/// value. Handles accesses that straddle a word boundary (never a line
/// boundary — `byte_mask` enforces that upstream).
#[inline]
pub fn line_read(data: &LineData, off: usize, len: usize) -> u64 {
    debug_assert!(len >= 1 && len <= 8 && off + len <= 64);
    let w = off / 8;
    let sh = (off % 8) * 8;
    let mut v = data[w] >> sh;
    if off % 8 + len > 8 {
        // Straddles into the next word; sh > 0 here, so 64 - sh < 64.
        v |= data[w + 1] << (64 - sh);
    }
    if len < 8 {
        v &= (1u64 << (8 * len)) - 1;
    }
    v
}

/// Write `len <= 8` little-endian bytes of `value` at in-line offset
/// `off`. The word-straddling counterpart of [`line_read`].
#[inline]
pub fn line_write(data: &mut LineData, off: usize, len: usize, value: u64) {
    debug_assert!(len >= 1 && len <= 8 && off + len <= 64);
    let m = if len == 8 { u64::MAX } else { (1u64 << (8 * len)) - 1 };
    let value = value & m;
    let w = off / 8;
    let sh = (off % 8) * 8;
    data[w] = (data[w] & !(m << sh)) | (value << sh);
    if off % 8 + len > 8 {
        let hi = 64 - sh; // sh > 0 whenever the access straddles
        data[w + 1] = (data[w + 1] & !(m >> hi)) | (value >> hi);
    }
}

/// Merge the bytes selected by the per-byte `mask` from `src` into
/// `dst`: eight branchless `(old & !m) | (new & m)` word merges.
#[inline]
pub fn merge_masked(dst: &mut LineData, src: &LineData, mask: u64) {
    for w in 0..8 {
        let m = expand8((mask >> (8 * w)) & 0xFF);
        dst[w] = (dst[w] & !m) | (src[w] & m);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_math() {
        assert_eq!(line_of(0), 0);
        assert_eq!(line_of(63), 0);
        assert_eq!(line_of(64), 1);
        assert_eq!(offset_in_line(64 + 5), 5);
    }

    #[test]
    fn masks() {
        assert_eq!(byte_mask(0, 4), 0xF);
        assert_eq!(byte_mask(4, 4), 0xF0);
        assert_eq!(byte_mask(0, 64), u64::MAX);
        assert_eq!(byte_mask(60, 4), 0xF << 60);
    }

    #[test]
    #[should_panic]
    fn straddle_panics_in_debug() {
        byte_mask(62, 4);
    }

    #[test]
    fn expand8_spreads_every_mask() {
        assert_eq!(expand8(0), 0);
        assert_eq!(expand8(0xFF), u64::MAX);
        assert_eq!(expand8(0b0000_0001), 0x0000_0000_0000_00FF);
        assert_eq!(expand8(0b1000_0000), 0xFF00_0000_0000_0000);
        assert_eq!(expand8(0b0101_0101), 0x00FF_00FF_00FF_00FF);
        // Exhaustive against the per-byte reference.
        for m8 in 0u64..=0xFF {
            let mut want = 0u64;
            for i in 0..8 {
                if m8 & (1 << i) != 0 {
                    want |= 0xFFu64 << (8 * i);
                }
            }
            assert_eq!(expand8(m8), want, "m8={m8:#04x}");
        }
    }

    #[test]
    fn line_read_write_round_trip_all_offsets() {
        for len in 1..=8usize {
            for off in 0..=(64 - len) {
                let mut data = ZERO_LINE;
                let m = if len == 8 { u64::MAX } else { (1 << (8 * len)) - 1 };
                let v = 0x1122_3344_5566_7788u64 & m;
                line_write(&mut data, off, len, v);
                assert_eq!(line_read(&data, off, len), v, "off={off} len={len}");
                // Neighbouring bytes untouched.
                if off > 0 {
                    assert_eq!(line_read(&data, off - 1, 1), 0);
                }
                if off + len < 64 {
                    assert_eq!(line_read(&data, off + len, 1), 0);
                }
            }
        }
    }

    #[test]
    fn line_write_straddles_word_boundary() {
        let mut data = ZERO_LINE;
        line_write(&mut data, 6, 4, 0xAABB_CCDD);
        assert_eq!(data[0], 0xCCDD_0000_0000_0000);
        assert_eq!(data[1], 0x0000_0000_0000_AABB);
        assert_eq!(line_read(&data, 6, 4), 0xAABB_CCDD);
    }

    #[test]
    fn merge_masked_matches_per_byte_reference() {
        let mut dst = ZERO_LINE;
        let mut src = ZERO_LINE;
        for k in 0..64 {
            line_write(&mut dst, k, 1, k as u64);
            line_write(&mut src, k, 1, 0xA0 + k as u64 % 0x20);
        }
        let mask = 0xF0F0_1234_8001_FFFEu64;
        let mut want = dst;
        for k in 0..64 {
            if mask & (1 << k) != 0 {
                let b = line_read(&src, k, 1);
                line_write(&mut want, k, 1, b);
            }
        }
        let mut got = dst;
        merge_masked(&mut got, &src, mask);
        assert_eq!(got, want);
    }
}
