//! The synchronization FIFO (sFIFO) of QuickRelease (Hechtman et al.,
//! HPCA'14), as used by the paper's baseline GPU and by sRSP.
//!
//! The sFIFO tracks the addresses of dirty cache lines in write order. A
//! cache-flush drains it in FIFO order; sRSP's *selective-flush* drains it
//! only **up to a ticket** — the sFIFO position recorded in the LR-TBL by
//! the local sharer's last wg-scope release.
//!
//! Entries are lazily invalidated: a line that was written back early (e.g.
//! evicted by replacement) keeps its stale entry; draining skips entries
//! whose line is no longer dirty. Capacity pressure therefore counts stale
//! entries too, exactly like a real FIFO of addresses would.

use super::LineAddr;
use std::collections::VecDeque;

/// Monotone position in a cache's dirty-write order. Ticket `t1 < t2`
/// means the write tracked by `t1` entered the sFIFO first.
pub type Ticket = u64;

/// One sFIFO entry: the ticket and the dirty line it tracks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SfifoEntry {
    pub ticket: Ticket,
    pub line: LineAddr,
}

/// Bounded FIFO of dirty-line addresses.
#[derive(Debug)]
pub struct Sfifo {
    entries: VecDeque<SfifoEntry>,
    capacity: usize,
    next_ticket: Ticket,
}

impl Sfifo {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "sFIFO capacity must be > 0");
        Self {
            entries: VecDeque::with_capacity(capacity),
            capacity,
            next_ticket: 0,
        }
    }

    /// Push a newly-dirtied line. If the FIFO is full the **oldest entry is
    /// popped and returned**; the caller must write that line back before
    /// completing the push (QuickRelease overflow behaviour).
    pub fn push(&mut self, line: LineAddr) -> (Ticket, Option<SfifoEntry>) {
        let evicted = if self.entries.len() == self.capacity {
            self.entries.pop_front()
        } else {
            None
        };
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.entries.push_back(SfifoEntry { ticket, line });
        (ticket, evicted)
    }

    /// Pop the oldest entry (drain step).
    pub fn pop(&mut self) -> Option<SfifoEntry> {
        self.entries.pop_front()
    }

    /// Pop the oldest entry only if its ticket is `<= upto`.
    pub fn pop_if_at_most(&mut self, upto: Ticket) -> Option<SfifoEntry> {
        match self.entries.front() {
            Some(e) if e.ticket <= upto => self.entries.pop_front(),
            _ => None,
        }
    }

    /// Ticket that the *next* push would receive. All existing entries have
    /// tickets strictly below this.
    pub fn frontier(&self) -> Ticket {
        self.next_ticket
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Oldest ticket still queued (None when empty).
    pub fn oldest_ticket(&self) -> Option<Ticket> {
        self.entries.front().map(|e| e.ticket)
    }

    /// Iterate entries oldest-first (diagnostics / invariant checks).
    pub fn iter(&self) -> impl Iterator<Item = &SfifoEntry> {
        self.entries.iter()
    }

    /// Clear all entries (used by flash-invalidate after a full drain; the
    /// caller asserts no dirty line remains).
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_tickets() {
        let mut f = Sfifo::new(4);
        let (t0, e0) = f.push(10);
        let (t1, e1) = f.push(11);
        assert!(t0 < t1);
        assert!(e0.is_none() && e1.is_none());
        assert_eq!(f.pop().unwrap().line, 10);
        assert_eq!(f.pop().unwrap().line, 11);
        assert!(f.pop().is_none());
    }

    #[test]
    fn overflow_evicts_oldest() {
        let mut f = Sfifo::new(2);
        f.push(1);
        f.push(2);
        let (_, evicted) = f.push(3);
        assert_eq!(evicted.unwrap().line, 1);
        assert_eq!(f.len(), 2);
        assert_eq!(f.pop().unwrap().line, 2);
    }

    #[test]
    fn selective_drain_respects_ticket() {
        let mut f = Sfifo::new(8);
        let (t0, _) = f.push(100);
        let (t1, _) = f.push(101);
        let (_t2, _) = f.push(102);
        // Drain up to t1: pops entries t0 and t1, leaves t2.
        assert_eq!(f.pop_if_at_most(t1).unwrap().ticket, t0);
        assert_eq!(f.pop_if_at_most(t1).unwrap().ticket, t1);
        assert!(f.pop_if_at_most(t1).is_none());
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn drain_to_already_popped_ticket_is_noop() {
        let mut f = Sfifo::new(8);
        let (t0, _) = f.push(5);
        f.pop();
        // t0 is gone; draining to it pops nothing.
        assert!(f.pop_if_at_most(t0).is_none());
    }

    #[test]
    fn frontier_monotone() {
        let mut f = Sfifo::new(2);
        let a = f.frontier();
        f.push(1);
        f.push(2);
        f.push(3); // overflow
        let b = f.frontier();
        assert!(b > a);
        assert_eq!(b, 3);
    }
}
