//! The GPU device model: compute units, work-group dispatch and the
//! kernel-launch event loop.

pub mod device;

pub use device::{Device, LaunchReport};
