//! `Device`: the simulated GPU — CU array + memory system + the
//! kernel-launch event loop.
//!
//! Work-groups are dispatched round-robin over CUs (wg *i* runs on CU
//! `i % num_cus`, matching the paper's one-deque-per-work-group setup when
//! `wgs_per_cu == 1`). A kernel launch runs every work-group's KIR program
//! to `Halt`, driven by the deterministic event queue; the launch ends with
//! the standard GPU kernel-boundary barrier (all L1s flushed + invalidated,
//! L2 flushed) so the host observes all device writes.

use std::time::Instant;

use crate::config::{DeviceConfig, Protocol};
use crate::kir::{ComputeEngine, DecodedProgram, NoopEngine, Program, StepResult, WgContext};
use crate::mem::MemSystem;
use crate::sim::perfstats::{self, TimedEngine};
use crate::sim::trace::DEVICE_CU;
use crate::sim::{Cycle, EventQueue, PerfStats, Stats, TraceKind};

/// Result of one kernel launch.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    /// Cycle at which the last work-group halted (before the end barrier).
    pub last_halt: Cycle,
    /// Cycle at which the kernel-end barrier completed.
    pub end_cycle: Cycle,
    /// Events processed (diagnostics).
    pub events: u64,
}

/// The simulated GPU device.
pub struct Device {
    pub cfg: DeviceConfig,
    pub protocol: Protocol,
    pub mem: MemSystem,
    /// Running cycle count across launches (kernel launches are
    /// back-to-back; the host gap is ignored, as in the paper's
    /// device-side measurements).
    pub now: Cycle,
    /// Host-side cost counters accumulated across this device's launches
    /// (wall time split into sim vs compute-engine attribution; see
    /// [`crate::sim::perfstats`]). Never feeds the simulated stats or the
    /// report pipeline.
    pub perf: PerfStats,
}

impl Device {
    /// Build a device running `protocol`. The config's `proto_params`
    /// overrides (`--proto-param k=v`) are resolved against the
    /// protocol's registry spec here: keys the protocol does not declare
    /// are ignored (a mixed grid's scoped cells have no tables to size),
    /// and an explicit `lr_tbl_entries`/`pa_tbl_entries` override wins
    /// over the config fields for the sRSP family.
    pub fn new(cfg: DeviceConfig, protocol: Protocol) -> Self {
        let mut cfg = cfg;
        let spec = protocol.proto().params();
        let mut params = crate::sync::protocol::resolve_overrides(protocol, &cfg.proto_params)
            .unwrap_or_else(|e| panic!("{e}"));
        if spec.iter().any(|p| p.key == "lr_tbl_entries") {
            if params.is_explicit("lr_tbl_entries") {
                cfg.lr_tbl_entries = params.get_u32("lr_tbl_entries");
            } else {
                params.set_auto("lr_tbl_entries", f64::from(cfg.lr_tbl_entries));
            }
        }
        if spec.iter().any(|p| p.key == "pa_tbl_entries") {
            if params.is_explicit("pa_tbl_entries") {
                cfg.pa_tbl_entries = params.get_u32("pa_tbl_entries");
            } else {
                params.set_auto("pa_tbl_entries", f64::from(cfg.pa_tbl_entries));
            }
        }
        let mut mem = MemSystem::new(cfg.clone());
        mem.proto_params = params;
        Self {
            mem,
            cfg,
            protocol,
            now: 0,
            perf: PerfStats::default(),
        }
    }

    /// CU on which work-group `wg` runs.
    pub fn cu_of_wg(&self, wg: u32) -> u32 {
        wg % self.cfg.num_cus
    }

    /// Launch `num_wgs` work-groups of `prog` and run them to completion.
    ///
    /// `init` seeds each context's registers before execution (argument
    /// passing: kernels read their parameters from registers or from
    /// well-known addresses set up by the host driver).
    pub fn launch_with_init(
        &mut self,
        prog: &Program,
        num_wgs: u32,
        engine: &mut dyn ComputeEngine,
        init: impl Fn(&mut WgContext),
    ) -> LaunchReport {
        assert!(num_wgs > 0, "kernel launch needs at least one work-group");
        let wall0 = Instant::now();
        // Decode once per launch for the hot interpreter path; the
        // reference switch selects the original instruction-by-instruction
        // interpreter (the semantic oracle the identity tests compare
        // against).
        let decoded = if perfstats::reference_paths() {
            None
        } else {
            Some(DecodedProgram::decode(prog))
        };
        // Attribute wall time spent inside the compute engine (workload
        // numerics) separately from simulator time.
        let mut engine = TimedEngine::new(engine);
        let mut queue = EventQueue::new();
        let mut contexts: Vec<WgContext> = (0..num_wgs)
            .map(|wg| {
                let mut ctx = WgContext::new(wg, self.cu_of_wg(wg));
                init(&mut ctx);
                ctx
            })
            .collect();

        // Stagger dispatch: one work-group issues per cycle (models the
        // command-processor dispatch rate).
        for wg in 0..num_wgs {
            queue.schedule(self.now + wg as u64, wg);
        }
        self.mem
            .trace
            .emit(self.now, DEVICE_CU, TraceKind::LaunchBegin, 0, num_wgs as u64);

        let mut events = 0u64;
        let mut running = num_wgs;
        let mut last_halt = self.now;
        while let Some(ev) = queue.pop() {
            events += 1;
            self.mem.trace.set_wg(ev.wg);
            let ctx = &mut contexts[ev.wg as usize];
            debug_assert!(!ctx.halted, "halted wg rescheduled");
            let result = match &decoded {
                Some(d) => crate::kir::interp::step_decoded(
                    ctx,
                    d,
                    &mut self.mem,
                    self.protocol,
                    num_wgs,
                    &mut engine,
                    ev.cycle,
                ),
                None => crate::kir::interp::step(
                    ctx,
                    prog,
                    &mut self.mem,
                    self.protocol,
                    num_wgs,
                    &mut engine,
                    ev.cycle,
                ),
            };
            match result {
                StepResult::Continue(next) => {
                    // Guarantee forward progress in the queue even for
                    // zero-latency outcomes.
                    queue.schedule(next.max(ev.cycle + 1), ev.wg);
                }
                StepResult::Halted => {
                    running -= 1;
                    last_halt = last_halt.max(ev.cycle);
                    if running == 0 {
                        break;
                    }
                }
            }
        }
        assert_eq!(running, 0, "deadlock: {running} work-groups never halted");

        // Kernel-end barrier: device writes become host-visible.
        self.mem.trace.set_wg(DEVICE_CU);
        let end_cycle = self.mem.kernel_end_barrier(last_halt);
        self.mem
            .trace
            .emit(end_cycle, DEVICE_CU, TraceKind::LaunchEnd, 0, events);
        self.now = end_cycle;
        self.mem.stats.cycles = self.now;
        let launch_perf = PerfStats {
            launches: 1,
            events,
            launch_nanos: wall0.elapsed().as_nanos() as u64,
            engine_nanos: engine.nanos,
            ..PerfStats::default()
        };
        self.perf.merge(&launch_perf);
        perfstats::add_thread(&launch_perf);
        LaunchReport {
            last_halt,
            end_cycle,
            events,
        }
    }

    /// Launch with zeroed registers.
    pub fn launch(
        &mut self,
        prog: &Program,
        num_wgs: u32,
        engine: &mut dyn ComputeEngine,
    ) -> LaunchReport {
        self.launch_with_init(prog, num_wgs, engine, |_| {})
    }

    /// Launch a kernel that needs no compute engine.
    pub fn launch_simple(&mut self, prog: &Program, num_wgs: u32) -> LaunchReport {
        let mut eng = NoopEngine;
        self.launch(prog, num_wgs, &mut eng)
    }

    /// Take the accumulated statistics (resets for the next measurement).
    pub fn take_stats(&mut self) -> Stats {
        let mut s = std::mem::take(&mut self.mem.stats);
        s.cycles = self.now;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kir::{Asm, Src};
    use crate::sync::{AtomicOp, MemOrder, Scope};

    /// Every work-group stores its id into out[wg].
    fn store_id_kernel() -> Program {
        let mut a = Asm::new();
        let wg = a.reg();
        let base = a.reg();
        let addr = a.reg();
        let off = a.reg();
        a.wg_id(wg);
        a.imm(base, 0x1000);
        a.shl(off, wg, Src::I(2));
        a.add(addr, base, Src::R(off));
        a.st(addr, 0, wg, 4);
        a.halt();
        a.finish()
    }

    #[test]
    fn all_wgs_run_and_results_host_visible() {
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        let report = dev.launch_simple(&store_id_kernel(), 8);
        assert!(report.end_cycle > 0);
        for wg in 0..8u64 {
            assert_eq!(
                dev.mem.backing.read_u32(0x1000 + wg * 4),
                wg as u32,
                "wg {wg} result lost"
            );
        }
    }

    #[test]
    fn wg_to_cu_mapping_round_robin() {
        let dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        assert_eq!(dev.cu_of_wg(0), 0);
        assert_eq!(dev.cu_of_wg(3), 3);
        assert_eq!(dev.cu_of_wg(4), 0); // 4 CUs in small()
    }

    #[test]
    fn global_atomic_counter_exact() {
        // Every wg atomically increments a global counter at cmp scope.
        let mut a = Asm::new();
        let addr = a.reg();
        let old = a.reg();
        a.imm(addr, 0x2000);
        a.atomic(
            old,
            AtomicOp::Add,
            addr,
            Src::I(1),
            Src::I(0),
            MemOrder::AcqRel,
            Scope::Cmp,
        );
        a.halt();
        let p = a.finish();

        for proto in [Protocol::SCOPED_ONLY, Protocol::RSP_NAIVE, Protocol::SRSP] {
            let mut dev = Device::new(DeviceConfig::small(), proto);
            dev.launch_simple(&p, 16);
            assert_eq!(
                dev.mem.backing.read_u32(0x2000),
                16,
                "{proto:?}: atomics must not lose increments"
            );
        }
    }

    #[test]
    fn launches_accumulate_time() {
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        let p = store_id_kernel();
        let r1 = dev.launch_simple(&p, 4);
        let r2 = dev.launch_simple(&p, 4);
        assert!(r2.end_cycle > r1.end_cycle, "time is cumulative");
        assert_eq!(dev.now, r2.end_cycle);
    }

    #[test]
    fn proto_params_size_the_tables_and_ignore_undeclared_keys() {
        // An explicit lr_tbl_entries proto-param must win over the
        // config field for the sRSP family...
        let cfg = DeviceConfig {
            proto_params: vec![("lr_tbl_entries".to_string(), 2.0)],
            ..DeviceConfig::small()
        };
        let dev = Device::new(cfg.clone(), Protocol::SRSP);
        assert_eq!(dev.cfg.lr_tbl_entries, 2);
        // ...the non-explicit pa_tbl_entries keeps the config value and
        // is surfaced truthfully in the resolved params...
        assert_eq!(dev.cfg.pa_tbl_entries, 16);
        assert_eq!(dev.mem.proto_params.get("pa_tbl_entries"), 16.0);
        assert_eq!(dev.mem.proto_params.get("lr_tbl_entries"), 2.0);
        // ...and a protocol that declares no tables ignores the key.
        let dev = Device::new(cfg, Protocol::SCOPED_ONLY);
        assert_eq!(dev.cfg.lr_tbl_entries, 16);
    }

    #[test]
    fn perf_counters_accumulate_per_launch() {
        let _ = perfstats::take_thread(); // isolate from earlier launches
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        let r = dev.launch_simple(&store_id_kernel(), 4);
        assert_eq!(dev.perf.launches, 1);
        assert_eq!(dev.perf.events, r.events);
        assert!(dev.perf.launch_nanos >= dev.perf.engine_nanos);
        // The thread-local collector saw the same launch.
        let tl = perfstats::take_thread();
        assert_eq!(tl.launches, 1);
        assert_eq!(tl.events, r.events);
    }

    #[test]
    fn reference_and_fast_paths_agree_on_a_launch() {
        let p = store_id_kernel();
        let mut fast = Device::new(DeviceConfig::small(), Protocol::SRSP);
        fast.launch_simple(&p, 8);
        let fast_stats = fast.take_stats();
        perfstats::set_reference_paths(true);
        let mut reference = Device::new(DeviceConfig::small(), Protocol::SRSP);
        reference.launch_simple(&p, 8);
        perfstats::set_reference_paths(false);
        let ref_stats = reference.take_stats();
        assert_eq!(fast_stats.cycles, ref_stats.cycles);
        assert_eq!(fast_stats.instructions, ref_stats.instructions);
        assert_eq!(fast_stats.l1_hits, ref_stats.l1_hits);
        assert_eq!(fast_stats.l1_misses, ref_stats.l1_misses);
    }

    #[test]
    fn stats_capture_cycles() {
        let mut dev = Device::new(DeviceConfig::small(), Protocol::SRSP);
        dev.launch_simple(&store_id_kernel(), 4);
        let s = dev.take_stats();
        assert_eq!(s.cycles, dev.now);
        assert!(s.instructions >= 4 * 6);
    }
}
