//! `srsp` — the Layer-3 coordinator CLI.
//!
//! Subcommands regenerate the paper's tables/figures, run individual
//! scenarios, sweep CU counts and validate results against native oracles.
//! No external CLI crate is available offline; parsing is hand-rolled.

use srsp::config::{parse_config_str, DeviceConfig, Scenario};
use srsp::harness::figures::{
    fig4_speedup, fig5_l2, fig6_overhead, run_matrix, run_one, scaling_sweep,
};
use srsp::harness::presets::{WorkloadPreset, WorkloadSize};
use srsp::harness::report::format_table;
use srsp::workload::driver::App;
use srsp::workload::graph::Graph;

const USAGE: &str = "srsp — scalable remote-scope promotion (paper reproduction)

USAGE:
    srsp <COMMAND> [OPTIONS]

COMMANDS:
    table1                 Print the Table-1 simulation parameters
    fig4                   Regenerate Fig. 4 (speedup vs Baseline)
    fig5                   Regenerate Fig. 5 (L2 accesses vs Baseline)
    fig6                   Regenerate Fig. 6 (sync overhead vs RSP)
    sweep                  CU-count scaling sweep (RSP vs sRSP geomean)
    run                    Run one app under one scenario, print stats
    validate               Run every app/scenario and check the oracles
    help                   Show this message

OPTIONS:
    --app <prk|sssp|mis>        App for `run` (default prk)
    --scenario <name>           baseline|scope|steal|rsp|srsp|hlrc (default srsp)
    --cus <n>                   Override CU count
    --size <tiny|paper>         Workload scale (default paper)
    --graph <file.gr|file.mtx>  Use a real DIMACS/MatrixMarket graph
    --config <file>             Device config file (key = value)
";

struct Opts {
    app: App,
    scenario: Scenario,
    cus: Option<u32>,
    size: WorkloadSize,
    graph: Option<String>,
    config: Option<String>,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        app: App::PageRank,
        scenario: Scenario::Srsp,
        cus: None,
        size: WorkloadSize::Paper,
        graph: None,
        config: None,
    };
    let mut i = 0;
    while i < args.len() {
        let key = args[i].clone();
        let mut val = || -> Result<String, String> {
            i += 1;
            args.get(i)
                .cloned()
                .ok_or_else(|| format!("{key} needs a value"))
        };
        match key.as_str() {
            "--app" => {
                o.app = match val()?.as_str() {
                    "prk" | "pagerank" => App::PageRank,
                    "sssp" => App::Sssp,
                    "mis" => App::Mis,
                    other => return Err(format!("unknown app '{other}'")),
                }
            }
            "--scenario" => {
                let v = val()?;
                o.scenario = Scenario::from_name(&v).ok_or(format!("unknown scenario '{v}'"))?;
            }
            "--cus" => o.cus = Some(val()?.parse().map_err(|e| format!("--cus: {e}"))?),
            "--size" => {
                o.size = match val()?.as_str() {
                    "tiny" => WorkloadSize::Tiny,
                    "paper" => WorkloadSize::Paper,
                    other => return Err(format!("unknown size '{other}'")),
                }
            }
            "--graph" => o.graph = Some(val()?),
            "--config" => o.config = Some(val()?),
            other => return Err(format!("unknown option '{other}'")),
        }
        i += 1;
    }
    Ok(o)
}

fn device_config(o: &Opts) -> Result<DeviceConfig, String> {
    let mut cfg = match &o.config {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            parse_config_str(&text).map_err(|e| e.to_string())?
        }
        None => DeviceConfig::default(),
    };
    if let Some(n) = o.cus {
        cfg.num_cus = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn load_preset(o: &Opts) -> Result<WorkloadPreset, String> {
    let mut preset = WorkloadPreset::new(o.app, o.size);
    if let Some(path) = &o.graph {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let g = if path.ends_with(".mtx") {
            Graph::from_matrix_market(&text)?
        } else {
            Graph::from_dimacs_gr(&text)?
        };
        g.validate()?;
        preset = preset.with_graph(g);
    }
    Ok(preset)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cmd, &opts) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, o: &Opts) -> Result<(), String> {
    match cmd {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "table1" => {
            let cfg = device_config(o)?;
            println!("Table 1 — simulation parameters\n{}", cfg.table1());
        }
        "fig4" | "fig5" | "fig6" => {
            let cfg = device_config(o)?;
            eprintln!(
                "running {} scenarios × 3 apps at {:?} scale on {} CUs ...",
                Scenario::ALL.len(),
                o.size,
                cfg.num_cus
            );
            let results = run_matrix(&cfg, o.size);
            let table = match cmd {
                "fig4" => fig4_speedup(&results),
                "fig5" => fig5_l2(&results),
                _ => fig6_overhead(&results),
            };
            println!("{}", table.render());
        }
        "sweep" => {
            let cus = [4u32, 8, 16, 32, 64];
            eprintln!("scaling sweep over {cus:?} CUs ...");
            let rows = scaling_sweep(&cus, o.size);
            let header = vec!["CUs".to_string(), "RSP".to_string(), "sRSP".to_string()];
            let body: Vec<Vec<String>> = rows
                .iter()
                .map(|(n, r, s)| vec![n.to_string(), format!("{r:.3}"), format!("{s:.3}")])
                .collect();
            println!(
                "Scalability — geomean speedup vs Baseline at equal CU count\n{}",
                format_table(&header, &body)
            );
        }
        "run" => {
            let cfg = device_config(o)?;
            let preset = load_preset(o)?;
            eprintln!(
                "running {} under {} on {} CUs (n={}, m={}) ...",
                o.app.name(),
                o.scenario,
                cfg.num_cus,
                preset.graph.n,
                preset.graph.num_edges()
            );
            let r = run_one(&cfg, &preset, o.scenario);
            println!(
                "app={} scenario={} rounds={} converged={}",
                r.app, r.scenario, r.rounds, r.converged
            );
            println!("{}", r.stats);
        }
        "validate" => {
            let cfg = device_config(o)?;
            validate_all(&cfg, o.size)?;
        }
        other => {
            return Err(format!("unknown command '{other}' (try `srsp help`)"));
        }
    }
    Ok(())
}

/// Run every app under every scenario and check results against the
/// native oracles (exactness for SSSP/MIS, tolerance for PageRank).
fn validate_all(cfg: &DeviceConfig, size: WorkloadSize) -> Result<(), String> {
    use srsp::mem::{BackingStore, MemAlloc};
    use srsp::workload::driver::run_scenario_seeded;
    use srsp::workload::engine::NativeMath;
    use srsp::workload::mis::Mis;
    use srsp::workload::pagerank::PageRank;
    use srsp::workload::sssp::Sssp;

    let mut failures = 0;
    for app in App::ALL {
        let preset = WorkloadPreset::new(app, size);
        for scenario in Scenario::ALL {
            let mut alloc = MemAlloc::new();
            let mut image = BackingStore::new();
            let ok = match app {
                App::PageRank => {
                    let mut wl = PageRank::setup(
                        &preset.graph,
                        &mut alloc,
                        &mut image,
                        preset.chunk,
                        preset.iters,
                    );
                    let oracle = PageRank::oracle(&preset.graph, preset.iters);
                    let (run, mem) = run_scenario_seeded(
                        cfg, scenario, &mut wl, NativeMath, preset.max_rounds, image,
                    );
                    let got = wl.result(&mem);
                    let diff: f32 = got.iter().zip(&oracle).map(|(a, b)| (a - b).abs()).sum();
                    run.converged && diff < 1e-3
                }
                App::Sssp => {
                    let mut wl =
                        Sssp::setup(&preset.graph, &mut alloc, &mut image, preset.chunk, 0);
                    let oracle = Sssp::oracle(&preset.graph, 0);
                    let (run, mem) = run_scenario_seeded(
                        cfg, scenario, &mut wl, NativeMath, preset.max_rounds, image,
                    );
                    run.converged && wl.result(&mem) == oracle
                }
                App::Mis => {
                    let mut wl = Mis::setup(&preset.graph, &mut alloc, &mut image, preset.chunk);
                    let oracle = Mis::oracle(&preset.graph);
                    let (run, mem) = run_scenario_seeded(
                        cfg, scenario, &mut wl, NativeMath, preset.max_rounds, image,
                    );
                    let got = wl.result(&mem);
                    run.converged
                        && Mis::validate_mis(&preset.graph, &got).is_ok()
                        && got == oracle
                }
            };
            println!(
                "{:>5} / {:<9} {}",
                app.name(),
                scenario.name(),
                if ok { "OK" } else { "FAIL" }
            );
            if !ok {
                failures += 1;
            }
        }
    }
    if failures > 0 {
        return Err(format!("{failures} validation failures"));
    }
    println!("all validations passed");
    Ok(())
}
