//! `srsp` — the Layer-3 coordinator CLI.
//!
//! Subcommands regenerate the paper's tables/figures, run individual
//! scenarios, sweep registered axes (remote ratio, CU count, hot-set
//! width, migration period — composable into surfaces), and validate
//! results against native oracles. Workloads are resolved by name
//! through the [`srsp::workload::registry`], protocols through
//! [`srsp::sync::protocol`], sweep dimensions through
//! [`srsp::coordinator::axis`] — adding an entry to any registry makes
//! it reachable from every subcommand with no CLI changes. Everything
//! matrix-shaped (figures, sweeps, validation, the CI smoke gate) flows
//! through one plan → shard → execute → merge pipeline
//! ([`srsp::coordinator`] + [`srsp::harness::runner`]): `--jobs N` runs
//! the plan as a shared work-stealing cell queue on in-process
//! threads, `sweep --workers N` runs its shards as spawned
//! `srsp worker` subprocesses — and the merged report is
//! byte-identical either way. No external CLI crate is available
//! offline; parsing is hand-rolled.

use std::process::Command;
use std::time::{Duration, Instant};

use srsp::config::{parse_config_str, DeviceConfig, Scenario};
use srsp::coordinator::axis::{self, AxisId};
use srsp::coordinator::cache::{self, CacheCounters, CacheStore};
use srsp::coordinator::serve::{self, ServeOpts};
use srsp::coordinator::{
    classic_grid, full_grid, scaling_cells, shard, ExecutionPlan, Seeding, SweepPlan,
    MAX_SWEEP_AXES, RATIO_SCENARIOS,
};
use srsp::harness::bench::{self, BenchOpts};
use srsp::harness::figures::{
    fig4_speedup, fig5_l2, fig6_overhead, run_one, scaling_rows, sweep_speedup_rows_report,
};
use srsp::harness::presets::{WorkloadPreset, WorkloadSize, DEFAULT_SEED};
use srsp::harness::report::{format_table, PartialReport, Report, ReportFormat};
use srsp::harness::runner::{
    execute_plan_cached, execute_shard, execute_shard_cached, into_run_results, CellResult, Runner,
};
use srsp::harness::tracefile::{self, TraceCell, TracePartial, TraceReport};
use srsp::sim::perfstats;
use srsp::sim::trace::DEFAULT_TRACE_CAPACITY;
use srsp::sync::protocol;
use srsp::workload::graph::Graph;
use srsp::workload::registry::{self, Params, WorkloadId};

const USAGE: &str = "srsp — scalable remote-scope promotion (paper reproduction)

USAGE:
    srsp <COMMAND> [OPTIONS]

COMMANDS:
    table1                 Print the Table-1 simulation parameters
    list-workloads         Print the registered workload table
    list-protocols         Print the registered sync-protocol table
    list-axes              Print the registered sweep-axis table
    fig4                   Regenerate Fig. 4 (speedup vs Baseline)
    fig5                   Regenerate Fig. 5 (L2 accesses vs Baseline)
    fig6                   Regenerate Fig. 6 (sync overhead vs RSP)
    sweep                  Parameter sweep: --axis cus (RSP vs sRSP geomean
                           as CUs grow, the classic default) or 1-3
                           registered axes composed into a cross-product
                           grid (e.g. --axis remote-ratio,cu-count for the
                           protocol × r × device-size surface), each cell
                           oracle-gated; see `srsp list-axes`
    run                    Run one workload under one scenario, print stats
    bench [kind]           Measure simulator throughput and emit a versioned
                           BENCH_*.json artifact (kinds: hotpath, list;
                           default hotpath). --compare-reference also times
                           the pre-decode reference interpreter and records
                           the speedup, asserting identical simulated results
    validate               Run every workload/scenario and check the oracles
    ci-smoke               Tiny-scale workload × scenario matrix, oracle-checked
                           in parallel; exits non-zero on any mismatch
    worker                 Execute one shard file (spawned by sweep --workers;
                           also usable by an external launcher), emitting a
                           PartialReport JSON
    merge-reports          Merge worker PartialReport files into the final
                           grid-ordered report; fails loudly on any gap
    serve                  Run the sweep-service coordinator: accept queued
                           sweep requests from `submit` clients, dispatch
                           deadline-guarded shard batches to connected
                           `work` processes (retry/re-shard on death or
                           timeout), answer warm cells from --cache without
                           dispatching, and stream results back — merged
                           reports stay byte-identical to a local --jobs 1
                           run
    work                   Connect a persistent remote worker to a serve
                           coordinator and execute dispatched batches until
                           the coordinator drains
    submit                 Send a registry-axis sweep to a serve coordinator,
                           stream its progress, and emit the merged report
                           exactly like a local sweep
    trace [kind]           Render a recorded JSONL sync-event trace
                           (kinds: summary, timeline, perfetto, kinds;
                           default summary); input via --trace <file>
    cache [kind]           Inspect or maintain a result-cache directory
                           (kinds: stats, verify, clear; default stats);
                           select the store with --cache <dir>
    help                   Show this message

OPTIONS:
    --app <name>                Workload by registry name (see
                                `srsp list-workloads`; default prk, or
                                stress for registry-axis sweeps)
    --param <k=v>               Override a workload parameter (repeatable;
                                single-workload commands only)
    --protocol <name>           Run `run` under a protocol's canonical
                                scenario by registry name (see
                                `srsp list-protocols`; overrides --scenario)
    --proto-param <k=v>         Override a protocol parameter (repeatable;
                                e.g. lr_tbl_entries, pa_tbl_entries,
                                overflow_threshold; run + sweep commands)
    --scenario <name>           baseline|scope|steal or any protocol name
                                (rsp|srsp|hlrc|srsp-adaptive; default srsp)
    --axis <cus|a1[,a2[,a3]]>   Sweep axes: the classic 'cus' scaling grid,
                                or up to 3 registered axes composed into a
                                surface (see `srsp list-axes`; default cus)
    --points <axis>=<v1,v2,..>  Grid points for one composed axis
                                (repeatable, one per axis; default: the
                                axis's registry points)
    --ratios <r1,r2,...>        Shorthand for --points remote-ratio=...
    --cu-counts <n1,n2,...>     Shorthand for --points cu-count=...
    --cus <n>                   Override CU count (ci-smoke/bench default: 8)
    --size <tiny|paper>         Workload scale (default paper; ci-smoke and
                                bench: tiny)
    --jobs <n>                  In-process executor threads for matrix
                                commands (default: all available cores)
    --workers <n>               Distribute a registry-axis sweep over <n>
                                `srsp worker` subprocesses instead of
                                in-process threads; the merged report is
                                byte-identical to the --jobs run
    --shard <file>              ShardSpec input for the worker command
    --partial <file>            PartialReport input for merge-reports
                                (repeatable, one per worker)
    --trace <file>              Record the cycle-stamped sync-event trace:
                                run/sweep write the JSONL trace file there
                                (a worker writes a TracePartial). Tracing
                                is observe-only — simulated results are
                                byte-identical with it off. For the trace
                                command: the file to read
    --trace-buf <n>             Per-cell trace ring capacity in events
                                (run/sweep; default 65536). On overflow
                                the oldest events drop and the cell is
                                marked truncated; per-CU counts stay exact
    --seed <n>                  Derive a distinct workload seed per grid
                                cell from base <n> (decimal or 0x hex);
                                omit to use the classic shared seed that
                                reproduces the paper figures
    --repeats <n>               Timed repetitions per bench cell (default 5)
    --warmup <n>                Untimed warmup runs per bench cell (default 1)
    --compare-reference         bench: also measure the reference interpreter
                                path and record the decoded-path speedup
    --report <json|csv>         Emit a machine-readable matrix report
    --out <file>                Write the report to <file> (default stdout)
    --graph <file.gr|file.mtx>  Use a real DIMACS/MatrixMarket graph
    --config <file>             Device config file (key = value)
    --cache <dir>               Content-addressed result cache: sweeps and
                                validation reuse oracle-validated cell rows
                                and generated workload presets across
                                invocations, so repeated runs only simulate
                                what changed; reports stay byte-identical
                                to uncached runs (run, sweep, validate,
                                ci-smoke, worker; also selects the store
                                for the cache command)
    --no-cache                  Ignore any cache — the flag and a shard-
                                carried directory — and simulate fresh
    --listen <addr>             serve: TCP address to bind (host:port;
                                port 0 picks a free port — the bound
                                address is announced on stderr)
    --connect <addr>            work/submit: the coordinator's address
    --deadline <secs>           serve: per-batch ack deadline; a dispatched
                                batch not acked in time is re-dispatched
                                (default 60)
    --retries <n>               serve: re-dispatch budget per batch beyond
                                the first attempt; a batch failing every
                                attempt fails its whole job loudly
                                (default 2)
    --max-jobs <n>              serve: drain and exit after <n> accepted
                                jobs (default: serve until killed)
    --shard-cells <n|auto>      serve: grid cells per dispatched batch
                                (default 4); auto sizes batches from the
                                fleet's observed ack times
    --die-after <n>             work: exit abruptly instead of acking batch
                                <n>+1 (deterministic fault injection for
                                the retry path; exit status 3)
";

/// What `sweep` runs: the classic fixed CU-scaling grid, or a composed
/// plan over registered axes.
#[derive(Clone, PartialEq, Eq)]
enum SweepSel {
    /// `--axis cus`: the classic-apps scaling grid with the geomean
    /// reduction (not a registry axis — it varies apps, not a parameter).
    Classic,
    /// 1-3 registered axes, cross-product grid on one workload.
    Axes(Vec<AxisId>),
}

struct Opts {
    app: Option<WorkloadId>,
    scenario: Scenario,
    protocol: Option<srsp::config::Protocol>,
    sweep: SweepSel,
    /// Was `--axis` given explicitly? (Rejected on non-sweep commands.)
    axis_given: bool,
    /// Per-axis grid points (`--points`, `--ratios`, `--cu-counts`).
    points: Vec<(AxisId, Vec<f64>)>,
    params: Vec<(String, f64)>,
    proto_params: Vec<(String, f64)>,
    cus: Option<u32>,
    size: Option<WorkloadSize>,
    jobs: Option<usize>,
    /// Subprocess executor count for distributed sweeps (`--workers`).
    workers: Option<usize>,
    /// ShardSpec input file (`worker` command only).
    shard: Option<String>,
    /// PartialReport input files (`merge-reports` command only).
    partials: Vec<String>,
    /// Trace output file for run/sweep/worker, input file for `trace`.
    trace: Option<String>,
    /// Per-cell trace ring capacity (`--trace-buf`; needs `--trace`).
    trace_buf: Option<u32>,
    seed: Option<u64>,
    report: Option<ReportFormat>,
    out: Option<String>,
    graph: Option<String>,
    config: Option<String>,
    /// Result-cache directory (`--cache`; execution commands plus the
    /// `cache` maintenance command).
    cache: Option<String>,
    /// Ignore every cache source, including a shard-carried directory
    /// (`--no-cache`).
    no_cache: bool,
    /// Positional kind (`bench`, `trace` and `cache` commands only),
    /// peeled off in `main` before flag parsing.
    bench_kind: Option<String>,
    /// Was `--scenario` given explicitly? (`bench` narrows its scenario
    /// set only on an explicit flag; the default field value means
    /// "bench the full hot-path set".)
    scenario_given: bool,
    /// Timed repetitions per bench cell (`--repeats`, bench only).
    repeats: Option<u32>,
    /// Untimed warmup runs per bench cell (`--warmup`, bench only).
    warmup: Option<u32>,
    /// Also time the reference interpreter path (`--compare-reference`).
    compare_reference: bool,
    /// Coordinator bind address (`--listen`, serve only).
    listen: Option<String>,
    /// Coordinator address to dial (`--connect`, work and submit).
    connect: Option<String>,
    /// Per-batch ack deadline in seconds (`--deadline`, serve only).
    deadline: Option<u64>,
    /// Re-dispatch budget per batch (`--retries`, serve only).
    retries: Option<u32>,
    /// Drain after this many accepted jobs (`--max-jobs`, serve only).
    max_jobs: Option<u64>,
    /// Batch capacity for dispatch (`--shard-cells`, serve only): a
    /// fixed cell count, or `auto` to size from observed ack times.
    shard_cells: Option<serve::ShardCells>,
    /// Fault injection: die instead of acking batch n+1 (`--die-after`,
    /// work only).
    die_after: Option<u64>,
}

/// Record grid points for `axis`, rejecting duplicates and out-of-domain
/// values with the originating flag named (shared by `--points` and its
/// single-axis shorthands).
fn add_points(
    points: &mut Vec<(AxisId, Vec<f64>)>,
    axis: AxisId,
    pts: Vec<f64>,
    flag: &str,
) -> Result<(), String> {
    if points.iter().any(|(a, _)| *a == axis) {
        return Err(format!(
            "{flag}: points for axis '{}' given twice",
            axis.name()
        ));
    }
    if pts.is_empty() {
        return Err(format!("{flag} needs at least one point"));
    }
    for &v in &pts {
        axis.axis()
            .check_point(v)
            .map_err(|e| format!("{flag}: {e}"))?;
    }
    points.push((axis, pts));
    Ok(())
}

/// Parse a comma-separated point list as `f64`s.
fn parse_point_list(v: &str, flag: &str) -> Result<Vec<f64>, String> {
    let mut pts = Vec::new();
    for part in v.split(',') {
        let x: f64 = part
            .trim()
            .parse()
            .map_err(|e| format!("{flag}: bad point '{part}': {e}"))?;
        pts.push(x);
    }
    Ok(pts)
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        app: None,
        scenario: Scenario::SRSP,
        protocol: None,
        sweep: SweepSel::Classic,
        axis_given: false,
        points: Vec::new(),
        params: Vec::new(),
        proto_params: Vec::new(),
        cus: None,
        size: None,
        jobs: None,
        workers: None,
        shard: None,
        partials: Vec::new(),
        trace: None,
        trace_buf: None,
        seed: None,
        report: None,
        out: None,
        graph: None,
        config: None,
        cache: None,
        no_cache: false,
        bench_kind: None,
        scenario_given: false,
        repeats: None,
        warmup: None,
        compare_reference: false,
        listen: None,
        connect: None,
        deadline: None,
        retries: None,
        max_jobs: None,
        shard_cells: None,
        die_after: None,
    };
    let mut i = 0;
    while i < args.len() {
        let key = args[i].clone();
        let mut val = || -> Result<String, String> {
            i += 1;
            args.get(i)
                .cloned()
                .ok_or_else(|| format!("{key} needs a value"))
        };
        match key.as_str() {
            "--app" => {
                let v = val()?;
                o.app = Some(registry::resolve(&v).ok_or_else(|| {
                    let names: Vec<&str> = registry::all().map(|id| id.name()).collect();
                    format!("unknown workload '{v}' (registered: {})", names.join(", "))
                })?);
            }
            "--param" => {
                let v = val()?;
                let (k, raw) = v
                    .split_once('=')
                    .ok_or_else(|| format!("--param needs key=value, got '{v}'"))?;
                let num: f64 = raw
                    .parse()
                    .map_err(|e| format!("--param {k}: bad value '{raw}': {e}"))?;
                o.params.push((k.to_string(), num));
            }
            "--scenario" => {
                let v = val()?;
                o.scenario =
                    Scenario::from_name(&v).ok_or_else(|| format!("unknown scenario '{v}'"))?;
                o.scenario_given = true;
            }
            "--protocol" => {
                let v = val()?;
                o.protocol = Some(protocol::resolve(&v).ok_or_else(|| {
                    let names: Vec<&str> = protocol::all().map(|p| p.name()).collect();
                    format!("unknown protocol '{v}' (registered: {})", names.join(", "))
                })?);
            }
            "--proto-param" => {
                let v = val()?;
                let (k, raw) = v
                    .split_once('=')
                    .ok_or_else(|| format!("--proto-param needs key=value, got '{v}'"))?;
                let num: f64 = raw
                    .parse()
                    .map_err(|e| format!("--proto-param {k}: bad value '{raw}': {e}"))?;
                if !num.is_finite() || num < 0.0 {
                    return Err(format!(
                        "--proto-param {k}: must be a finite non-negative number, got '{raw}'"
                    ));
                }
                o.proto_params.push((k.to_string(), num));
            }
            "--axis" => {
                let v = val()?;
                o.axis_given = true;
                if v == "cus" {
                    o.sweep = SweepSel::Classic;
                } else {
                    let mut axes: Vec<AxisId> = Vec::new();
                    for part in v.split(',') {
                        let name = part.trim();
                        let a = axis::resolve(name).ok_or_else(|| {
                            let names: Vec<&str> = axis::all().map(|id| id.name()).collect();
                            format!(
                                "unknown axis '{name}' (registered: {}; or 'cus' for the \
                                 classic scaling grid)",
                                names.join(", ")
                            )
                        })?;
                        if axes.contains(&a) {
                            return Err(format!("--axis: duplicate sweep axis '{}'", a.name()));
                        }
                        axes.push(a);
                    }
                    if axes.len() > MAX_SWEEP_AXES {
                        return Err(format!(
                            "--axis: a sweep composes at most {MAX_SWEEP_AXES} axes, got {}",
                            axes.len()
                        ));
                    }
                    o.sweep = SweepSel::Axes(axes);
                }
            }
            "--points" => {
                let v = val()?;
                let (name, list) = v
                    .split_once('=')
                    .ok_or_else(|| format!("--points needs <axis>=<v1,v2,...>, got '{v}'"))?;
                let a = axis::resolve(name.trim()).ok_or_else(|| {
                    let names: Vec<&str> = axis::all().map(|id| id.name()).collect();
                    format!(
                        "--points: unknown axis '{}' (registered: {})",
                        name.trim(),
                        names.join(", ")
                    )
                })?;
                let pts = parse_point_list(list, "--points")?;
                add_points(&mut o.points, a, pts, "--points")?;
            }
            "--ratios" => {
                let pts = parse_point_list(&val()?, "--ratios")?;
                add_points(&mut o.points, axis::REMOTE_RATIO, pts, "--ratios")?;
            }
            "--cu-counts" => {
                let pts = parse_point_list(&val()?, "--cu-counts")?;
                add_points(&mut o.points, axis::CU_COUNT, pts, "--cu-counts")?;
            }
            "--cus" => o.cus = Some(val()?.parse().map_err(|e| format!("--cus: {e}"))?),
            "--size" => {
                o.size = match val()?.as_str() {
                    "tiny" => Some(WorkloadSize::Tiny),
                    "paper" => Some(WorkloadSize::Paper),
                    other => return Err(format!("unknown size '{other}'")),
                }
            }
            "--jobs" => o.jobs = Some(val()?.parse().map_err(|e| format!("--jobs: {e}"))?),
            "--workers" => {
                let n: usize = val()?.parse().map_err(|e| format!("--workers: {e}"))?;
                if n == 0 {
                    return Err("--workers needs at least 1".into());
                }
                o.workers = Some(n);
            }
            "--shard" => o.shard = Some(val()?),
            "--partial" => o.partials.push(val()?),
            "--trace" => o.trace = Some(val()?),
            "--trace-buf" => {
                let n: u32 = val()?.parse().map_err(|e| format!("--trace-buf: {e}"))?;
                if n == 0 {
                    return Err(
                        "--trace-buf needs at least 1 event (omit --trace to disable tracing)"
                            .into(),
                    );
                }
                o.trace_buf = Some(n);
            }
            "--seed" => o.seed = Some(parse_u64(&val()?).map_err(|e| format!("--seed: {e}"))?),
            "--report" => {
                let v = val()?;
                let format =
                    ReportFormat::from_name(&v).ok_or_else(|| format!("unknown format '{v}'"))?;
                o.report = Some(format);
            }
            "--out" => o.out = Some(val()?),
            "--graph" => o.graph = Some(val()?),
            "--config" => o.config = Some(val()?),
            "--cache" => o.cache = Some(val()?),
            "--no-cache" => o.no_cache = true,
            "--repeats" => {
                let n: u32 = val()?.parse().map_err(|e| format!("--repeats: {e}"))?;
                if n == 0 {
                    return Err("--repeats needs at least 1".into());
                }
                o.repeats = Some(n);
            }
            "--warmup" => o.warmup = Some(val()?.parse().map_err(|e| format!("--warmup: {e}"))?),
            "--compare-reference" => o.compare_reference = true,
            "--listen" => o.listen = Some(val()?),
            "--connect" => o.connect = Some(val()?),
            "--deadline" => {
                let n: u64 = val()?.parse().map_err(|e| format!("--deadline: {e}"))?;
                if n == 0 {
                    return Err("--deadline needs at least 1 second".into());
                }
                o.deadline = Some(n);
            }
            "--retries" => {
                o.retries = Some(val()?.parse().map_err(|e| format!("--retries: {e}"))?)
            }
            "--max-jobs" => {
                let n: u64 = val()?.parse().map_err(|e| format!("--max-jobs: {e}"))?;
                if n == 0 {
                    return Err("--max-jobs needs at least 1".into());
                }
                o.max_jobs = Some(n);
            }
            "--shard-cells" => {
                let v = val()?;
                o.shard_cells = Some(if v == "auto" {
                    serve::ShardCells::Auto
                } else {
                    let n: usize = v.parse().map_err(|e| format!("--shard-cells: {e}"))?;
                    if n == 0 {
                        return Err("--shard-cells needs at least 1".into());
                    }
                    serve::ShardCells::Fixed(n)
                });
            }
            "--die-after" => {
                o.die_after = Some(val()?.parse().map_err(|e| format!("--die-after: {e}"))?)
            }
            other => return Err(format!("unknown option '{other}'")),
        }
        i += 1;
    }
    Ok(o)
}

/// Parse a u64 in decimal or `0x` hexadecimal.
fn parse_u64(s: &str) -> Result<u64, String> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).map_err(|e| e.to_string()),
        None => s.parse().map_err(|e: std::num::ParseIntError| e.to_string()),
    }
}

/// Every command-scoped flag, gated by the [`COMMANDS`] registry. A flag
/// on a command that would silently ignore it is rejected up front, so
/// the user never plots a grid believing a flag constrained it.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Flag {
    Workers,
    Shard,
    Partial,
    Repeats,
    Warmup,
    CompareReference,
    Trace,
    TraceBuf,
    Cache,
    NoCache,
    Listen,
    Connect,
    Deadline,
    Retries,
    MaxJobs,
    ShardCells,
    DieAfter,
}

use Flag::*;

/// One scoped flag: its CLI spelling, the scope phrase its rejection
/// message names ("<name> applies to <scope>, not '<cmd>'"), and how to
/// tell it was given.
struct FlagSpec {
    flag: Flag,
    name: &'static str,
    scope: &'static str,
    given: fn(&Opts) -> bool,
}

const FLAGS: &[FlagSpec] = &[
    FlagSpec {
        flag: Workers,
        name: "--workers",
        scope: "registry-axis sweeps",
        given: |o| o.workers.is_some(),
    },
    FlagSpec {
        flag: Shard,
        name: "--shard",
        scope: "worker",
        given: |o| o.shard.is_some(),
    },
    FlagSpec {
        flag: Partial,
        name: "--partial",
        scope: "merge-reports",
        given: |o| !o.partials.is_empty(),
    },
    FlagSpec {
        flag: Repeats,
        name: "--repeats",
        scope: "bench",
        given: |o| o.repeats.is_some(),
    },
    FlagSpec {
        flag: Warmup,
        name: "--warmup",
        scope: "bench",
        given: |o| o.warmup.is_some(),
    },
    FlagSpec {
        flag: CompareReference,
        name: "--compare-reference",
        scope: "bench",
        given: |o| o.compare_reference,
    },
    FlagSpec {
        flag: Trace,
        name: "--trace",
        scope: "run, sweep, worker and trace",
        given: |o| o.trace.is_some(),
    },
    FlagSpec {
        flag: TraceBuf,
        name: "--trace-buf",
        scope: "run and sweep (a worker inherits the capacity from its shard's device config)",
        given: |o| o.trace_buf.is_some(),
    },
    FlagSpec {
        flag: Cache,
        name: "--cache",
        scope: "run, sweep, validate, ci-smoke, worker, serve, work and cache",
        given: |o| o.cache.is_some(),
    },
    FlagSpec {
        flag: NoCache,
        name: "--no-cache",
        scope: "run, sweep, validate, ci-smoke, worker, serve and work",
        given: |o| o.no_cache,
    },
    FlagSpec {
        flag: Listen,
        name: "--listen",
        scope: "serve",
        given: |o| o.listen.is_some(),
    },
    FlagSpec {
        flag: Connect,
        name: "--connect",
        scope: "work and submit",
        given: |o| o.connect.is_some(),
    },
    FlagSpec {
        flag: Deadline,
        name: "--deadline",
        scope: "serve",
        given: |o| o.deadline.is_some(),
    },
    FlagSpec {
        flag: Retries,
        name: "--retries",
        scope: "serve",
        given: |o| o.retries.is_some(),
    },
    FlagSpec {
        flag: MaxJobs,
        name: "--max-jobs",
        scope: "serve",
        given: |o| o.max_jobs.is_some(),
    },
    FlagSpec {
        flag: ShardCells,
        name: "--shard-cells",
        scope: "serve",
        given: |o| o.shard_cells.is_some(),
    },
    FlagSpec {
        flag: DieAfter,
        name: "--die-after",
        scope: "work",
        given: |o| o.die_after.is_some(),
    },
];

/// One command's flag scope: the gated flags it consumes. A command
/// absent from [`COMMANDS`] (including `help` and unknown names) allows
/// none. Unscoped flags (`--app`, `--jobs`, `--out`, ...) are validated
/// by the command arms themselves, where the right answer depends on
/// more than presence.
struct CommandSpec {
    name: &'static str,
    allowed: &'static [Flag],
}

const COMMANDS: &[CommandSpec] = &[
    CommandSpec { name: "table1", allowed: &[] },
    CommandSpec { name: "list-workloads", allowed: &[] },
    CommandSpec { name: "list-protocols", allowed: &[] },
    CommandSpec { name: "list-axes", allowed: &[] },
    CommandSpec { name: "fig4", allowed: &[] },
    CommandSpec { name: "fig5", allowed: &[] },
    CommandSpec { name: "fig6", allowed: &[] },
    CommandSpec {
        name: "sweep",
        allowed: &[Workers, Trace, TraceBuf, Cache, NoCache],
    },
    CommandSpec {
        name: "run",
        allowed: &[Trace, TraceBuf, Cache, NoCache],
    },
    CommandSpec {
        name: "bench",
        allowed: &[Repeats, Warmup, CompareReference],
    },
    CommandSpec { name: "validate", allowed: &[Cache, NoCache] },
    CommandSpec { name: "ci-smoke", allowed: &[Cache, NoCache] },
    CommandSpec {
        name: "worker",
        allowed: &[Shard, Trace, Cache, NoCache],
    },
    CommandSpec { name: "merge-reports", allowed: &[Partial] },
    CommandSpec { name: "trace", allowed: &[Trace] },
    CommandSpec { name: "cache", allowed: &[Cache] },
    CommandSpec {
        name: "serve",
        allowed: &[Listen, Deadline, Retries, MaxJobs, ShardCells, Cache, NoCache],
    },
    CommandSpec {
        name: "work",
        allowed: &[Connect, DieAfter, Cache, NoCache],
    },
    CommandSpec { name: "submit", allowed: &[Connect] },
];

/// One validation rule of the [`RULES`] pass: `Scope` rejects a present
/// flag on a command whose [`CommandSpec`] does not allow it; `Refuse`
/// rejects a flag combination on every command.
enum Rule {
    Scope(Flag),
    Refuse {
        when: fn(&Opts) -> bool,
        msg: &'static str,
    },
}

const RULES: &[Rule] = &[
    Rule::Scope(Workers),
    Rule::Refuse {
        when: |o| o.workers.is_some() && o.jobs.is_some(),
        msg: "--jobs selects in-process executor threads; with --workers each subprocess \
              executes its shard serially — pick one",
    },
    Rule::Scope(Shard),
    Rule::Scope(Partial),
    Rule::Scope(Repeats),
    Rule::Scope(Warmup),
    Rule::Scope(CompareReference),
    Rule::Scope(Trace),
    Rule::Refuse {
        when: |o| o.trace_buf.is_some() && o.trace.is_none(),
        msg: "--trace-buf sizes the trace ring; it needs --trace <file>",
    },
    Rule::Scope(TraceBuf),
    Rule::Scope(Cache),
    Rule::Refuse {
        when: |o| o.cache.is_some() && o.trace.is_some(),
        msg: "--cache conflicts with --trace: a cached cell replays no sync events, \
              so traced runs bypass the result cache — drop one of the flags",
    },
    Rule::Scope(NoCache),
    Rule::Scope(Listen),
    Rule::Scope(Connect),
    Rule::Scope(Deadline),
    Rule::Scope(Retries),
    Rule::Scope(MaxJobs),
    Rule::Scope(ShardCells),
    Rule::Scope(DieAfter),
];

impl Opts {
    fn jobs(&self) -> usize {
        self.jobs.unwrap_or_else(Runner::default_jobs)
    }

    /// When `--report` goes to stdout, human-readable output moves to
    /// stderr so the report stays machine-parseable.
    fn stdout_is_human(&self) -> bool {
        self.report.is_none() || self.out.is_some()
    }

    fn seeding(&self) -> Seeding {
        match self.seed {
            Some(base) => Seeding::PerCell(base),
            None => Seeding::Shared(DEFAULT_SEED),
        }
    }

    fn runner(&self, cfg: DeviceConfig, size: WorkloadSize, validate: bool) -> Runner {
        Runner {
            jobs: self.jobs(),
            seeding: self.seeding(),
            size,
            validate,
            params: self.params.clone(),
            cfg,
        }
    }

    /// Multi-workload grids run pure defaults; `--param` keys are only
    /// meaningful against one kernel's spec.
    fn reject_params(&self, cmd: &str) -> Result<(), String> {
        if self.params.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "--param applies to single-workload commands (run, registry-axis sweeps), \
                 not '{cmd}'"
            ))
        }
    }

    /// A sweep validates its own flag combination: every `--points`
    /// entry (including the `--ratios`/`--cu-counts` shorthands) must
    /// target a selected axis, and `--cus` may not fight an axis that
    /// varies the device size itself — a flag the sweep would silently
    /// ignore is rejected so the user never plots a grid believing it
    /// was constrained (`--cus` vs the cu-count axis especially invites
    /// the mix-up). Runs as the sweep-conditional rule of [`RULES`];
    /// `submit` calls it directly (its plan is a registry-axis sweep).
    fn sweep_axis_conflicts(&self) -> Result<(), String> {
        match &self.sweep {
            SweepSel::Classic => {
                if let Some((a, _)) = self.points.first() {
                    return Err(format!(
                        "points for axis '{}' apply to a registry-axis sweep (e.g. --axis {}); \
                         --axis cus runs the fixed classic grid",
                        a.name(),
                        a.name()
                    ));
                }
                if self.cus.is_some() {
                    return Err(
                        "sweep --axis cus runs the fixed 4,8,16,32,64 grid; --cus does not \
                         apply"
                            .into(),
                    );
                }
            }
            SweepSel::Axes(axes) => {
                for (a, _) in &self.points {
                    if !axes.contains(a) {
                        let selected: Vec<&str> = axes.iter().map(|x| x.name()).collect();
                        return Err(format!(
                            "points for axis '{}' apply to sweep --axis {}; the selected \
                             axes ({}) would ignore them",
                            a.name(),
                            a.name(),
                            selected.join(", ")
                        ));
                    }
                }
                if axes.contains(&axis::CU_COUNT) && self.cus.is_some() {
                    return Err(
                        "--cus conflicts with the cu-count axis (the axis varies the CU \
                         count; use --points cu-count=...)"
                            .into(),
                    );
                }
            }
        }
        Ok(())
    }

    /// The sweep flags mean nothing outside `sweep`.
    fn reject_axis_points(&self, cmd: &str) -> Result<(), String> {
        if self.axis_given {
            return Err(format!("--axis applies to sweep, not '{cmd}'"));
        }
        if let Some((a, _)) = self.points.first() {
            return Err(format!(
                "--points/--ratios/--cu-counts (axis '{}') apply to sweep, not '{cmd}'",
                a.name()
            ));
        }
        Ok(())
    }

    /// Only `run` consumes `--protocol`; everywhere else the flag would
    /// be silently ignored — reject it like a bad `--param` key so the
    /// user never plots a grid believing it ran their protocol.
    fn reject_protocol(&self, cmd: &str) -> Result<(), String> {
        if self.protocol.is_none() {
            Ok(())
        } else {
            Err(format!(
                "--protocol applies to run, not '{cmd}' (matrix commands run fixed \
                 scenario grids; see `srsp list-protocols`)"
            ))
        }
    }

    /// Mixed coverage grids run protocol defaults; `--proto-param` keys
    /// are only meaningful against the protocols a command selects.
    fn reject_proto_params(&self, cmd: &str) -> Result<(), String> {
        if self.proto_params.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "--proto-param applies to run and the registry-axis sweeps, not '{cmd}'"
            ))
        }
    }

    /// Every `--proto-param` key must be declared by at least one of the
    /// protocols the command runs (a clean CLI error instead of a
    /// silently-ignored typo).
    fn check_proto_params(&self, scenarios: &[Scenario]) -> Result<(), String> {
        'keys: for (key, _) in &self.proto_params {
            for s in scenarios {
                let spec = s.protocol().proto().params();
                if spec.iter().any(|p| p.key == key.as_str()) {
                    continue 'keys;
                }
            }
            let protos: Vec<&str> = scenarios.iter().map(|s| s.protocol().name()).collect();
            return Err(format!(
                "--proto-param '{key}' is not declared by any selected protocol ({}); \
                 see `srsp list-protocols`",
                protos.join(", ")
            ));
        }
        Ok(())
    }

    /// The result-cache directory this invocation runs against, when
    /// any (`--no-cache` wins over `--cache`).
    fn cache_dir(&self) -> Option<&str> {
        if self.no_cache {
            None
        } else {
            self.cache.as_deref()
        }
    }

    /// The per-cell trace ring capacity this invocation simulates with:
    /// 0 (tracing off, the default hot path) unless `--trace` was given.
    fn trace_capacity(&self) -> u32 {
        if self.trace.is_some() {
            self.trace_buf.unwrap_or(DEFAULT_TRACE_CAPACITY)
        } else {
            0
        }
    }

    /// The single declarative flag-validation pass, replacing the old
    /// per-family `check_*_flags` validators: walk [`RULES`] in order,
    /// rejecting any present scoped flag the [`COMMANDS`] row for `cmd`
    /// does not allow, and any refused flag combination. Rule order is
    /// load-bearing — it reproduces the historical validator order
    /// (distributed → bench → trace → cache → service), so every
    /// rejection message fires exactly where it used to.
    fn check_flags(&self, cmd: &str) -> Result<(), String> {
        let allowed = COMMANDS
            .iter()
            .find(|c| c.name == cmd)
            .map(|c| c.allowed)
            .unwrap_or(&[]);
        for rule in RULES {
            match rule {
                Rule::Scope(flag) => {
                    let spec = FLAGS
                        .iter()
                        .find(|s| s.flag == *flag)
                        .expect("every gated flag has a FLAGS row");
                    if (spec.given)(self) && !allowed.contains(&spec.flag) {
                        return Err(format!(
                            "{} applies to {}, not '{cmd}'",
                            spec.name, spec.scope
                        ));
                    }
                }
                Rule::Refuse { when, msg } => {
                    if when(self) {
                        return Err((*msg).to_string());
                    }
                }
            }
        }
        Ok(())
    }

    /// The scenario `run` executes: `--protocol <name>`'s canonical
    /// scenario when given, `--scenario` otherwise.
    fn run_scenario(&self) -> Scenario {
        match self.protocol {
            Some(p) => Scenario::for_protocol(p),
            None => self.scenario,
        }
    }
}

fn device_config(o: &Opts) -> Result<DeviceConfig, String> {
    let mut cfg = match &o.config {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            parse_config_str(&text).map_err(|e| e.to_string())?
        }
        None => DeviceConfig::default(),
    };
    if let Some(n) = o.cus {
        cfg.num_cus = n;
    }
    cfg.proto_params = o.proto_params.clone();
    cfg.trace_capacity = o.trace_capacity();
    cfg.validate()?;
    Ok(cfg)
}

fn load_preset(
    o: &Opts,
    app: WorkloadId,
    size: WorkloadSize,
    store: Option<&CacheStore>,
) -> Result<WorkloadPreset, String> {
    // For a single run, --seed is used directly as the generator seed.
    let seed = o.seed.unwrap_or(DEFAULT_SEED);
    if let Some(path) = &o.graph {
        // A file-backed graph bypasses the preset cache: the store keys
        // presets by generator inputs, never by file contents.
        let preset = WorkloadPreset::with_params(app, size, seed, &o.params)?;
        if preset.graph.is_none() {
            return Err(format!(
                "--graph: workload '{}' takes no graph input",
                app.name()
            ));
        }
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let g = if path.ends_with(".mtx") {
            Graph::from_matrix_market(&text)?
        } else {
            Graph::from_dimacs_gr(&text)?
        };
        g.validate()?;
        return Ok(preset.with_graph(g));
    }
    if let Some(store) = store {
        let key = cache::preset_key(app, size, seed, &o.params);
        if let Some(p) = store.load_preset(&key, app, size, seed) {
            return Ok(p);
        }
        let preset = WorkloadPreset::with_params(app, size, seed, &o.params)?;
        store.insert_preset(&key, &preset);
        return Ok(preset);
    }
    WorkloadPreset::with_params(app, size, seed, &o.params)
}

/// Open the `--cache` store when one applies to this invocation.
fn open_store(o: &Opts) -> Result<Option<CacheStore>, String> {
    match o.cache_dir() {
        Some(dir) => Ok(Some(CacheStore::open(dir)?)),
        None => Ok(None),
    }
}

/// Print the per-run cache tally and append it to the store's
/// `runs.jsonl` (what `srsp cache stats` reports as the last run).
/// No-op without a store. Always on stderr — like [`print_perfstats`],
/// it is host-side accounting, never report data.
fn finish_cached_run(dir: Option<&str>, counters: &CacheCounters) {
    let Some(dir) = dir else { return };
    eprintln!(
        "cache: hits={} misses={} preset_reuses={}",
        counters.hits, counters.misses, counters.preset_reuses
    );
    cache::record_run(dir, counters);
}

/// The one "`--out` → file else stdout" emission path: every rendered
/// artifact (matrix report, bench JSON, rendered trace, worker partial,
/// served report) flows through here. `announce` adds the "wrote
/// <path>" stderr line the interactive surfaces (bench, trace) print;
/// pipeline artifacts stay silent so their stderr is pure diagnostics.
fn emit_to(out: Option<&str>, text: &str, announce: bool) -> Result<(), String> {
    match out {
        Some(path) => {
            std::fs::write(path, text).map_err(|e| format!("{path}: {e}"))?;
            if announce {
                eprintln!("wrote {path}");
            }
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// Write `report` in `format` to `--out` or stdout.
fn write_report(report: &Report, format: ReportFormat, o: &Opts) -> Result<(), String> {
    let text = match format {
        ReportFormat::Json => report.to_json(),
        ReportFormat::Csv => report.to_csv(),
    };
    emit_to(o.out.as_deref(), &text, false)
}

/// Emit the machine-readable report when `--report` was given.
fn emit_report(report: &Report, o: &Opts) -> Result<(), String> {
    match o.report {
        Some(format) => write_report(report, format, o),
        None => Ok(()),
    }
}

/// Write the harvested grid trace when `--trace` was given. Loud when
/// any executed cell carried no trace — a traced command never writes a
/// silently shorter trace file.
fn emit_trace(results: &[CellResult], o: &Opts) -> Result<(), String> {
    let Some(path) = &o.trace else {
        return Ok(());
    };
    let report = TraceReport::from_cells(results)?;
    std::fs::write(path, report.render_jsonl()).map_err(|e| format!("{path}: {e}"))?;
    eprintln!("wrote trace: {path} ({} cell(s))", report.cells.len());
    Ok(())
}

/// One host-side cost line per matrix run (`ci-smoke`, `validate`): the
/// thread-local [`perfstats`] collector aggregated across executor
/// threads by [`execute_plan`](srsp::harness::runner::execute_plan).
/// Always on stderr — it is wall-clock attribution, never report data.
fn print_perfstats() {
    let p = perfstats::take_thread();
    let sched = match p.utilization() {
        Some(u) => format!(
            " sched_steals={} sched_idle_nanos={} utilization={u:.3}",
            p.sched_steals, p.sched_idle_nanos
        ),
        None => String::new(),
    };
    eprintln!(
        "perfstats: launches={} events={} launch_nanos={} engine_nanos={} sim_nanos={} \
         cache_hits={} cache_misses={} preset_reuses={}{sched}",
        p.launches,
        p.events,
        p.launch_nanos,
        p.engine_nanos,
        p.sim_nanos(),
        p.cache_hits,
        p.cache_misses,
        p.preset_reuses
    );
}

/// Print `text` to stdout, or to stderr when stdout is carrying the
/// machine-readable report.
fn human(o: &Opts, text: &str) {
    if o.stdout_is_human() {
        println!("{text}");
    } else {
        eprintln!("{text}");
    }
}

/// Print one `app / scenario OK|FAIL` line per validated report row;
/// returns the failure count. Works off the report — not raw cell
/// results — so the in-process and distributed paths print identically.
fn print_validation(report: &Report, o: &Opts) -> usize {
    let mut failures = 0;
    for r in &report.rows {
        let ok = r.validated == Some(true) && r.converged;
        let tag = if r.params.is_empty() {
            String::new()
        } else {
            format!(" [{}]", r.params)
        };
        human(
            o,
            &format!(
                "{:>8} / {:<9}{tag} {}",
                r.app,
                r.scenario,
                if ok { "OK" } else { "FAIL" }
            ),
        );
        if !ok {
            failures += 1;
        }
    }
    failures
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    // `bench`, `trace` and `cache` take an optional positional kind
    // (`srsp bench hotpath`, `srsp trace perfetto`, `srsp cache stats`)
    // ahead of the flags; everything after the command is flag-only for
    // every other command.
    let mut flag_args = &args[1..];
    let mut bench_kind = None;
    if cmd == "bench" || cmd == "trace" || cmd == "cache" {
        if let Some(first) = flag_args.first() {
            if !first.starts_with('-') {
                bench_kind = Some(first.clone());
                flag_args = &flag_args[1..];
            }
        }
    }
    let opts = match parse_opts(flag_args) {
        Ok(mut o) => {
            o.bench_kind = bench_kind;
            o
        }
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cmd, &opts) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Stage 3 in subprocess mode: lower the plan, write each [`ShardSpec`]
/// to a scratch file, spawn one `srsp worker --shard <file>` per shard,
/// then merge their [`PartialReport`]s (stage 4). A worker that exits
/// non-zero, dies, or emits a short report fails the whole sweep loudly
/// — never a short report.
///
/// [`ShardSpec`]: srsp::coordinator::shard::ShardSpec
fn run_distributed(
    runner: &Runner,
    plan: &SweepPlan,
    workers: usize,
    o: &Opts,
) -> Result<Report, String> {
    let lowered = ExecutionPlan::lower_sweep(runner, plan);
    let mut shards = shard::partition(&lowered, workers);
    if let Some(dir) = o.cache_dir() {
        // Workers open the coordinator's store themselves (one segment
        // file per process — appends never interleave).
        for s in &mut shards {
            s.cache_dir = Some(dir.to_string());
        }
    }
    let exe = std::env::current_exe().map_err(|e| format!("cannot locate the srsp binary: {e}"))?;
    let dir = std::env::temp_dir().join(format!("srsp-workers-{}", std::process::id()));
    std::fs::create_dir_all(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;

    // Spawn phase. On any failure, kill and reap what already started —
    // an orphan must never keep simulating into the deleted scratch dir.
    type Spawned = (
        usize,
        std::process::Child,
        std::path::PathBuf,
        Option<std::path::PathBuf>,
    );
    let mut children: Vec<Spawned> = Vec::new();
    for s in &shards {
        let shard_path = dir.join(format!("shard-{}.json", s.shard));
        let out_path = dir.join(format!("partial-{}.json", s.shard));
        // Tracing rides the same artifact protocol as the report: one
        // TracePartial file per worker, merged below.
        let trace_path = o
            .trace
            .as_ref()
            .map(|_| dir.join(format!("partial-trace-{}.json", s.shard)));
        let spawned = std::fs::write(&shard_path, s.to_json())
            .map_err(|e| format!("{}: {e}", shard_path.display()))
            .and_then(|()| {
                let mut cmd = Command::new(&exe);
                cmd.arg("worker")
                    .arg("--shard")
                    .arg(&shard_path)
                    .arg("--out")
                    .arg(&out_path);
                if let Some(tp) = &trace_path {
                    cmd.arg("--trace").arg(tp);
                }
                cmd.spawn()
                    .map_err(|e| format!("spawning worker {}: {e}", s.shard))
            });
        match spawned {
            Ok(child) => children.push((s.shard, child, out_path, trace_path)),
            Err(e) => {
                for (_, child, _, _) in &mut children {
                    let _ = child.kill();
                    let _ = child.wait();
                }
                let _ = std::fs::remove_dir_all(&dir);
                return Err(e);
            }
        }
    }

    // Wait phase: reap EVERY worker before judging the run, so an early
    // failure never leaves orphans behind the error return.
    let mut finished: Vec<(usize, std::path::PathBuf, Option<std::path::PathBuf>)> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    for (i, mut child, out_path, trace_path) in children {
        match child.wait() {
            Ok(status) if status.success() => finished.push((i, out_path, trace_path)),
            Ok(status) => failures.push(format!("worker {i} failed ({status})")),
            Err(e) => failures.push(format!("worker {i}: {e}")),
        }
    }

    let collect_and_merge = || -> Result<Report, String> {
        if let Some(first) = failures.first() {
            return Err(format!(
                "{first}; distributed sweep aborted ({} of {} workers failed)",
                failures.len(),
                shards.len()
            ));
        }
        let mut partials = Vec::new();
        for (i, out_path, _) in &finished {
            let text = std::fs::read_to_string(out_path)
                .map_err(|e| format!("worker {i} left no partial report: {e}"))?;
            partials
                .push(PartialReport::from_json(&text).map_err(|e| format!("worker {i}: {e}"))?);
        }
        let report = Report::merge(&partials)?;
        if let Some(dir) = o.cache_dir() {
            // Each worker tallied its own shard; the coordinator sums
            // them into the one per-run record (workers never write
            // runs.jsonl themselves).
            let mut total = CacheCounters::default();
            for p in &partials {
                total.add(&p.cache);
            }
            perfstats::add_cache(total.hits, total.misses, total.preset_reuses);
            finish_cached_run(Some(dir), &total);
        }
        if let Some(path) = &o.trace {
            // Merge the trace partials under the same completeness proof
            // as the report; the merged file is byte-identical to the
            // one an in-process (--jobs) traced sweep writes.
            let mut tpartials = Vec::new();
            for (i, _, trace_path) in &finished {
                let tp = trace_path.as_ref().expect("--trace gave every worker a path");
                let text = std::fs::read_to_string(tp)
                    .map_err(|e| format!("worker {i} left no trace partial: {e}"))?;
                tpartials
                    .push(TracePartial::from_json(&text).map_err(|e| format!("worker {i}: {e}"))?);
            }
            let trace = TracePartial::merge(&tpartials)?;
            std::fs::write(path, trace.render_jsonl()).map_err(|e| format!("{path}: {e}"))?;
            eprintln!("wrote trace: {path} ({} cell(s))", trace.cells.len());
        }
        Ok(report)
    };
    let result = collect_and_merge();
    let _ = std::fs::remove_dir_all(&dir);
    result
}

/// Everything a registry-axis sweep resolves before executing — shared
/// by the local `sweep` path and the `submit` client, which lowers the
/// identical plan and ships it to a `serve` coordinator instead of
/// executing here.
struct AxisSweep {
    app: WorkloadId,
    plan: SweepPlan,
    runner: Runner,
    size: WorkloadSize,
    axis_names: Vec<String>,
}

/// Validate the sweep-shaped flags and resolve the plan and runner for
/// a registry-axis sweep; `cmd` names the rejecting command.
fn prepare_axis_sweep(o: &Opts, axes: &[AxisId], cmd: &str) -> Result<AxisSweep, String> {
    let app = o.app.unwrap_or(registry::STRESS);
    // Surface bad --param keys as a clean CLI error before the runner
    // (which would panic inside an executor).
    Params::resolve(app.kernel().params(), &o.params).map_err(|e| format!("{}: {e}", app.name()))?;
    o.check_proto_params(&RATIO_SCENARIOS)?;
    o.reject_protocol(cmd)?;
    o.sweep_axis_conflicts()?;
    let mut plan = SweepPlan::new(app, axes)?;
    for (a, pts) in &o.points {
        plan = plan.with_points(*a, pts.clone())?;
    }
    let cfg = device_config(o)?;
    let size = o.size.unwrap_or(WorkloadSize::Paper);
    let axis_names: Vec<String> = axes.iter().map(|a| a.name().to_string()).collect();
    let runner = o.runner(cfg, size, true);
    Ok(AxisSweep {
        app,
        plan,
        runner,
        size,
        axis_names,
    })
}

/// Emit a finished registry-axis sweep — report file/stdout, per-row
/// validation lines, the human speedup table, loud oracle failures —
/// identically for the local and served paths.
fn finish_axis_sweep(o: &Opts, prep: &AxisSweep, report: &Report) -> Result<(), String> {
    emit_report(report, o)?;
    let failures = print_validation(report, o);
    let rows = sweep_speedup_rows_report(&prep.plan, report);
    let mut header: Vec<String> = prep.axis_names.clone();
    header.extend([
        "steal cycles".to_string(),
        "rsp ×".to_string(),
        "srsp ×".to_string(),
    ]);
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let mut row: Vec<String> = r.coords.iter().map(|(_, v)| v.to_string()).collect();
            row.push(r.steal_cycles.to_string());
            row.push(format!("{:.3}", r.rsp_speedup));
            row.push(format!("{:.3}", r.srsp_speedup));
            row
        })
        .collect();
    human(
        o,
        &format!(
            "Sweep — {} — {} — speedup vs global-scope stealing (steal = 1.0)\n{}",
            prep.app.display(),
            prep.axis_names.join(" × "),
            format_table(&header, &body)
        ),
    );
    if failures > 0 {
        return Err(format!("{failures} oracle failures in the sweep"));
    }
    Ok(())
}

/// Run a composed registry-axis sweep: build the [`SweepPlan`], execute
/// the cross-product grid oracle-gated — in-process (`--jobs`) or over
/// worker subprocesses (`--workers`), byte-identical either way — emit
/// the long-format report and the human protocol-comparison table.
fn run_axis_sweep(o: &Opts, axes: &[AxisId]) -> Result<(), String> {
    let prep = prepare_axis_sweep(o, axes, "sweep")?;
    let size = prep.size;
    let executors = match o.workers {
        Some(w) => format!("{w} worker subprocesses"),
        None => format!("{} jobs", o.jobs()),
    };
    eprintln!(
        "sweep on {} over {} ({} grid points × {} protocols) at {size:?} scale ({executors}) ...",
        prep.app.name(),
        prep.axis_names.join(" × "),
        prep.plan.combos().len(),
        prep.plan.scenarios.len(),
    );
    let report = match o.workers {
        Some(workers) => run_distributed(&prep.runner, &prep.plan, workers, o)?,
        None => match open_store(o)? {
            Some(store) => {
                // Cached in-process path: probe the store per cell, run
                // only the misses, reassemble by grid index. The report
                // is byte-identical to the uncached run (--trace cannot
                // ride along; the CLI rejects the combination).
                let lowered = ExecutionPlan::lower_sweep(&prep.runner, &prep.plan);
                let (outcomes, counters) = execute_plan_cached(&lowered, o.jobs(), Some(&store));
                finish_cached_run(Some(store.dir()), &counters);
                Report::from_outcomes(&outcomes)
            }
            None => {
                let results = prep.runner.run_sweep(&prep.plan);
                emit_trace(&results, o)?;
                Report::from_cells(&results)
            }
        },
    };
    finish_axis_sweep(o, &prep, &report)
}

fn dispatch(cmd: &str, o: &Opts) -> Result<(), String> {
    o.check_flags(cmd)?;
    match cmd {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "table1" => {
            let cfg = device_config(o)?;
            println!("Table 1 — simulation parameters\n{}", cfg.table1());
        }
        "list-workloads" => {
            let header = vec![
                "name".to_string(),
                "aliases".to_string(),
                "oracle".to_string(),
                "params (defaults)".to_string(),
                "summary".to_string(),
            ];
            let rows: Vec<Vec<String>> = registry::all()
                .map(|id| {
                    let k = id.kernel();
                    let params: Vec<String> = k
                        .params()
                        .iter()
                        .map(|p| format!("{}={}", p.key, p.default))
                        .collect();
                    vec![
                        k.name().to_string(),
                        k.aliases().join(","),
                        k.oracle().to_string(),
                        params.join(","),
                        k.summary().to_string(),
                    ]
                })
                .collect();
            println!("{}", format_table(&header, &rows));
        }
        "list-protocols" => {
            let header = vec![
                "name".to_string(),
                "aliases".to_string(),
                "remote".to_string(),
                "params (defaults)".to_string(),
                "summary".to_string(),
            ];
            let rows: Vec<Vec<String>> = protocol::all()
                .map(|id| {
                    let p = id.proto();
                    let params: Vec<String> = p
                        .params()
                        .iter()
                        .map(|s| format!("{}={}", s.key, s.default))
                        .collect();
                    vec![
                        p.name().to_string(),
                        p.aliases().join(","),
                        if p.supports_remote() { "yes" } else { "no" }.to_string(),
                        params.join(","),
                        p.summary().to_string(),
                    ]
                })
                .collect();
            println!("{}", format_table(&header, &rows));
        }
        "list-axes" => {
            let header = vec![
                "name".to_string(),
                "aliases".to_string(),
                "domain".to_string(),
                "default points".to_string(),
                "drives".to_string(),
                "summary".to_string(),
            ];
            let rows: Vec<Vec<String>> = axis::all()
                .map(|id| {
                    let a = id.axis();
                    let points: Vec<String> =
                        a.default_points().iter().map(|v| v.to_string()).collect();
                    let drives = match a.required_param() {
                        Some(p) => format!("--param {p}"),
                        None => "device num_cus".to_string(),
                    };
                    vec![
                        a.name().to_string(),
                        a.aliases().join(","),
                        a.domain().to_string(),
                        points.join(","),
                        drives,
                        a.summary().to_string(),
                    ]
                })
                .collect();
            println!("{}", format_table(&header, &rows));
        }
        "fig4" | "fig5" | "fig6" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            let cfg = device_config(o)?;
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            let cells = classic_grid(cfg.num_cus);
            eprintln!(
                "running {} cells ({} apps × {} scenarios) at {size:?} scale on {} CUs \
                 ({} jobs) ...",
                cells.len(),
                cells.len() / Scenario::ALL.len(),
                Scenario::ALL.len(),
                cfg.num_cus,
                o.jobs()
            );
            let runner = o.runner(cfg, size, false);
            let cells = runner.run_cells(&cells);
            emit_report(&Report::from_cells(&cells), o)?;
            let results = into_run_results(cells);
            let table = match cmd {
                "fig4" => fig4_speedup(&results),
                "fig5" => fig5_l2(&results),
                _ => fig6_overhead(&results),
            };
            human(o, &table.render());
        }
        "sweep" => match &o.sweep {
            SweepSel::Classic => {
                o.reject_params("sweep --axis cus")?;
                o.reject_proto_params("sweep --axis cus")?;
                o.reject_protocol("sweep --axis cus")?;
                o.sweep_axis_conflicts()?;
                if o.workers.is_some() {
                    return Err(
                        "--workers applies to registry-axis sweeps (e.g. --axis \
                         remote-ratio,cu-count); --axis cus runs the fixed classic grid \
                         in-process"
                            .into(),
                    );
                }
                if o.app.is_some() {
                    return Err(
                        "sweep --axis cus runs the fixed classic grid; --app applies to \
                         registry-axis sweeps"
                            .into(),
                    );
                }
                let cus = [4u32, 8, 16, 32, 64];
                let size = o.size.unwrap_or(WorkloadSize::Paper);
                eprintln!("scaling sweep over {cus:?} CUs ({} jobs) ...", o.jobs());
                let runner = o.runner(device_config(o)?, size, false);
                let results = runner.run_cells(&scaling_cells(&cus));
                emit_trace(&results, o)?;
                emit_report(&Report::from_cells(&results), o)?;
                let rows = scaling_rows(&cus, &results);
                let header = vec!["CUs".to_string(), "RSP".to_string(), "sRSP".to_string()];
                let body: Vec<Vec<String>> = rows
                    .iter()
                    .map(|(n, r, s)| vec![n.to_string(), format!("{r:.3}"), format!("{s:.3}")])
                    .collect();
                human(
                    o,
                    &format!(
                        "Scalability — geomean speedup vs Baseline at equal CU count\n{}",
                        format_table(&header, &body)
                    ),
                );
            }
            SweepSel::Axes(axes) => run_axis_sweep(o, axes)?,
        },
        "run" => {
            o.reject_axis_points(cmd)?;
            let scenario = o.run_scenario();
            // Strict validation against the selected protocol's spec: an
            // unknown key is a typo, not a mixed-grid mismatch.
            Params::resolve(scenario.protocol().proto().params(), &o.proto_params)
                .map_err(|e| format!("{}: {e}", scenario.protocol().name()))?;
            let cfg = device_config(o)?;
            let app = o.app.unwrap_or(registry::PRK);
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            // `run` prints full Stats (not reconstructible from a cached
            // report row), so only the preset layer engages here: the
            // generated graph is reused, the simulation always runs.
            let store = open_store(o)?;
            let preset = load_preset(o, app, size, store.as_ref())?;
            let shape = match &preset.graph {
                Some(g) => format!(" (n={}, m={})", g.n, g.num_edges()),
                None => String::new(),
            };
            let overrides = preset.params.overrides_display();
            let overrides = if overrides.is_empty() {
                String::new()
            } else {
                format!(" [{overrides}]")
            };
            eprintln!(
                "running {}{overrides} under {} on {} CUs{shape} ...",
                app.name(),
                scenario,
                cfg.num_cus,
            );
            let r = run_one(&cfg, &preset, scenario);
            println!(
                "app={} scenario={} rounds={} converged={}",
                r.app, r.scenario, r.rounds, r.converged
            );
            println!("{}", r.stats);
            if let Some(store) = &store {
                let counters = store.take_counters();
                perfstats::add_cache(counters.hits, counters.misses, counters.preset_reuses);
                finish_cached_run(Some(store.dir()), &counters);
            }
            if let Some(path) = &o.trace {
                let Some(t) = &r.trace else {
                    return Err("run recorded no trace despite --trace (trace_capacity 0?)".into());
                };
                let report = TraceReport {
                    cells: vec![TraceCell {
                        app: r.app.to_string(),
                        scenario: r.scenario.name().to_string(),
                        seed: o.seed.unwrap_or(DEFAULT_SEED),
                        trace: (**t).clone(),
                    }],
                };
                std::fs::write(path, report.render_jsonl())
                    .map_err(|e| format!("{path}: {e}"))?;
                eprintln!("wrote trace: {path} (1 cell)");
            }
        }
        "bench" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err("bench always emits BENCH_*.json; --report does not apply".into());
            }
            if o.jobs.is_some() {
                return Err(
                    "bench times a serial hot loop (parallel cells would contend for \
                     cores and skew the numbers); --jobs does not apply"
                        .into(),
                );
            }
            if o.seed.is_some() || o.graph.is_some() {
                return Err(
                    "bench runs the fixed registry presets so BENCH_*.json artifacts stay \
                     comparable across runs; --seed/--graph do not apply"
                        .into(),
                );
            }
            match o.bench_kind.as_deref().unwrap_or("hotpath") {
                "list" => {
                    println!("hotpath    prk × scope/srsp/rsp — the simulator's event hot loop");
                }
                "hotpath" => {
                    let mut cfg = device_config(o)?;
                    if o.cus.is_none() && o.config.is_none() {
                        // Same small-device default as ci-smoke: fast in
                        // CI, still multi-CU enough for real contention.
                        cfg.num_cus = 8;
                    }
                    let size = o.size.unwrap_or(WorkloadSize::Tiny);
                    let mut bopts = BenchOpts::hotpath(size);
                    if let Some(app) = o.app {
                        bopts.apps = vec![app];
                    }
                    if o.scenario_given {
                        bopts.scenarios = vec![o.scenario];
                    }
                    if let Some(n) = o.repeats {
                        bopts.repeats = n;
                    }
                    if let Some(n) = o.warmup {
                        bopts.warmup = n;
                    }
                    bopts.compare_reference = o.compare_reference;
                    eprintln!(
                        "bench hotpath: {} app(s) × {} scenario(s) at {size:?} scale on {} \
                         CUs, {} repeat(s) + {} warmup{} ...",
                        bopts.apps.len(),
                        bopts.scenarios.len(),
                        cfg.num_cus,
                        bopts.repeats,
                        bopts.warmup,
                        if bopts.compare_reference {
                            ", reference comparison on"
                        } else {
                            ""
                        },
                    );
                    let report = bench::run_bench(&cfg, &bopts);
                    eprint!("{}", report.render_human());
                    emit_to(o.out.as_deref(), &report.to_json(), true)?;
                }
                other => {
                    return Err(format!("unknown bench kind '{other}' (try `srsp bench list`)"));
                }
            }
        }
        "validate" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            let cfg = device_config(o)?;
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            let runner = o.runner(cfg.clone(), size, true);
            let store = open_store(o)?;
            let lowered = ExecutionPlan::lower_cells(&runner, &full_grid(cfg.num_cus));
            let (outcomes, counters) = execute_plan_cached(&lowered, o.jobs(), store.as_ref());
            let report = Report::from_outcomes(&outcomes);
            emit_report(&report, o)?;
            let failures = print_validation(&report, o);
            finish_cached_run(store.as_ref().map(|s| s.dir()), &counters);
            print_perfstats();
            if failures > 0 {
                return Err(format!("{failures} validation failures"));
            }
            human(o, "all validations passed");
        }
        "ci-smoke" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            let mut cfg = device_config(o)?;
            if o.cus.is_none() && o.config.is_none() {
                // Small device so the gate stays fast in CI, but still
                // multi-CU enough for real stealing/promotion traffic.
                // An explicit --cus or config file wins.
                cfg.num_cus = 8;
            }
            let size = o.size.unwrap_or(WorkloadSize::Tiny);
            let jobs = o.jobs();
            let cells = full_grid(cfg.num_cus);
            let scenarios = cells.len() / registry::all().count();
            eprintln!(
                "ci-smoke: {} cells ({} workloads × {} scenarios) at {size:?} scale on {} CUs, \
                 {jobs} job(s) ...",
                cells.len(),
                registry::all().count(),
                scenarios,
                cfg.num_cus
            );
            let t0 = Instant::now();
            let runner = o.runner(cfg, size, true);
            let store = open_store(o)?;
            let lowered = ExecutionPlan::lower_cells(&runner, &cells);
            let (outcomes, counters) = execute_plan_cached(&lowered, jobs, store.as_ref());
            let wall = t0.elapsed();
            let report = Report::from_outcomes(&outcomes);
            emit_report(&report, o)?;
            let failures = print_validation(&report, o);
            finish_cached_run(store.as_ref().map(|s| s.dir()), &counters);
            print_perfstats();
            eprintln!("ci-smoke wall time: {wall:.2?} with {jobs} job(s)");
            if failures > 0 {
                return Err(format!("ci-smoke: {failures} oracle mismatches"));
            }
            human(
                o,
                &format!("ci-smoke passed: all {} cells validated", outcomes.len()),
            );
        }
        "worker" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err(
                    "worker always emits PartialReport JSON; --report does not apply".into(),
                );
            }
            if o.jobs.is_some() {
                return Err(
                    "worker executes its shard serially (the shard IS the parallel unit); \
                     --jobs does not apply"
                        .into(),
                );
            }
            if o.trace.is_some() && o.out.is_none() {
                return Err(
                    "worker --trace writes a TracePartial to <file> alongside the report; \
                     pair it with --out <file> so stdout stays one artifact"
                        .into(),
                );
            }
            let Some(path) = &o.shard else {
                return Err("worker needs --shard <file>".into());
            };
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let spec = shard::ShardSpec::from_json(&text).map_err(|e| format!("{path}: {e}"))?;
            eprintln!(
                "worker: shard {}/{} ({} of {} cells) ...",
                spec.shard,
                spec.num_shards,
                spec.cells.len(),
                spec.total_cells
            );
            // The worker's own flags win over the shard-carried cache
            // directory (a traced parent never sets one — the CLI
            // rejects --cache with --trace — but a handcrafted spec
            // could, so the combination is refused, not ignored).
            let store_dir = if o.no_cache {
                None
            } else {
                o.cache.clone().or_else(|| spec.cache_dir.clone())
            };
            if o.trace.is_some() && store_dir.is_some() {
                return Err(
                    "worker --trace conflicts with the shard's result cache; pass --no-cache \
                     to trace this shard fresh"
                        .into(),
                );
            }
            let partial = match &store_dir {
                Some(dir) => {
                    let store = CacheStore::open(dir)?;
                    let (outcomes, counters) = execute_shard_cached(&spec, &store);
                    // The tally rides the PartialReport; the coordinator
                    // sums the fleet into one per-run record.
                    PartialReport::from_outcomes(&spec, &outcomes, counters)
                }
                None => {
                    let results = execute_shard(&spec);
                    if let Some(tp) = &o.trace {
                        // Collection was enabled by the shard's own device
                        // config (trace_capacity > 0, set by the traced
                        // parent sweep); a capacity-0 spec fails loudly
                        // here.
                        let tpart = TracePartial::from_shard(&spec, &results)?;
                        std::fs::write(tp, tpart.to_json()).map_err(|e| format!("{tp}: {e}"))?;
                    }
                    PartialReport::from_shard(&spec, &results)
                }
            };
            emit_to(o.out.as_deref(), &partial.to_json(), false)?;
        }
        "trace" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err("trace renders its own output formats; --report does not apply".into());
            }
            let kind = o.bench_kind.as_deref().unwrap_or("summary");
            if kind == "kinds" {
                // The registered event-kind listing needs no input file.
                print!("{}", tracefile::kinds_listing());
                return Ok(());
            }
            if !matches!(kind, "summary" | "timeline" | "perfetto") {
                return Err(format!(
                    "unknown trace kind '{kind}' (kinds: summary, timeline, perfetto, kinds)"
                ));
            }
            let Some(path) = &o.trace else {
                return Err(format!(
                    "trace {kind} needs --trace <file> (the JSONL file a traced run/sweep wrote)"
                ));
            };
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let report = TraceReport::parse_jsonl(&text).map_err(|e| format!("{path}: {e}"))?;
            let rendered = match kind {
                "summary" => report.summary_table(),
                "timeline" => report.timeline_table(),
                _ => report.render_perfetto(),
            };
            emit_to(o.out.as_deref(), &rendered, true)?;
        }
        "cache" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err("cache prints its own summary; --report does not apply".into());
            }
            let Some(dir) = o.cache.as_deref() else {
                return Err("cache needs --cache <dir> (the store directory)".into());
            };
            match o.bench_kind.as_deref().unwrap_or("stats") {
                "stats" => {
                    let store = CacheStore::open(dir)?;
                    let s = store.summary();
                    println!("cache dir: {dir}");
                    println!(
                        "store: {} segment file(s), {} cell row(s), {} preset(s), {} skipped \
                         line(s)",
                        s.segments, s.cells, s.presets, s.skipped
                    );
                    let runs = cache::run_records(dir);
                    match runs.last() {
                        Some(last) => {
                            let lookups = last.lookups();
                            let rate = if lookups == 0 {
                                "n/a".to_string()
                            } else {
                                format!("{:.1}%", 100.0 * last.hits as f64 / lookups as f64)
                            };
                            println!(
                                "last run: lookups={lookups} hits={} misses={} preset_reuses={} \
                                 hit_rate={rate}",
                                last.hits, last.misses, last.preset_reuses
                            );
                        }
                        None => println!("last run: none recorded"),
                    }
                    let mut total = CacheCounters::default();
                    for r in &runs {
                        total.add(r);
                    }
                    println!(
                        "all runs: {} run(s), hits={} misses={} preset_reuses={}",
                        runs.len(),
                        total.hits,
                        total.misses,
                        total.preset_reuses
                    );
                }
                "verify" => {
                    let store = CacheStore::open(dir)?;
                    println!("{}", store.verify()?);
                }
                "clear" => {
                    println!("{}", cache::clear(dir)?);
                }
                other => {
                    return Err(format!(
                        "unknown cache kind '{other}' (kinds: stats, verify, clear)"
                    ));
                }
            }
        }
        "merge-reports" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.partials.is_empty() {
                return Err("merge-reports needs at least one --partial <file>".into());
            }
            let mut partials = Vec::new();
            for path in &o.partials {
                let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
                partials.push(PartialReport::from_json(&text).map_err(|e| format!("{path}: {e}"))?);
            }
            let report = Report::merge(&partials)?;
            write_report(&report, o.report.unwrap_or(ReportFormat::Csv), o)?;
            eprintln!(
                "merged {} partial report(s): {} rows",
                partials.len(),
                report.rows.len()
            );
        }
        "serve" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err(
                    "serve streams results to submit clients; --report does not apply".into(),
                );
            }
            if o.jobs.is_some() {
                return Err(
                    "serve dispatches batches to connected work processes; --jobs does not \
                     apply"
                        .into(),
                );
            }
            let Some(listen) = o.listen.clone() else {
                return Err("serve needs --listen <addr>".into());
            };
            serve::serve(ServeOpts {
                listen,
                deadline: Duration::from_secs(o.deadline.unwrap_or(60)),
                retries: o.retries.unwrap_or(2),
                shard_cells: o.shard_cells.unwrap_or(serve::ShardCells::Fixed(4)),
                max_jobs: o.max_jobs,
                cache_dir: o.cache_dir().map(|d| d.to_string()),
            })?;
        }
        "work" => {
            o.reject_params(cmd)?;
            o.reject_proto_params(cmd)?;
            o.reject_protocol(cmd)?;
            o.reject_axis_points(cmd)?;
            if o.report.is_some() {
                return Err(
                    "work acks PartialReports over the wire; --report does not apply".into(),
                );
            }
            if o.jobs.is_some() {
                return Err(
                    "work executes each dispatched batch serially (the batch IS the parallel \
                     unit); --jobs does not apply"
                        .into(),
                );
            }
            let Some(addr) = o.connect.as_deref() else {
                return Err("work needs --connect <addr>".into());
            };
            serve::run_worker(addr, o.cache_dir(), o.die_after)?;
        }
        "submit" => {
            let Some(addr) = o.connect.as_deref() else {
                return Err("submit needs --connect <addr>".into());
            };
            if o.jobs.is_some() {
                return Err(
                    "submit ships the sweep to the coordinator's fleet; --jobs does not apply"
                        .into(),
                );
            }
            let SweepSel::Axes(axes) = &o.sweep else {
                return Err(
                    "submit runs a registry-axis sweep on the coordinator (e.g. --axis \
                     remote-ratio,cu-count); --axis cus is the in-process classic grid"
                        .into(),
                );
            };
            let prep = prepare_axis_sweep(o, axes, cmd)?;
            let size = prep.size;
            eprintln!(
                "submit to {addr}: sweep on {} over {} ({} grid points × {} protocols) at \
                 {size:?} scale ...",
                prep.app.name(),
                prep.axis_names.join(" × "),
                prep.plan.combos().len(),
                prep.plan.scenarios.len(),
            );
            let lowered = ExecutionPlan::lower_sweep(&prep.runner, &prep.plan);
            let partial = serve::submit(addr, &lowered)?;
            // One all-covering partial through the same merge gate the
            // distributed path uses: any gap or lossy row fails loudly,
            // and the merged report is byte-identical to --jobs 1.
            let report = Report::merge(&[partial])?;
            finish_axis_sweep(o, &prep, &report)?;
        }
        other => {
            return Err(format!("unknown command '{other}' (try `srsp help`)"));
        }
    }
    Ok(())
}
