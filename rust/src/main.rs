//! `srsp` — the Layer-3 coordinator CLI.
//!
//! Subcommands regenerate the paper's tables/figures, run individual
//! scenarios, sweep CU counts and validate results against native
//! oracles. Everything matrix-shaped (figures, sweeps, validation, the
//! CI smoke gate) is sharded across OS threads by the scenario-matrix
//! runner ([`srsp::harness::runner`]); `--jobs N` controls the worker
//! count and results are byte-identical for every N. No external CLI
//! crate is available offline; parsing is hand-rolled.

use std::time::Instant;

use srsp::config::{parse_config_str, DeviceConfig, Scenario};
use srsp::harness::figures::{
    fig4_speedup, fig5_l2, fig6_overhead, run_one, scaling_cells, scaling_rows,
};
use srsp::harness::presets::{WorkloadPreset, WorkloadSize, DEFAULT_SEED};
use srsp::harness::report::{format_table, Report, ReportFormat};
use srsp::harness::runner::{full_grid, into_run_results, CellResult, Runner, Seeding};
use srsp::workload::driver::App;
use srsp::workload::graph::Graph;

const USAGE: &str = "srsp — scalable remote-scope promotion (paper reproduction)

USAGE:
    srsp <COMMAND> [OPTIONS]

COMMANDS:
    table1                 Print the Table-1 simulation parameters
    fig4                   Regenerate Fig. 4 (speedup vs Baseline)
    fig5                   Regenerate Fig. 5 (L2 accesses vs Baseline)
    fig6                   Regenerate Fig. 6 (sync overhead vs RSP)
    sweep                  CU-count scaling sweep (RSP vs sRSP geomean)
    run                    Run one app under one scenario, print stats
    validate               Run every app/scenario and check the oracles
    ci-smoke               Tiny-scale app × scenario matrix, oracle-checked
                           in parallel; exits non-zero on any mismatch
    help                   Show this message

OPTIONS:
    --app <prk|sssp|mis>        App for `run` (default prk)
    --scenario <name>           baseline|scope|steal|rsp|srsp|hlrc (default srsp)
    --cus <n>                   Override CU count (ci-smoke default: 8)
    --size <tiny|paper>         Workload scale (default paper; ci-smoke: tiny)
    --jobs <n>                  Worker threads for matrix commands
                                (default: all available cores)
    --seed <n>                  Derive a distinct workload seed per grid
                                cell from base <n> (decimal or 0x hex);
                                omit to use the classic shared seed that
                                reproduces the paper figures
    --report <json|csv>         Emit a machine-readable matrix report
    --out <file>                Write the report to <file> (default stdout)
    --graph <file.gr|file.mtx>  Use a real DIMACS/MatrixMarket graph
    --config <file>             Device config file (key = value)
";

struct Opts {
    app: App,
    scenario: Scenario,
    cus: Option<u32>,
    size: Option<WorkloadSize>,
    jobs: Option<usize>,
    seed: Option<u64>,
    report: Option<ReportFormat>,
    out: Option<String>,
    graph: Option<String>,
    config: Option<String>,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts {
        app: App::PageRank,
        scenario: Scenario::Srsp,
        cus: None,
        size: None,
        jobs: None,
        seed: None,
        report: None,
        out: None,
        graph: None,
        config: None,
    };
    let mut i = 0;
    while i < args.len() {
        let key = args[i].clone();
        let mut val = || -> Result<String, String> {
            i += 1;
            args.get(i)
                .cloned()
                .ok_or_else(|| format!("{key} needs a value"))
        };
        match key.as_str() {
            "--app" => {
                o.app = match val()?.as_str() {
                    "prk" | "pagerank" => App::PageRank,
                    "sssp" => App::Sssp,
                    "mis" => App::Mis,
                    other => return Err(format!("unknown app '{other}'")),
                }
            }
            "--scenario" => {
                let v = val()?;
                o.scenario = Scenario::from_name(&v)
                    .ok_or_else(|| format!("unknown scenario '{v}'"))?;
            }
            "--cus" => o.cus = Some(val()?.parse().map_err(|e| format!("--cus: {e}"))?),
            "--size" => {
                o.size = match val()?.as_str() {
                    "tiny" => Some(WorkloadSize::Tiny),
                    "paper" => Some(WorkloadSize::Paper),
                    other => return Err(format!("unknown size '{other}'")),
                }
            }
            "--jobs" => o.jobs = Some(val()?.parse().map_err(|e| format!("--jobs: {e}"))?),
            "--seed" => o.seed = Some(parse_u64(&val()?).map_err(|e| format!("--seed: {e}"))?),
            "--report" => {
                let v = val()?;
                let format =
                    ReportFormat::from_name(&v).ok_or_else(|| format!("unknown format '{v}'"))?;
                o.report = Some(format);
            }
            "--out" => o.out = Some(val()?),
            "--graph" => o.graph = Some(val()?),
            "--config" => o.config = Some(val()?),
            other => return Err(format!("unknown option '{other}'")),
        }
        i += 1;
    }
    Ok(o)
}

/// Parse a u64 in decimal or `0x` hexadecimal.
fn parse_u64(s: &str) -> Result<u64, String> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).map_err(|e| e.to_string()),
        None => s.parse().map_err(|e: std::num::ParseIntError| e.to_string()),
    }
}

impl Opts {
    fn jobs(&self) -> usize {
        self.jobs.unwrap_or_else(Runner::default_jobs)
    }

    /// When `--report` goes to stdout, human-readable output moves to
    /// stderr so the report stays machine-parseable.
    fn stdout_is_human(&self) -> bool {
        self.report.is_none() || self.out.is_some()
    }

    fn seeding(&self) -> Seeding {
        match self.seed {
            Some(base) => Seeding::PerCell(base),
            None => Seeding::Shared(DEFAULT_SEED),
        }
    }

    fn runner(&self, cfg: DeviceConfig, size: WorkloadSize, validate: bool) -> Runner {
        Runner {
            jobs: self.jobs(),
            seeding: self.seeding(),
            size,
            validate,
            cfg,
        }
    }
}

fn device_config(o: &Opts) -> Result<DeviceConfig, String> {
    let mut cfg = match &o.config {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            parse_config_str(&text).map_err(|e| e.to_string())?
        }
        None => DeviceConfig::default(),
    };
    if let Some(n) = o.cus {
        cfg.num_cus = n;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn load_preset(o: &Opts, size: WorkloadSize) -> Result<WorkloadPreset, String> {
    // For a single run, --seed is used directly as the generator seed.
    let mut preset = WorkloadPreset::new_seeded(o.app, size, o.seed.unwrap_or(DEFAULT_SEED));
    if let Some(path) = &o.graph {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let g = if path.ends_with(".mtx") {
            Graph::from_matrix_market(&text)?
        } else {
            Graph::from_dimacs_gr(&text)?
        };
        g.validate()?;
        preset = preset.with_graph(g);
    }
    Ok(preset)
}

/// Emit the machine-readable report when `--report` was given.
fn emit_report(results: &[CellResult], o: &Opts) -> Result<(), String> {
    let Some(format) = o.report else {
        return Ok(());
    };
    let report = Report::from_cells(results);
    let text = match format {
        ReportFormat::Json => report.to_json(),
        ReportFormat::Csv => report.to_csv(),
    };
    match &o.out {
        Some(path) => std::fs::write(path, &text).map_err(|e| format!("{path}: {e}"))?,
        None => print!("{text}"),
    }
    Ok(())
}

/// Print `text` to stdout, or to stderr when stdout is carrying the
/// machine-readable report.
fn human(o: &Opts, text: &str) {
    if o.stdout_is_human() {
        println!("{text}");
    } else {
        eprintln!("{text}");
    }
}

/// Print one `app / scenario OK|FAIL` line per validated cell; returns
/// the failure count.
fn print_validation(results: &[CellResult], o: &Opts) -> usize {
    let mut failures = 0;
    for c in results {
        let ok = c.validated == Some(true) && c.result.converged;
        human(
            o,
            &format!(
                "{:>5} / {:<9} {}",
                c.result.app,
                c.result.scenario.name(),
                if ok { "OK" } else { "FAIL" }
            ),
        );
        if !ok {
            failures += 1;
        }
    }
    failures
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().cloned() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let opts = match parse_opts(&args[1..]) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&cmd, &opts) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, o: &Opts) -> Result<(), String> {
    match cmd {
        "help" | "--help" | "-h" => print!("{USAGE}"),
        "table1" => {
            let cfg = device_config(o)?;
            println!("Table 1 — simulation parameters\n{}", cfg.table1());
        }
        "fig4" | "fig5" | "fig6" => {
            let cfg = device_config(o)?;
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            eprintln!(
                "running {} scenarios × {} apps at {size:?} scale on {} CUs ({} jobs) ...",
                Scenario::ALL.len(),
                App::ALL.len(),
                cfg.num_cus,
                o.jobs()
            );
            let runner = o.runner(cfg.clone(), size, false);
            let cells = runner.run_cells(&full_grid(cfg.num_cus));
            emit_report(&cells, o)?;
            let results = into_run_results(cells);
            let table = match cmd {
                "fig4" => fig4_speedup(&results),
                "fig5" => fig5_l2(&results),
                _ => fig6_overhead(&results),
            };
            human(o, &table.render());
        }
        "sweep" => {
            let cus = [4u32, 8, 16, 32, 64];
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            eprintln!("scaling sweep over {cus:?} CUs ({} jobs) ...", o.jobs());
            let runner = o.runner(device_config(o)?, size, false);
            let results = runner.run_cells(&scaling_cells(&cus));
            emit_report(&results, o)?;
            let rows = scaling_rows(&cus, &results);
            let header = vec!["CUs".to_string(), "RSP".to_string(), "sRSP".to_string()];
            let body: Vec<Vec<String>> = rows
                .iter()
                .map(|(n, r, s)| vec![n.to_string(), format!("{r:.3}"), format!("{s:.3}")])
                .collect();
            human(
                o,
                &format!(
                    "Scalability — geomean speedup vs Baseline at equal CU count\n{}",
                    format_table(&header, &body)
                ),
            );
        }
        "run" => {
            let cfg = device_config(o)?;
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            let preset = load_preset(o, size)?;
            eprintln!(
                "running {} under {} on {} CUs (n={}, m={}) ...",
                o.app.name(),
                o.scenario,
                cfg.num_cus,
                preset.graph.n,
                preset.graph.num_edges()
            );
            let r = run_one(&cfg, &preset, o.scenario);
            println!(
                "app={} scenario={} rounds={} converged={}",
                r.app, r.scenario, r.rounds, r.converged
            );
            println!("{}", r.stats);
        }
        "validate" => {
            let cfg = device_config(o)?;
            let size = o.size.unwrap_or(WorkloadSize::Paper);
            let runner = o.runner(cfg.clone(), size, true);
            let results = runner.run_cells(&full_grid(cfg.num_cus));
            emit_report(&results, o)?;
            let failures = print_validation(&results, o);
            if failures > 0 {
                return Err(format!("{failures} validation failures"));
            }
            human(o, "all validations passed");
        }
        "ci-smoke" => {
            let mut cfg = device_config(o)?;
            if o.cus.is_none() && o.config.is_none() {
                // Small device so the gate stays fast in CI, but still
                // multi-CU enough for real stealing/promotion traffic.
                // An explicit --cus or config file wins.
                cfg.num_cus = 8;
            }
            let size = o.size.unwrap_or(WorkloadSize::Tiny);
            let jobs = o.jobs();
            let cells = full_grid(cfg.num_cus);
            eprintln!(
                "ci-smoke: {} cells ({} apps × {} scenarios) at {size:?} scale on {} CUs, \
                 {jobs} job(s) ...",
                cells.len(),
                App::ALL.len(),
                Scenario::ALL.len(),
                cfg.num_cus
            );
            let t0 = Instant::now();
            let runner = o.runner(cfg, size, true);
            let results = runner.run_cells(&cells);
            let wall = t0.elapsed();
            emit_report(&results, o)?;
            let failures = print_validation(&results, o);
            eprintln!("ci-smoke wall time: {wall:.2?} with {jobs} job(s)");
            if failures > 0 {
                return Err(format!("ci-smoke: {failures} oracle mismatches"));
            }
            human(
                o,
                &format!("ci-smoke passed: all {} cells validated", results.len()),
            );
        }
        other => {
            return Err(format!("unknown command '{other}' (try `srsp help`)"));
        }
    }
    Ok(())
}
