//! Minimal JSON reader/writer for the distributed-pipeline stage
//! boundaries.
//!
//! The plan → shard → execute → merge pipeline crosses process
//! boundaries as files: [`ExecutionPlan`](crate::coordinator::ExecutionPlan)
//! and [`ShardSpec`](crate::coordinator::shard::ShardSpec) going down to
//! `srsp worker` subprocesses, [`PartialReport`](crate::harness::report::PartialReport)
//! coming back up. No serde is available offline (the crate builds with
//! zero dependencies), so — like the config-file parser and the report
//! emitters — the tree is hand-rolled.
//!
//! One representation choice is load-bearing: [`Json::Num`] stores the
//! **raw number token**, not an `f64`. Workload seeds are full-width
//! `u64`s (beyond `f64`'s 2^53 integer range) and the merged report must
//! be byte-identical to the single-process run, so numbers must survive
//! a serialize → parse round trip with zero loss. `u64`s are written via
//! `Display` and re-parsed as `u64`; `f64`s are written via `Display`
//! (Rust's shortest round-trip float rendering) and re-parsed as `f64`.

use std::fmt::Write as _;

/// One JSON value. Numbers keep their raw source token (see module doc).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Raw number token exactly as written or parsed.
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    pub fn u32(v: u32) -> Json {
        Json::Num(v.to_string())
    }

    pub fn usize(v: usize) -> Json {
        Json::Num(v.to_string())
    }

    /// Shortest round-trip rendering; JSON has no NaN/infinity, and no
    /// pipeline value is ever non-finite (parameters are range-checked).
    pub fn f64(v: f64) -> Json {
        assert!(v.is_finite(), "JSON cannot carry non-finite number {v}");
        Json::Num(v.to_string())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_bool(&self) -> Result<bool, String> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {}", other.kind())),
        }
    }

    pub fn as_u64(&self) -> Result<u64, String> {
        match self {
            Json::Num(raw) => raw
                .parse()
                .map_err(|_| format!("expected unsigned integer, got '{raw}'")),
            other => Err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_u32(&self) -> Result<u32, String> {
        match self {
            Json::Num(raw) => raw
                .parse()
                .map_err(|_| format!("expected u32, got '{raw}'")),
            other => Err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize, String> {
        match self {
            Json::Num(raw) => raw
                .parse()
                .map_err(|_| format!("expected index, got '{raw}'")),
            other => Err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_f64(&self) -> Result<f64, String> {
        match self {
            Json::Num(raw) => raw
                .parse()
                .map_err(|_| format!("expected number, got '{raw}'")),
            other => Err(format!("expected number, got {}", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str, String> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(format!("expected string, got {}", other.kind())),
        }
    }

    pub fn arr(&self) -> Result<&[Json], String> {
        match self {
            Json::Arr(items) => Ok(items),
            other => Err(format!("expected array, got {}", other.kind())),
        }
    }

    /// Field lookup on an object; a missing key is a loud error naming it.
    pub fn get(&self, key: &str) -> Result<&Json, String> {
        match self {
            Json::Obj(fields) => fields
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("missing field '{key}'")),
            other => Err(format!("expected object with '{key}', got {}", other.kind())),
        }
    }

    /// Render to compact JSON text (no trailing newline).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(raw) => out.push_str(raw),
            Json::Str(s) => write_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                write!(out, "\\u{:04x}", c as u32).expect("writing to a String cannot fail");
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// `[["k", v], ...]` encoding of a parameter-override list (`--param` /
/// `--proto-param` pairs), order-preserving — override precedence is
/// positional, so a map encoding would corrupt it.
pub fn pairs_to_json(pairs: &[(String, f64)]) -> Json {
    Json::Arr(
        pairs
            .iter()
            .map(|(k, v)| Json::Arr(vec![Json::str(k.clone()), Json::f64(*v)]))
            .collect(),
    )
}

/// Inverse of [`pairs_to_json`].
pub fn pairs_from_json(v: &Json) -> Result<Vec<(String, f64)>, String> {
    let mut pairs = Vec::new();
    for item in v.arr()? {
        let pair = item.arr()?;
        if pair.len() != 2 {
            return Err(format!(
                "parameter pair must be [key, value], got {} element(s)",
                pair.len()
            ));
        }
        pairs.push((pair[0].as_str()?.to_string(), pair[1].as_f64()?));
    }
    Ok(pairs)
}

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content after the JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl std::fmt::Display) -> String {
        format!("JSON byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E') | Some(b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let raw = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ASCII slice");
        // Validate the token now so accessors can assume a number shape.
        raw.parse::<f64>()
            .map_err(|_| self.err(format!("invalid number '{raw}'")))?;
        Ok(Json::Num(raw.to_string()))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let Some(b) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("non-ASCII \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err(format!("invalid \\u escape '{hex}'")))?;
                            self.pos += 4;
                            // Surrogate pairs never occur in pipeline data
                            // (names and k=v strings are ASCII).
                            let c = char::from_u32(code)
                                .ok_or_else(|| self.err(format!("invalid code point {code:#x}")))?;
                            s.push(c);
                        }
                        other => {
                            return Err(self.err(format!("bad escape '\\{}'", other as char)));
                        }
                    }
                }
                _ => {
                    // Multi-byte UTF-8: copy the full scalar value.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    self.pos = start + width;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated UTF-8 sequence"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid UTF-8 in string"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Byte length of the UTF-8 sequence starting with `first`.
fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = parse(text).unwrap();
            assert_eq!(v.render(), text);
        }
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert!(parse("true").unwrap().as_bool().unwrap());
        assert_eq!(parse("\"a b\"").unwrap().as_str().unwrap(), "a b");
    }

    #[test]
    fn u64_seeds_survive_beyond_f64_precision() {
        // 2^63 + 1 is not representable as f64; the raw-token Num must
        // carry it losslessly — the property the whole pipeline rests on.
        let seed = (1u64 << 63) + 1;
        let v = Json::u64(seed);
        let back = parse(&v.render()).unwrap();
        assert_eq!(back.as_u64().unwrap(), seed);
    }

    #[test]
    fn f64_shortest_display_round_trips() {
        for x in [0.1, 0.25, 1.0 / 3.0, 1e-9, 123456.789, 0.0] {
            let back = parse(&Json::f64(x).render()).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} must round-trip exactly");
        }
    }

    #[test]
    fn nested_structures_round_trip() {
        let v = Json::Obj(vec![
            ("name".into(), Json::str("remote-ratio")),
            ("points".into(), Json::Arr(vec![Json::f64(0.0), Json::f64(0.5)])),
            ("nested".into(), Json::Obj(vec![("ok".into(), Json::Bool(true))])),
            ("none".into(), Json::Null),
        ]);
        let text = v.render();
        assert_eq!(parse(&text).unwrap(), v);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "remote-ratio");
        assert_eq!(v.get("points").unwrap().arr().unwrap().len(), 2);
        assert!(v.get("missing").unwrap_err().contains("missing"));
    }

    #[test]
    fn string_escapes() {
        let v = Json::str("a\"b\\c\nd\te");
        let text = v.render();
        assert_eq!(text, "\"a\\\"b\\\\c\\nd\\te\"");
        assert_eq!(parse(&text).unwrap(), v);
        // Standard escapes parse even when the writer would not emit them.
        assert_eq!(parse("\"\\u0041\\/\"").unwrap().as_str().unwrap(), "A/");
        // Non-ASCII passes through unescaped.
        let v = Json::str("ölçek");
        assert_eq!(parse(&v.render()).unwrap(), v);
    }

    #[test]
    fn pairs_round_trip_preserving_order() {
        let pairs = vec![
            ("remote_ratio".to_string(), 0.5),
            ("hot_set".to_string(), 2.0),
            ("remote_ratio".to_string(), 0.75), // later override wins: order matters
        ];
        let back = pairs_from_json(&pairs_to_json(&pairs)).unwrap();
        assert_eq!(back, pairs);
        assert_eq!(pairs_from_json(&pairs_to_json(&[])).unwrap(), vec![]);
    }

    #[test]
    fn malformed_documents_are_loud() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "1 2", "{\"a\" 1}", "[1 2]",
            "nanana", "--5",
        ] {
            assert!(parse(bad).is_err(), "'{bad}' must not parse");
        }
        assert!(Json::Null.as_u64().is_err());
        assert!(Json::Num("1.5".into()).as_u64().is_err());
        assert!(Json::Num("-1".into()).as_u32().is_err());
    }

    #[test]
    fn whitespace_tolerated_between_tokens() {
        let v = parse(" {\n \"a\" : [ 1 , 2 ] ,\t\"b\" : { } }\n").unwrap();
        assert_eq!(v.get("a").unwrap().arr().unwrap().len(), 2);
        assert_eq!(v.get("b").unwrap(), &Json::Obj(vec![]));
    }
}
