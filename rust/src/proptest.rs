//! Minimal property-based testing support.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so the crate
//! carries a small deterministic harness: a generator context over
//! [`SplitMix64`](crate::sim::SplitMix64) plus a runner that, on failure,
//! retries with a simple size-halving shrink schedule and reports the
//! failing seed so the case can be replayed exactly.
//!
//! Usage:
//! ```no_run
//! use srsp::proptest::{run_prop, Gen};
//! run_prop("sum_commutes", 100, |g: &mut Gen| {
//!     let a = g.u64(0..1000);
//!     let b = g.u64(0..1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::sim::SplitMix64;
use std::ops::Range;

/// Generator context handed to each property iteration.
pub struct Gen {
    rng: SplitMix64,
    /// Size hint in `[0.0, 1.0]`: shrinking reruns with smaller sizes.
    pub size: f64,
    /// Seed of this iteration (for reproduction).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: SplitMix64::new(seed),
            size,
            seed,
        }
    }

    /// Uniform u64 in `range` (end exclusive).
    pub fn u64(&mut self, range: Range<u64>) -> u64 {
        assert!(range.end > range.start);
        range.start + self.rng.below(range.end - range.start)
    }

    pub fn u32(&mut self, range: Range<u32>) -> u32 {
        self.u64(range.start as u64..range.end as u64) as u32
    }

    pub fn usize(&mut self, range: Range<usize>) -> usize {
        self.u64(range.start as u64..range.end as u64) as usize
    }

    /// Size-scaled length: shrinks toward `range.start` as `size` drops.
    pub fn len(&mut self, range: Range<usize>) -> usize {
        let span = (range.end - range.start) as f64;
        let scaled = range.start + (span * self.size).ceil() as usize;
        let hi = scaled.max(range.start + 1).min(range.end);
        self.usize(range.start..hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// A vector of generated values with size-scaled length.
    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.len(len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Access the raw RNG (e.g. to fork per-work-group streams).
    pub fn rng(&mut self) -> &mut SplitMix64 {
        &mut self.rng
    }
}

/// Run `prop` for `iters` iterations with deterministic per-iteration seeds.
///
/// A panicking iteration is retried at smaller sizes (a crude shrink); the
/// smallest failing `(seed, size)` is reported in the final panic message.
/// Set `SRSP_PROP_SEED` to replay a single seed.
pub fn run_prop(name: &str, iters: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let base = 0x5EED_0000u64 ^ fxhash(name);

    if let Ok(s) = std::env::var("SRSP_PROP_SEED") {
        let seed: u64 = s.parse().expect("SRSP_PROP_SEED must be a u64");
        let mut g = Gen::new(seed, 1.0);
        prop(&mut g);
        return;
    }

    for i in 0..iters {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let size = 0.1 + 0.9 * (i as f64 / iters.max(1) as f64);
        let failed = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, size);
            prop(&mut g);
        })
        .is_err();

        if failed {
            // Shrink: rerun the same seed at halving sizes, keep the
            // smallest size that still fails.
            let mut fail_size = size;
            let mut s = size / 2.0;
            while s > 0.01 {
                let still = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, s);
                    prop(&mut g);
                })
                .is_err();
                if still {
                    fail_size = s;
                }
                s /= 2.0;
            }
            panic!(
                "property '{name}' failed: seed={seed} size={fail_size:.3} \
                 (replay with SRSP_PROP_SEED={seed})"
            );
        }
    }
}

/// FxHash-style string hash for stable per-property seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run_prop("trivial", 50, |g| {
            let v = g.vec(0..20, |g| g.u64(0..100));
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure_with_seed() {
        run_prop("fails", 50, |g| {
            // Deterministically fails for later (larger-size) iterations
            // and passes under shrinking, exercising the shrink loop.
            assert!(g.size < 0.5, "too big");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 1.0);
        for _ in 0..100 {
            let x = g.u64(10..20);
            assert!((10..20).contains(&x));
            let l = g.len(2..8);
            assert!((2..8).contains(&l));
        }
    }

    #[test]
    fn pick_returns_member() {
        let mut g = Gen::new(2, 1.0);
        let xs = [1, 5, 9];
        for _ in 0..20 {
            assert!(xs.contains(g.pick(&xs)));
        }
    }
}
