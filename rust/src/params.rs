//! Generic tunable-parameter machinery shared by the pluggable
//! registries: workloads (`--param k=v` against a [`Kernel`] spec) and
//! sync protocols (`--proto-param k=v` against a [`SyncProtocol`] spec).
//!
//! A registry entry declares a static [`ParamSpec`] slice; [`Params`]
//! overlays user overrides on the spec defaults and remembers which keys
//! were explicit (the `k=v;...` report columns render only those).
//!
//! [`Kernel`]: crate::workload::registry::Kernel
//! [`SyncProtocol`]: crate::sync::protocol::SyncProtocol

use std::collections::{BTreeMap, BTreeSet};

/// One tunable parameter a registry entry exposes.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    pub key: &'static str,
    /// Default value; by convention `0` often means "auto by size"
    /// (materialized in `prepare`/device construction) — the `help`
    /// text says so.
    pub default: f64,
    pub help: &'static str,
}

/// Resolved parameter values for one registry-entry instance: the spec
/// defaults overlaid with the user's explicit overrides.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Params {
    vals: BTreeMap<&'static str, f64>,
    explicit: BTreeSet<&'static str>,
}

impl Params {
    /// Overlay `overrides` on `specs`' defaults. Unknown keys are an
    /// error listing the valid ones.
    pub fn resolve(
        specs: &'static [ParamSpec],
        overrides: &[(String, f64)],
    ) -> Result<Params, String> {
        let mut p = Params::default();
        for s in specs {
            p.vals.insert(s.key, s.default);
        }
        for (key, val) in overrides {
            if !val.is_finite() || *val < 0.0 {
                return Err(format!(
                    "parameter '{key}' must be a finite non-negative number, got {val}"
                ));
            }
            let Some(spec) = specs.iter().find(|s| s.key == key.as_str()) else {
                let valid: Vec<&str> = specs.iter().map(|s| s.key).collect();
                return Err(format!(
                    "unknown parameter '{key}' (valid: {})",
                    if valid.is_empty() {
                        "none".to_string()
                    } else {
                        valid.join(", ")
                    }
                ));
            };
            p.vals.insert(spec.key, *val);
            p.explicit.insert(spec.key);
        }
        Ok(p)
    }

    /// Value of `key`. Panics on a key the spec does not declare —
    /// that is a registry-author bug, not a user error.
    pub fn get(&self, key: &str) -> f64 {
        *self
            .vals
            .get(key)
            .unwrap_or_else(|| panic!("parameter '{key}' not declared in the registry spec"))
    }

    /// Value of `key`, or `default` when the spec never declared it
    /// (e.g. a bare [`crate::mem::MemSystem`] constructed without going
    /// through [`crate::gpu::Device`]).
    pub fn get_or(&self, key: &str, default: f64) -> f64 {
        self.vals.get(key).copied().unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str) -> u32 {
        self.get(key) as u32
    }

    /// Was `key` explicitly overridden by the user?
    pub fn is_explicit(&self, key: &str) -> bool {
        self.explicit.contains(key)
    }

    /// Materialize an auto default (used by `prepare` hooks for
    /// size-dependent defaults); does not mark the key explicit.
    pub fn set_auto(&mut self, key: &'static str, val: f64) {
        self.vals.insert(key, val);
    }

    /// Every resolved `(key, value, explicit)` triple, in key order —
    /// the serialization the result cache's preset layer stores.
    pub fn entries(&self) -> Vec<(&'static str, f64, bool)> {
        self.vals
            .iter()
            .map(|(k, v)| (*k, *v, self.explicit.contains(k)))
            .collect()
    }

    /// Rebuild a [`Params`] from stored [`Params::entries`] triples.
    /// Strict against registry drift: an entry whose key the current
    /// spec does not declare, or a spec key the entries do not cover, is
    /// an error — the caller regenerates instead of trusting a stale
    /// record. (Keys materialized by `set_auto` outside the spec fail
    /// here by design: such presets are regenerated, never rehydrated.)
    pub fn rehydrate(
        specs: &'static [ParamSpec],
        entries: &[(String, f64, bool)],
    ) -> Result<Params, String> {
        let mut p = Params::default();
        for (key, val, explicit) in entries {
            let Some(spec) = specs.iter().find(|s| s.key == key.as_str()) else {
                return Err(format!("stored parameter '{key}' is not in the registry spec"));
            };
            p.vals.insert(spec.key, *val);
            if *explicit {
                p.explicit.insert(spec.key);
            }
        }
        for s in specs {
            if !p.vals.contains_key(s.key) {
                return Err(format!("stored preset predates parameter '{}'", s.key));
            }
        }
        Ok(p)
    }

    /// Compact `k=v;k2=v2` rendering of the explicit overrides (report
    /// column; empty when the run used pure defaults).
    pub fn overrides_display(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for key in &self.explicit {
            let v = self.vals[key];
            if v == v.trunc() && v.abs() < 1e15 {
                parts.push(format!("{key}={}", v as i64));
            } else {
                parts.push(format!("{key}={v}"));
            }
        }
        parts.join(";")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_resolution_and_errors() {
        let specs: &'static [ParamSpec] = &[
            ParamSpec {
                key: "alpha",
                default: 2.0,
                help: "",
            },
            ParamSpec {
                key: "beta",
                default: 0.5,
                help: "",
            },
        ];
        let p = Params::resolve(specs, &[("beta".into(), 0.25)]).unwrap();
        assert_eq!(p.get("alpha"), 2.0);
        assert_eq!(p.get("beta"), 0.25);
        assert!(p.is_explicit("beta") && !p.is_explicit("alpha"));
        assert_eq!(p.overrides_display(), "beta=0.25");
        let err = Params::resolve(specs, &[("gamma".into(), 1.0)]).unwrap_err();
        assert!(err.contains("alpha") && err.contains("beta"), "{err}");
        // Values are range-checked: a negative would silently saturate
        // to 0 in `get_u32` (e.g. sticky-overflow table mode).
        let err = Params::resolve(specs, &[("alpha".into(), -1.0)]).unwrap_err();
        assert!(err.contains("non-negative"), "{err}");
        let err = Params::resolve(specs, &[("alpha".into(), f64::NAN)]).unwrap_err();
        assert!(err.contains("finite"), "{err}");
    }

    #[test]
    fn entries_rehydrate_round_trip_and_reject_drift() {
        let specs: &'static [ParamSpec] = &[
            ParamSpec {
                key: "gamma",
                default: 1.0,
                help: "",
            },
            ParamSpec {
                key: "delta",
                default: 4.0,
                help: "",
            },
        ];
        let p = Params::resolve(specs, &[("delta".into(), 8.0)]).unwrap();
        let entries: Vec<(String, f64, bool)> = p
            .entries()
            .into_iter()
            .map(|(k, v, e)| (k.to_string(), v, e))
            .collect();
        assert_eq!(
            entries,
            vec![("delta".to_string(), 8.0, true), ("gamma".to_string(), 1.0, false)]
        );
        let back = Params::rehydrate(specs, &entries).unwrap();
        assert_eq!(back, p);
        assert_eq!(back.overrides_display(), "delta=8");
        // A stored key the spec no longer declares is refused...
        let alien = vec![("epsilon".to_string(), 1.0, false)];
        let err = Params::rehydrate(specs, &alien).unwrap_err();
        assert!(err.contains("not in the registry spec"), "{err}");
        // ...and so is a record that predates a spec key.
        let short = vec![("gamma".to_string(), 1.0, false)];
        let err = Params::rehydrate(specs, &short).unwrap_err();
        assert!(err.contains("predates parameter 'delta'"), "{err}");
    }

    #[test]
    fn get_or_falls_back_on_undeclared_keys() {
        let p = Params::default();
        assert_eq!(p.get_or("anything", 0.75), 0.75);
        let specs: &'static [ParamSpec] = &[ParamSpec {
            key: "x",
            default: 3.0,
            help: "",
        }];
        let p = Params::resolve(specs, &[]).unwrap();
        assert_eq!(p.get_or("x", 9.0), 3.0);
    }
}
