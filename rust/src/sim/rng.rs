//! Deterministic PRNGs for workload generation and property testing.
//!
//! No external `rand` dependency is available offline, so the crate carries
//! its own SplitMix64 — the standard 64-bit mixing generator (Steele et al.),
//! statistically solid for simulation seeds, graph generation and schedule
//! randomization.

/// SplitMix64 generator. Deterministic for a given seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero. Uses Lemire's
    /// multiply-shift rejection-free approximation (bias negligible for the
    /// bounds used in simulation: ≤ 2^32).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize index in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-work-group streams).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(7);
        for bound in [1u64, 2, 3, 10, 1000, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        // Mean should be near 0.5 for a sane generator.
        let mean = acc / 1000.0;
        assert!((0.4..0.6).contains(&mean), "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = SplitMix64::new(3);
        let mut a = root.fork();
        let mut b = root.fork();
        // Not a statistical test, just non-identical streams.
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
