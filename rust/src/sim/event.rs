//! The global event queue: deterministic min-heap of work-group wakeups.

use super::Cycle;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A scheduled wakeup for a work-group context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Cycle at which the work-group becomes runnable again.
    pub cycle: Cycle,
    /// Monotone sequence number; breaks ties deterministically (FIFO among
    /// events scheduled for the same cycle).
    pub seq: u64,
    /// Work-group id to resume.
    pub wg: u32,
}

// BinaryHeap is a max-heap; invert the ordering for earliest-first.
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .cycle
            .cmp(&self.cycle)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic earliest-first event queue.
///
/// Determinism contract: two runs that push the same (cycle, wg) sequence
/// pop the same order, because ties are broken by insertion sequence.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
    /// High-water mark of the simulated clock: the cycle of the last popped
    /// event. Time never goes backwards.
    now: Cycle,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `wg` to resume at `cycle`. Scheduling in the past is clamped
    /// to `now` (can happen when a zero-latency operation completes).
    pub fn schedule(&mut self, cycle: Cycle, wg: u32) {
        let cycle = cycle.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { cycle, seq, wg });
    }

    /// Pop the earliest event, advancing the clock.
    pub fn pop(&mut self) -> Option<Event> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.cycle >= self.now, "time went backwards");
        self.now = ev.cycle;
        Some(ev)
    }

    /// Current simulated cycle (cycle of the last popped event).
    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_cycle_order() {
        let mut q = EventQueue::new();
        q.schedule(30, 0);
        q.schedule(10, 1);
        q.schedule(20, 2);
        assert_eq!(q.pop().unwrap().wg, 1);
        assert_eq!(q.pop().unwrap().wg, 2);
        assert_eq!(q.pop().unwrap().wg, 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_fifo() {
        let mut q = EventQueue::new();
        for wg in 0..8 {
            q.schedule(5, wg);
        }
        for wg in 0..8 {
            assert_eq!(q.pop().unwrap().wg, wg);
        }
    }

    #[test]
    fn clock_monotone_and_past_clamped() {
        let mut q = EventQueue::new();
        q.schedule(100, 0);
        assert_eq!(q.pop().unwrap().cycle, 100);
        assert_eq!(q.now(), 100);
        // Scheduling "in the past" clamps to now.
        q.schedule(50, 1);
        let ev = q.pop().unwrap();
        assert_eq!(ev.cycle, 100);
        assert_eq!(q.now(), 100);
    }

    #[test]
    fn len_and_empty() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.schedule(1, 0);
        q.schedule(2, 1);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
    }
}
