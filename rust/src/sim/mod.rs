//! Discrete-event simulation core.
//!
//! The simulator is *serialized* discrete-event: a single [`EventQueue`]
//! orders work-group wakeups by `(cycle, seq)`; each wakeup executes one (or
//! a quantum of) KIR instruction(s) functionally at that cycle and
//! reschedules at its computed completion cycle. Contention is modeled by
//! banked next-free-cycle resources ([`timing`](crate::mem::timing)) rather
//! than split transactions — adequate for the paper's first-order effects
//! (flush drain cost, invalidation-induced miss storms, L2 port pressure).

pub mod event;
pub mod perfstats;
pub mod rng;
pub mod stats;
pub mod trace;

pub use event::{Event, EventQueue};
pub use perfstats::PerfStats;
pub use rng::SplitMix64;
pub use stats::Stats;
pub use trace::{CellTrace, TraceEvent, TraceKind, TraceSink, TRACE_SCHEMA};

/// Simulated GPU core clock cycle. The device clock is the unit of all
/// latencies in [`DeviceConfig`](crate::config::DeviceConfig).
pub type Cycle = u64;
