//! Cycle-timestamped sync-event tracing: the observability counterpart
//! to [`perfstats`](crate::sim::perfstats)' wall-clock split.
//!
//! [`Stats`](crate::sim::Stats) can only say *how many* promotions a run
//! made; this module records *when* each one happened and *which* CU it
//! hit. A [`TraceSink`] lives on the memory system and collects
//! [`TraceEvent`]s — `{cycle, cu, wg, kind, addr, detail}` — from hooks
//! in the sync protocols, the memory hierarchy and the device event
//! loop, into a bounded ring buffer (oldest events overwritten, loudly
//! counted in `dropped`) plus an exact per-CU × per-kind counter matrix
//! that no ring overflow can truncate.
//!
//! Tracing is **observe-only and off by default**: a sink with capacity
//! 0 is disabled and every `emit` returns immediately, so the simulated
//! results — and therefore all reports — are byte-identical whether a
//! run is traced or not. The sink is part of the per-cell
//! [`MemSystem`](crate::mem::MemSystem), so per-cell traces are
//! deterministic and independent of `--jobs`/`--workers` sharding.
//!
//! The serialized forms (the per-cell [`CellTrace`] JSON, the JSONL
//! trace files and the worker trace partials in
//! [`harness::tracefile`](crate::harness::tracefile)) are all versioned
//! by [`TRACE_SCHEMA`].

use crate::jsonio::Json;

use super::Cycle;

/// Version stamp of every serialized trace artifact (per-cell JSON,
/// JSONL files, worker trace partials). Bumped on any event-kind or
/// field change so mixed binary generations are refused, not misread.
pub const TRACE_SCHEMA: u32 = 1;

/// Ring-buffer capacity `--trace` selects when `--trace-buf` is absent.
pub const DEFAULT_TRACE_CAPACITY: u32 = 65536;

/// Width of one cycle bucket in the time-series reduction
/// ([`CellTrace::timeline`]).
pub const TIMELINE_BUCKET_CYCLES: u64 = 1024;

/// Pseudo-CU id for device-wide events (kernel-launch begin/end) that
/// no single CU owns. Excluded from the per-CU counter matrix.
pub const DEVICE_CU: u32 = u32::MAX;

/// The traced moments — the events the paper's argument is made of.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// wg-scope acquire (protocol-independent dispatch point).
    WgAcquire,
    /// wg-scope release.
    WgRelease,
    /// cmp-scope acquire (shared core, protocol-independent).
    CmpAcquire,
    /// cmp-scope release.
    CmpRelease,
    /// Remote-scope acquire promotion request.
    RemoteAcquire,
    /// Remote-scope release promotion request.
    RemoteRelease,
    /// Remote-scope acquire+release promotion request.
    RemoteAcqRel,
    /// wg acquire promoted to global scope by a PA-TBL hit (sRSP §4).
    Promotion,
    /// wg acquire that stayed on the local fast path (PA-TBL miss).
    LocalAcquire,
    /// Selective-flush broadcast issued by a remote acquire.
    SelFlushRequest,
    /// Selective-flush answered immediately at a target (LR-TBL miss).
    SelFlushNop,
    /// Selective-flush that drained a target's sFIFO (LR-TBL hit).
    SelFlushDrain,
    /// Selective-invalidate broadcast issued by a pure remote release.
    SelInvRequest,
    /// LR-TBL insertion (detail: address recorded).
    LrInsert,
    /// LR-TBL sticky overflow.
    LrOverflow,
    /// PA-TBL insertion at a target CU.
    PaInsert,
    /// PA-TBL overflow at a target CU (conservative eager invalidate).
    PaOverflow,
    /// Full L1 flush (sFIFO drain; detail: lines pending).
    L1Flush,
    /// L1 flash invalidate (detail: valid lines discarded).
    L1Invalidate,
    /// srsp-adaptive fell back to eager all-L1 invalidation.
    AdaptiveEager,
    /// srsp-adaptive stayed on the selective path.
    AdaptiveSelective,
    /// hLRC wg op on the registered owner's fast path.
    HlrcLocal,
    /// hLRC ownership transfer (flush previous owner, invalidate next).
    HlrcTransfer,
    /// hLRC registry eviction (capacity pressure).
    HlrcEvict,
    /// Kernel launch began (device-wide, cu = [`DEVICE_CU`]).
    LaunchBegin,
    /// Kernel launch ended at the end barrier (device-wide).
    LaunchEnd,
}

impl TraceKind {
    /// Every kind, in stable serialization order.
    pub const ALL: [TraceKind; 26] = [
        TraceKind::WgAcquire,
        TraceKind::WgRelease,
        TraceKind::CmpAcquire,
        TraceKind::CmpRelease,
        TraceKind::RemoteAcquire,
        TraceKind::RemoteRelease,
        TraceKind::RemoteAcqRel,
        TraceKind::Promotion,
        TraceKind::LocalAcquire,
        TraceKind::SelFlushRequest,
        TraceKind::SelFlushNop,
        TraceKind::SelFlushDrain,
        TraceKind::SelInvRequest,
        TraceKind::LrInsert,
        TraceKind::LrOverflow,
        TraceKind::PaInsert,
        TraceKind::PaOverflow,
        TraceKind::L1Flush,
        TraceKind::L1Invalidate,
        TraceKind::AdaptiveEager,
        TraceKind::AdaptiveSelective,
        TraceKind::HlrcLocal,
        TraceKind::HlrcTransfer,
        TraceKind::HlrcEvict,
        TraceKind::LaunchBegin,
        TraceKind::LaunchEnd,
    ];

    /// Number of kinds (the width of the per-CU counter matrix).
    pub const COUNT: usize = TraceKind::ALL.len();

    /// Stable wire name (JSONL `kind` field, Perfetto event name).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::WgAcquire => "wg_acquire",
            TraceKind::WgRelease => "wg_release",
            TraceKind::CmpAcquire => "cmp_acquire",
            TraceKind::CmpRelease => "cmp_release",
            TraceKind::RemoteAcquire => "remote_acquire",
            TraceKind::RemoteRelease => "remote_release",
            TraceKind::RemoteAcqRel => "remote_acqrel",
            TraceKind::Promotion => "promotion",
            TraceKind::LocalAcquire => "local_acquire",
            TraceKind::SelFlushRequest => "sel_flush_request",
            TraceKind::SelFlushNop => "sel_flush_nop",
            TraceKind::SelFlushDrain => "sel_flush_drain",
            TraceKind::SelInvRequest => "sel_inv_request",
            TraceKind::LrInsert => "lr_insert",
            TraceKind::LrOverflow => "lr_overflow",
            TraceKind::PaInsert => "pa_insert",
            TraceKind::PaOverflow => "pa_overflow",
            TraceKind::L1Flush => "l1_flush",
            TraceKind::L1Invalidate => "l1_invalidate",
            TraceKind::AdaptiveEager => "adaptive_eager",
            TraceKind::AdaptiveSelective => "adaptive_selective",
            TraceKind::HlrcLocal => "hlrc_local",
            TraceKind::HlrcTransfer => "hlrc_transfer",
            TraceKind::HlrcEvict => "hlrc_evict",
            TraceKind::LaunchBegin => "launch_begin",
            TraceKind::LaunchEnd => "launch_end",
        }
    }

    /// Resolve a wire name back to its kind.
    pub fn from_name(s: &str) -> Option<TraceKind> {
        TraceKind::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// Index into the per-CU counter matrix (= position in [`Self::ALL`]).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// One recorded sync event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub cycle: Cycle,
    /// CU the event happened *at* (the target of a selective-flush nop,
    /// not its requester); [`DEVICE_CU`] for device-wide events.
    pub cu: u32,
    /// Work-group whose instruction caused the event (the requester even
    /// for events landing at another CU).
    pub wg: u32,
    pub kind: TraceKind,
    /// The synchronized address / cache line, 0 where not applicable.
    pub addr: u64,
    /// Kind-specific payload (lines drained, target CU, ...), else 0.
    pub detail: u64,
}

/// The bounded event collector living on each cell's memory system.
///
/// Disabled (capacity 0) it is a single predictable branch per hook;
/// enabled it records into the ring and the exact per-CU counters. It
/// never touches [`Stats`](crate::sim::Stats) or any timing state —
/// observe-only is the invariant the determinism tests pin.
#[derive(Debug, Clone, Default)]
pub struct TraceSink {
    enabled: bool,
    capacity: usize,
    ring: Vec<TraceEvent>,
    /// Oldest slot once the ring has wrapped.
    head: usize,
    /// Events overwritten after the ring filled (loud, never silent).
    dropped: u64,
    /// Work-group the device event loop is currently stepping; stamps
    /// every emitted event (set via [`TraceSink::set_wg`]).
    cur_wg: u32,
    /// Exact per-CU × per-kind counters; immune to ring overflow.
    per_cu: Vec<[u64; TraceKind::COUNT]>,
}

impl TraceSink {
    /// A sink for `num_cus` CUs; `capacity == 0` disables tracing.
    pub fn new(capacity: u32, num_cus: u32) -> TraceSink {
        let enabled = capacity > 0;
        TraceSink {
            enabled,
            capacity: capacity as usize,
            ring: Vec::with_capacity(if enabled { capacity as usize } else { 0 }),
            head: 0,
            dropped: 0,
            cur_wg: 0,
            per_cu: if enabled {
                vec![[0; TraceKind::COUNT]; num_cus as usize]
            } else {
                Vec::new()
            },
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Stamp the work-group whose instruction is about to execute.
    #[inline]
    pub fn set_wg(&mut self, wg: u32) {
        if self.enabled {
            self.cur_wg = wg;
        }
    }

    /// Record one event (no-op while disabled).
    #[inline]
    pub fn emit(&mut self, cycle: Cycle, cu: u32, kind: TraceKind, addr: u64, detail: u64) {
        if !self.enabled {
            return;
        }
        self.record(cycle, cu, kind, addr, detail);
    }

    fn record(&mut self, cycle: Cycle, cu: u32, kind: TraceKind, addr: u64, detail: u64) {
        if let Some(row) = self.per_cu.get_mut(cu as usize) {
            row[kind.index()] += 1;
        }
        let ev = TraceEvent {
            cycle,
            cu,
            wg: self.cur_wg,
            kind,
            addr,
            detail,
        };
        if self.ring.len() < self.capacity {
            self.ring.push(ev);
        } else {
            // Overwrite the oldest event; the drop is counted, and the
            // exporters surface it as a loud `truncated: true`.
            self.ring[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
            self.dropped += 1;
        }
    }

    /// Take the collected trace as an immutable per-cell snapshot
    /// (chronological event order), resetting the sink for reuse.
    /// `None` while disabled — callers distinguish "tracing off" from
    /// "traced but empty".
    pub fn take_cell(&mut self) -> Option<Box<CellTrace>> {
        if !self.enabled {
            return None;
        }
        let mut events = Vec::with_capacity(self.ring.len());
        events.extend_from_slice(&self.ring[self.head..]);
        events.extend_from_slice(&self.ring[..self.head]);
        let cell = CellTrace {
            capacity: self.capacity as u64,
            dropped: self.dropped,
            events,
            per_cu: std::mem::replace(
                &mut self.per_cu,
                vec![[0; TraceKind::COUNT]; self.per_cu.len()],
            ),
        };
        self.ring.clear();
        self.head = 0;
        self.dropped = 0;
        Some(Box::new(cell))
    }
}

/// One run's finished trace: the ring contents in chronological order
/// plus the exact per-CU counter matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellTrace {
    /// Ring capacity the run recorded under.
    pub capacity: u64,
    /// Events overwritten after the ring filled; `> 0` ⇒ truncated.
    pub dropped: u64,
    /// Ring contents, oldest first.
    pub events: Vec<TraceEvent>,
    /// Exact per-CU × per-kind counts (index = [`TraceKind::index`]).
    pub per_cu: Vec<[u64; TraceKind::COUNT]>,
}

impl CellTrace {
    /// Did the ring overflow (i.e. is `events` missing the oldest part)?
    pub fn truncated(&self) -> bool {
        self.dropped > 0
    }

    /// Total events per CU (row sums of the counter matrix).
    pub fn cu_totals(&self) -> Vec<u64> {
        self.per_cu.iter().map(|row| row.iter().sum()).collect()
    }

    /// The cycle-bucketed time series: `(bucket start cycle, events)`
    /// pairs ascending, buckets of [`TIMELINE_BUCKET_CYCLES`], computed
    /// over the (possibly truncated) ring contents. Empty buckets are
    /// omitted.
    pub fn timeline(&self) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = Vec::new();
        for ev in &self.events {
            let start = (ev.cycle / TIMELINE_BUCKET_CYCLES) * TIMELINE_BUCKET_CYCLES;
            match out.last_mut() {
                // Events are chronological, so buckets close in order.
                Some((s, n)) if *s == start => *n += 1,
                _ => out.push((start, 1)),
            }
        }
        out
    }

    /// Lossless JSON encoding (the worker trace-partial payload).
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::Obj(vec![
                    ("cycle".into(), Json::u64(e.cycle)),
                    ("cu".into(), Json::u32(e.cu)),
                    ("wg".into(), Json::u32(e.wg)),
                    ("kind".into(), Json::str(e.kind.name())),
                    ("addr".into(), Json::u64(e.addr)),
                    ("detail".into(), Json::u64(e.detail)),
                ])
            })
            .collect();
        // Counter rows are sparse-encoded (all-zero rows and zero cells
        // omitted); `cus` preserves the matrix height for the decoder.
        let mut per_cu = Vec::new();
        for (cu, row) in self.per_cu.iter().enumerate() {
            let counts: Vec<(String, Json)> = TraceKind::ALL
                .iter()
                .filter(|k| row[k.index()] > 0)
                .map(|k| (k.name().to_string(), Json::u64(row[k.index()])))
                .collect();
            if !counts.is_empty() {
                per_cu.push(Json::Obj(vec![
                    ("cu".into(), Json::usize(cu)),
                    ("counts".into(), Json::Obj(counts)),
                ]));
            }
        }
        Json::Obj(vec![
            ("capacity".into(), Json::u64(self.capacity)),
            ("dropped".into(), Json::u64(self.dropped)),
            ("truncated".into(), Json::Bool(self.truncated())),
            ("cus".into(), Json::usize(self.per_cu.len())),
            ("events".into(), Json::Arr(events)),
            ("per_cu".into(), Json::Arr(per_cu)),
        ])
    }

    /// Decode [`CellTrace::to_json`]; loud on malformation.
    pub fn from_json(v: &Json) -> Result<CellTrace, String> {
        let mut events = Vec::new();
        for (i, e) in v.get("events")?.arr()?.iter().enumerate() {
            let kind_name = e.get("kind")?.as_str()?;
            let kind = TraceKind::from_name(kind_name)
                .ok_or_else(|| format!("event {i}: unknown trace kind '{kind_name}'"))?;
            events.push(TraceEvent {
                cycle: e.get("cycle")?.as_u64()?,
                cu: e.get("cu")?.as_u32()?,
                wg: e.get("wg")?.as_u32()?,
                kind,
                addr: e.get("addr")?.as_u64()?,
                detail: e.get("detail")?.as_u64()?,
            });
        }
        let cus = v.get("cus")?.as_usize()?;
        let mut per_cu = vec![[0u64; TraceKind::COUNT]; cus];
        for row in v.get("per_cu")?.arr()? {
            let cu = row.get("cu")?.as_usize()?;
            let slot = per_cu
                .get_mut(cu)
                .ok_or_else(|| format!("per_cu row for CU {cu} outside the declared {cus}"))?;
            let Json::Obj(counts) = row.get("counts")? else {
                return Err(format!("per_cu row for CU {cu}: counts is not an object"));
            };
            for (name, val) in counts {
                let kind = TraceKind::from_name(name)
                    .ok_or_else(|| format!("CU {cu}: unknown trace kind '{name}'"))?;
                slot[kind.index()] = val.as_u64().map_err(|e| format!("CU {cu} {name}: {e}"))?;
            }
        }
        Ok(CellTrace {
            capacity: v.get("capacity")?.as_u64()?,
            dropped: v.get("dropped")?.as_u64()?,
            events,
            per_cu,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jsonio;

    fn ev(sink: &mut TraceSink, cycle: Cycle, cu: u32, kind: TraceKind) {
        sink.emit(cycle, cu, kind, 0x40, 1);
    }

    #[test]
    fn disabled_sink_records_nothing() {
        let mut s = TraceSink::new(0, 4);
        assert!(!s.enabled());
        ev(&mut s, 1, 0, TraceKind::WgAcquire);
        assert!(s.take_cell().is_none());
    }

    #[test]
    fn wire_names_are_unique_and_round_trip() {
        for (i, k) in TraceKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "{} out of order in ALL", k.name());
            assert_eq!(TraceKind::from_name(k.name()), Some(*k));
        }
        let mut names: Vec<&str> = TraceKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), TraceKind::COUNT, "duplicate wire name");
    }

    #[test]
    fn ring_overflow_is_counted_and_keeps_newest() {
        let mut s = TraceSink::new(3, 2);
        for c in 0..5u64 {
            ev(&mut s, c, 0, TraceKind::WgAcquire);
        }
        let t = s.take_cell().unwrap();
        assert!(t.truncated());
        assert_eq!(t.dropped, 2);
        let cycles: Vec<Cycle> = t.events.iter().map(|e| e.cycle).collect();
        assert_eq!(cycles, vec![2, 3, 4], "oldest overwritten, order kept");
        // The counter matrix is exact regardless of the overflow.
        assert_eq!(t.per_cu[0][TraceKind::WgAcquire.index()], 5);
    }

    #[test]
    fn per_cu_attribution_and_wg_stamp() {
        let mut s = TraceSink::new(8, 2);
        s.set_wg(7);
        ev(&mut s, 1, 0, TraceKind::LocalAcquire);
        ev(&mut s, 2, 1, TraceKind::SelFlushNop);
        s.emit(3, DEVICE_CU, TraceKind::LaunchEnd, 0, 0);
        let t = s.take_cell().unwrap();
        assert_eq!(t.events.len(), 3);
        assert!(t.events.iter().all(|e| e.wg == 7));
        assert_eq!(t.per_cu[0][TraceKind::LocalAcquire.index()], 1);
        assert_eq!(t.per_cu[1][TraceKind::SelFlushNop.index()], 1);
        // Device-wide events stay out of the per-CU matrix.
        assert_eq!(t.cu_totals(), vec![1, 1]);
    }

    #[test]
    fn take_cell_resets_for_reuse() {
        let mut s = TraceSink::new(4, 1);
        ev(&mut s, 9, 0, TraceKind::L1Flush);
        let first = s.take_cell().unwrap();
        assert_eq!(first.events.len(), 1);
        let second = s.take_cell().unwrap();
        assert!(second.events.is_empty());
        assert_eq!(second.dropped, 0);
        assert_eq!(second.per_cu[0][TraceKind::L1Flush.index()], 0);
    }

    #[test]
    fn timeline_buckets_close_in_order() {
        let mut s = TraceSink::new(16, 1);
        ev(&mut s, 10, 0, TraceKind::WgAcquire);
        ev(&mut s, 20, 0, TraceKind::WgAcquire);
        ev(&mut s, TIMELINE_BUCKET_CYCLES + 1, 0, TraceKind::WgRelease);
        let t = s.take_cell().unwrap();
        assert_eq!(t.timeline(), vec![(0, 2), (TIMELINE_BUCKET_CYCLES, 1)]);
    }

    #[test]
    fn cell_trace_json_round_trips() {
        let mut s = TraceSink::new(4, 3);
        s.set_wg(2);
        s.emit(5, 1, TraceKind::Promotion, 0x1234_5678_9abc_def0, 3);
        for c in 0..6u64 {
            ev(&mut s, c + 6, 0, TraceKind::LrInsert);
        }
        let t = *s.take_cell().unwrap();
        assert!(t.truncated());
        let text = t.to_json().render();
        assert!(text.contains("\"truncated\":true"));
        assert!(text.contains("\"kind\":\"promotion\""));
        let back = CellTrace::from_json(&jsonio::parse(&text).unwrap()).unwrap();
        assert_eq!(back, t);
        // CU 2 never emitted: sparse rows still rebuild the full matrix.
        assert_eq!(back.per_cu.len(), 3);
        assert_eq!(back.per_cu[2], [0; TraceKind::COUNT]);
    }
}
