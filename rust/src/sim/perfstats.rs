//! Host-side performance accounting for the simulator itself.
//!
//! Everything in [`crate::sim::stats`] counts *simulated* events — cache
//! hits, sync promotions, retired instructions. This module counts the
//! cost of producing them: wall time per launch, wall time spent inside
//! the compute engines (workload-side numerics), and the interpreter
//! switch between the frozen reference paths and the decode-once fast
//! paths. Splitting sim-cost from workload-cost is what lets the
//! `srsp bench` trend record say *where* a regression landed.
//!
//! Three pieces live here:
//!
//! * [`set_reference_paths`] / [`reference_paths`] — a process-wide
//!   switch selecting the original instruction-by-instruction
//!   interpreter and per-event allocations (the pre-optimization code,
//!   kept in-tree as the semantic reference) instead of the decoded fast
//!   paths. The byte-identity tests and `srsp bench --compare-reference`
//!   flip it; everything else runs the fast paths.
//! * [`PerfStats`] + the thread-local collector — per-launch host-side
//!   counters accumulated by [`crate::gpu::Device`], readable around any
//!   run without threading a handle through the driver/report layers
//!   (whose serialized output must stay byte-identical).
//! * The per-run [`Stats`] accessor API — [`record_compute`] /
//!   [`record_rounds`] on `Stats` and the exhaustive [`stat_pairs`]
//!   projection, so engines and benches stop poking counter fields
//!   directly and new counters cannot silently miss the bench emitter.
//!
//! [`record_compute`]: Stats::record_compute
//! [`record_rounds`]: Stats::record_rounds

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use super::stats::Stats;
use crate::kir::interp::{ComputeEngine, MemAccess};

/// When set, [`crate::gpu::Device`] interprets programs with the original
/// (pre-decode) `step` path and the memory system's original allocation
/// behaviour. Default off: the decoded fast paths run. The two must be
/// observationally identical — that equivalence is pinned by the
/// `hotpath_identity` integration test.
static REFERENCE_PATHS: AtomicBool = AtomicBool::new(false);

/// Select the reference interpreter paths (true) or the decoded fast
/// paths (false, the default). Process-wide: tests that flip it must not
/// run concurrently with other launches (the byte-identity test is a
/// single `#[test]` for exactly this reason).
pub fn set_reference_paths(on: bool) {
    REFERENCE_PATHS.store(on, Ordering::SeqCst);
}

/// Is the frozen reference interpreter selected?
pub fn reference_paths() -> bool {
    REFERENCE_PATHS.load(Ordering::SeqCst)
}

/// Host-side cost counters for one or more launches.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct PerfStats {
    /// Kernel launches measured.
    pub launches: u64,
    /// Scheduling events popped by the device event loops.
    pub events: u64,
    /// Wall nanoseconds inside `launch_with_init` (sim + workload cost).
    pub launch_nanos: u64,
    /// Wall nanoseconds inside compute-engine callbacks (workload cost).
    pub engine_nanos: u64,
    /// Result-cache cell lookups answered from the store (cells whose
    /// simulation was skipped entirely).
    pub cache_hits: u64,
    /// Result-cache cell lookups that fell through to a fresh run.
    pub cache_misses: u64,
    /// Workload presets rehydrated from the store instead of
    /// regenerated (graph builds skipped).
    pub preset_reuses: u64,
    /// Work-stealing sweep scheduler: cells a thread pulled from outside
    /// its static (contiguous-deal) share of the plan — the load
    /// imbalance the shared queue actually corrected.
    pub sched_steals: u64,
    /// Wall nanoseconds worker threads spent executing cells inside
    /// work-stealing sections.
    pub sched_busy_nanos: u64,
    /// Wall nanoseconds worker threads spent in a work-stealing section
    /// *not* executing cells (queue drained, waiting for the join).
    pub sched_idle_nanos: u64,
    /// Worker threads that participated in work-stealing sections.
    pub sched_threads: u64,
}

impl PerfStats {
    /// Simulator-attributed wall time: launch time minus the slice spent
    /// in workload numerics.
    pub fn sim_nanos(&self) -> u64 {
        self.launch_nanos.saturating_sub(self.engine_nanos)
    }

    /// Scheduler utilization: the fraction of worker-thread wall time
    /// spent executing cells. `None` until a work-stealing section ran.
    pub fn utilization(&self) -> Option<f64> {
        let total = self.sched_busy_nanos + self.sched_idle_nanos;
        (total > 0).then(|| self.sched_busy_nanos as f64 / total as f64)
    }

    pub fn merge(&mut self, other: &PerfStats) {
        let PerfStats {
            launches,
            events,
            launch_nanos,
            engine_nanos,
            cache_hits,
            cache_misses,
            preset_reuses,
            sched_steals,
            sched_busy_nanos,
            sched_idle_nanos,
            sched_threads,
        } = other;
        self.launches += launches;
        self.events += events;
        self.launch_nanos += launch_nanos;
        self.engine_nanos += engine_nanos;
        self.cache_hits += cache_hits;
        self.cache_misses += cache_misses;
        self.preset_reuses += preset_reuses;
        self.sched_steals += sched_steals;
        self.sched_busy_nanos += sched_busy_nanos;
        self.sched_idle_nanos += sched_idle_nanos;
        self.sched_threads += sched_threads;
    }
}

/// Fold scheduler counters into this thread's collector (the
/// work-stealing executor calls it once per parallel section, after the
/// join).
pub fn add_sched(steals: u64, busy_nanos: u64, idle_nanos: u64, threads: u64) {
    THREAD_PERF.with(|tp| {
        let mut p = tp.borrow_mut();
        p.sched_steals += steals;
        p.sched_busy_nanos += busy_nanos;
        p.sched_idle_nanos += idle_nanos;
        p.sched_threads += threads;
    });
}

thread_local! {
    /// Per-thread collector: devices add into it from `launch_with_init`,
    /// benches bracket a run with [`take_thread`] without any driver or
    /// report signature changing (their bytes are frozen by the identity
    /// gates).
    static THREAD_PERF: RefCell<PerfStats> = RefCell::new(PerfStats::default());
}

/// Accumulate `p` into this thread's collector.
pub fn add_thread(p: &PerfStats) {
    THREAD_PERF.with(|tp| tp.borrow_mut().merge(p));
}

/// Take (and reset) this thread's accumulated counters.
pub fn take_thread() -> PerfStats {
    THREAD_PERF.with(|tp| std::mem::take(&mut *tp.borrow_mut()))
}

/// Fold result-cache counters into this thread's collector (the cached
/// execution entry points call it once per run, after draining the
/// store's own tallies).
pub fn add_cache(hits: u64, misses: u64, preset_reuses: u64) {
    THREAD_PERF.with(|tp| {
        let mut p = tp.borrow_mut();
        p.cache_hits += hits;
        p.cache_misses += misses;
        p.preset_reuses += preset_reuses;
    });
}

/// A [`ComputeEngine`] wrapper that attributes wall time spent inside the
/// inner engine (the workload-cost side of the split).
pub struct TimedEngine<'a> {
    pub inner: &'a mut dyn ComputeEngine,
    pub nanos: u64,
}

impl<'a> TimedEngine<'a> {
    pub fn new(inner: &'a mut dyn ComputeEngine) -> Self {
        Self { inner, nanos: 0 }
    }
}

impl ComputeEngine for TimedEngine<'_> {
    fn compute(&mut self, mem: &mut MemAccess<'_>, kind: u32, arg: u64) -> u64 {
        let t0 = Instant::now();
        let items = self.inner.compute(mem, kind, arg);
        self.nanos += t0.elapsed().as_nanos() as u64;
        items
    }
}

impl Stats {
    /// Record one retired `Compute` instruction that processed `items`
    /// work-items (the accessor behind the interpreter and the engines;
    /// replaces direct `compute_ops`/`compute_items` field-poking).
    pub fn record_compute(&mut self, items: u64) {
        self.compute_ops += 1;
        self.compute_items += items;
    }

    /// Record the host-loop round count of a finished scenario run.
    pub fn record_rounds(&mut self, rounds: u64) {
        self.bump("rounds", rounds);
    }
}

/// Project every counter of a [`Stats`] block to `(name, value)` pairs,
/// fixed fields first (declaration order), then the named `misc`
/// counters. The full destructure (no `..`) is the drift guard: adding a
/// field to `Stats` without deciding how benches and perf tooling surface
/// it becomes a compile error here — the same pattern
/// `DeviceConfig::to_json` uses.
pub fn stat_pairs(s: &Stats) -> Vec<(&'static str, u64)> {
    let Stats {
        l1_hits,
        l1_misses,
        l1_writes,
        l1_writebacks,
        l1_flushes,
        l1_invalidates,
        lines_flushed,
        lines_invalidated,
        selective_flush_requests,
        selective_flush_nops,
        selective_flush_drains,
        selective_inv_requests,
        promoted_acquires,
        local_acquires,
        lr_tbl_insertions,
        lr_tbl_overflows,
        pa_tbl_insertions,
        pa_tbl_overflows,
        l2_accesses,
        l2_hits,
        l2_misses,
        l2_atomics,
        dram_reads,
        dram_writes,
        wg_acquires,
        wg_releases,
        cmp_acquires,
        cmp_releases,
        remote_acquires,
        remote_releases,
        remote_acqrels,
        sync_overhead_cycles,
        tasks_executed,
        tasks_stolen,
        steal_attempts,
        steal_failures,
        instructions,
        compute_ops,
        compute_items,
        cycles,
        misc,
    } = s;
    let mut pairs = vec![
        ("l1_hits", *l1_hits),
        ("l1_misses", *l1_misses),
        ("l1_writes", *l1_writes),
        ("l1_writebacks", *l1_writebacks),
        ("l1_flushes", *l1_flushes),
        ("l1_invalidates", *l1_invalidates),
        ("lines_flushed", *lines_flushed),
        ("lines_invalidated", *lines_invalidated),
        ("selective_flush_requests", *selective_flush_requests),
        ("selective_flush_nops", *selective_flush_nops),
        ("selective_flush_drains", *selective_flush_drains),
        ("selective_inv_requests", *selective_inv_requests),
        ("promoted_acquires", *promoted_acquires),
        ("local_acquires", *local_acquires),
        ("lr_tbl_insertions", *lr_tbl_insertions),
        ("lr_tbl_overflows", *lr_tbl_overflows),
        ("pa_tbl_insertions", *pa_tbl_insertions),
        ("pa_tbl_overflows", *pa_tbl_overflows),
        ("l2_accesses", *l2_accesses),
        ("l2_hits", *l2_hits),
        ("l2_misses", *l2_misses),
        ("l2_atomics", *l2_atomics),
        ("dram_reads", *dram_reads),
        ("dram_writes", *dram_writes),
        ("wg_acquires", *wg_acquires),
        ("wg_releases", *wg_releases),
        ("cmp_acquires", *cmp_acquires),
        ("cmp_releases", *cmp_releases),
        ("remote_acquires", *remote_acquires),
        ("remote_releases", *remote_releases),
        ("remote_acqrels", *remote_acqrels),
        ("sync_overhead_cycles", *sync_overhead_cycles),
        ("tasks_executed", *tasks_executed),
        ("tasks_stolen", *tasks_stolen),
        ("steal_attempts", *steal_attempts),
        ("steal_failures", *steal_failures),
        ("instructions", *instructions),
        ("compute_ops", *compute_ops),
        ("compute_items", *compute_items),
        ("cycles", *cycles),
    ];
    for (k, v) in misc {
        pairs.push((k, *v));
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_switch_round_trips() {
        assert!(!reference_paths(), "fast paths are the default");
        set_reference_paths(true);
        assert!(reference_paths());
        set_reference_paths(false);
        assert!(!reference_paths());
    }

    #[test]
    fn perf_merge_and_attribution() {
        let mut a = PerfStats {
            launches: 1,
            events: 10,
            launch_nanos: 100,
            engine_nanos: 30,
            cache_hits: 4,
            cache_misses: 2,
            preset_reuses: 1,
            sched_steals: 2,
            sched_busy_nanos: 60,
            sched_idle_nanos: 20,
            sched_threads: 4,
        };
        let b = PerfStats {
            launches: 2,
            events: 5,
            launch_nanos: 50,
            engine_nanos: 20,
            cache_hits: 1,
            cache_misses: 3,
            preset_reuses: 2,
            sched_steals: 1,
            sched_busy_nanos: 20,
            sched_idle_nanos: 0,
            sched_threads: 4,
        };
        a.merge(&b);
        assert_eq!(a.launches, 3);
        assert_eq!(a.events, 15);
        assert_eq!(a.sim_nanos(), 150 - 50);
        assert_eq!(a.cache_hits, 5);
        assert_eq!(a.cache_misses, 5);
        assert_eq!(a.preset_reuses, 3);
        assert_eq!(a.sched_steals, 3);
        assert_eq!(a.utilization(), Some(80.0 / 100.0));
        assert_eq!(PerfStats::default().utilization(), None);
    }

    #[test]
    fn thread_collector_takes_and_resets() {
        let _ = take_thread(); // isolate from other tests on this thread
        add_thread(&PerfStats {
            launches: 1,
            events: 7,
            launch_nanos: 9,
            engine_nanos: 2,
            ..PerfStats::default()
        });
        add_cache(5, 1, 2);
        add_sched(3, 40, 10, 4);
        let got = take_thread();
        assert_eq!(got.events, 7);
        assert_eq!(got.cache_hits, 5);
        assert_eq!(got.cache_misses, 1);
        assert_eq!(got.preset_reuses, 2);
        assert_eq!(got.sched_steals, 3);
        assert_eq!(got.sched_busy_nanos, 40);
        assert_eq!(got.sched_idle_nanos, 10);
        assert_eq!(got.sched_threads, 4);
        assert_eq!(take_thread(), PerfStats::default());
    }

    #[test]
    fn record_accessors_hit_the_right_counters() {
        let mut s = Stats::new();
        s.record_compute(5);
        s.record_compute(0);
        s.record_rounds(3);
        assert_eq!(s.compute_ops, 2);
        assert_eq!(s.compute_items, 5);
        assert_eq!(s.misc["rounds"], 3);
    }

    #[test]
    fn stat_pairs_exhaustive_and_ordered() {
        let mut s = Stats::new();
        s.l1_hits = 4;
        s.cycles = 99;
        s.bump("rounds", 2);
        let pairs = stat_pairs(&s);
        assert_eq!(pairs[0], ("l1_hits", 4));
        assert_eq!(
            pairs.iter().find(|(k, _)| *k == "cycles"),
            Some(&("cycles", 99))
        );
        // misc counters ride at the end, after every fixed field.
        assert_eq!(pairs.last(), Some(&("rounds", 2)));
        // 40 fixed counters + cycles handled above; a drift in the count
        // means a Stats field changed without updating the projection.
        assert_eq!(pairs.len(), 40 + 1);
    }
}
