//! Simulation statistics: the counters behind the paper's figures.
//!
//! Hot counters (cache hits/misses, L2 accesses) are plain struct fields —
//! they are bumped on every simulated memory operation. Rarer, named
//! counters go through the `misc` map.

use std::collections::BTreeMap;
use std::fmt;

/// All counters collected during one kernel run / scenario execution.
///
/// `l2_accesses` is the paper's bandwidth-utilization proxy (Fig. 5);
/// `sync_overhead_cycles` is the promotion-cost metric behind Fig. 6.
#[derive(Debug, Default, Clone)]
pub struct Stats {
    // --- L1 (summed over all CUs) ---
    pub l1_hits: u64,
    pub l1_misses: u64,
    pub l1_writes: u64,
    pub l1_writebacks: u64,
    /// Full cache-flush operations (drain entire sFIFO).
    pub l1_flushes: u64,
    /// Flash-invalidate operations.
    pub l1_invalidates: u64,
    /// Dirty lines written back by flush/selective-flush drains.
    pub lines_flushed: u64,
    /// Valid lines discarded by invalidates (locality destroyed).
    pub lines_invalidated: u64,

    // --- Selective (sRSP) operations ---
    pub selective_flush_requests: u64,
    /// Selective-flush requests answered immediately (LR-TBL miss).
    pub selective_flush_nops: u64,
    /// Selective-flush requests that drained (LR-TBL hit).
    pub selective_flush_drains: u64,
    pub selective_inv_requests: u64,
    /// wg-scope acquires promoted to global scope by a PA-TBL hit.
    pub promoted_acquires: u64,
    /// wg-scope acquires that stayed local (PA-TBL miss).
    pub local_acquires: u64,
    pub lr_tbl_insertions: u64,
    pub lr_tbl_overflows: u64,
    pub pa_tbl_insertions: u64,
    pub pa_tbl_overflows: u64,

    // --- L2 / DRAM ---
    /// Total L2 accesses (reads + writes + atomics): the Fig. 5 metric.
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub l2_atomics: u64,
    pub dram_reads: u64,
    pub dram_writes: u64,

    // --- Synchronization operations ---
    pub wg_acquires: u64,
    pub wg_releases: u64,
    pub cmp_acquires: u64,
    pub cmp_releases: u64,
    pub remote_acquires: u64,
    pub remote_releases: u64,
    pub remote_acqrels: u64,
    /// Cycles spent inside synchronization operations (the Fig. 6 metric):
    /// everything beyond a plain L1-latency access for an op that carries
    /// acquire/release semantics or remote promotion.
    pub sync_overhead_cycles: u64,

    // --- Work stealing ---
    pub tasks_executed: u64,
    pub tasks_stolen: u64,
    pub steal_attempts: u64,
    pub steal_failures: u64,

    // --- Execution ---
    pub instructions: u64,
    pub compute_ops: u64,
    pub compute_items: u64,
    /// Final cycle of the kernel (the performance metric of Fig. 4).
    pub cycles: u64,

    /// Rare named counters.
    pub misc: BTreeMap<&'static str, u64>,
}

impl Stats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bump a named counter.
    pub fn bump(&mut self, name: &'static str, by: u64) {
        *self.misc.entry(name).or_insert(0) += by;
    }

    /// L1 hit rate over reads.
    pub fn l1_hit_rate(&self) -> f64 {
        let total = self.l1_hits + self.l1_misses;
        if total == 0 {
            0.0
        } else {
            self.l1_hits as f64 / total as f64
        }
    }

    /// Merge another stats block into this one (cycles take the max: they
    /// are end-times, not sums).
    pub fn merge(&mut self, other: &Stats) {
        macro_rules! add {
            ($($f:ident),*) => { $( self.$f += other.$f; )* };
        }
        add!(
            l1_hits, l1_misses, l1_writes, l1_writebacks, l1_flushes, l1_invalidates,
            lines_flushed, lines_invalidated, selective_flush_requests,
            selective_flush_nops, selective_flush_drains, selective_inv_requests,
            promoted_acquires, local_acquires, lr_tbl_insertions, lr_tbl_overflows,
            pa_tbl_insertions, pa_tbl_overflows, l2_accesses, l2_hits, l2_misses,
            l2_atomics, dram_reads, dram_writes, wg_acquires, wg_releases,
            cmp_acquires, cmp_releases, remote_acquires, remote_releases,
            remote_acqrels, sync_overhead_cycles, tasks_executed, tasks_stolen,
            steal_attempts, steal_failures, instructions, compute_ops, compute_items
        );
        self.cycles = self.cycles.max(other.cycles);
        for (k, v) in &other.misc {
            *self.misc.entry(k).or_insert(0) += v;
        }
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "cycles                 {:>14}", self.cycles)?;
        writeln!(f, "instructions           {:>14}", self.instructions)?;
        writeln!(
            f,
            "L1  hits/misses        {:>14}/{} (hit rate {:.1}%)",
            self.l1_hits,
            self.l1_misses,
            100.0 * self.l1_hit_rate()
        )?;
        writeln!(f, "L1  writebacks         {:>14}", self.l1_writebacks)?;
        writeln!(
            f,
            "L1  flushes/invalidates{:>14}/{}",
            self.l1_flushes, self.l1_invalidates
        )?;
        writeln!(
            f,
            "    lines flushed/inv  {:>14}/{}",
            self.lines_flushed, self.lines_invalidated
        )?;
        writeln!(f, "L2  accesses           {:>14}", self.l2_accesses)?;
        writeln!(
            f,
            "L2  hits/misses/atomics{:>14}/{}/{}",
            self.l2_hits, self.l2_misses, self.l2_atomics
        )?;
        writeln!(
            f,
            "DRAM reads/writes      {:>14}/{}",
            self.dram_reads, self.dram_writes
        )?;
        writeln!(
            f,
            "sync wg acq/rel        {:>14}/{}",
            self.wg_acquires, self.wg_releases
        )?;
        writeln!(
            f,
            "sync cmp acq/rel       {:>14}/{}",
            self.cmp_acquires, self.cmp_releases
        )?;
        writeln!(
            f,
            "sync remote acq/rel/ar {:>14}/{}/{}",
            self.remote_acquires, self.remote_releases, self.remote_acqrels
        )?;
        writeln!(
            f,
            "sync overhead cycles   {:>14}",
            self.sync_overhead_cycles
        )?;
        writeln!(
            f,
            "promoted/local acq     {:>14}/{}",
            self.promoted_acquires, self.local_acquires
        )?;
        writeln!(
            f,
            "sel flush req/nop/drain{:>14}/{}/{}",
            self.selective_flush_requests,
            self.selective_flush_nops,
            self.selective_flush_drains
        )?;
        writeln!(
            f,
            "tasks exec/stolen      {:>14}/{}",
            self.tasks_executed, self.tasks_stolen
        )?;
        writeln!(
            f,
            "steal attempts/failures{:>14}/{}",
            self.steal_attempts, self.steal_failures
        )?;
        for (k, v) in &self.misc {
            writeln!(f, "{k:<23}{v:>14}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_and_merge() {
        let mut a = Stats::new();
        a.l1_hits = 10;
        a.cycles = 100;
        a.bump("x", 3);
        let mut b = Stats::new();
        b.l1_hits = 5;
        b.cycles = 250;
        b.bump("x", 2);
        b.bump("y", 1);
        a.merge(&b);
        assert_eq!(a.l1_hits, 15);
        assert_eq!(a.cycles, 250); // max, not sum
        assert_eq!(a.misc["x"], 5);
        assert_eq!(a.misc["y"], 1);
    }

    #[test]
    fn hit_rate_guards_div0() {
        let s = Stats::new();
        assert_eq!(s.l1_hit_rate(), 0.0);
    }

    #[test]
    fn display_smoke() {
        let mut s = Stats::new();
        s.l1_hits = 1;
        s.bump("custom", 7);
        let text = format!("{s}");
        assert!(text.contains("custom"));
        assert!(text.contains("L2  accesses"));
    }
}
