//! PJRT bridge: HLO-text artifacts → compiled executables → `TileMath`.
//!
//! Loading follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. The artifacts were lowered with
//! `return_tuple=True`, so every result is a 1-tuple.
//!
//! The tile contract (ROWS×K) must match the Python side
//! (`python/compile/kernels/ref.py`) — checked against `manifest.json`
//! at load time.

use crate::workload::engine::{TileMath, K_TILE};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Tile rows per executable invocation (the AOT-lowered batch height).
pub const ROWS: usize = 256;

/// Compiled artifacts, ready to execute.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pagerank: xla::PjRtLoadedExecutable,
    sssp: xla::PjRtLoadedExecutable,
    mis: xla::PjRtLoadedExecutable,
    /// Executions performed (diagnostics).
    pub calls: u64,
}

impl PjrtRuntime {
    /// Load and compile all artifacts from `dir` (usually `artifacts/`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        if manifest_path.exists() {
            let manifest = std::fs::read_to_string(&manifest_path)?;
            // Minimal manifest validation without a JSON dep: the tile
            // contract constants must appear verbatim.
            if !manifest.contains(&format!("\"rows\": {ROWS}"))
                || !manifest.contains(&format!("\"k\": {K_TILE}"))
            {
                bail!(
                    "artifact tile contract mismatch: expected rows={ROWS} k={K_TILE}; \
                     re-run `make artifacts`"
                );
            }
        }
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let load = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parsing {path:?} (run `make artifacts`?)"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))
        };
        Ok(Self {
            pagerank: load("pagerank")?,
            sssp: load("sssp")?,
            mis: load("mis")?,
            client,
            calls: 0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Run the PageRank step on one padded tile.
    fn run_pagerank(&mut self, contribs: &[f32], damping: f32, inv_n: f32) -> Result<Vec<f32>> {
        debug_assert_eq!(contribs.len(), ROWS * K_TILE);
        self.calls += 1;
        let c = xla::Literal::vec1(contribs).reshape(&[ROWS as i64, K_TILE as i64])?;
        let d = xla::Literal::vec1(&[damping]);
        let n = xla::Literal::vec1(&[inv_n]);
        let result = self.pagerank.execute::<xla::Literal>(&[c, d, n])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(result.to_vec::<f32>()?)
    }

    fn run_sssp(&mut self, tile: &[i32]) -> Result<Vec<i32>> {
        debug_assert_eq!(tile.len(), ROWS * K_TILE);
        self.calls += 1;
        let t = xla::Literal::vec1(tile).reshape(&[ROWS as i64, K_TILE as i64])?;
        let result = self.sssp.execute::<xla::Literal>(&[t])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(result.to_vec::<i32>()?)
    }

    fn run_mis(&mut self, my_pri: &[u32], nbr_pri: &[u32]) -> Result<Vec<u32>> {
        debug_assert_eq!(my_pri.len(), ROWS);
        debug_assert_eq!(nbr_pri.len(), ROWS * K_TILE);
        self.calls += 1;
        let m = xla::Literal::vec1(my_pri);
        let n = xla::Literal::vec1(nbr_pri).reshape(&[ROWS as i64, K_TILE as i64])?;
        let result = self.mis.execute::<xla::Literal>(&[m, n])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(result.to_vec::<u32>()?)
    }
}

/// [`TileMath`] backend over the PJRT executables. Variable-row requests
/// are padded to the fixed ROWS batch (padding conventions per
/// `kernels/ref.py`); oversized requests are split into multiple calls.
pub struct PjrtMath {
    pub rt: PjrtRuntime,
}

impl PjrtMath {
    pub fn new(rt: PjrtRuntime) -> Self {
        Self { rt }
    }

    /// Convenience: load from the default `artifacts/` directory.
    pub fn from_artifacts(dir: &Path) -> Result<Self> {
        Ok(Self::new(PjrtRuntime::load(dir)?))
    }
}

impl TileMath for PjrtMath {
    fn pagerank_rows(&mut self, contribs: &[f32], rows: usize, damping: f32, n: u32) -> Vec<f32> {
        assert_eq!(contribs.len(), rows * K_TILE);
        let inv_n = 1.0 / n as f32;
        let mut out = Vec::with_capacity(rows);
        for chunk in contribs.chunks(ROWS * K_TILE) {
            let valid = chunk.len() / K_TILE;
            let mut padded = vec![0f32; ROWS * K_TILE];
            padded[..chunk.len()].copy_from_slice(chunk);
            let r = self
                .rt
                .run_pagerank(&padded, damping, inv_n)
                .expect("pagerank artifact execution");
            out.extend_from_slice(&r[..valid]);
        }
        out
    }

    fn sssp_rows(&mut self, dist_plus_w: &[i32], rows: usize) -> Vec<i32> {
        assert_eq!(dist_plus_w.len(), rows * K_TILE);
        let mut out = Vec::with_capacity(rows);
        for chunk in dist_plus_w.chunks(ROWS * K_TILE) {
            let valid = chunk.len() / K_TILE;
            let mut padded = vec![i32::MAX; ROWS * K_TILE];
            padded[..chunk.len()].copy_from_slice(chunk);
            let r = self.rt.run_sssp(&padded).expect("sssp artifact execution");
            out.extend_from_slice(&r[..valid]);
        }
        out
    }

    fn mis_rows(&mut self, my_pri: &[u32], nbr_pri: &[u32], rows: usize) -> Vec<bool> {
        assert_eq!(my_pri.len(), rows);
        assert_eq!(nbr_pri.len(), rows * K_TILE);
        let mut out = Vec::with_capacity(rows);
        for (mp, np) in my_pri.chunks(ROWS).zip(nbr_pri.chunks(ROWS * K_TILE)) {
            let valid = mp.len();
            let mut pm = vec![0u32; ROWS];
            pm[..valid].copy_from_slice(mp);
            // Padded rows: my_pri 0 vs all-zero neighbors -> 0 > 0 false.
            let mut pn = vec![0u32; ROWS * K_TILE];
            pn[..np.len()].copy_from_slice(np);
            let r = self.rt.run_mis(&pm, &pn).expect("mis artifact execution");
            out.extend(r[..valid].iter().map(|&x| x != 0));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::engine::NativeMath;
    use crate::sim::SplitMix64;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn load() -> Option<PjrtMath> {
        let dir = artifacts_dir();
        if !dir.join("pagerank.hlo.txt").exists() {
            eprintln!("skipping PJRT test: run `make artifacts` first");
            return None;
        }
        Some(PjrtMath::from_artifacts(&dir).expect("load artifacts"))
    }

    #[test]
    fn pjrt_matches_native_pagerank() {
        let Some(mut pjrt) = load() else { return };
        let mut native = NativeMath;
        let mut rng = SplitMix64::new(1);
        for rows in [1usize, 7, 256, 300] {
            let contribs: Vec<f32> = (0..rows * K_TILE)
                .map(|_| (rng.f64() as f32) * 0.01)
                .collect();
            let a = pjrt.pagerank_rows(&contribs, rows, 0.85, 4096);
            let b = native.pagerank_rows(&contribs, rows, 0.85, 4096);
            assert_eq!(a.len(), rows);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn pjrt_matches_native_sssp_exact() {
        let Some(mut pjrt) = load() else { return };
        let mut native = NativeMath;
        let mut rng = SplitMix64::new(2);
        for rows in [1usize, 255, 257] {
            let tile: Vec<i32> = (0..rows * K_TILE)
                .map(|_| rng.below(0x3FFF_FFFF) as i32)
                .collect();
            assert_eq!(
                pjrt.sssp_rows(&tile, rows),
                native.sssp_rows(&tile, rows),
                "rows={rows}"
            );
        }
    }

    #[test]
    fn pjrt_matches_native_mis_exact_unsigned() {
        let Some(mut pjrt) = load() else { return };
        let mut native = NativeMath;
        let mut rng = SplitMix64::new(3);
        let rows = 300usize;
        // Full u32 range: catches signed-comparison bugs.
        let my: Vec<u32> = (0..rows).map(|_| rng.next_u32()).collect();
        let nbr: Vec<u32> = (0..rows * K_TILE).map(|_| rng.next_u32()).collect();
        assert_eq!(pjrt.mis_rows(&my, &nbr, rows), native.mis_rows(&my, &nbr, rows));
    }

    #[test]
    fn tile_contract_mismatch_detected() {
        let dir = std::env::temp_dir().join("srsp_bad_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"rows\": 1, \"k\": 1}").unwrap();
        let err = match PjrtRuntime::load(&dir) {
            Ok(_) => panic!("mismatched manifest must be rejected"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("tile contract"));
    }
}
