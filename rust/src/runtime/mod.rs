//! The PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and serves
//! them as the simulator's [`TileMath`](crate::workload::TileMath)
//! backend. Python never runs here — the HLO text is compiled by the
//! `xla` crate's PJRT CPU client and executed natively.

pub mod pjrt;

pub use pjrt::{PjrtMath, PjrtRuntime};
