//! `srsp-adaptive`: sRSP with an eager-invalidation fallback under
//! LR-TBL pressure — the paper's §4 monitoring idea taken one step
//! further, and the proof that the protocol axis is open: this protocol
//! is a pure registry entry (one file + one [`PROTOCOLS`] line), landed
//! without touching the engine, config, coordinator, harness or CLI.
//!
//! **Rationale.** sRSP's selective flush wins exactly when LR-TBL
//! lookups are *precise*: a miss is a one-cycle nop ack, a hit drains
//! one sFIFO prefix. Once a table sticky-overflows, every lookup answers
//! conservatively (`drain everything`) and each remote acquire pays a
//! full drain *plus* the PA-TBL arming — strictly more work than naive
//! RSP's flash invalidate of the same cache. This protocol monitors the
//! device-wide remote-acquire pressure through the LR-TBL overflow rate
//! (`lr_tbl_overflows / lr_tbl_insertions`, both already maintained by
//! the shared core) and, past a tunable threshold, falls back from the
//! selective-flush broadcast to naive RSP's eager all-L1 invalidation
//! for the acquire side of remote ops.
//!
//! Correctness is free in both modes: the eager broadcast is a strict
//! superset of the selective obligations (invalidating an L1 drains its
//! sFIFO and clears both tables, so the local sharer's next access
//! misses to the L2 and reads fresh). Pure releases (`rem_rel`) stay on
//! sRSP's selective-invalidate path even under pressure; a combined
//! `rem_ar` past the threshold delegates wholesale to the naive
//! promotion, so its release side goes eager too (it already paid the
//! all-L1 invalidate — arming PA-TBLs on top would be redundant work).
//! The decision input is deterministic simulator state, so runs replay
//! byte-identically.
//!
//! [`PROTOCOLS`]: super::protocol::PROTOCOLS

use super::ops::{SyncOp, SyncOutcome};
use super::protocol::SyncProtocol;
use super::{rsp_naive, srsp};
use crate::mem::MemSystem;
use crate::params::ParamSpec;
use crate::sim::TraceKind;

/// Default LR-TBL overflow rate above which remote acquires go eager.
pub const DEFAULT_OVERFLOW_THRESHOLD: f64 = 0.25;

/// Registry entry for the adaptive sRSP variant.
pub struct SrspAdaptive;

static PARAMS: [ParamSpec; 3] = [
    srsp::TABLE_PARAMS[0],
    srsp::TABLE_PARAMS[1],
    ParamSpec {
        key: "overflow_threshold",
        default: DEFAULT_OVERFLOW_THRESHOLD,
        help: "LR-TBL overflow rate beyond which remote acquires invalidate eagerly",
    },
];

impl SyncProtocol for SrspAdaptive {
    fn name(&self) -> &'static str {
        "srsp-adaptive"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["adaptive", "srsp_adaptive"]
    }

    fn summary(&self) -> &'static str {
        "sRSP that falls back to eager invalidation under LR-TBL overflow pressure"
    }

    fn params(&self) -> &'static [ParamSpec] {
        &PARAMS
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        // Identical to sRSP: PA-TBL promotion check, LR-TBL recording.
        srsp::wg(m, s)
    }

    fn remote_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        // Monitor: fraction of LR-TBL insertions that displaced an entry.
        // Above the threshold the tables are thrashing, so selective
        // flushes have degenerated to conservative full drains — eager
        // invalidation is cheaper and equally correct.
        let insertions = m.stats.lr_tbl_insertions;
        let overflows = m.stats.lr_tbl_overflows;
        let threshold = m
            .proto_params
            .get_or("overflow_threshold", DEFAULT_OVERFLOW_THRESHOLD);
        let thrashing = insertions > 0 && overflows as f64 > threshold * insertions as f64;
        if thrashing && s.order.acquires() {
            m.stats.bump("adaptive_eager_promotions", 1);
            m.trace.emit(s.at, s.cu, TraceKind::AdaptiveEager, s.addr, 0);
            return rsp_naive::remote(m, s);
        }
        if s.order.acquires() {
            m.stats.bump("adaptive_selective_promotions", 1);
            m.trace.emit(s.at, s.cu, TraceKind::AdaptiveSelective, s.addr, 0);
        }
        srsp::remote(m, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, Protocol};
    use crate::mem::MemSystem;
    use crate::sync::engine::{remote_op, sync_op};
    use crate::sync::{AtomicOp, MemOrder, Scope};

    const LOCK: u64 = 0x1000;
    const LOCK2: u64 = 0x3000;
    const DATA: u64 = 0x2000;

    fn sys_with_lr(lr: u32) -> MemSystem {
        MemSystem::new(DeviceConfig {
            lr_tbl_entries: lr,
            ..DeviceConfig::small()
        })
    }

    /// wg-scope release on `cu` guarding `data`.
    fn release(m: &mut MemSystem, cu: u32, lock: u64, data: u64, v: u32, t: u64) -> u64 {
        let t = m.l1_write(cu, data, 4, v as u64, t);
        sync_op(
            m,
            Protocol::SRSP_ADAPTIVE,
            cu,
            lock,
            AtomicOp::Store,
            MemOrder::Release,
            Scope::Wg,
            1,
            0,
            t,
        )
        .done
    }

    #[test]
    fn healthy_tables_stay_selective_and_match_srsp() {
        // Roomy tables: no overflow pressure, so the adaptive protocol
        // must take exactly sRSP's selective path (same counters, same
        // correctness).
        let mut m = sys_with_lr(16);
        let t = release(&mut m, 0, LOCK, DATA, 41, 0);
        let out = remote_op(
            &mut m,
            Protocol::SRSP_ADAPTIVE,
            1,
            LOCK,
            AtomicOp::Cas,
            MemOrder::Acquire,
            2,
            1,
            t,
        );
        assert_eq!(out.value, 1, "CAS must see the released lock");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 41, "selective path must publish the sharer's data");
        assert_eq!(m.stats.selective_flush_requests, 1, "must broadcast selectively");
        assert_eq!(m.stats.misc.get("adaptive_selective_promotions"), Some(&1));
        assert_eq!(m.stats.misc.get("adaptive_eager_promotions"), None);
    }

    #[test]
    fn overflow_pressure_triggers_eager_fallback_and_stays_correct() {
        // lr_tbl_entries = 0: every insertion overflows, so the overflow
        // rate is 1.0 > threshold from the first release — the remote
        // acquire must go eager (no selective broadcast) and still
        // observe the local sharer's release.
        let mut m = sys_with_lr(0);
        let t = release(&mut m, 0, LOCK, DATA, 7, 0);
        assert!(m.stats.lr_tbl_overflows > 0);
        let out = remote_op(
            &mut m,
            Protocol::SRSP_ADAPTIVE,
            1,
            LOCK,
            AtomicOp::Cas,
            MemOrder::Acquire,
            2,
            1,
            t,
        );
        assert_eq!(out.value, 1, "eager fallback must see the released lock");
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 7, "eager invalidation must publish the sharer's data");
        assert_eq!(
            m.stats.selective_flush_requests, 0,
            "past the threshold the selective broadcast is skipped"
        );
        assert_eq!(m.stats.misc.get("adaptive_eager_promotions"), Some(&1));
    }

    #[test]
    fn threshold_param_disables_the_fallback() {
        // overflow_threshold = 2.0 can never be exceeded (rate <= 1), so
        // even a permanently-overflowed table stays on the selective
        // (conservative full-drain) path.
        let mut m = sys_with_lr(0);
        m.proto_params = crate::params::Params::resolve(
            &PARAMS,
            &[("overflow_threshold".to_string(), 2.0)],
        )
        .unwrap();
        let t = release(&mut m, 0, LOCK, DATA, 9, 0);
        let out = remote_op(
            &mut m,
            Protocol::SRSP_ADAPTIVE,
            1,
            LOCK,
            AtomicOp::Cas,
            MemOrder::Acquire,
            2,
            1,
            t,
        );
        assert_eq!(out.value, 1);
        let (v, _) = m.l1_read(1, DATA, 4, out.done);
        assert_eq!(v, 9);
        assert_eq!(
            m.stats.selective_flush_requests, 1,
            "threshold 2.0 must keep the selective broadcast"
        );
        assert_eq!(m.stats.misc.get("adaptive_eager_promotions"), None);
    }

    #[test]
    fn release_side_stays_selective_even_under_pressure() {
        // Remote releases keep sRSP's selective-invalidate (PA arming)
        // regardless of the monitor: the fallback targets the
        // acquire-side selective-flush only.
        let mut m = sys_with_lr(0);
        let _ = release(&mut m, 0, LOCK2, DATA, 1, 0); // build pressure
        let t = m.l1_write(1, DATA, 4, 5, 0);
        let out = remote_op(
            &mut m,
            Protocol::SRSP_ADAPTIVE,
            1,
            LOCK,
            AtomicOp::Store,
            MemOrder::Release,
            1,
            0,
            t,
        );
        assert!(out.done > t);
        assert_eq!(m.stats.selective_inv_requests, 1);
        assert_eq!(m.stats.misc.get("adaptive_eager_promotions"), None);
    }
}
