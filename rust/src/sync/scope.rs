//! Scopes, memory orderings and atomic operations (paper §2.1).

use std::fmt;

/// OpenCL synchronization scopes. The simulator distinguishes the two the
/// paper evaluates: work-group (local, L1-level) and device (global,
/// L2-level). `System` is modeled for completeness (L2 flush + backing
/// store atomics) but unused by the workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scope {
    /// Work-group scope (`wg`): synchronizes through the CU-private L1.
    Wg,
    /// Device scope (`cmp`): synchronizes through the shared L2.
    Cmp,
    /// System scope (`sys`): synchronizes through the backing store.
    Sys,
}

impl fmt::Display for Scope {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Scope::Wg => "wg",
            Scope::Cmp => "cmp",
            Scope::Sys => "sys",
        })
    }
}

/// Memory ordering attached to an atomic (acquire/release semantics,
/// §2.1). `Relaxed` atomics synchronize nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemOrder {
    Relaxed,
    Acquire,
    Release,
    AcqRel,
}

impl MemOrder {
    pub fn acquires(self) -> bool {
        matches!(self, MemOrder::Acquire | MemOrder::AcqRel)
    }
    pub fn releases(self) -> bool {
        matches!(self, MemOrder::Release | MemOrder::AcqRel)
    }
}

/// Atomic read-modify-write operations available to KIR programs.
/// All operate on naturally-aligned 4-byte words (the workloads' queue
/// indices, locks and counters are u32, as in the paper's OpenCL code).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AtomicOp {
    /// Plain atomic load.
    Load,
    /// Plain atomic store of the operand.
    Store,
    /// Compare-and-swap: `if *p == cmp { *p = new }`; returns old value.
    Cas,
    /// Fetch-add; returns old value.
    Add,
    /// Exchange; returns old value.
    Exch,
    /// Fetch-min (unsigned); returns old value.
    Min,
}

impl AtomicOp {
    /// Apply the operation to the current value; returns
    /// `(new_value_to_store, result_returned_to_program)`.
    /// `Load` stores nothing (new == old).
    pub fn apply(self, old: u32, operand: u32, cmp: u32) -> (u32, u32) {
        match self {
            AtomicOp::Load => (old, old),
            AtomicOp::Store => (operand, old),
            AtomicOp::Cas => {
                if old == cmp {
                    (operand, old)
                } else {
                    (old, old)
                }
            }
            AtomicOp::Add => (old.wrapping_add(operand), old),
            AtomicOp::Exch => (operand, old),
            AtomicOp::Min => (old.min(operand), old),
        }
    }

    /// Does this op ever write?
    pub fn writes(self) -> bool {
        !matches!(self, AtomicOp::Load)
    }

    /// Does this op write given the observed old value? (CAS only writes
    /// on success; Min only when the operand is smaller.)
    pub fn writes_given(self, old: u32, operand: u32, cmp: u32) -> bool {
        match self {
            AtomicOp::Load => false,
            AtomicOp::Cas => old == cmp,
            AtomicOp::Min => operand < old,
            _ => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_predicates() {
        assert!(MemOrder::Acquire.acquires() && !MemOrder::Acquire.releases());
        assert!(MemOrder::Release.releases() && !MemOrder::Release.acquires());
        assert!(MemOrder::AcqRel.acquires() && MemOrder::AcqRel.releases());
        assert!(!MemOrder::Relaxed.acquires() && !MemOrder::Relaxed.releases());
    }

    #[test]
    fn cas_semantics() {
        assert_eq!(AtomicOp::Cas.apply(5, 9, 5), (9, 5)); // success
        assert_eq!(AtomicOp::Cas.apply(6, 9, 5), (6, 6)); // failure
        assert!(AtomicOp::Cas.writes_given(5, 9, 5));
        assert!(!AtomicOp::Cas.writes_given(6, 9, 5));
    }

    #[test]
    fn add_min_exch() {
        assert_eq!(AtomicOp::Add.apply(10, 3, 0), (13, 10));
        assert_eq!(AtomicOp::Min.apply(10, 3, 0), (3, 10));
        assert_eq!(AtomicOp::Min.apply(3, 10, 0), (3, 3));
        assert_eq!(AtomicOp::Exch.apply(1, 2, 0), (2, 1));
        assert!(!AtomicOp::Min.writes_given(3, 10, 0));
    }

    #[test]
    fn load_never_writes() {
        assert!(!AtomicOp::Load.writes());
        assert_eq!(AtomicOp::Load.apply(7, 99, 99), (7, 7));
    }
}
