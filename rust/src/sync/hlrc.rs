//! hLRC — heterogeneous Lazy Release Consistency (Alsop et al.,
//! MICRO'16), the paper's §6 closest related work, implemented as an
//! extension comparator: sync variables are *owned* by one L1 at a time
//! (registry at the L2); any other CU's wg-scope sync op lazily transfers
//! ownership (previous owner flushes, requester invalidates). Scalable,
//! but lock transfers ping-pong and each registered variable burns
//! registry/cache capacity — the costs the paper calls out.

use super::ops::{self, SyncOp, SyncOutcome};
use super::protocol::SyncProtocol;
use crate::mem::{line_of, MemSystem};
use crate::sim::TraceKind;

/// Registry entry for the hLRC extension protocol.
pub struct Hlrc;

impl SyncProtocol for Hlrc {
    fn name(&self) -> &'static str {
        "hlrc"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["lazy-rc"]
    }

    fn summary(&self) -> &'static str {
        "lazy release consistency: L2 ownership registry, lazy wg-scope transfer"
    }

    fn lazy_wg_transfer(&self) -> bool {
        true
    }

    /// hLRC wg-scope synchronization. Ownership of the sync variable
    /// lives in a registry at the L2:
    ///
    /// * requester already owns it → plain L1 atomic (the fast path hLRC
    ///   is built around);
    /// * otherwise → lazy transfer: previous owner's L1 is flushed (its
    ///   releases become globally visible), the requester's L1 is
    ///   invalidated (acquire side), the atomic completes at the L2, and
    ///   the requester becomes the owner;
    /// * registry eviction (capacity) forces the evictee's owner to flush
    ///   — the replacement-policy sensitivity the paper criticizes.
    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        match m.hlrc_owner(s.addr) {
            Some(owner) if owner == s.cu => {
                // Fast path: L1-local.
                m.stats.bump("hlrc_local_ops", 1);
                m.trace.emit(s.at, s.cu, TraceKind::HlrcLocal, s.addr, 0);
                let (value, _ticket, done) =
                    m.l1_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, s.at);
                ops::charge_overhead(m, s.at, done);
                SyncOutcome { value, done }
            }
            prev => {
                // Lazy transfer through the L2 registry. detail carries the
                // previous owner (or DEVICE_CU when unowned).
                m.stats.bump("hlrc_transfers", 1);
                m.trace.emit(
                    s.at,
                    s.cu,
                    TraceKind::HlrcTransfer,
                    s.addr,
                    prev.unwrap_or(crate::sim::trace::DEVICE_CU) as u64,
                );
                let line = line_of(s.addr);
                // Registry probe at the L2.
                let t_req = m.xbar_hop(s.cu, s.at);
                let mut t_ready = m.l2_control_hop(line, t_req) + 2;
                if let Some(owner) = prev {
                    // Previous owner publishes everything up to its last
                    // sync op on this variable (full flush: hLRC keeps no
                    // per-variable tickets).
                    let t_arrive = m.xbar_hop(owner, t_ready);
                    let t_flush = m.full_flush_l1(owner, t_arrive);
                    // The owner's cached copy of the line must go, or its
                    // later local reads would see a stale value.
                    if let Some(wb) = m.cu_mut(owner).l1.invalidate_line(line) {
                        // Flush above already cleaned it; belt and braces.
                        m.backing.write_line_masked(wb.line, wb.mask, &wb.data);
                    }
                    t_ready = t_ready.max(m.xbar_hop(owner, t_flush));
                }
                // Requester acquires: drop its stale state.
                let t_own = m.invalidate_l1(s.cu, s.at);
                let t_ready = t_ready.max(t_own);
                // Claim ownership; a capacity eviction forces the
                // evictee's owner to flush (it loses its exclusive hold).
                if let Some((_, evicted_owner)) = m.hlrc_claim(s.addr, s.cu) {
                    m.stats.bump("hlrc_evictions", 1);
                    m.trace
                        .emit(t_ready, evicted_owner, TraceKind::HlrcEvict, s.addr, 0);
                    m.full_flush_l1(evicted_owner, t_ready);
                }
                // The op itself completes at the L2 (the transfer point).
                let (value, done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t_ready);
                ops::charge_overhead(m, s.at, done);
                SyncOutcome { value, done }
            }
        }
    }
}
