//! Naive Remote-Scope-Promotion (Orr et al., ASPLOS'15): remote ops are
//! promoted by flushing and invalidating **every** L1 in the device —
//! the scalability problem the paper fixes.
//!
//! | op             | behavior                                          |
//! |----------------|---------------------------------------------------|
//! | wg acquire/rel | plain L1 atomic                                   |
//! | remote acquire | flush+inv **all** L1s + L2 op                     |
//! | remote release | flush own + L2 op + inv **all**                   |
//! | remote acq+rel | both of the above                                 |

use super::ops::{self, SyncOp, SyncOutcome};
use super::protocol::SyncProtocol;
use crate::mem::{line_of, MemSystem};

/// Registry entry for naive RSP.
pub struct RspNaive;

impl SyncProtocol for RspNaive {
    fn name(&self) -> &'static str {
        "rsp"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["rsp-naive", "naive"]
    }

    fn summary(&self) -> &'static str {
        "naive RSP: remote ops flush/invalidate every L1 (Orr et al.)"
    }

    fn supports_remote(&self) -> bool {
        true
    }

    fn wg_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        // Plain wg-scope atomic; naive RSP needs no release bookkeeping
        // (its promotions always drain every L1).
        ops::wg_plain(m, s, false)
    }

    fn remote_op(&self, m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
        remote(m, s)
    }
}

/// The eager all-L1 promotion, exposed as a free function so the
/// adaptive protocol can fall back to it under table pressure.
pub fn remote(m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
    let line = line_of(s.addr);

    let mut t_ready = s.at;
    if s.order.acquires() {
        // rem_acq: promote the local sharer's past releases — since we
        // don't know *which* L1 is the local sharer, flush them all; and
        // since we don't know which lines are stale, invalidate them all.
        // The broadcast fans out through the L2.
        let t_req = m.xbar_hop(s.cu, s.at);
        let t_fan = m.l2_control_hop(line, t_req);
        let mut t_all = t_fan;
        for target in 0..m.num_cus() {
            if target == s.cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_inv = m.invalidate_l1(target, t_arrive); // drain + flash
            let t_ack = m.xbar_hop(target, t_inv);
            t_all = t_all.max(t_ack);
        }
        // Requester drains its own dirty data and invalidates (global
        // acquire semantics for itself).
        let t_own = m.invalidate_l1(s.cu, s.at);
        t_ready = t_all.max(t_own);
    }
    if s.order.releases() && !s.order.acquires() {
        // rem_rel: the remote sharer's updates must reach global scope
        // before the releasing store.
        t_ready = m.full_flush_l1(s.cu, s.at);
    } else if s.order.releases() {
        // rem_ar already flushed everything via the invalidates above.
    }

    // Lock the sync variable's line at the L2 for the duration (§4.2).
    m.lock_l2_line(line, t_ready);
    let (value, mut done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t_ready);
    m.lock_l2_line(line, done);

    if s.order.releases() && !s.order.acquires() {
        // rem_rel: promote the local sharer's *next* acquire eagerly —
        // invalidate every other L1 so no stale copy can satisfy it.
        // (rem_ar already invalidated every L1 above; repeating the
        // broadcast would double-charge the combined operation.)
        let t_fan = m.l2_control_hop(line, done);
        let mut t_all = done;
        for target in 0..m.num_cus() {
            if target == s.cu {
                continue;
            }
            let t_arrive = m.xbar_hop(target, t_fan);
            let t_inv = m.invalidate_l1(target, t_arrive);
            let t_ack = m.xbar_hop(target, t_inv);
            t_all = t_all.max(t_ack);
        }
        done = t_all;
    }
    SyncOutcome { value, done }
}
