//! The shared scoped-op core: the pieces of synchronization that are
//! *protocol-independent*, factored out of the per-protocol modules.
//!
//! * [`SyncOp`] — one synchronization request (the argument bundle every
//!   [`SyncProtocol`](super::protocol::SyncProtocol) hook receives).
//! * [`cmp_scope_op`] / [`sys_scope_op`] — §2.2's heavyweight global and
//!   system scopes, identical under every protocol.
//! * [`wg_plain`] — the plain wg-scope L1 atomic every protocol's fast
//!   path bottoms out in.
//! * [`record_lr_release`] / [`record_pa`] — the LR-TBL/PA-TBL
//!   bookkeeping shared by the sRSP protocol family.
//! * [`charge_overhead`] — the Fig. 6 overhead accounting: every cycle
//!   beyond what the *same atomic at wg scope on an L1 hit* would cost is
//!   charged to `stats.sync_overhead_cycles`.

use super::scope::{AtomicOp, MemOrder};
use crate::mem::{Addr, MemSystem, Ticket};
use crate::sim::{Cycle, TraceKind};

/// Result of a synchronization operation.
#[derive(Debug, Clone, Copy)]
pub struct SyncOutcome {
    /// Value returned to the program (old value for RMW ops).
    pub value: u32,
    /// Completion cycle.
    pub done: Cycle,
}

/// One synchronization request, as handed to the protocol hooks.
#[derive(Debug, Clone, Copy)]
pub struct SyncOp {
    /// Requesting CU.
    pub cu: u32,
    /// Sync-variable address.
    pub addr: Addr,
    pub op: AtomicOp,
    pub order: MemOrder,
    pub operand: u32,
    pub cmp: u32,
    /// Issue cycle.
    pub at: Cycle,
}

/// Baseline cost of the same atomic if it were a wg-scope L1 hit — used to
/// compute promotion/synchronization overhead.
fn plain_cost(m: &MemSystem) -> u64 {
    m.cfg.l1_latency + 1
}

/// Charge everything beyond the plain wg-scope L1-hit cost to
/// `sync_overhead_cycles` (the Fig. 6 metric).
pub fn charge_overhead(m: &mut MemSystem, at: Cycle, done: Cycle) {
    let plain = plain_cost(m);
    let took = done.saturating_sub(at);
    m.stats.sync_overhead_cycles += took.saturating_sub(plain);
}

/// Plain wg-scope atomic at the L1. With `record_lr`, a sync *write*
/// records (addr → sFIFO ticket) in the LR-TBL so a later remote acquire
/// can selectively flush (§4.1) — the sRSP family sets it; the eager
/// protocols do not. Releases are the textbook case, but an acquire-CAS's
/// store (e.g. taking a lock: CAS_acq_wg 0→1) must be recorded too —
/// otherwise a remote acquire arriving before the owner's first release
/// finds an empty LR-TBL, skips the drain, reads the stale unlocked value
/// from the L2 and breaks mutual exclusion. (Naive RSP is immune: it
/// always drains every L1.)
pub fn wg_plain(m: &mut MemSystem, s: &SyncOp, record_lr: bool) -> SyncOutcome {
    let (value, ticket, done) = m.l1_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, s.at);
    if record_lr && s.op.writes_given(value, s.operand, s.cmp) {
        record_lr_release(m, s.cu, s.addr, Some(ticket), s.at);
    }
    charge_overhead(m, s.at, done);
    SyncOutcome { value, done }
}

/// Record a wg-scope sync write in the requester's LR-TBL (§4.1).
pub fn record_lr_release(
    m: &mut MemSystem,
    cu: u32,
    addr: Addr,
    ticket: Option<Ticket>,
    at: Cycle,
) {
    let Some(ticket) = ticket else { return };
    m.stats.lr_tbl_insertions += 1;
    m.trace.emit(at, cu, TraceKind::LrInsert, addr, ticket);
    if m.cu_mut(cu).lr_tbl.record(addr, ticket) {
        m.stats.lr_tbl_overflows += 1;
        m.trace.emit(at, cu, TraceKind::LrOverflow, addr, ticket);
    }
}

/// Record a promoted-acquire obligation at `target`'s PA-TBL. A full
/// table forces an eager local invalidate first (clearing both tables —
/// every deferred obligation is discharged), then records.
pub fn record_pa(m: &mut MemSystem, target: u32, addr: Addr, at: Cycle) -> Cycle {
    use crate::sync::tables::PaRecord;
    m.stats.pa_tbl_insertions += 1;
    m.trace.emit(at, target, TraceKind::PaInsert, addr, 0);
    let mut t = at;
    if m.cu(target).pa_tbl.is_full() && !m.cu(target).pa_tbl.needs_promotion(addr) {
        m.stats.pa_tbl_overflows += 1;
        m.trace.emit(at, target, TraceKind::PaOverflow, addr, 0);
        t = m.invalidate_l1(target, t);
    }
    match m.cu_mut(target).pa_tbl.record(addr) {
        PaRecord::Recorded => t,
        // Only reachable with `pa_tbl_entries = 0`: nothing can ever be
        // recorded, but the eager invalidate above already discharged the
        // obligation — the target's next access misses to the L2 and
        // reads fresh data — so skipping the record is correct (the table
        // degenerates to "promote eagerly, every time").
        PaRecord::NeedsInvalidate => t,
    }
}

/// cmp (global/device) scope — §2.2's heavyweight path, identical in all
/// protocols.
pub fn cmp_scope_op(m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
    let mut t = s.at;
    if s.order.releases() {
        m.stats.cmp_releases += 1;
        m.trace.emit(s.at, s.cu, TraceKind::CmpRelease, s.addr, 0);
        // Global release: every local update must reach the global sync
        // point (L2) — full cache-flush of the own L1.
        t = m.full_flush_l1(s.cu, t);
    }
    if s.order.acquires() {
        m.stats.cmp_acquires += 1;
        m.trace.emit(s.at, s.cu, TraceKind::CmpAcquire, s.addr, 0);
        // Global acquire: all possibly-stale local data must be discarded.
        t = m.invalidate_l1(s.cu, t);
    }
    let (value, done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t);
    charge_overhead(m, s.at, done);
    SyncOutcome { value, done }
}

/// sys scope (completeness).
pub fn sys_scope_op(m: &mut MemSystem, s: &SyncOp) -> SyncOutcome {
    let mut t = s.at;
    if s.order.releases() {
        t = m.full_flush_l1(s.cu, t);
        t = m.full_flush_l2(t);
    }
    if s.order.acquires() {
        t = m.invalidate_l1(s.cu, t);
        t = m.invalidate_l2(t);
    }
    // The atomic itself executes at the memory controller on the backing
    // store (we route it through the L2 path after the L2 was flushed —
    // equivalent values, conservative timing).
    let (value, done) = m.l2_atomic(s.cu, s.addr, s.op, s.operand, s.cmp, t);
    charge_overhead(m, s.at, done);
    SyncOutcome { value, done }
}
